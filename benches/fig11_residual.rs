//! Fig. 11 — accuracy: relative residual ‖Ax−b‖₁/‖b‖₁ per matrix.
//!
//! Paper result: HYLU is about an order of magnitude more accurate than MKL
//! PARDISO on geometric mean (better pivoting control + automatic
//! refinement), and *both* solvers fail on the extremely ill-conditioned
//! Hamrle3 — the suite's `hamrle3_s` reproduces that case.

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, geomean, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 11: relative residual (lower is better; 'ratio' = baseline/hylu)",
        &["matrix", "class", "hylu", "baseline", "ratio"],
    );
    let mut ratios = Vec::new();
    for bm in &common::suite() {
        let a = (bm.build)();
        let b = common::rhs(&a);
        let hylu = common::hylu_solver(false);
        // baseline: refinement AND dynamic (supernode) pivoting disabled,
        // modeling PARDISO's default static-pivoting-plus-perturbation
        // accuracy (the paper attributes HYLU's accuracy edge to "better
        // control of pivoting and iterative refinement")
        let mut base_cfg = hylu::baseline::pardiso_like(common::threads());
        base_cfg.refine_max_iter = 0;
        base_cfg.pivot.supernode_pivoting = false;
        let base = hylu::api::Solver::from_config(base_cfg).expect("baseline solver");
        let sys_h = hylu.analyze(&a).expect("analyze").factor().expect("factor");
        let sys_b = base.analyze(&a).expect("analyze").factor().expect("factor");
        let (_, st_h) = sys_h.solve_with_stats(&b).expect("solve");
        let x_b = sys_b.solve(&b).expect("solve");
        let r_b = a.relative_residual(&x_b, &b);
        let ratio = r_b / st_h.residual.max(1e-300);
        ratios.push(ratio.max(1e-6)); // clamp for geomean sanity
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                format!("{:.2e}", st_h.residual),
                format!("{:.2e}", r_b),
                format!("{:.1}x", ratio),
            ],
            ratio,
        );
    }
    table.print();
    println!(
        "geomean accuracy advantage: {:.1}x (paper: ~10x vs MKL PARDISO)",
        geomean(&ratios)
    );
}

//! Fig. 8 — numerical (re)factorization time and speedup, repeated solving.
//!
//! Paper result: 2.90x geometric-mean speedup over MKL PARDISO — larger
//! than the one-time 2.36x because HYLU's repeated mode skips the pivot
//! search and replays static patterns/pivot order.

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, fmt_time, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 8: refactorization time, repeated solve",
        &["matrix", "class", "n", "kernel", "hylu", "baseline", "speedup"],
    );
    for bm in &common::suite() {
        let a = (bm.build)();
        let hylu = common::hylu_solver(true); // repeated mode
        let base = common::baseline_solver();
        let mut sys_h = hylu.analyze(&a).expect("analyze").factor().expect("factor");
        let mut sys_b = base.analyze(&a).expect("analyze").factor().expect("factor");
        let t_h = common::best(3, || {
            sys_h.refactor(&a.vals).expect("refactor");
        });
        let t_b = common::best(3, || {
            sys_b.refactor(&a.vals).expect("refactor");
        });
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                format!("{}", sys_h.analysis().mode),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!("paper reference: repeated-solve factorization speedup 2.90x geomean");
}

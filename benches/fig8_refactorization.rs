//! Fig. 8 — numerical (re)factorization time and speedup, repeated solving.
//!
//! Paper result: 2.90x geometric-mean speedup over MKL PARDISO — larger
//! than the one-time 2.36x because HYLU's repeated mode skips the pivot
//! search and replays static patterns/pivot order.

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, fmt_time, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 8: refactorization time, repeated solve",
        &["matrix", "class", "n", "kernel", "hylu", "baseline", "speedup"],
    );
    for bm in &common::suite() {
        let a = (bm.build)();
        let hylu = common::hylu_solver(true); // repeated mode
        let base = common::baseline_solver();
        let an_h = hylu.analyze(&a).expect("analyze");
        let an_b = base.analyze(&a).expect("analyze");
        let mut f_h = hylu.factor(&a, &an_h).expect("factor");
        let mut f_b = base.factor(&a, &an_b).expect("factor");
        let t_h = common::best(3, || {
            hylu.refactor(&a, &an_h, &mut f_h).expect("refactor");
        });
        let t_b = common::best(3, || {
            base.refactor(&a, &an_b, &mut f_b).expect("refactor");
        });
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                format!("{}", an_h.mode),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!("paper reference: repeated-solve factorization speedup 2.90x geomean");
}

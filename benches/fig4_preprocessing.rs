//! Fig. 4 — preprocessing time and speedup, one-time solving.
//!
//! Paper result: HYLU preprocessing is 1.48x faster than MKL PARDISO on
//! geometric mean; additionally (§3.2) repeated-mode preprocessing is
//! ~1.75x slower than one-time preprocessing (it buys relaxed supernodes).

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, fmt_time, geomean, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 4: preprocessing time, one-time solve",
        &["matrix", "class", "n", "hylu", "baseline", "speedup"],
    );
    let mut repeated_ratio = Vec::new();
    for bm in &common::suite() {
        let a = (bm.build)();
        let hylu = common::hylu_solver(false);
        let base = common::baseline_solver();
        let t_h = common::best(2, || {
            let _ = hylu.analyze(&a).expect("hylu analyze");
        });
        let t_b = common::best(2, || {
            let _ = base.analyze(&a).expect("baseline analyze");
        });
        // repeated-mode preprocessing cost ratio (paper §3.2: 1.75x slower)
        let hylu_r = common::hylu_solver(true);
        let t_r = common::best(1, || {
            let _ = hylu_r.analyze(&a).expect("repeated analyze");
        });
        repeated_ratio.push(t_r / t_h);
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!(
        "repeated-mode preprocessing / one-time preprocessing: {:.2}x (paper: 1.75x)",
        geomean(&repeated_ratio)
    );
    println!("paper reference: preprocessing speedup 1.48x geomean vs MKL PARDISO");
}

//! Fig. 9 — forward-backward substitution time and speedup, repeated
//! solving.
//!
//! Paper result: HYLU substitution is ~20% slower than MKL PARDISO on
//! geometric mean in the repeated scenario (refinement overhead again).

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, fmt_time, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 9: substitution time, repeated solve",
        &["matrix", "class", "n", "hylu", "baseline", "speedup"],
    );
    for bm in &common::suite() {
        let a = (bm.build)();
        let b = common::rhs(&a);
        let hylu = common::hylu_solver(true);
        let base = common::baseline_solver();
        let mut sys_h = hylu.analyze(&a).expect("analyze").factor().expect("factor");
        let mut sys_b = base.analyze(&a).expect("analyze").factor().expect("factor");
        sys_h.refactor(&a.vals).expect("refactor");
        sys_b.refactor(&a.vals).expect("refactor");
        let t_h = common::best(3, || {
            let _ = sys_h.solve(&b).expect("solve");
        });
        let t_b = common::best(3, || {
            let _ = sys_b.solve(&b).expect("solve");
        });
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!("paper reference: HYLU repeated substitution ~20% SLOWER than PARDISO");
}

//! Fig. 9 — forward-backward substitution time and speedup, repeated
//! solving.
//!
//! Paper result: HYLU substitution is ~20% slower than MKL PARDISO on
//! geometric mean in the repeated scenario (refinement overhead again).

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, fmt_time, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 9: substitution time, repeated solve",
        &["matrix", "class", "n", "hylu", "baseline", "speedup"],
    );
    for bm in &common::suite() {
        let a = (bm.build)();
        let b = common::rhs(&a);
        let hylu = common::hylu_solver(true);
        let base = common::baseline_solver();
        let an_h = hylu.analyze(&a).expect("analyze");
        let an_b = base.analyze(&a).expect("analyze");
        let mut f_h = hylu.factor(&a, &an_h).expect("factor");
        let mut f_b = base.factor(&a, &an_b).expect("factor");
        hylu.refactor(&a, &an_h, &mut f_h).expect("refactor");
        base.refactor(&a, &an_b, &mut f_b).expect("refactor");
        let t_h = common::best(3, || {
            let _ = hylu.solve(&a, &an_h, &f_h, &b).expect("solve");
        });
        let t_b = common::best(3, || {
            let _ = base.solve(&a, &an_b, &f_b, &b).expect("solve");
        });
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!("paper reference: HYLU repeated substitution ~20% SLOWER than PARDISO");
}

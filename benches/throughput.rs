//! Serving throughput: solves/sec, p50 latency, and batch width vs.
//! concurrent caller count — static coalescing tick vs. the adaptive
//! window, against the serialized one-mutex baseline.
//!
//! One shard, C caller threads each submitting single right-hand sides.
//! Three configurations per caller count:
//!
//! - **baseline** — the pre-service front door: one `Solver` behind one
//!   mutex, exactly one in-flight solve.
//! - **static** — `SolverService` with a fixed 200µs coalescing tick.
//! - **adaptive** — `SolverService` with `tick_max = 2ms`: the window
//!   stretches while sustained arrivals keep widening batches and
//!   collapses to zero when the shard idles.
//!
//! Acceptance (the PR 5 criterion): at every concurrency level the
//! adaptive tick must reach a mean batch width >= the static tick's at
//! equal or lower p50 latency (5% tolerance).
//!
//! A second experiment measures shard-set elasticity overhead: the same
//! workload against a static 2-shard set vs. one that breathes 2 <-> 4
//! (live `grow`/`rebalance`/`shrink`) for the whole run. The gap is the
//! price of topology churn; `max tick` reports the longest window a
//! dispatcher actually slept (not the requested window), so an
//! uninterruptible-sleep regression shows up here directly.
//!
//! ```bash
//! cargo bench --bench throughput
//! ```

use std::sync::Mutex;
use std::time::{Duration, Instant};

use hylu::api::Solver;
use hylu::bench_harness::{environment, Table};
use hylu::coordinator::SolverConfig;
use hylu::service::{ServiceConfig, SolverService, SystemId};
use hylu::sparse::gen;

/// Run `requests` invocations of `op` spread over `callers` threads;
/// returns (elapsed seconds, per-request latencies in seconds).
fn drive(callers: usize, requests: usize, op: impl Fn() + Sync) -> (f64, Vec<f64>) {
    let latencies = Mutex::new(Vec::with_capacity(requests));
    let t0 = Instant::now();
    std::thread::scope(|sc| {
        for w in 0..callers {
            let (op, latencies) = (&op, &latencies);
            sc.spawn(move || {
                let per = requests / callers + usize::from(w < requests % callers);
                let mut local = Vec::with_capacity(per);
                for _ in 0..per {
                    let t = Instant::now();
                    op();
                    local.push(t.elapsed().as_secs_f64());
                }
                latencies.lock().unwrap().extend(local);
            });
        }
    });
    (t0.elapsed().as_secs_f64(), latencies.into_inner().unwrap())
}

fn p50(lat: &mut [f64]) -> f64 {
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if lat.is_empty() {
        0.0
    } else {
        lat[lat.len() / 2]
    }
}

struct ServiceRun {
    rate: f64,
    p50_us: f64,
    mean_batch: f64,
    max_batch: usize,
}

fn run_service(
    cfg: &SolverConfig,
    a: &hylu::sparse::csr::Csr,
    b: &[f64],
    callers: usize,
    requests: usize,
    tick: Duration,
    tick_max: Duration,
) -> ServiceRun {
    let service = SolverService::new(
        ServiceConfig {
            shards: 1,
            solver: cfg.clone(),
            max_batch: 64,
            tick,
            tick_max,
            ..ServiceConfig::default()
        },
        vec![a.clone()],
    )
    .expect("service");
    let (t, mut lat) = drive(callers, requests, || {
        let x = service.solve(SystemId(0), b.to_vec()).expect("service solve");
        assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-6));
    });
    let st = service.stats();
    drop(service);
    ServiceRun {
        rate: requests as f64 / t,
        p50_us: p50(&mut lat) * 1e6,
        mean_batch: st.mean_batch(),
        max_batch: st.max_batch,
    }
}

/// One elastic-overhead measurement: `nsys` systems over `shards`
/// shards, callers hammering `solve` while (optionally) a breather
/// thread grows the set to `grow_to` and drains it back, repeatedly.
fn run_elastic(
    cfg: &SolverConfig,
    a: &hylu::sparse::csr::Csr,
    callers: usize,
    requests: usize,
    shards: usize,
    grow_to: usize,
) -> (ServiceRun, u64, u64) {
    let nsys = 4usize;
    let systems: Vec<_> = (0..nsys)
        .map(|s| {
            let mut m = a.clone();
            let f = 1.0 + 0.1 * s as f64;
            for v in &mut m.vals {
                *v *= f;
            }
            m
        })
        .collect();
    let bs: Vec<Vec<f64>> = systems.iter().map(gen::rhs_for_ones).collect();
    let service = SolverService::new(
        ServiceConfig {
            shards,
            solver: cfg.clone(),
            max_batch: 64,
            tick: Duration::from_micros(50),
            tick_max: Duration::from_millis(2),
            ..ServiceConfig::default()
        },
        systems,
    )
    .expect("service");
    let stop = std::sync::atomic::AtomicBool::new(false);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let (t, mut lat) = std::thread::scope(|sc| {
        let breather = (grow_to > shards).then(|| {
            let (service, stop) = (&service, &stop);
            sc.spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    while service.shard_count() < grow_to {
                        service.grow(1).expect("grow");
                        service.rebalance().expect("rebalance");
                        std::thread::sleep(Duration::from_micros(500));
                    }
                    while service.shard_count() > shards {
                        service.shrink(1).expect("shrink");
                        std::thread::sleep(Duration::from_micros(500));
                    }
                }
            })
        });
        let out = drive(callers, requests, || {
            let k = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % nsys;
            let x = service.solve(SystemId(k as u64), bs[k].to_vec()).expect("service solve");
            assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-6));
        });
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = breather {
            h.join().expect("breather");
        }
        out
    });
    // settle so the drained shards' stats fold into the totals
    while service.shard_count() > shards {
        service.shrink(1).expect("settle shrink");
    }
    let st = service.stats();
    drop(service);
    (
        ServiceRun {
            rate: requests as f64 / t,
            p50_us: p50(&mut lat) * 1e6,
            mean_batch: st.mean_batch(),
            max_batch: st.max_batch,
        },
        st.max_tick.as_micros() as u64,
        st.moves,
    )
}

fn main() {
    let a = gen::grid2d(56, 56); // n = 3136
    let b = gen::rhs_for_ones(&a);
    let requests = 256usize;
    let cfg = SolverConfig {
        threads: 1,
        repeated: true,
        ..SolverConfig::default()
    };

    println!("{}", environment());
    println!(
        "matrix: grid2d n={} nnz={}, {} requests per configuration\n",
        a.n,
        a.nnz(),
        requests
    );
    let mut table = Table::new(
        "serving throughput, 1 shard: static tick vs adaptive window vs serialized mutex",
        &[
            "callers",
            "mode",
            "sol/s",
            "p50 us",
            "vs base",
            "mean batch",
            "max batch",
        ],
    );

    let mut acceptance = Vec::new();
    for &callers in &[1usize, 2, 4, 8] {
        // serialized baseline: the pre-service front door
        let solver = Solver::from_config(cfg.clone()).expect("solver");
        let sys = solver.analyze(&a).expect("analyze").factor().expect("factor");
        let lock = Mutex::new(());
        let (t_base, mut lat_base) = drive(callers, requests, || {
            let _g = lock.lock().unwrap();
            sys.solve(&b).expect("baseline solve");
        });
        let base_rate = requests as f64 / t_base;
        table.row(
            vec![
                callers.to_string(),
                "baseline".into(),
                format!("{base_rate:.0}"),
                format!("{:.0}", p50(&mut lat_base) * 1e6),
                "1.00x".into(),
                "-".into(),
                "-".into(),
            ],
            1.0,
        );

        let fixed = run_service(
            &cfg,
            &a,
            &b,
            callers,
            requests,
            Duration::from_micros(200),
            Duration::ZERO,
        );
        table.row(
            vec![
                callers.to_string(),
                "static".into(),
                format!("{:.0}", fixed.rate),
                format!("{:.0}", fixed.p50_us),
                format!("{:.2}x", fixed.rate / base_rate),
                format!("{:.2}", fixed.mean_batch),
                fixed.max_batch.to_string(),
            ],
            fixed.rate / base_rate,
        );

        let adaptive = run_service(
            &cfg,
            &a,
            &b,
            callers,
            requests,
            Duration::from_micros(50),
            Duration::from_millis(2),
        );
        table.row(
            vec![
                callers.to_string(),
                "adaptive".into(),
                format!("{:.0}", adaptive.rate),
                format!("{:.0}", adaptive.p50_us),
                format!("{:.2}x", adaptive.rate / base_rate),
                format!("{:.2}", adaptive.mean_batch),
                adaptive.max_batch.to_string(),
            ],
            adaptive.rate / base_rate,
        );

        acceptance.push((callers, fixed, adaptive));
    }
    table.print();

    println!("\nacceptance: adaptive mean batch >= static at p50 <= static * 1.05");
    for (callers, fixed, adaptive) in &acceptance {
        let batch_ok = adaptive.mean_batch >= fixed.mean_batch * 0.999;
        let lat_ok = adaptive.p50_us <= fixed.p50_us * 1.05;
        println!(
            "  {callers} callers: batch {:.2} vs {:.2} [{}], p50 {:.0}us vs {:.0}us [{}]",
            adaptive.mean_batch,
            fixed.mean_batch,
            if batch_ok { "ok" } else { "MISS" },
            adaptive.p50_us,
            fixed.p50_us,
            if lat_ok { "ok" } else { "MISS" },
        );
    }

    // elasticity overhead: static 2-shard set vs. one breathing 2 <-> 4
    // under the same load. `max tick` is the longest window a dispatcher
    // actually slept — the SLO-aware wait keeps it preemptible even
    // while the topology churns.
    let callers = 8usize;
    let mut elastic_table = Table::new(
        "shard-set elasticity, 8 callers over 4 systems: static vs breathing 2 <-> 4",
        &["mode", "sol/s", "p50 us", "mean batch", "max tick us", "moves"],
    );
    let (stat, stat_tick, stat_moves) = run_elastic(&cfg, &a, callers, requests, 2, 2);
    elastic_table.row(
        vec![
            "static 2".into(),
            format!("{:.0}", stat.rate),
            format!("{:.0}", stat.p50_us),
            format!("{:.2}", stat.mean_batch),
            stat_tick.to_string(),
            stat_moves.to_string(),
        ],
        1.0,
    );
    let (ela, ela_tick, ela_moves) = run_elastic(&cfg, &a, callers, requests, 2, 4);
    elastic_table.row(
        vec![
            "breathe 2<->4".into(),
            format!("{:.0}", ela.rate),
            format!("{:.0}", ela.p50_us),
            format!("{:.2}", ela.mean_batch),
            ela_tick.to_string(),
            ela_moves.to_string(),
        ],
        ela.rate / stat.rate.max(1e-12),
    );
    println!();
    elastic_table.print();
}

//! Serving throughput: solves/sec vs. concurrent caller count.
//!
//! One shard, C caller threads each submitting single right-hand sides.
//! The coalescing [`SolverService`] front door is compared against the
//! serialized baseline the service replaced: one `Solver` behind one
//! mutex, exactly one in-flight solve. The service wins by (a) checking
//! per-call scratch out of a pool so callers overlap, and (b) draining
//! the queue into one batched `solve_many` block dispatch per tick.
//!
//! ```bash
//! cargo bench --bench throughput
//! ```

use std::sync::Mutex;
use std::time::{Duration, Instant};

use hylu::api::Solver;
use hylu::bench_harness::{environment, Table};
use hylu::coordinator::SolverConfig;
use hylu::service::{ServiceConfig, SolverService};
use hylu::sparse::gen;

/// Run `requests` invocations of `op` spread over `callers` threads;
/// returns elapsed seconds.
fn drive(callers: usize, requests: usize, op: impl Fn() + Sync) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|sc| {
        for w in 0..callers {
            let op = &op;
            sc.spawn(move || {
                let per = requests / callers + usize::from(w < requests % callers);
                for _ in 0..per {
                    op();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

fn main() {
    let a = gen::grid2d(56, 56); // n = 3136
    let b = gen::rhs_for_ones(&a);
    let requests = 256usize;
    let cfg = SolverConfig {
        threads: 1,
        repeated: true,
        ..SolverConfig::default()
    };

    println!("{}", environment());
    println!(
        "matrix: grid2d n={} nnz={}, {} requests per configuration\n",
        a.n,
        a.nnz(),
        requests
    );
    let mut table = Table::new(
        "serving throughput, 1 shard: coalescing service vs serialized mutex front door",
        &[
            "callers",
            "service sol/s",
            "baseline sol/s",
            "speedup",
            "mean batch",
            "max batch",
        ],
    );

    for &callers in &[1usize, 2, 4, 8] {
        let service = SolverService::new(
            ServiceConfig {
                shards: 1,
                solver: cfg.clone(),
                max_batch: 64,
                tick: Duration::from_micros(200),
                ..ServiceConfig::default()
            },
            vec![a.clone()],
        )
        .expect("service");
        let t_service = drive(callers, requests, || {
            let x = service.solve(0, b.clone()).expect("service solve");
            assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-6));
        });
        let st = service.stats();
        drop(service);
        let service_rate = requests as f64 / t_service;

        let solver = Solver::from_config(cfg.clone()).expect("solver");
        let sys = solver.analyze(&a).expect("analyze").factor().expect("factor");
        let lock = Mutex::new(());
        let t_base = drive(callers, requests, || {
            let _g = lock.lock().unwrap();
            sys.solve(&b).expect("baseline solve");
        });
        let base_rate = requests as f64 / t_base;

        table.row(
            vec![
                callers.to_string(),
                format!("{service_rate:.0}"),
                format!("{base_rate:.0}"),
                format!("{:.2}x", service_rate / base_rate),
                format!("{:.2}", st.mean_batch()),
                st.max_batch.to_string(),
            ],
            service_rate / base_rate,
        );
    }
    table.print();
}

//! Shared skeleton for the figure benches. Each bench binary reproduces one
//! figure of the paper's evaluation as a text table: per-matrix times for
//! HYLU and the PARDISO-like baseline, per-matrix speedup, geometric-mean
//! footer (the number the paper headlines).
//!
//! Env knobs:
//! - `HYLU_BENCH_FAST=1` — run the 6-matrix smoke subset instead of all 37.
//! - `HYLU_BENCH_THREADS=N` — thread count (default: all cores).

use hylu::api::{Solver, SolverBuilder};
use hylu::bench_suite::{suite37, suite_small, BenchMatrix};
use hylu::sparse::csr::Csr;

/// Suite selected by env.
pub fn suite() -> Vec<BenchMatrix> {
    if std::env::var("HYLU_BENCH_FAST").as_deref() == Ok("1") {
        suite_small()
    } else {
        suite37()
    }
}

/// Threads selected by env (0 = all cores).
pub fn threads() -> usize {
    std::env::var("HYLU_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// HYLU solver under benchmark configuration.
pub fn hylu_solver(repeated: bool) -> Solver {
    let b = SolverBuilder::new().threads(threads());
    let b = if repeated { b.repeated() } else { b.one_shot() };
    b.build().expect("hylu solver")
}

/// The PARDISO-like comparator.
pub fn baseline_solver() -> Solver {
    Solver::from_config(hylu::baseline::pardiso_like(threads())).expect("baseline solver")
}

/// The KLU-like comparator (used by the ablation bench).
#[allow(dead_code)]
pub fn klu_solver() -> Solver {
    Solver::from_config(hylu::baseline::klu_like(threads())).expect("klu solver")
}

/// Right-hand side with known solution 1.
pub fn rhs(a: &Csr) -> Vec<f64> {
    hylu::sparse::gen::rhs_for_ones(a)
}

/// Best-of-`reps` seconds.
pub fn best<F: FnMut()>(reps: usize, f: F) -> f64 {
    hylu::bench_harness::time_best(reps, f)
}

#[allow(dead_code)]
fn main() {} // allows `cargo bench` to treat common.rs as a bench target too

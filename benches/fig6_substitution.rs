//! Fig. 6 — forward-backward substitution time and speedup, one-time
//! solving.
//!
//! Paper result: HYLU's substitution is slightly *slower* than MKL PARDISO
//! (18% on geometric mean) — the cost of automatic iterative refinement.
//! Expect the speedup column to hover below 1x.

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, fmt_time, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 6: forward-backward substitution time, one-time solve",
        &["matrix", "class", "n", "hylu", "baseline", "speedup", "refine"],
    );
    for bm in &common::suite() {
        let a = (bm.build)();
        let b = common::rhs(&a);
        let hylu = common::hylu_solver(false);
        let base = common::baseline_solver();
        let sys_h = hylu.analyze(&a).expect("analyze").factor().expect("factor");
        let sys_b = base.analyze(&a).expect("analyze").factor().expect("factor");
        let mut iters = 0;
        let t_h = common::best(3, || {
            let (_, st) = sys_h.solve_with_stats(&b).expect("solve");
            iters = st.refine_iters;
        });
        let t_b = common::best(3, || {
            let _ = sys_b.solve(&b).expect("solve");
        });
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
                iters.to_string(),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!("paper reference: HYLU substitution ~18% SLOWER than PARDISO (refinement cost)");
}

//! Fig. 7 — total one-time solve time (preprocessing + factorization +
//! substitution) and speedup.
//!
//! Paper result: 1.70x geometric-mean speedup over MKL PARDISO.

#[path = "common.rs"]
mod common;

use hylu::api::Solver;
use hylu::bench_harness::{environment, fmt_time, Table};
use hylu::sparse::csr::Csr;

fn total_once(s: &Solver, a: &Csr, b: &[f64]) -> f64 {
    let t = std::time::Instant::now();
    let sys = s.analyze(a).expect("analyze").factor().expect("factor");
    let _ = sys.solve(b).expect("solve");
    t.elapsed().as_secs_f64()
}

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 7: total one-time solve time",
        &["matrix", "class", "n", "hylu", "baseline", "speedup"],
    );
    for bm in &common::suite() {
        let a = (bm.build)();
        let b = common::rhs(&a);
        let hylu = common::hylu_solver(false);
        let base = common::baseline_solver();
        let t_h = total_once(&hylu, &a, &b).min(total_once(&hylu, &a, &b));
        let t_b = total_once(&base, &a, &b).min(total_once(&base, &a, &b));
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!("paper reference: total one-time speedup 1.70x geomean vs MKL PARDISO");
}

//! Ablation micro-benches for the design choices DESIGN.md calls out:
//!
//! 1. hybrid auto-selection vs each forced kernel, per sparsity class;
//! 2. native Rust microkernel GEMM vs the XLA/PJRT AOT-Pallas artifact
//!    (per-call overhead on this CPU testbed; a TPU amortizes differently);
//! 3. dual-mode (bulk+pipeline) vs bulk-only vs pipeline-only scheduling;
//! 4. supernode relaxation budget sweep (one-time vs repeated tradeoff).

#[path = "common.rs"]
mod common;

use hylu::api::Solver;
use hylu::bench_harness::{environment, fmt_time, Table};
use hylu::coordinator::SolverConfig;
use hylu::numeric::select::KernelMode;
use hylu::sparse::gen;
use hylu::symbolic::MergePolicy;

fn factor_time(cfg: SolverConfig, a: &hylu::sparse::csr::Csr) -> f64 {
    let s = Solver::from_config(cfg).expect("solver");
    let mut sys = s.analyze(a).expect("analyze").factor().expect("factor");
    common::best(2, || {
        sys.factorize().expect("factor");
    })
}

fn main() {
    println!("{}", environment());

    // --- 1. hybrid vs forced kernels ---
    let mut t1 = Table::new(
        "ablation 1: auto kernel selection vs forced kernels (factor time)",
        &["class", "auto", "row-row", "sup-row", "sup-sup", "auto/best"],
    );
    let cases: Vec<(&str, hylu::sparse::csr::Csr)> = vec![
        ("circuit", gen::circuit(10000, 3)),
        ("power", gen::power_network(8000, 4)),
        ("mesh2d", gen::grid2d(70, 70)),
        ("mesh3d", gen::grid3d(13, 13, 13)),
        ("kkt", gen::kkt(2500, 800, 5)),
        ("banded", gen::banded(3000, 16, 6)),
    ];
    for (name, a) in &cases {
        let forced = |k| SolverConfig {
            kernel: Some(k),
            threads: common::threads(),
            ..SolverConfig::default()
        };
        let t_auto = factor_time(
            SolverConfig {
                threads: common::threads(),
                ..SolverConfig::default()
            },
            a,
        );
        let t_rr = factor_time(forced(KernelMode::RowRow), a);
        let t_sr = factor_time(forced(KernelMode::SupRow), a);
        let t_ss = factor_time(forced(KernelMode::SupSup), a);
        let best = t_rr.min(t_sr).min(t_ss);
        t1.row(
            vec![
                name.to_string(),
                fmt_time(t_auto),
                fmt_time(t_rr),
                fmt_time(t_sr),
                fmt_time(t_ss),
                format!("{:.2}", t_auto / best),
            ],
            best.max(1e-9) / t_auto.max(1e-9),
        );
    }
    t1.print();

    // --- 2. native vs XLA GEMM backend ---
    match hylu::runtime::XlaGemm::load(std::path::Path::new("artifacts"), 1) {
        Ok(xla) => {
            let mut t2 = Table::new(
                "ablation 2: GEMM backend, per-call time (C(m,2m) -= A(m,m) B(m,2m))",
                &["m", "native", "xla/pjrt", "xla/native"],
            );
            for m in [16usize, 32, 64, 128] {
                let a: Vec<f64> = (0..m * m).map(|i| (i % 7) as f64 - 3.0).collect();
                let b: Vec<f64> = (0..m * 2 * m).map(|i| (i % 5) as f64 - 2.0).collect();
                let c: Vec<f64> = vec![1.0; m * 2 * m];
                let tier = hylu::numeric::kernels::active_tier();
                let t_native = common::best(20, || {
                    let mut cc = c.clone();
                    hylu::numeric::kernels::gemm_sub(
                        tier, &mut cc, 2 * m, &a, m, &b, 2 * m, m, m, 2 * m,
                    );
                    std::hint::black_box(cc);
                });
                let t_xla = common::best(20, || {
                    let out = xla.gemm_update(&c, &a, &b, m, m, 2 * m).expect("xla gemm");
                    std::hint::black_box(out);
                });
                t2.row(
                    vec![
                        m.to_string(),
                        fmt_time(t_native),
                        fmt_time(t_xla),
                        format!("{:.1}x", t_xla / t_native),
                    ],
                    t_xla / t_native,
                );
            }
            t2.print();
            println!("(XLA per-call overhead dominates at these sizes on CPU-PJRT; DESIGN.md §Hardware-Adaptation)");
        }
        Err(e) => println!("ablation 2 skipped: {e} (run `make artifacts`)"),
    }

    // --- 3. scheduling modes ---
    let mut t3 = Table::new(
        "ablation 3: dual-mode scheduling (factor time, 4 threads)",
        &["matrix", "dual-mode", "bulk-only", "pipeline-only"],
    );
    for (name, a) in [
        ("mesh2d 80x80", gen::grid2d(80, 80)),
        ("banded 4000", gen::banded(4000, 12, 7)),
    ] {
        let cfg = |bulk_threshold: usize| SolverConfig {
            threads: 4,
            bulk_threshold,
            ..SolverConfig::default()
        };
        // dual-mode: default threshold; bulk-only: threshold 1 (every level
        // stays bulk); pipeline-only: huge threshold (no level qualifies)
        let t_dual = factor_time(cfg(8), &a);
        let t_bulk = factor_time(cfg(1), &a);
        let t_pipe = factor_time(cfg(usize::MAX), &a);
        t3.row(
            vec![
                name.to_string(),
                fmt_time(t_dual),
                fmt_time(t_bulk),
                fmt_time(t_pipe),
            ],
            t_bulk / t_dual,
        );
    }
    t3.print();

    // --- 4. relaxation budget sweep ---
    let mut t4 = Table::new(
        "ablation 4: supernode relaxation budget (mesh2d 80x80)",
        &["budget", "analyze", "factor", "refactor", "lu entries"],
    );
    let a = gen::grid2d(80, 80);
    for (label, policy) in [
        ("exact", MergePolicy::Exact { max_width: 128 }),
        (
            "relax 0.1",
            MergePolicy::Relaxed {
                max_width: 128,
                budget_frac: 0.1,
                budget_abs: 8,
            },
        ),
        (
            "relax 0.2",
            MergePolicy::Relaxed {
                max_width: 128,
                budget_frac: 0.2,
                budget_abs: 24,
            },
        ),
        (
            "relax 0.4",
            MergePolicy::Relaxed {
                max_width: 128,
                budget_frac: 0.4,
                budget_abs: 64,
            },
        ),
    ] {
        let s = Solver::from_config(SolverConfig {
            merge_policy: Some(policy),
            kernel: Some(KernelMode::SupSup),
            threads: common::threads(),
            ..SolverConfig::default()
        })
        .expect("solver");
        let t_an = common::best(2, || {
            let _ = s.analyze(&a).expect("analyze");
        });
        let mut sys = s.analyze(&a).expect("analyze").factor().expect("factor");
        let t_f = common::best(2, || {
            sys.factorize().expect("factor");
        });
        let t_r = common::best(3, || {
            sys.refactor(&a.vals).expect("refactor");
        });
        t4.row(
            vec![
                label.to_string(),
                fmt_time(t_an),
                fmt_time(t_f),
                fmt_time(t_r),
                sys.symbolic_stats().lu_entries.to_string(),
            ],
            1.0,
        );
    }
    t4.print();
}

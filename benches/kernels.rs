//! Microbench: the dense-kernel dispatch tiers against each other.
//!
//! Seeds the perf trajectory for the SIMD microkernel subsystem:
//!
//! 1. `gemm_sub` per tier (scalar / portable / native / avx512) across
//!    panel shapes — the headline is native >= 2x scalar on 64x64x64;
//! 2. `trsm_right_upper` per tier across triangle sizes;
//! 3. block substitution at k in {1, 4, 16} per tier, against the
//!    k x (single-RHS scalar sweep) baseline — the headline is k=16
//!    block >= 1.5x that baseline;
//! 4. tuned-vs-default A/B: every enumerated autotuner GEMM tile
//!    variant and packed-A vs strided-A against the active tier's
//!    default kernel — the rows the `hylu gauntlet` artifact records.

use hylu::bench_harness::{environment, fmt_time, time_best, Table};
use hylu::numeric::factor::{factor, NativeGemm};
use hylu::numeric::kernels::{self, tuner, GemmVariant, KernelPlan, KernelTier};
use hylu::numeric::select::KernelMode;
use hylu::numeric::{LuFactors, PivotConfig};
use hylu::solve::{backward, backward_block_with, forward, forward_block_with};
use hylu::sparse::gen;
use hylu::symbolic::{analyze_pattern, MergePolicy};
use hylu::testutil::Prng;

const ALL_TIERS: [KernelTier; 4] = [
    KernelTier::Scalar,
    KernelTier::Portable,
    KernelTier::Native,
    KernelTier::Avx512,
];

fn tiers() -> Vec<KernelTier> {
    ALL_TIERS.into_iter().filter(|t| t.available()).collect()
}

fn main() {
    println!("{}", environment());
    let p = kernels::probe();
    println!(
        "active tier {} | probe: gemm {:.2} GFLOP/s vs scalar {:.2} GFLOP/s \
         (advantage {:.2}x, selection calibration {:.2})",
        kernels::active_tier(),
        p.gemm_gflops,
        p.scalar_gflops,
        p.advantage(),
        kernels::calibration()
    );
    if !KernelTier::Native.available() {
        println!("(native tier unavailable on this machine: AVX2+FMA not detected)");
    }
    if !KernelTier::Avx512.available() {
        println!(
            "(avx512 tier unavailable: needs avx512f+avx512vl at runtime AND \
             RUSTFLAGS=-C target-feature=+avx512f,+avx512vl at compile time)"
        );
    }

    // --- 1. gemm_sub tiers ---
    let mut rng = Prng::new(11);
    let mut t1 = Table::new(
        "gemm_sub dispatch tiers (C[mxn] -= A[mxk] B[kxn], per-call time)",
        &["m,k,n", "scalar", "portable", "native", "avx512", "native/scalar"],
    );
    let mut native_64 = f64::NAN;
    for (m, k, n) in [(16usize, 16usize, 16usize), (32, 32, 32), (64, 64, 64), (64, 64, 192)] {
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        let mut times = [f64::NAN; 4];
        for (ti, tier) in ALL_TIERS.into_iter().enumerate() {
            if !tier.available() {
                continue;
            }
            let mut c = c0.clone();
            times[ti] = time_best(30, || {
                kernels::gemm_sub(tier, &mut c, n, &a, k, &b, n, m, k, n);
                std::hint::black_box(c[0]);
            });
        }
        let speed = times[0] / times[2];
        if (m, k, n) == (64, 64, 64) {
            native_64 = speed;
        }
        t1.row(
            vec![
                format!("{m},{k},{n}"),
                fmt_time(times[0]),
                fmt_time(times[1]),
                if times[2].is_nan() { "n/a".into() } else { fmt_time(times[2]) },
                if times[3].is_nan() { "n/a".into() } else { fmt_time(times[3]) },
                if speed.is_nan() { "n/a".into() } else { format!("{speed:.2}x") },
            ],
            if speed.is_finite() { speed } else { 1.0 },
        );
    }
    t1.print();
    if native_64.is_finite() {
        println!(
            "acceptance: native gemm_sub on 64x64x64 = {:.2}x scalar (target >= 2x): {}",
            native_64,
            if native_64 >= 2.0 { "PASS" } else { "MISS" }
        );
    }

    // --- 2. trsm tiers ---
    let mut t2 = Table::new(
        "trsm_right_upper dispatch tiers (m rows vs len-wide triangle)",
        &["m,len", "scalar", "portable", "native", "avx512", "native/scalar"],
    );
    for (m, len) in [(32usize, 16usize), (64, 48), (64, 96)] {
        let ldu = len + 2;
        let mut u = vec![0.0; (len + 1) * ldu];
        for r in 0..len {
            for c in r..len {
                u[(1 + r) * ldu + 1 + c] =
                    if r == c { 2.0 + rng.uniform() } else { 0.2 * rng.normal() };
            }
        }
        let ldx = len;
        let x0: Vec<f64> = (0..m * ldx).map(|_| rng.normal()).collect();
        let mut times = [f64::NAN; 4];
        for (ti, tier) in ALL_TIERS.into_iter().enumerate() {
            if !tier.available() {
                continue;
            }
            let mut x = x0.clone();
            let mut scratch = Vec::new();
            times[ti] = time_best(30, || {
                x.copy_from_slice(&x0);
                kernels::trsm_right_upper(
                    tier,
                    &mut x,
                    ldx,
                    0,
                    m,
                    &u,
                    ldu,
                    1,
                    1,
                    len,
                    &mut scratch,
                );
                std::hint::black_box(x[0]);
            });
        }
        let speed = times[0] / times[2];
        t2.row(
            vec![
                format!("{m},{len}"),
                fmt_time(times[0]),
                fmt_time(times[1]),
                if times[2].is_nan() { "n/a".into() } else { fmt_time(times[2]) },
                if times[3].is_nan() { "n/a".into() } else { fmt_time(times[3]) },
                if speed.is_nan() { "n/a".into() } else { format!("{speed:.2}x") },
            ],
            if speed.is_finite() { speed } else { 1.0 },
        );
    }
    t2.print();

    // --- 3. block substitution: k lanes vs k x single-RHS ---
    let a = gen::grid2d(60, 60);
    let n = a.n;
    let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 32 }, 8);
    let cfg = PivotConfig::default();
    let mut fac = LuFactors::alloc(&sym);
    factor(&a, &sym, KernelMode::SupSup, &cfg, &mut fac, false, &NativeGemm);
    let b = gen::rhs_for_ones(&a);

    // baseline: the single-RHS scalar sweep
    let mut y1 = b.clone();
    let t_single = time_best(20, || {
        y1.copy_from_slice(&b);
        forward(&sym, &fac, &mut y1);
        backward(&sym, &fac, &mut y1);
        std::hint::black_box(y1[0]);
    });
    println!(
        "\nblock substitution on mesh2d n={n} (single-RHS scalar sweep: {} per rhs)",
        fmt_time(t_single)
    );
    let mut t3 = Table::new(
        "block substitution tiers (per-RHS time, speedup vs k x single-RHS)",
        &["tier,k", "total", "per rhs", "vs kx single"],
    );
    let mut native_k16 = f64::NAN;
    for tier in tiers() {
        for k in [1usize, 4, 16] {
            let mut yb = vec![0.0; n * k];
            let t_block = time_best(10, || {
                for i in 0..n {
                    for q in 0..k {
                        yb[i * k + q] = b[i];
                    }
                }
                forward_block_with(tier, &sym, &fac, &mut yb, k);
                backward_block_with(tier, &sym, &fac, &mut yb, k);
                std::hint::black_box(yb[0]);
            });
            let speed = t_single * k as f64 / t_block;
            if k == 16 && tier == *tiers().last().unwrap() {
                native_k16 = speed;
            }
            t3.row(
                vec![
                    format!("{tier},k={k}"),
                    fmt_time(t_block),
                    fmt_time(t_block / k as f64),
                    format!("{speed:.2}x"),
                ],
                speed,
            );
        }
    }
    t3.print();
    if native_k16.is_finite() {
        println!(
            "acceptance: k=16 block substitution (best tier) = {:.2}x the 16 x single-RHS \
             scalar baseline (target >= 1.5x): {}",
            native_k16,
            if native_k16 >= 1.5 { "PASS" } else { "MISS" }
        );
    }

    // --- 4. autotuner variants: tuned vs tier default ---
    // The same A/B rows `hylu gauntlet` records in its JSON artifact:
    // every enumerated GEMM tile variant, plus packed-A vs strided-A,
    // against the active tier's default kernel on a representative
    // sup-sup shape (strided A, like a panel read in place).
    let tier = kernels::active_tier();
    let (m, k, n) = (48usize, 32usize, 96usize);
    let lda = k + 8;
    let a: Vec<f64> = (0..m * lda).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
    let mut c = vec![0.0; m * n];
    let t_def = time_best(30, || {
        kernels::gemm_sub(tier, &mut c, n, &a, lda, &b, n, m, k, n);
        std::hint::black_box(c[0]);
    });
    let mut t4 = Table::new(
        "autotuner GEMM variants vs tier default (48x32x96, strided A)",
        &["variant", "default", "variant", "default/variant"],
    );
    let mut best_ratio = f64::NAN;
    for &(mr, nr, ku) in tuner::TILE_VARIANTS.iter() {
        let plan = KernelPlan {
            gemm: GemmVariant::Tiled { mr, nr, ku },
            ..Default::default()
        };
        let t_var = time_best(30, || {
            kernels::gemm_sub_planned(tier, &plan, &mut c, n, &a, lda, &b, n, m, k, n);
            std::hint::black_box(c[0]);
        });
        let ratio = t_def / t_var;
        // f64::max ignores the NaN seed on the first row
        best_ratio = best_ratio.max(ratio);
        t4.row(
            vec![
                format!("tile {mr}x{nr} k-unroll {ku}"),
                fmt_time(t_def),
                fmt_time(t_var),
                format!("{ratio:.2}x"),
            ],
            ratio,
        );
    }
    let mut packed = Vec::new();
    let t_packed = time_best(30, || {
        kernels::pack_rows(&mut packed, &a, lda, m, k);
        kernels::gemm_sub(tier, &mut c, n, &packed, k, &b, n, m, k, n);
        std::hint::black_box(c[0]);
    });
    t4.row(
        vec![
            "packed-A (pack + gemm)".into(),
            fmt_time(t_def),
            fmt_time(t_packed),
            format!("{:.2}x", t_def / t_packed),
        ],
        t_def / t_packed,
    );
    t4.print();
    if best_ratio.is_finite() {
        println!(
            "acceptance: best enumerated variant = {:.2}x the {} default on 48x32x96 \
             (tuner picks the max of these per pattern; >= 1x by construction): {}",
            best_ratio,
            tier,
            if best_ratio >= 0.95 { "PASS" } else { "MISS" }
        );
    }
}

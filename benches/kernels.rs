//! Microbench: the dense-kernel dispatch tiers against each other.
//!
//! Seeds the perf trajectory for the SIMD microkernel subsystem:
//!
//! 1. `gemm_sub` per tier (scalar / portable / native) across panel
//!    shapes — the headline is native >= 2x scalar on 64x64x64;
//! 2. `trsm_right_upper` per tier across triangle sizes;
//! 3. block substitution at k in {1, 4, 16} per tier, against the
//!    k x (single-RHS scalar sweep) baseline — the headline is k=16
//!    block >= 1.5x that baseline.

use hylu::bench_harness::{environment, fmt_time, time_best, Table};
use hylu::numeric::factor::{factor, NativeGemm};
use hylu::numeric::kernels::{self, KernelTier};
use hylu::numeric::select::KernelMode;
use hylu::numeric::{LuFactors, PivotConfig};
use hylu::solve::{backward, backward_block_with, forward, forward_block_with};
use hylu::sparse::gen;
use hylu::symbolic::{analyze_pattern, MergePolicy};
use hylu::testutil::Prng;

fn tiers() -> Vec<KernelTier> {
    [KernelTier::Scalar, KernelTier::Portable, KernelTier::Native]
        .into_iter()
        .filter(|t| t.available())
        .collect()
}

fn main() {
    println!("{}", environment());
    let p = kernels::probe();
    println!(
        "active tier {} | probe: gemm {:.2} GFLOP/s vs scalar {:.2} GFLOP/s \
         (advantage {:.2}x, selection calibration {:.2})",
        kernels::active_tier(),
        p.gemm_gflops,
        p.scalar_gflops,
        p.advantage(),
        kernels::calibration()
    );
    if !KernelTier::Native.available() {
        println!("(native tier unavailable on this machine: AVX2+FMA not detected)");
    }

    // --- 1. gemm_sub tiers ---
    let mut rng = Prng::new(11);
    let mut t1 = Table::new(
        "gemm_sub dispatch tiers (C[mxn] -= A[mxk] B[kxn], per-call time)",
        &["m,k,n", "scalar", "portable", "native", "native/scalar"],
    );
    let mut native_64 = f64::NAN;
    for (m, k, n) in [(16usize, 16usize, 16usize), (32, 32, 32), (64, 64, 64), (64, 64, 192)] {
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        let mut times = [f64::NAN; 3];
        for (ti, tier) in [KernelTier::Scalar, KernelTier::Portable, KernelTier::Native]
            .into_iter()
            .enumerate()
        {
            if !tier.available() {
                continue;
            }
            let mut c = c0.clone();
            times[ti] = time_best(30, || {
                kernels::gemm_sub(tier, &mut c, n, &a, k, &b, n, m, k, n);
                std::hint::black_box(c[0]);
            });
        }
        let speed = times[0] / times[2];
        if (m, k, n) == (64, 64, 64) {
            native_64 = speed;
        }
        t1.row(
            vec![
                format!("{m},{k},{n}"),
                fmt_time(times[0]),
                fmt_time(times[1]),
                if times[2].is_nan() { "n/a".into() } else { fmt_time(times[2]) },
                if speed.is_nan() { "n/a".into() } else { format!("{speed:.2}x") },
            ],
            if speed.is_finite() { speed } else { 1.0 },
        );
    }
    t1.print();
    if native_64.is_finite() {
        println!(
            "acceptance: native gemm_sub on 64x64x64 = {:.2}x scalar (target >= 2x): {}",
            native_64,
            if native_64 >= 2.0 { "PASS" } else { "MISS" }
        );
    }

    // --- 2. trsm tiers ---
    let mut t2 = Table::new(
        "trsm_right_upper dispatch tiers (m rows vs len-wide triangle)",
        &["m,len", "scalar", "portable", "native", "native/scalar"],
    );
    for (m, len) in [(32usize, 16usize), (64, 48), (64, 96)] {
        let ldu = len + 2;
        let mut u = vec![0.0; (len + 1) * ldu];
        for r in 0..len {
            for c in r..len {
                u[(1 + r) * ldu + 1 + c] =
                    if r == c { 2.0 + rng.uniform() } else { 0.2 * rng.normal() };
            }
        }
        let ldx = len;
        let x0: Vec<f64> = (0..m * ldx).map(|_| rng.normal()).collect();
        let mut times = [f64::NAN; 3];
        for (ti, tier) in [KernelTier::Scalar, KernelTier::Portable, KernelTier::Native]
            .into_iter()
            .enumerate()
        {
            if !tier.available() {
                continue;
            }
            let mut x = x0.clone();
            let mut scratch = Vec::new();
            times[ti] = time_best(30, || {
                x.copy_from_slice(&x0);
                kernels::trsm_right_upper(
                    tier,
                    &mut x,
                    ldx,
                    0,
                    m,
                    &u,
                    ldu,
                    1,
                    1,
                    len,
                    &mut scratch,
                );
                std::hint::black_box(x[0]);
            });
        }
        let speed = times[0] / times[2];
        t2.row(
            vec![
                format!("{m},{len}"),
                fmt_time(times[0]),
                fmt_time(times[1]),
                if times[2].is_nan() { "n/a".into() } else { fmt_time(times[2]) },
                if speed.is_nan() { "n/a".into() } else { format!("{speed:.2}x") },
            ],
            if speed.is_finite() { speed } else { 1.0 },
        );
    }
    t2.print();

    // --- 3. block substitution: k lanes vs k x single-RHS ---
    let a = gen::grid2d(60, 60);
    let n = a.n;
    let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 32 }, 8);
    let cfg = PivotConfig::default();
    let mut fac = LuFactors::alloc(&sym);
    factor(&a, &sym, KernelMode::SupSup, &cfg, &mut fac, false, &NativeGemm);
    let b = gen::rhs_for_ones(&a);

    // baseline: the single-RHS scalar sweep
    let mut y1 = b.clone();
    let t_single = time_best(20, || {
        y1.copy_from_slice(&b);
        forward(&sym, &fac, &mut y1);
        backward(&sym, &fac, &mut y1);
        std::hint::black_box(y1[0]);
    });
    println!(
        "\nblock substitution on mesh2d n={n} (single-RHS scalar sweep: {} per rhs)",
        fmt_time(t_single)
    );
    let mut t3 = Table::new(
        "block substitution tiers (per-RHS time, speedup vs k x single-RHS)",
        &["tier,k", "total", "per rhs", "vs kx single"],
    );
    let mut native_k16 = f64::NAN;
    for tier in tiers() {
        for k in [1usize, 4, 16] {
            let mut yb = vec![0.0; n * k];
            let t_block = time_best(10, || {
                for i in 0..n {
                    for q in 0..k {
                        yb[i * k + q] = b[i];
                    }
                }
                forward_block_with(tier, &sym, &fac, &mut yb, k);
                backward_block_with(tier, &sym, &fac, &mut yb, k);
                std::hint::black_box(yb[0]);
            });
            let speed = t_single * k as f64 / t_block;
            if k == 16 && tier == *tiers().last().unwrap() {
                native_k16 = speed;
            }
            t3.row(
                vec![
                    format!("{tier},k={k}"),
                    fmt_time(t_block),
                    fmt_time(t_block / k as f64),
                    format!("{speed:.2}x"),
                ],
                speed,
            );
        }
    }
    t3.print();
    if native_k16.is_finite() {
        println!(
            "acceptance: k=16 block substitution (best tier) = {:.2}x the 16 x single-RHS \
             scalar baseline (target >= 1.5x): {}",
            native_k16,
            if native_k16 >= 1.5 { "PASS" } else { "MISS" }
        );
    }
}

//! Fig. 5 — numerical factorization time and speedup, one-time solving.
//!
//! Paper result: 2.36x geometric-mean speedup over MKL PARDISO, with the
//! largest wins on circuit-class matrices (ASIC_680k, circuit5M) where the
//! always-BLAS baseline drowns in padded fill.

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, fmt_time, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 5: numerical factorization time, one-time solve",
        &["matrix", "class", "n", "kernel", "hylu", "baseline", "speedup"],
    );
    for bm in &common::suite() {
        let a = (bm.build)();
        let hylu = common::hylu_solver(false);
        let base = common::baseline_solver();
        // first factor transitions the handle; `factorize` re-runs the
        // full pivot-searching factorization (what the figure times)
        let mut sys_h = hylu.analyze(&a).expect("hylu analyze").factor().expect("factor");
        let mut sys_b = base.analyze(&a).expect("baseline analyze").factor().expect("factor");
        let t_h = common::best(2, || {
            sys_h.factorize().expect("hylu factor");
        });
        let t_b = common::best(2, || {
            sys_b.factorize().expect("baseline factor");
        });
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                format!("{}", sys_h.analysis().mode),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!("paper reference: factorization speedup 2.36x geomean vs MKL PARDISO");
}

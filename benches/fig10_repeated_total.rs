//! Fig. 10 — total time of refactorization + substitution, repeated
//! solving.
//!
//! Paper result: 2.53x geometric-mean speedup, and HYLU is faster than MKL
//! PARDISO on **ALL** tested benchmarks for this metric — the bench prints
//! a win/loss count to check that claim's shape.

#[path = "common.rs"]
mod common;

use hylu::bench_harness::{environment, fmt_time, Table};

fn main() {
    println!("{}", environment());
    let mut table = Table::new(
        "Fig 10: refactorization + substitution total, repeated solve",
        &["matrix", "class", "n", "hylu", "baseline", "speedup"],
    );
    let mut wins = 0usize;
    let mut total = 0usize;
    for bm in &common::suite() {
        let a = (bm.build)();
        let b = common::rhs(&a);
        let hylu = common::hylu_solver(true);
        let base = common::baseline_solver();
        let mut sys_h = hylu.analyze(&a).expect("analyze").factor().expect("factor");
        let mut sys_b = base.analyze(&a).expect("analyze").factor().expect("factor");
        let t_h = common::best(3, || {
            sys_h.refactor(&a.vals).expect("refactor");
            let _ = sys_h.solve(&b).expect("solve");
        });
        let t_b = common::best(3, || {
            sys_b.refactor(&a.vals).expect("refactor");
            let _ = sys_b.solve(&b).expect("solve");
        });
        total += 1;
        if t_h < t_b {
            wins += 1;
        }
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    println!("HYLU wins {wins}/{total} matrices (paper: ALL)");
    println!("paper reference: repeated refactor+solve speedup 2.53x geomean");
}

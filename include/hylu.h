/* hylu.h — stable C ABI for the HYLU sparse LU solver (Rust crate,
 * feature `ffi`; build with `cargo build --release --features ffi` to
 * get libhylu.so / libhylu.dylib).
 *
 * Lifecycle (mirrors upstream HYLU's Analyze/Factorize/ReFactorize/
 * Solve/Free):
 *
 *   hylu_handle h;
 *   hylu_create(0, 1, &h);                    // all cores, repeated mode
 *   hylu_analyze(h, n, ap, ai, ax);           // CSR, 0-based indices
 *   hylu_factorize(h);                        // pivot-searching factor
 *   while (newton_step) {
 *       hylu_refactorize(h, ax_new);          // same pattern, new values
 *       hylu_solve(h, b, x);
 *   }
 *   hylu_free(h);
 *
 * Matrix contract: `ap` holds n+1 monotone row offsets with ap[0] == 0;
 * `ai`/`ax` hold ap[n] column indices (0-based, strictly increasing
 * within each row) and values. `hylu_refactorize`'s `ax` aligns
 * element-for-element with the analyzed `ai`/`ax`.
 *
 * Every function returns HYLU_OK (0) or a stable positive error code
 * (shared with the `hylu` CLI exit status and Rust's `Error::code`).
 * `hylu_last_error` returns a human-readable message for the last
 * failing call on the handle.
 *
 * Threading: handles are not thread-safe. Every call (including
 * hylu_solve/hylu_solve_many, which record failures in the handle's
 * error slot) takes the handle exclusively; serialize all calls per
 * handle or use one handle per thread. Concurrent solving on shared
 * factors is a Rust-API capability, not an ABI one.
 *
 * Panics: a caught internal panic (HYLU_ERR_PANIC) from analyze/
 * factorize/refactorize poisons the handle — factors may be
 * inconsistent, and every later call returns HYLU_ERR_INVALID until a
 * fresh hylu_analyze resets the state.
 *
 * Tuning knobs (process-wide environment variables; the ABI itself is
 * unchanged — plans live inside the analysis):
 *
 *   HYLU_KERNEL=scalar|portable|native|avx512
 *       Pin the dense-microkernel dispatch tier (default: best
 *       available; avx512 additionally needs a build with
 *       RUSTFLAGS="-C target-feature=+avx512f,+avx512vl").
 *   HYLU_TUNING=off|quick|full
 *       Per-pattern kernel autotuning level applied at hylu_analyze
 *       time (default off). quick/full search GEMM tile variants,
 *       A-operand packing, and TRSM crossover thresholds against the
 *       analyzed pattern's supernode shape histogram; the winning plan
 *       is cached in the analysis, so hylu_refactorize/hylu_solve pay
 *       no tuning cost. Results are unchanged to solver accuracy
 *       (GEMM variants are bit-identical to the scalar reference).
 *   HYLU_TUNE_CACHE=dir
 *       Persist tuned plans to `dir` keyed by (version, tier, pattern
 *       hash) and reload them on the next analyze of the same pattern
 *       — a process restart starts warm. Corrupt or version-bumped
 *       entries are ignored; writes are best-effort.
 *   HYLU_PROBE=off
 *       Disable the kernel-selection throughput calibration probe
 *       (pins the selection crossovers to their reference tuning).
 *   HYLU_FAULT=SEED:PERIOD:KINDS[:LIMIT]
 *       Deterministic fault injection for resilience testing: every
 *       PERIOD-th factorization/solve entering a solver created while
 *       the variable is set draws a fault (panic-factor, panic-solve,
 *       zero-pivot, slow=MICROS; comma-separated KINDS) from a seeded
 *       stream. Unset in production: the check is a single branch on
 *       an always-None option, and malformed specs are ignored.
 *
 * Precision: the C ABI is pinned to f64. Every handle created by
 * hylu_create factors and solves in double precision regardless of the
 * HYLU_PRECISION environment variable, which applies only to the Rust
 * API's SolverBuilder-configured solvers (Precision::Mixed: f32 factor
 * core + f64 refinement recovery with stall-driven f64 fallback). The
 * values/rhs/solution types below (double) are the contract; a future
 * mixed-precision ABI opt-in would be a new flag on hylu_create, not a
 * behavior change to existing callers. */

#ifndef HYLU_H
#define HYLU_H

#include <stdint.h>

#ifdef __cplusplus
extern "C" {
#endif

/* Opaque solver + system handle. */
typedef struct hylu_handle_s *hylu_handle;

/* Stable status codes (append-only). */
#define HYLU_OK 0             /* success */
#define HYLU_ERR_PANIC 1      /* internal panic caught at the boundary */
#define HYLU_ERR_INVALID 2    /* invalid input or out-of-order call */
#define HYLU_ERR_IO 3         /* i/o or parse failure */
#define HYLU_ERR_SINGULAR 4   /* structurally singular matrix */
#define HYLU_ERR_ZERO_PIVOT 5 /* unperturbable zero pivot */
#define HYLU_ERR_RUNTIME 6    /* runtime/backend failure */
#define HYLU_ERR_SHARD_PANICKED 7   /* service shard caught a panic on
                                     * this request; the shard lives on */
#define HYLU_ERR_DEADLINE_EXPIRED 8 /* deadline passed before dispatch */
#define HYLU_ERR_QUARANTINED 9      /* system quarantined after a numeric
                                     * or panic failure; recovery is
                                     * retried on later traffic */

/* Create a solver handle. threads = 0 uses all cores; repeated != 0
 * selects the repeated-solve preset (relaxed supernodes, fast
 * refactorization). */
int32_t hylu_create(int64_t threads, int32_t repeated, hylu_handle *out);

/* Analyze a CSR matrix (preprocessing: static pivoting, ordering,
 * symbolic factorization, kernel selection). Replaces any previous
 * system on the handle. */
int32_t hylu_analyze(hylu_handle h, int64_t n, const int64_t *ap,
                     const int64_t *ai, const double *ax);

/* Numeric factorization with pivot search. On an already-factorized
 * handle, re-runs the full factorization of the current values. */
int32_t hylu_factorize(hylu_handle h);

/* Refactorize with new values on the stored pivot order (no pivot
 * search — the repeated-solve fast path). */
int32_t hylu_refactorize(hylu_handle h, const double *ax);

/* Re-analyze with a matrix whose PATTERN may differ (dynamic-topology
 * step: circuit element stamped in or out). The incremental path reuses
 * the handle's engine, arenas, and ordering seeds; an unchanged pattern
 * reuses the symbolic factorization and tuned kernel plan outright, and
 * a local pattern edit patches the symbolic DAG incrementally —
 * bit-identical to a cold hylu_analyze either way. The system is
 * refactorized before returning, so the handle stays solvable; on
 * failure the previous matrix and factors are kept. Same CSR array
 * contract as hylu_analyze; requires a factorized handle. */
int32_t hylu_reanalyze(hylu_handle h, int64_t n, const int64_t *ap,
                       const int64_t *ai, const double *ax);

/* Solve A x = b (length-n arrays; iterative refinement is automatic). */
int32_t hylu_solve(hylu_handle h, const double *b, double *x);

/* Batched solve: nrhs right-hand sides packed column-after-column
 * (b + q*n); column q is bit-identical to hylu_solve of that column. */
int32_t hylu_solve_many(hylu_handle h, int64_t nrhs, const double *b,
                        double *x);

/* Dimension / stored nonzeros of the analyzed system (0 when none). */
int64_t hylu_n(hylu_handle h);
int64_t hylu_nnz(hylu_handle h);

/* Message of the last error on this handle (empty string when none);
 * valid until the next failing call or hylu_free. */
const char *hylu_last_error(hylu_handle h);

/* Release the handle (null is a no-op). */
void hylu_free(hylu_handle h);

/* ---- Elastic solve service ------------------------------------------
 *
 * Mirrors the Rust SolverService: a sharded, request-coalescing front
 * door whose systems — and whose *shard set* — come and go on a live
 * service. Matrices enter with hylu_service_register (same CSR contract
 * as hylu_analyze, plus an internal factorization); requests are routed
 * by the returned id; hylu_service_retire drains in-flight work for the
 * system before dropping its factors; hylu_service_rebalance moves hot
 * systems onto quiet shards by observed load; hylu_service_grow /
 * hylu_service_shrink add and drain dispatcher threads under traffic.
 * Ids are never reused.
 *
 * Like hylu_handle, a hylu_service handle is not thread-safe at the
 * ABI: serialize calls per handle (concurrent submission is a Rust-API
 * capability). */

typedef struct hylu_service_s *hylu_service;

/* Create an elastic service: `shards` dispatcher threads, `threads`
 * engine workers per registered solver (0 = all cores). Starts empty. */
int32_t hylu_service_create(int64_t shards, int64_t threads,
                            hylu_service *out);

/* Analyze + factorize a CSR matrix and register it on the live
 * service; writes the routing id to *out_id. */
int32_t hylu_service_register(hylu_service s, int64_t n, const int64_t *ap,
                              const int64_t *ai, const double *ax,
                              uint64_t *out_id);

/* Retire a system: queued solves for it drain first, then its factors
 * drop. Later calls with the id fail with HYLU_ERR_INVALID. */
int32_t hylu_service_retire(hylu_service s, uint64_t id);

/* Solve A x = b on system `id` through the coalescing queue (blocking,
 * bulk lane; b and x are length-n arrays for that system). */
int32_t hylu_service_solve(hylu_service s, uint64_t id, const double *b,
                           double *x);

/* hylu_service_solve on the deadline lane: dispatches ahead of bulk
 * traffic, earliest deadline first. deadline_us is relative to now in
 * microseconds. When the service expires deadlines, a request whose
 * deadline passes before dispatch fails with HYLU_ERR_DEADLINE_EXPIRED
 * — and the dispatcher's coalescing sleep is clamped by the earliest
 * queued deadline minus a dispatch margin, so an admitted-live request
 * is never expired by the shard's own sleep. */
int32_t hylu_service_solve_deadline(hylu_service s, uint64_t id,
                                    const double *b, double *x,
                                    uint64_t deadline_us);

/* Per-call refinement overrides for hylu_service_solve_opts. Negative
 * numeric knobs (and precision 0) fall back to the service solver's
 * configured defaults. Requests carrying different overrides are never
 * coalesced into one block dispatch. */
typedef struct hylu_solve_opts_s {
    int64_t refine_max_iter; /* < 0 default; 0 disables refinement */
    double refine_tol;       /* < 0 default */
    double refine_target;    /* < 0 default */
    int32_t precision;       /* 0 default, 1 force f64, 2 mixed */
} hylu_solve_opts;

/* hylu_service_solve with per-call overrides (opts may be NULL for
 * all-default, which is bit-identical to hylu_service_solve). */
int32_t hylu_service_solve_opts(hylu_service s, uint64_t id, const double *b,
                                double *x, const hylu_solve_opts *opts);

/* Batched service solve: nrhs right-hand sides packed column-after-
 * column (b + q*n) are all submitted before any is waited on, so they
 * coalesce into wide block dispatches. Column q is bit-identical to a
 * scalar hylu_service_solve of that column. On failure the first error
 * in submission order is returned; columns whose requests succeeded are
 * still written. */
int32_t hylu_service_solve_many(hylu_service s, uint64_t id, int64_t nrhs,
                                const double *b, double *x);

/* Move hot systems onto quiet shards by observed load; writes the
 * number of systems moved to *moved (may be NULL). */
int32_t hylu_service_rebalance(hylu_service s, int64_t *moved);

/* Grow the shard set by k dispatcher threads on the live service;
 * writes the new shard count to *out_shards (may be NULL). New shards
 * start empty — follow with hylu_service_rebalance to move load. */
int32_t hylu_service_grow(hylu_service s, int64_t k, int64_t *out_shards);

/* Shrink the shard set by k dispatcher threads (at least one must
 * remain): resident systems migrate off the draining shards, queued
 * work drains, the threads join; no accepted request is lost. Writes
 * the new shard count to *out_shards (may be NULL). */
int32_t hylu_service_shrink(hylu_service s, int64_t k, int64_t *out_shards);

/* Number of shard dispatcher threads currently running (0 for NULL). */
int64_t hylu_service_shards(hylu_service s);

/* Aggregate service counters (append-only struct; includes shards
 * already drained by hylu_service_shrink). */
typedef struct hylu_service_stats_s {
    uint64_t requests;          /* solve requests accepted */
    uint64_t deadline_requests; /* subset on the deadline lane */
    uint64_t dispatches;        /* batched block dispatches issued */
    uint64_t rhs_solved;        /* right-hand sides solved */
    uint64_t refactors;         /* refactorizations applied */
    uint64_t reanalyzes;        /* live re-analyses applied */
    uint64_t forwarded;         /* requests re-routed between shards */
    uint64_t refine_iters;      /* refinement rounds executed */
    uint64_t registers;         /* systems registered (lifetime) */
    uint64_t retires;           /* systems retired */
    uint64_t moves;             /* systems moved between shards */
    uint64_t panics_caught;     /* panics caught by shard supervision */
    uint64_t quarantines;       /* healthy -> quarantined transitions */
    uint64_t recoveries;        /* successful quarantine recoveries */
    uint64_t expired;           /* deadline requests expired pre-dispatch */
    uint64_t shed;              /* bulk requests shed at admission */
    uint64_t max_batch;         /* widest single batch dispatched */
    double mean_batch;          /* mean RHS per block dispatch */
    uint64_t max_tick_us;       /* widest coalescing wait actually slept
                                 * (measured after preemption), in us */
} hylu_service_stats_t;

/* Snapshot the service's aggregate counters into *out. */
int32_t hylu_service_stats(hylu_service s, hylu_service_stats_t *out);

/* Health of a registered system. Quarantined systems fail solves fast
 * with HYLU_ERR_QUARANTINED until a supervised full refactorization
 * (automatic, on later refactorize/solve traffic) restores them. */
#define HYLU_HEALTH_OK 0           /* healthy, serving */
#define HYLU_HEALTH_ZERO_PIVOT 1   /* quarantined: unperturbable zero pivot */
#define HYLU_HEALTH_SINGULAR 2     /* quarantined: structurally singular */
#define HYLU_HEALTH_PIVOT_GROWTH 3 /* quarantined: pivot growth over limit */
#define HYLU_HEALTH_PANIC 4        /* quarantined: panic during factorization */
int32_t hylu_service_health(hylu_service s, uint64_t id); /* -1: unknown id */

/* Message of the last error on this service handle (empty when none);
 * valid until the next failing call or hylu_service_free. */
const char *hylu_service_last_error(hylu_service s);

/* Release the service (null is a no-op): queued work drains, dispatcher
 * threads join, all registered factors drop. */
void hylu_service_free(hylu_service s);

#ifdef __cplusplus
} /* extern "C" */
#endif

#endif /* HYLU_H */

//! The paper's core claim, observable: HYLU's kernel selection adapts to
//! the sparsity class, and each forced single-kernel configuration loses
//! somewhere. Runs one matrix per class through auto selection and all
//! three forced kernels.
//!
//! ```bash
//! cargo run --release --example kernel_selection
//! ```

use hylu::prelude::*;
use hylu::sparse::gen;
use std::time::Instant;

fn factor_time(cfg: SolverConfig, a: &hylu::sparse::csr::Csr) -> (String, f64) {
    let s = Solver::from_config(cfg).expect("solver");
    let mut sys = s.analyze(a).expect("analyze").factor().expect("factor");
    // best of 2 to de-noise; `factorize` repeats the full pivot-searching
    // factorization on the handle
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t = Instant::now();
        sys.factorize().expect("factor");
        best = best.min(t.elapsed().as_secs_f64());
    }
    (format!("{}", sys.analysis().mode), best)
}

fn main() {
    let cases: Vec<(&str, hylu::sparse::csr::Csr)> = vec![
        ("circuit (ASIC-like)", gen::circuit(15000, 3)),
        ("power network", gen::power_network(10000, 4)),
        ("2-D mesh", gen::grid2d(80, 80)),
        ("3-D mesh", gen::grid3d(14, 14, 14)),
        ("KKT saddle-point", gen::kkt(3000, 1000, 5)),
        ("banded", gen::banded(4000, 16, 6)),
    ];
    println!(
        "{:>20} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "class", "auto-mode", "auto", "row-row", "sup-row", "sup-sup"
    );
    for (name, a) in &cases {
        let (mode, t_auto) = factor_time(SolverConfig::default(), a);
        let forced = |k| SolverConfig {
            kernel: Some(k),
            ..SolverConfig::default()
        };
        let (_, t_rr) = factor_time(forced(KernelMode::RowRow), a);
        let (_, t_sr) = factor_time(forced(KernelMode::SupRow), a);
        let (_, t_ss) = factor_time(forced(KernelMode::SupSup), a);
        let best = t_rr.min(t_sr).min(t_ss);
        println!(
            "{:>20} {:>10} {:>10.1}ms {:>10.1}ms {:>10.1}ms {:>10.1}ms   (auto within {:.2}x of best)",
            name,
            mode,
            t_auto * 1e3,
            t_rr * 1e3,
            t_sr * 1e3,
            t_ss * 1e3,
            t_auto / best
        );
    }
    println!("\nkernel_selection OK");
}

//! Mesh-class workload: 2-D convection-diffusion with increasing Péclet
//! number — the territory where HYLU's sup-sup (level-3) kernel and nested
//! dissection earn their keep, and where a row-only solver (KLU-like)
//! collapses.
//!
//! ```bash
//! cargo run --release --example pde_grid
//! ```

use hylu::baseline;
use hylu::prelude::*;
use hylu::sparse::gen;
use std::time::Instant;

fn solve_once(solver: &Solver, a: &hylu::sparse::csr::Csr) -> (f64, f64) {
    let b = gen::rhs_for_ones(a);
    let t = Instant::now();
    let sys = solver.analyze(a).expect("analyze").factor().expect("factor");
    let (_, st) = sys.solve_with_stats(&b).expect("solve");
    (t.elapsed().as_secs_f64(), st.residual)
}

fn main() {
    let hylu = SolverBuilder::new().build().expect("solver");
    let klu = Solver::from_config(baseline::klu_like(0)).expect("solver");

    println!("2-D convection-diffusion, n = 96x96, sweeping Péclet number\n");
    println!(
        "{:>8} {:>12} {:>12} {:>10} {:>12}",
        "peclet", "hylu", "row-only", "speedup", "residual"
    );
    for peclet in [0.0, 2.0, 8.0, 32.0] {
        let a = gen::convdiff2d(96, 96, peclet, 7);
        let (t_h, res) = solve_once(&hylu, &a);
        let (t_k, _) = solve_once(&klu, &a);
        println!(
            "{:>8.1} {:>10.1}ms {:>10.1}ms {:>9.2}x {:>12.2e}",
            peclet,
            t_h * 1e3,
            t_k * 1e3,
            t_k / t_h,
            res
        );
    }

    // 3-D: heavier fill, wider supernodes
    println!("\n3-D Poisson, increasing size\n");
    println!(
        "{:>8} {:>8} {:>12} {:>12} {:>10}",
        "grid", "n", "hylu", "row-only", "speedup"
    );
    for s in [10usize, 13, 16] {
        let a = gen::grid3d(s, s, s);
        let (t_h, _) = solve_once(&hylu, &a);
        let (t_k, _) = solve_once(&klu, &a);
        println!(
            "{:>5}^3 {:>8} {:>10.1}ms {:>10.1}ms {:>9.2}x",
            s,
            a.n,
            t_h * 1e3,
            t_k * 1e3,
            t_k / t_h
        );
    }
    println!("\npde_grid OK");
}

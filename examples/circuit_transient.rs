//! Repeated-solve scenario: transient simulation of a nonlinear circuit.
//!
//! This is the workload HYLU's repeated-solve mode is designed for (paper
//! §3.2): a Newton iteration inside a timestep loop refactors the same
//! sparsity pattern hundreds of times with changing values. The example
//! simulates a circuit-class system where each Newton step perturbs device
//! conductances, and compares the refactorization fast path against full
//! factorization.
//!
//! ```bash
//! cargo run --release --example circuit_transient
//! ```

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;
use std::time::Instant;

fn main() {
    let n = 20_000;
    let a0 = gen::circuit(n, 42);
    println!("circuit: n = {}, nnz = {}", a0.n, a0.nnz());

    // repeated-mode solver: pays for relaxed supernode analysis once
    let solver = SolverBuilder::new().repeated().build().expect("solver");
    let t = Instant::now();
    let analyzed = solver.analyze(&a0).expect("analyze");
    println!(
        "analyze: {:.1} ms (kernel {}, fill {:.2}x)",
        t.elapsed().as_secs_f64() * 1e3,
        analyzed.symbolic_stats().mode,
        analyzed.symbolic_stats().fill_ratio
    );

    let mut sys = analyzed.factor().expect("factor");
    println!("first factor: {:.2} ms", sys.factor_stats().t_factor * 1e3);

    // transient loop: timesteps x newton iterations
    let timesteps = 10;
    let newton_iters = 3;
    let mut rng = Prng::new(7);
    let mut a = a0.clone();
    let mut t_refactor = 0.0;
    let mut t_solve = 0.0;
    let mut worst_residual = 0.0f64;
    for _step in 0..timesteps {
        for _ni in 0..newton_iters {
            // device linearization changes values, never the pattern
            for v in &mut a.vals {
                *v *= 1.0 + 0.02 * rng.normal();
            }
            sys.refactor(&a.vals).expect("refactor");
            t_refactor += sys.factor_stats().t_factor;
            let b = gen::rhs_for_ones(&a);
            let (x, st) = sys.solve_with_stats(&b).expect("solve");
            t_solve += st.t_solve;
            worst_residual = worst_residual.max(st.residual);
            let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
            assert!(err < 1e-6, "newton solve drifted: {err}");
        }
    }
    let solves = (timesteps * newton_iters) as f64;
    println!(
        "transient: {} solves, refactor avg {:.2} ms, solve avg {:.2} ms, worst residual {:.2e}",
        solves as usize,
        t_refactor / solves * 1e3,
        t_solve / solves * 1e3,
        worst_residual
    );

    // compare against full factorization each step (what a non-repeated
    // solver would do)
    let t = Instant::now();
    for _ in 0..5 {
        sys.factorize().expect("factor");
    }
    let t_full = t.elapsed().as_secs_f64() / 5.0;
    println!(
        "full factor avg {:.2} ms => refactor speedup {:.2}x",
        t_full * 1e3,
        t_full / (t_refactor / solves)
    );
    println!("circuit_transient OK");
}

//! End-to-end driver: runs the full system — preprocessing (MC64 + ordering
//! + symbolic + kernel selection), parallel numeric factorization,
//! refactorization, parallel substitution with iterative refinement, both
//! baselines, and (if artifacts are present) the XLA/PJRT Pallas-kernel
//! path — on a real small workload slice of the benchmark suite, and
//! reports the paper's headline metric: geometric-mean factorization
//! speedup over the PARDISO-like baseline, one-time and repeated.
//!
//! The output of this run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use hylu::baseline;
use hylu::bench_harness::{environment, fmt_time, geomean, Table};
use hylu::bench_suite::suite_small;
use hylu::coordinator::{Solver, SolverConfig};
use hylu::sparse::gen;
use std::time::Instant;

fn main() {
    println!("{}\n", environment());
    let suite = suite_small();

    let mut one_time = Table::new(
        "end-to-end, one-time solve (factor phase, HYLU vs PARDISO-like)",
        &["matrix", "class", "n", "kernel", "hylu", "baseline", "speedup", "residual"],
    );
    let mut repeated_speedups = Vec::new();

    for bm in &suite {
        let a = (bm.build)();
        let b = gen::rhs_for_ones(&a);

        // HYLU one-time
        let hylu = Solver::new(SolverConfig::default());
        let an = hylu.analyze(&a).expect("analyze");
        let t = Instant::now();
        let f = hylu.factor(&a, &an).expect("factor");
        let t_h = t.elapsed().as_secs_f64();
        let (x, st) = hylu.solve_with_stats(&a, &an, &f, &b).expect("solve");
        let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-5, "{}: solution error {err}", bm.name);

        // PARDISO-like one-time
        let base = Solver::new(baseline::pardiso_like(0));
        let anb = base.analyze(&a).expect("analyze");
        let t = Instant::now();
        let fb = base.factor(&a, &anb).expect("factor");
        let t_b = t.elapsed().as_secs_f64();
        let _ = base.solve(&a, &anb, &fb, &b).expect("solve");

        one_time.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                format!("{}", an.mode),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
                format!("{:.1e}", st.residual),
            ],
            t_b / t_h,
        );

        // repeated mode: refactor vs baseline refactor
        let hylu_r = Solver::new(SolverConfig {
            repeated: true,
            ..SolverConfig::default()
        });
        let anr = hylu_r.analyze(&a).expect("analyze");
        let mut fr = hylu_r.factor(&a, &anr).expect("factor");
        let t = Instant::now();
        for _ in 0..3 {
            hylu_r.refactor(&a, &anr, &mut fr).expect("refactor");
        }
        let t_rh = t.elapsed().as_secs_f64() / 3.0;
        let mut frb = base.factor(&a, &anb).expect("factor");
        let t = Instant::now();
        for _ in 0..3 {
            base.refactor(&a, &anb, &mut frb).expect("refactor");
        }
        let t_rb = t.elapsed().as_secs_f64() / 3.0;
        repeated_speedups.push(t_rb / t_rh);
    }

    one_time.print();
    println!(
        "repeated-solve refactorization geomean speedup: {:.2}x (paper: 2.90x one Xeon, MKL)",
        geomean(&repeated_speedups)
    );

    // XLA/Pallas path, if artifacts were built
    match Solver::try_new(SolverConfig {
        use_xla: true,
        ..SolverConfig::default()
    }) {
        Ok(xla_solver) => {
            let a = gen::grid2d(60, 60);
            let b = gen::rhs_for_ones(&a);
            let an = xla_solver.analyze(&a).expect("analyze");
            let f = xla_solver.factor(&a, &an).expect("factor");
            let (x, st) = xla_solver.solve_with_stats(&a, &an, &f, &b).expect("solve");
            let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
            println!(
                "xla/pallas path: factor {} residual {:.1e} max|x-1| {:.1e} => numerics OK",
                fmt_time(f.stats.t_factor),
                st.residual,
                err
            );
            assert!(err < 1e-6);
        }
        Err(e) => println!("xla path skipped ({e}); run `make artifacts` first"),
    }
    println!("\nend_to_end OK");
}

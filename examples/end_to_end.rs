//! End-to-end driver: runs the full system — preprocessing (MC64 + ordering
//! + symbolic + kernel selection), parallel numeric factorization,
//! refactorization, parallel substitution with iterative refinement, both
//! baselines, and (if artifacts are present) the XLA/PJRT Pallas-kernel
//! path — on a real small workload slice of the benchmark suite, and
//! reports the paper's headline metric: geometric-mean factorization
//! speedup over the PARDISO-like baseline, one-time and repeated.
//!
//! The output of this run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use hylu::baseline;
use hylu::bench_harness::{environment, fmt_time, geomean, Table};
use hylu::bench_suite::suite_small;
use hylu::prelude::*;
use hylu::sparse::gen;
use std::time::Instant;

fn main() {
    println!("{}\n", environment());
    let suite = suite_small();

    let mut one_time = Table::new(
        "end-to-end, one-time solve (factor phase, HYLU vs PARDISO-like)",
        &["matrix", "class", "n", "kernel", "hylu", "baseline", "speedup", "residual"],
    );
    let mut repeated_speedups = Vec::new();

    for bm in &suite {
        let a = (bm.build)();
        let b = gen::rhs_for_ones(&a);

        // HYLU one-time
        let hylu = SolverBuilder::new().one_shot().build().expect("solver");
        let analyzed = hylu.analyze(&a).expect("analyze");
        let mode = analyzed.symbolic_stats().mode;
        let t = Instant::now();
        let sys = analyzed.factor().expect("factor");
        let t_h = t.elapsed().as_secs_f64();
        let (x, st) = sys.solve_with_stats(&b).expect("solve");
        let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-5, "{}: solution error {err}", bm.name);

        // PARDISO-like one-time
        let base = Solver::from_config(baseline::pardiso_like(0)).expect("solver");
        let base_an = base.analyze(&a).expect("analyze");
        let t = Instant::now();
        let mut base_sys = base_an.factor().expect("factor");
        let t_b = t.elapsed().as_secs_f64();
        let _ = base_sys.solve(&b).expect("solve");

        one_time.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                format!("{mode}"),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
                format!("{:.1e}", st.residual),
            ],
            t_b / t_h,
        );

        // repeated mode: refactor vs baseline refactor
        let hylu_r = SolverBuilder::new().repeated().build().expect("solver");
        let mut sys_r = hylu_r.analyze(&a).expect("analyze").factor().expect("factor");
        let t = Instant::now();
        for _ in 0..3 {
            sys_r.refactor(&a.vals).expect("refactor");
        }
        let t_rh = t.elapsed().as_secs_f64() / 3.0;
        let t = Instant::now();
        for _ in 0..3 {
            base_sys.refactor(&a.vals).expect("refactor");
        }
        let t_rb = t.elapsed().as_secs_f64() / 3.0;
        repeated_speedups.push(t_rb / t_rh);
    }

    one_time.print();
    println!(
        "repeated-solve refactorization geomean speedup: {:.2}x (paper: 2.90x one Xeon, MKL)",
        geomean(&repeated_speedups)
    );

    // XLA/Pallas path, if artifacts were built
    match SolverBuilder::new().use_xla("artifacts").build() {
        Ok(xla_solver) => {
            let a = gen::grid2d(60, 60);
            let b = gen::rhs_for_ones(&a);
            let sys = xla_solver.analyze(&a).expect("analyze").factor().expect("factor");
            let (x, st) = sys.solve_with_stats(&b).expect("solve");
            let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
            println!(
                "xla/pallas path: factor {} residual {:.1e} max|x-1| {:.1e} => numerics OK",
                fmt_time(sys.factor_stats().t_factor),
                st.residual,
                err
            );
            assert!(err < 1e-6);
        }
        Err(e) => println!("xla path skipped ({e}); run `make artifacts` first"),
    }
    println!("\nend_to_end OK");
}

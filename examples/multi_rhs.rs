//! Batched multi-RHS repeated solving on the persistent engine — the
//! traffic-serving scenario: one factorization, many right-hand sides per
//! step (multi-port networks, periodic small-signal analysis, batched
//! inference over one operating point).
//!
//! ```bash
//! cargo run --release --example multi_rhs
//! ```

use hylu::bench_harness::{fmt_time, time_best};
use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::max_abs_diff;

fn main() {
    let a = gen::grid2d(60, 60);
    let k = 8usize;
    println!("matrix: n = {}, nnz = {}, {} rhs per step", a.n, a.nnz(), k);

    let solver = SolverBuilder::new()
        .repeated()
        .configure(|cfg| cfg.parallel_solve_min_n = 0)
        .build()
        .expect("solver");
    let mut sys = solver.analyze(&a).expect("analyze").factor().expect("factor");

    // k right-hand sides with known solutions x*_q = q + 1
    let base = gen::rhs_for_ones(&a);
    let bs: Vec<Vec<f64>> = (1..=k)
        .map(|q| base.iter().map(|v| v * q as f64).collect())
        .collect();

    // warm the engine arenas, then time the two strategies
    sys.refactor(&a.vals).expect("refactor");
    let (xs, st) = sys.solve_many_with_stats(&bs).expect("solve_many");
    let t_batched = time_best(5, || {
        sys.solve_many(&bs).expect("solve_many");
    });
    let t_loop = time_best(5, || {
        for b in &bs {
            sys.solve(b).expect("solve");
        }
    });

    // batched result must agree with independent solves
    let mut worst = 0.0f64;
    for (q, b) in bs.iter().enumerate() {
        let x = sys.solve(b).expect("solve");
        worst = worst.max(max_abs_diff(&xs[q], &x));
    }
    assert!(worst <= 1e-12, "batched/scalar disagreement {worst}");

    let mut err = 0.0f64;
    for (q, x) in xs.iter().enumerate() {
        let want = (q + 1) as f64;
        err = x.iter().fold(err, |m, v| m.max((v - want).abs()));
    }

    println!(
        "solve_many: {} for {} rhs ({} per rhs, worst residual {:.2e})",
        fmt_time(t_batched),
        st.nrhs,
        fmt_time(t_batched / k as f64),
        st.residual
    );
    println!(
        "solve loop: {} for {} rhs ({} per rhs) => batching speedup {:.2}x",
        fmt_time(t_loop),
        k,
        fmt_time(t_loop / k as f64),
        t_loop / t_batched
    );
    println!("max |x_q - (q+1)| = {err:.2e}, batched == scalar to {worst:.1e}");
    println!(
        "engine: {} worker threads spawned once, {} scratch growth events total",
        solver.engine().threads_spawned(),
        solver.engine().scratch_alloc_events()
    );
    assert!(err < 1e-7, "solution drifted: {err}");
    println!("multi_rhs OK");
}

//! Quickstart: build a sparse system, solve it, check the residual.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hylu::prelude::*;
use hylu::sparse::gen;

fn main() {
    // A 2-D Poisson problem (the "hello world" of sparse direct solvers).
    let a = gen::grid2d(64, 64);
    println!("matrix: n = {}, nnz = {}", a.n, a.nnz());

    // Known solution x* = 1, right-hand side b = A·1.
    let b = gen::rhs_for_ones(&a);

    // analyze -> factor -> solve, as owning typestate handles: the
    // matrix, analysis and factors travel together, so a mismatched
    // pairing cannot be expressed
    let solver = SolverBuilder::new().one_shot().build().expect("solver");
    let system = solver.analyze(&a).expect("analyze"); // LinearSystem<Analyzed>
    let stats = system.symbolic_stats();
    println!(
        "analysis: kernel = {}, fill = {:.2}x, supernode coverage = {:.0}%",
        stats.mode,
        stats.fill_ratio,
        100.0 * stats.supernode_coverage
    );

    let system = system.factor().expect("factor"); // LinearSystem<Factored>
    println!(
        "factor: {:.3} ms, {} perturbed pivots",
        system.factor_stats().t_factor * 1e3,
        system.factor_stats().perturbed
    );

    let (x, st) = system.solve_with_stats(&b).expect("solve");
    let max_err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
    println!(
        "solve: {:.3} ms, residual = {:.3e}, max |x - 1| = {:.3e}",
        st.t_solve * 1e3,
        st.residual,
        max_err
    );
    assert!(max_err < 1e-8, "solution check failed");
    println!("quickstart OK");
}

"""L2 model graphs vs the oracle + the fused-step algebraic identity."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SIZES = st.sampled_from([4, 8, 16, 32, 64])


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(s=SIZES, seed=st.integers(0, 2**31 - 1))
def test_fused_equals_composed(s, seed):
    rng = np.random.default_rng(seed)
    # Scale L like a pivoted factor (bounded multipliers); unscaled random
    # triangles make the solve exponentially ill-conditioned in s.
    l, c = rand(rng, s, s) / s, rand(rng, s, 2 * s)
    a, b = rand(rng, s, s), rand(rng, s, 2 * s)
    fused = np.asarray(model.fused_update_trsm(l, c, a, b))
    composed = np.asarray(model.panel_trsm(l, model.supernode_update(c, a, b)))
    np.testing.assert_allclose(fused, composed, rtol=1e-4, atol=1e-4)
    oracle = np.asarray(ref.fused_update_trsm(l, c, a, b))
    np.testing.assert_allclose(fused, oracle, rtol=1e-3, atol=1e-3)


def test_supernode_step_reconstructs_panel():
    """After the step, L_diag @ X + A @ B == C: the LU invariant the rust
    numeric kernel relies on."""
    rng = np.random.default_rng(3)
    s = 32
    l = np.tril(rand(rng, s, s), -1) / s  # bounded multipliers, see test_kernel
    lw = l + np.eye(s, dtype=np.float32)
    c, a, b = rand(rng, s, 2 * s), rand(rng, s, s), rand(rng, s, 2 * s)
    x = np.asarray(model.fused_update_trsm(l, c, a, b))
    np.testing.assert_allclose(lw @ x + a @ b, c, rtol=1e-3, atol=1e-3)


def test_jit_variants_table_is_consistent():
    table = model.jit_variants()
    names = [n for n, _, _ in table]
    assert len(names) == len(set(names))
    assert {n.rsplit("_", 1)[-1] for n in names} == {"16", "32", "64", "128"}
    for _, fn, shapes in table:
        out = fn(*[np.zeros(s.shape, np.float32) for s in shapes])
        assert out.shape == shapes[-1].shape if fn is not model.panel_trsm else True

"""Round-trip the elastic-service C ABI through the ctypes bindings:
create / register / mixed bulk+deadline load / per-call opts / batched
submit / grow-rebalance-shrink / stats / retire / free.

Requires the cdylib (`cargo build --release --features ffi`); the whole
module skips cleanly when it is absent, so the pure-Python kernel tests
stay runnable without a Rust toolchain.
"""

import threading

import pytest

import hylu

LIB = hylu.find_library()
pytestmark = pytest.mark.skipif(
    LIB is None,
    reason="libhylu cdylib not found (cargo build --release --features ffi, or set HYLU_LIB)",
)


def tridiag(n, shift=0.0):
    """0-based CSR of a diagonally dominant tridiagonal system: the
    solver cannot perturb pivots on it, so solutions are exact to
    refinement accuracy and easy to check."""
    ap, ai, ax = [0], [], []
    for i in range(n):
        if i > 0:
            ai.append(i - 1)
            ax.append(-1.0)
        ai.append(i)
        ax.append(4.0 + shift + 0.01 * i)
        if i < n - 1:
            ai.append(i + 1)
            ax.append(-1.0)
        ap.append(len(ai))
    return n, ap, ai, ax


def spmv(csr, x):
    n, ap, ai, ax = csr
    y = [0.0] * n
    for i in range(n):
        y[i] = sum(ax[k] * x[ai[k]] for k in range(ap[i], ap[i + 1]))
    return y


def residual_inf(csr, x, b):
    ax = spmv(csr, x)
    return max(abs(ax[i] - b[i]) for i in range(csr[0]))


@pytest.fixture
def svc():
    with hylu.Service(shards=2, threads=1) as s:
        yield s


def test_register_solve_retire_roundtrip(svc):
    a = tridiag(40)
    sid = svc.register(*a)
    b = spmv(a, [1.0] * 40)
    x = svc.solve(sid, b)
    assert residual_inf(a, x, b) < 1e-10
    assert svc.health(sid) == hylu.HEALTH_OK
    svc.retire(sid)
    assert svc.health(sid) is None
    with pytest.raises(hylu.HyluError) as e:
        svc.solve(sid, b)
    assert e.value.code == hylu.HYLU_ERR_INVALID


def test_mixed_bulk_and_deadline_load(svc):
    """Concurrent bulk writers + deadline calls from the driving thread:
    every lane resolves with the right answer and the deadline lane is
    visible in the stats."""
    a = tridiag(60)
    sid = svc.register(*a)
    b = spmv(a, [1.0] * 60)

    errs = []

    def bulk(reps):
        # each worker gets its own Service *calls* serialized by the
        # GIL around ctypes entry; the underlying service is concurrent
        try:
            for _ in range(reps):
                x = svc.solve(sid, b)
                assert residual_inf(a, x, b) < 1e-10
        except Exception as e:  # pragma: no cover - failure reporting
            errs.append(e)

    workers = [threading.Thread(target=bulk, args=(8,)) for _ in range(3)]
    for t in workers:
        t.start()
    for _ in range(8):
        x = svc.solve_deadline(sid, b, deadline_us=2_000_000)
        assert residual_inf(a, x, b) < 1e-10
    for t in workers:
        t.join()
    assert not errs
    st = svc.stats()
    assert st["requests"] >= 32
    assert st["deadline_requests"] >= 8
    assert st["rhs_solved"] >= 32
    assert st["dispatches"] >= 1


def test_solve_opts_and_batched_submit(svc):
    a = tridiag(50)
    sid = svc.register(*a)
    b = spmv(a, [2.0] * 50)
    # raw substitution (refinement off) still nails a well-conditioned
    # system; the default-opts path must agree bitwise with plain solve
    raw = svc.solve_opts(sid, b, hylu.SolveOpts(refine_max_iter=0))
    assert residual_inf(a, raw, b) < 1e-9
    assert svc.solve_opts(sid, b, hylu.SolveOpts()) == svc.solve(sid, b)
    bs = [spmv(a, [float(q + 1)] * 50) for q in range(6)]
    xs = svc.solve_many(sid, bs)
    for q, (bq, xq) in enumerate(zip(bs, xs)):
        assert residual_inf(a, xq, bq) < 1e-9, f"column {q}"
    bad = hylu.SolveOpts(precision=7)
    with pytest.raises(hylu.HyluError) as e:
        svc.solve_opts(sid, b, bad)
    assert e.value.code == hylu.HYLU_ERR_INVALID


def test_grow_rebalance_shrink_under_answers(svc):
    """The elastic shard set through the ABI: results stay correct across
    grow + rebalance + shrink, and the shard count tracks."""
    systems = [tridiag(30, shift=s) for s in (0.0, 0.5, 1.0, 1.5)]
    sids = [svc.register(*a) for a in systems]
    rhss = [spmv(a, [1.0] * 30) for a in systems]
    assert svc.shards() == 2
    assert svc.grow(2) == 4
    assert svc.shards() == 4
    svc.rebalance()
    for a, sid, b in zip(systems, sids, rhss):
        assert residual_inf(a, svc.solve(sid, b), b) < 1e-10
    assert svc.shrink(3) == 1
    assert svc.shards() == 1
    # every system survived the drain and still answers correctly
    for a, sid, b in zip(systems, sids, rhss):
        assert svc.health(sid) == hylu.HEALTH_OK
        assert residual_inf(a, svc.solve(sid, b), b) < 1e-10
    with pytest.raises(hylu.HyluError):
        svc.shrink(1)  # the last shard must remain
    st = svc.stats()
    assert st["registers"] == 4
    # stats from the drained shards were folded in, not lost
    assert st["requests"] >= 8


def test_handle_lifecycle_still_works():
    """The one-system handle rides the same cdylib; exercise it so the
    bindings cover both front doors."""
    a = tridiag(25)
    with hylu.Handle(threads=1, repeated=True) as h:
        h.analyze(*a)
        h.factorize()
        assert (h.n, h.nnz) == (25, a[1][25])
        b = spmv(a, [3.0] * 25)
        x = h.solve(b)
        assert residual_inf(a, x, b) < 1e-10
        # same pattern, new values — the repeated-solve fast path
        n, ap, ai, ax = a
        bumped = (n, ap, ai, [v * 2.0 for v in ax])
        h.refactorize(bumped[3])
        x2 = h.solve(spmv(bumped, [1.0] * 25))
        assert residual_inf(bumped, x2, spmv(bumped, [1.0] * 25)) < 1e-10

"""f64 artifact variants: dtype coverage and numerical agreement with the
f64 numpy reference (these are the artifacts the Rust hot path executes)."""

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.kernels import gemm_update as gk  # noqa: E402
from compile.kernels import trsm as tk  # noqa: E402


def test_jit_variants_include_f64():
    names = [n for n, _, _ in model.jit_variants()]
    for s in (16, 32, 64, 128):
        assert f"gemm_update_f64_{s}" in names
        assert f"trsm_f64_{s}" in names
    # f64 shapes really are f64
    for name, _, shapes in model.jit_variants():
        if "_f64_" in name:
            assert all(str(s.dtype) == "float64" for s in shapes), name


def test_gemm_f64_matches_numpy_to_double_precision():
    rng = np.random.default_rng(1)
    m, k, n = 32, 32, 64
    c = rng.standard_normal((m, n))
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n))
    out = np.asarray(gk.gemm_update(c, a, b))
    assert out.dtype == np.float64
    np.testing.assert_allclose(out, c - a @ b, rtol=1e-13, atol=1e-13)


def test_trsm_f64_roundtrip_double_precision():
    rng = np.random.default_rng(2)
    w, n = 64, 96
    l = np.tril(rng.standard_normal((w, w)), -1) / w
    lw = l + np.eye(w)
    b = rng.standard_normal((w, n))
    x = np.asarray(tk.trsm_unit_lower(l, b))
    assert x.dtype == np.float64
    np.testing.assert_allclose(lw @ x, b, rtol=1e-12, atol=1e-12)

import os
import sys

# make the python/ tree importable (`import hylu`, `import compile.*`)
# no matter which directory pytest is invoked from
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

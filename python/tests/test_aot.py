"""AOT artifact generation: manifest coverage + HLO text sanity."""

import os

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_all_writes_manifest(tmp_path):
    entries = aot.lower_all(str(tmp_path))
    assert len(entries) == len(model.jit_variants())
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == len(entries)
    for line in manifest:
        name, fname, sig = line.split("\t")
        text = (tmp_path / fname).read_text()
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text
        # one shape entry per argument
        assert all(":" in part for part in sig.split(";"))


def test_existing_artifacts_are_hlo_text():
    if not os.path.exists(os.path.join(ART, "manifest.txt")):
        import pytest

        pytest.skip("make artifacts not run yet")
    with open(os.path.join(ART, "manifest.txt")) as f:
        for line in f:
            _, fname, _ = line.strip().split("\t")
            with open(os.path.join(ART, fname)) as g:
                head = g.read(64)
            assert head.startswith("HloModule")

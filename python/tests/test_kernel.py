"""L1 Pallas kernels vs the pure-jnp oracle (ref.py) — the CORE correctness
signal for the compile path.

Hypothesis sweeps shapes and seeds; every case asserts allclose against the
reference. Tolerances are tight because both paths compute in f32 with the
same contraction widths.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm_update as gk
from compile.kernels import ref
from compile.kernels import trsm as tk

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16, 24, 32, 48, 64, 96, 128])
SMALL_W = st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128])


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def rand_lower(rng, w):
    """Strictly-lower factor with bounded growth: real HYLU L blocks have
    |l_ij| <= 1 (supernode diagonal pivoting) and MC64 scaling keeps the
    solve well-conditioned; unscaled N(0,1) triangles grow ~2^w and make
    f32 comparison meaningless at w=128."""
    return np.tril(rand(rng, w, w), -1) / max(w, 1)


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_gemm_update_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    c, a, b = rand(rng, m, n), rand(rng, m, k), rand(rng, k, n)
    got = np.asarray(gk.gemm_update(c, a, b))
    want = np.asarray(ref.gemm_update(c, a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(w=SMALL_W, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_trsm_matches_ref(w, n, seed):
    rng = np.random.default_rng(seed)
    l, b = rand_lower(rng, w), rand(rng, w, n)
    got = np.asarray(tk.trsm_unit_lower(l, b))
    want = np.asarray(ref.trsm_unit_lower(l, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_update_zero_a_is_identity():
    rng = np.random.default_rng(7)
    c = rand(rng, 32, 64)
    a = np.zeros((32, 16), np.float32)
    b = rand(rng, 16, 64)
    np.testing.assert_array_equal(np.asarray(gk.gemm_update(c, a, b)), c)


def test_trsm_identity_lower_returns_b():
    rng = np.random.default_rng(8)
    b = rand(rng, 16, 32)
    l = np.zeros((16, 16), np.float32)  # strictly-lower part zero => L = I
    np.testing.assert_allclose(
        np.asarray(tk.trsm_unit_lower(l, b)), b, rtol=1e-6, atol=1e-6
    )


def test_trsm_ignores_upper_triangle_junk():
    rng = np.random.default_rng(9)
    l = rand(rng, 32, 32)
    b = rand(rng, 32, 32)
    junk = l + np.triu(100.0 * np.ones((32, 32), np.float32))
    got = np.asarray(tk.trsm_unit_lower(junk, b))
    want = np.asarray(tk.trsm_unit_lower(np.tril(l, -1), b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_trsm_roundtrip_against_matmul():
    """L @ X == B up to f32 roundoff, the defining property."""
    rng = np.random.default_rng(10)
    w, n = 64, 96
    l = rand_lower(rng, w)
    lw = l + np.eye(w, dtype=np.float32)
    b = rand(rng, w, n)
    x = np.asarray(tk.trsm_unit_lower(l, b))
    np.testing.assert_allclose(lw @ x, b, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("m,k,n", [(128, 128, 256), (64, 64, 128)])
def test_gemm_update_tile_classes(m, k, n):
    """The exact shapes the AOT artifacts are lowered at."""
    rng = np.random.default_rng(11)
    c, a, b = rand(rng, m, n), rand(rng, m, k), rand(rng, k, n)
    got = np.asarray(gk.gemm_update(c, a, b))
    want = np.asarray(c - a @ b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

"""Layer-1 Pallas kernel: tiled supernode GEMM update ``C - A @ B``.

This is HYLU's sup-sup numeric kernel hot spot. On a real TPU the BlockSpec
below expresses the HBM->VMEM schedule: (bm, bk) x (bk, bn) tiles stream
through VMEM while the (bm, bn) f32 output tile doubles as the accumulator,
and the (m//bm, n//bn, k//bk) grid walks k innermost so the accumulator is
reused across the whole contraction — the MXU-shaped analogue of MKL's cache
blocking in the paper (see DESIGN.md §Hardware-Adaptation).

CPU note: lowered with interpret=True (Mosaic custom-calls cannot run on the
CPU PJRT plugin); numerics are identical, performance is validated
analytically in DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, a_ref, b_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; grid axis 2 runs the k contraction."""
    ki = pl.program_id(2)
    dt = o_ref.dtype

    @pl.when(ki == 0)
    def _init():
        # Seed the accumulator with the incoming panel tile so the subtract
        # fuses into the accumulation (no separate epilogue pass over C).
        o_ref[...] = c_ref[...].astype(dt)

    o_ref[...] -= (a_ref[...].astype(dt) @ b_ref[...].astype(dt)).astype(dt)


def _pick_block(dim: int, cap: int = 128) -> int:
    """Largest power-of-two tile <= cap that divides ``dim``."""
    b = min(dim, cap)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gemm_update(c, a, b, *, interpret: bool = True):
    """Pallas tiled ``C - A @ B`` (f32), HYLU's sup-sup update.

    Shapes: c (m, n), a (m, k), b (k, n). Dims need a power-of-two tile
    divisor; the AOT tile classes are powers of two, so this always holds on
    the artifact path.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n), (c.shape, a.shape, b.shape)
    dt = jnp.result_type(c)
    if dt not in (jnp.float32, jnp.float64):
        dt = jnp.float32
    bm, bk, bn = _pick_block(m), _pick_block(k), _pick_block(n)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),  # C tile
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),  # A tile
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),  # B tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), dt),
        interpret=interpret,
    )(c, a, b)

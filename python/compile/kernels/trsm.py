"""Layer-1 Pallas kernel: unit-lower triangular panel solve ``L X = B``.

HYLU's supernode *internal factorization* applies this to the panel rows
below the diagonal block: once the diagonal block's L factor is known, the
remaining panel columns solve ``L_diag @ X = B``. The kernel keeps the whole
(w, w) triangle and a (w, bn) panel tile resident in VMEM (w <= 128, so the
triangle is at most 64 KiB — trivially VMEM-resident) and substitutes row by
row with a sequential fori_loop; the grid parallelizes over panel column
tiles, which are independent.

Only the strictly-lower part of ``l`` is read; the diagonal is implicitly 1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(l_ref, b_ref, o_ref, *, w: int):
    dt = o_ref.dtype
    l = l_ref[...].astype(dt)
    b = b_ref[...].astype(dt)
    # Mask to strictly-lower: rows >= i of x are still zero when row i is
    # computed, but masking makes the kernel robust to junk in the upper
    # triangle (the rust side passes the packed panel unmasked).
    tri = jnp.tril(jnp.ones((w, w), dt), k=-1)
    lm = l * tri

    def body(i, x):
        row = b[i, :] - lm[i, :] @ x
        return x.at[i, :].set(row)

    o_ref[...] = jax.lax.fori_loop(0, w, body, jnp.zeros_like(b))


def _pick_block(dim: int, cap: int = 256) -> int:
    b = min(dim, cap)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def trsm_unit_lower(l, b, *, interpret: bool = True):
    """Pallas unit-lower TRSM ``X = L^{-1} B``.

    Shapes: l (w, w) with w <= 128, b (w, n).
    """
    w, w2 = l.shape
    wb, n = b.shape
    assert w == w2 == wb, (l.shape, b.shape)
    dt = jnp.result_type(b)
    if dt not in (jnp.float32, jnp.float64):
        dt = jnp.float32
    bn = _pick_block(n)
    return pl.pallas_call(
        functools.partial(_kernel, w=w),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((w, w), lambda j: (0, 0)),
            pl.BlockSpec((w, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((w, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((w, n), dt),
        interpret=interpret,
    )(l, b)

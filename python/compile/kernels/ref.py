"""Pure-jnp reference oracle for the Pallas kernels.

These are the ground-truth semantics the Pallas kernels in gemm_update.py and
trsm.py must match (f32, compared with tight tolerances by pytest/hypothesis).

The dense hot spot of HYLU's sup-sup kernel is:

    panel <- panel - L_block @ U_block          (supernode x supernode update)
    X solves  L_diag @ X = panel_rows           (internal panel solve, TRSM)

with L_diag unit-lower-triangular (HYLU stores an implicit unit diagonal).
"""

from __future__ import annotations

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def gemm_update(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Reference supernode update: ``C - A @ B`` in f32.

    Shapes: c (m, n), a (m, k), b (k, n).
    """
    return (c - a @ b).astype(jnp.float32)


def trsm_unit_lower(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Reference unit-lower triangular solve ``L X = B``.

    Only the strictly-lower part of ``l`` is read; the diagonal is implicitly
    one (HYLU convention: L carries an implicit unit diagonal).
    Shapes: l (w, w), b (w, n).
    """
    lw = jnp.tril(l, k=-1) + jnp.eye(l.shape[0], dtype=l.dtype)
    return jsl.solve_triangular(lw, b, lower=True, unit_diagonal=True).astype(
        jnp.float32
    )


def fused_update_trsm(
    l_diag: jnp.ndarray, c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Reference fused supernode step: ``trsm(L_diag, C - A @ B)``."""
    return trsm_unit_lower(l_diag, gemm_update(c, a, b))

"""Layer-2 JAX model: HYLU's dense supernode-step compute graph.

The paper's numeric hot spot is the sup-sup kernel: a target supernode panel
is updated by every source supernode (GEMM) and then internally factorized
(TRSM against the diagonal block's unit-lower factor). This module expresses
those steps as jitted JAX functions *calling the Layer-1 Pallas kernels*, so
that one `jax.jit(...).lower()` in aot.py bakes kernel + glue into a single
HLO module per tile class.

Exported graphs (all f32):

- ``supernode_update(c, a, b)``      -> ``C - A @ B``            (Pallas GEMM)
- ``panel_trsm(l, b)``               -> ``L^{-1} B``             (Pallas TRSM)
- ``fused_update_trsm(l, c, a, b)``  -> ``L^{-1} (C - A @ B)``   (both; lets
  XLA fuse the update epilogue into the solve prologue — no HBM round-trip
  for the intermediate panel)

Python runs only at build time; the Rust runtime executes the lowered HLO.
"""

from __future__ import annotations

import jax

from .kernels import gemm_update as _gemm
from .kernels import trsm as _trsm


def supernode_update(c, a, b):
    """Sup-sup update of a target panel: ``C - A @ B``.

    c: (m, n) target panel rows (columns = target supernode's U pattern)
    a: (m, k) dense L block (target rows x source supernode columns)
    b: (k, n) dense U block (source supernode rows x target pattern)
    """
    return _gemm.gemm_update(c, a, b)


def panel_trsm(l, b):
    """Internal panel solve ``X = L^{-1} B`` with implicit unit diagonal."""
    return _trsm.trsm_unit_lower(l, b)


def fused_update_trsm(l, c, a, b):
    """One full supernode step: update then internal solve, fused by XLA."""
    return _trsm.trsm_unit_lower(l, _gemm.gemm_update(c, a, b))


def jit_variants():
    """The (name, fn, example-shape tuple) table aot.py lowers.

    Tile classes are powers of two; the Rust side pads supernode blocks to
    the nearest class (DESIGN.md §Hardware-Adaptation). Two dtype families:
    ``f32`` variants are the TPU/MXU-shaped story; ``f64`` variants are what
    the Rust runtime executes on its hot path (the solver is double
    precision, like the paper's).
    """

    def gemm_shapes(s, dt):
        m = k = s
        n = 2 * s  # panels are wider than they are tall in practice
        return (
            jax.ShapeDtypeStruct((m, n), dt),
            jax.ShapeDtypeStruct((m, k), dt),
            jax.ShapeDtypeStruct((k, n), dt),
        )

    def trsm_shapes(s, dt):
        return (
            jax.ShapeDtypeStruct((s, s), dt),
            jax.ShapeDtypeStruct((s, 2 * s), dt),
        )

    def fused_shapes(s, dt):
        return (
            jax.ShapeDtypeStruct((s, s), dt),
            jax.ShapeDtypeStruct((s, 2 * s), dt),
            jax.ShapeDtypeStruct((s, s), dt),
            jax.ShapeDtypeStruct((s, 2 * s), dt),
        )

    sizes = (16, 32, 64, 128)
    table = []
    for s in sizes:
        f32 = jax.numpy.float32
        table.append((f"gemm_update_{s}", supernode_update, gemm_shapes(s, f32)))
        table.append((f"trsm_{s}", panel_trsm, trsm_shapes(s, f32)))
        table.append((f"fused_{s}", fused_update_trsm, fused_shapes(s, f32)))
        f64 = jax.numpy.float64
        table.append((f"gemm_update_f64_{s}", supernode_update, gemm_shapes(s, f64)))
        table.append((f"trsm_f64_{s}", panel_trsm, trsm_shapes(s, f64)))
    return table

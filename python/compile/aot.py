"""AOT lowering: JAX/Pallas supernode kernels -> HLO *text* artifacts.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and NOT
a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the rust `xla` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the HLO *text* parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/): ``python -m compile.aot --out-dir ../artifacts``
Produces one ``<name>.hlo.txt`` per tile class listed by
``model.jit_variants()`` plus a ``manifest.txt`` the Rust runtime reads.

Python runs ONCE at build time; the artifacts are self-contained.
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # f64 artifact variants

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> list:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for name, fn, shapes in model.jit_variants():
        lowered = jax.jit(fn).lower(*shapes)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        arg_sig = ";".join(
            f"{'x'.join(str(d) for d in s.shape)}:{s.dtype}" for s in shapes
        )
        entries.append((name, f"{name}.hlo.txt", arg_sig))
        print(f"  {name}: {len(text)} chars, args [{arg_sig}]")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for name, fname, sig in entries:
            f.write(f"{name}\t{fname}\t{sig}\n")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias")
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    entries = lower_all(out_dir or ".")
    # Legacy alias: Makefile's sentinel file.
    if args.out:
        with open(args.out, "w") as f:
            f.write("".join(f"{n}\n" for n, _, _ in entries))
    print(f"wrote {len(entries)} HLO artifacts to {out_dir}")


if __name__ == "__main__":
    main()

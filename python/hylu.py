"""ctypes bindings over the hylu C ABI (the `libhylu` cdylib built with
`cargo build --release --features ffi`).

Two front doors, mirroring `include/hylu.h`:

- `Handle`: the one-system Analyze/Factorize/ReFactorize/Solve lifecycle.
- `Service`: the sharded, coalescing, *elastic* solve service — register
  CSR systems on a live service, solve on the bulk or deadline lane with
  optional per-call refinement overrides, batch-submit many right-hand
  sides, grow/shrink the shard set under traffic, and read the aggregate
  serving counters.

The bindings are dependency-free (pure ctypes; plain Python sequences in
and lists out). The shared library is located from, in order: an
explicit `path=` argument, the `HYLU_LIB` environment variable, the
crate's own `target/release/` next to this file, and the system loader.

    import hylu
    svc = hylu.Service(shards=2, threads=1)
    sid = svc.register(n, ap, ai, ax)              # 0-based CSR
    x = svc.solve(sid, b)                          # bulk lane
    x = svc.solve_deadline(sid, b, deadline_us=5000)
    xs = svc.solve_many(sid, [b0, b1, b2])         # one coalesced batch
    svc.grow(2); svc.rebalance(); svc.shrink(1)    # elastic shard set
    print(svc.stats()["requests"], svc.stats()["max_tick_us"])
    svc.close()
"""

import ctypes
import ctypes.util
import os

HYLU_OK = 0
HYLU_ERR_PANIC = 1
HYLU_ERR_INVALID = 2
HYLU_ERR_IO = 3
HYLU_ERR_SINGULAR = 4
HYLU_ERR_ZERO_PIVOT = 5
HYLU_ERR_RUNTIME = 6
HYLU_ERR_SHARD_PANICKED = 7
HYLU_ERR_DEADLINE_EXPIRED = 8
HYLU_ERR_QUARANTINED = 9

HEALTH_OK = 0
HEALTH_ZERO_PIVOT = 1
HEALTH_SINGULAR = 2
HEALTH_PIVOT_GROWTH = 3
HEALTH_PANIC = 4

PRECISION_DEFAULT = 0
PRECISION_F64 = 1
PRECISION_MIXED = 2


class HyluError(RuntimeError):
    """A non-zero status from the C ABI, carrying the stable code and the
    handle's last-error message."""

    def __init__(self, code, message=""):
        self.code = code
        super().__init__(f"hylu error {code}: {message}" if message else f"hylu error {code}")


class SolveOpts(ctypes.Structure):
    """Per-call refinement overrides (`hylu_solve_opts` in hylu.h).
    Negative numeric knobs and precision 0 mean "use the configured
    default"."""

    _fields_ = [
        ("refine_max_iter", ctypes.c_int64),
        ("refine_tol", ctypes.c_double),
        ("refine_target", ctypes.c_double),
        ("precision", ctypes.c_int32),
    ]

    def __init__(self, refine_max_iter=-1, refine_tol=-1.0, refine_target=-1.0,
                 precision=PRECISION_DEFAULT):
        super().__init__(refine_max_iter, refine_tol, refine_target, precision)


class ServiceStats(ctypes.Structure):
    """Aggregate serving counters (`hylu_service_stats_t` in hylu.h)."""

    _fields_ = [
        ("requests", ctypes.c_uint64),
        ("deadline_requests", ctypes.c_uint64),
        ("dispatches", ctypes.c_uint64),
        ("rhs_solved", ctypes.c_uint64),
        ("refactors", ctypes.c_uint64),
        ("reanalyzes", ctypes.c_uint64),
        ("forwarded", ctypes.c_uint64),
        ("refine_iters", ctypes.c_uint64),
        ("registers", ctypes.c_uint64),
        ("retires", ctypes.c_uint64),
        ("moves", ctypes.c_uint64),
        ("panics_caught", ctypes.c_uint64),
        ("quarantines", ctypes.c_uint64),
        ("recoveries", ctypes.c_uint64),
        ("expired", ctypes.c_uint64),
        ("shed", ctypes.c_uint64),
        ("max_batch", ctypes.c_uint64),
        ("mean_batch", ctypes.c_double),
        ("max_tick_us", ctypes.c_uint64),
    ]

    def as_dict(self):
        return {name: getattr(self, name) for name, _ in self._fields_}


def find_library():
    """Locate the hylu cdylib without loading it; None when absent."""
    env = os.environ.get("HYLU_LIB")
    if env:
        return env if os.path.exists(env) else None
    here = os.path.dirname(os.path.abspath(__file__))
    for ext in (".so", ".dylib"):
        cand = os.path.join(here, os.pardir, "target", "release", "libhylu" + ext)
        if os.path.exists(cand):
            return os.path.normpath(cand)
    return ctypes.util.find_library("hylu")


_I64P = ctypes.POINTER(ctypes.c_int64)
_F64P = ctypes.POINTER(ctypes.c_double)


def _declare(lib):
    """Pin argtypes/restypes for every entry point this module calls."""
    h, s = ctypes.c_void_p, ctypes.c_void_p
    decls = {
        "hylu_create": ([ctypes.c_int64, ctypes.c_int32, ctypes.POINTER(h)], ctypes.c_int32),
        "hylu_analyze": ([h, ctypes.c_int64, _I64P, _I64P, _F64P], ctypes.c_int32),
        "hylu_factorize": ([h], ctypes.c_int32),
        "hylu_refactorize": ([h, _F64P], ctypes.c_int32),
        "hylu_reanalyze": ([h, ctypes.c_int64, _I64P, _I64P, _F64P], ctypes.c_int32),
        "hylu_solve": ([h, _F64P, _F64P], ctypes.c_int32),
        "hylu_solve_many": ([h, ctypes.c_int64, _F64P, _F64P], ctypes.c_int32),
        "hylu_n": ([h], ctypes.c_int64),
        "hylu_nnz": ([h], ctypes.c_int64),
        "hylu_last_error": ([h], ctypes.c_char_p),
        "hylu_free": ([h], None),
        "hylu_service_create": ([ctypes.c_int64, ctypes.c_int64, ctypes.POINTER(s)], ctypes.c_int32),
        "hylu_service_register": ([s, ctypes.c_int64, _I64P, _I64P, _F64P,
                                   ctypes.POINTER(ctypes.c_uint64)], ctypes.c_int32),
        "hylu_service_retire": ([s, ctypes.c_uint64], ctypes.c_int32),
        "hylu_service_solve": ([s, ctypes.c_uint64, _F64P, _F64P], ctypes.c_int32),
        "hylu_service_solve_deadline": ([s, ctypes.c_uint64, _F64P, _F64P, ctypes.c_uint64],
                                        ctypes.c_int32),
        "hylu_service_solve_opts": ([s, ctypes.c_uint64, _F64P, _F64P,
                                     ctypes.POINTER(SolveOpts)], ctypes.c_int32),
        "hylu_service_solve_many": ([s, ctypes.c_uint64, ctypes.c_int64, _F64P, _F64P],
                                    ctypes.c_int32),
        "hylu_service_rebalance": ([s, _I64P], ctypes.c_int32),
        "hylu_service_grow": ([s, ctypes.c_int64, _I64P], ctypes.c_int32),
        "hylu_service_shrink": ([s, ctypes.c_int64, _I64P], ctypes.c_int32),
        "hylu_service_shards": ([s], ctypes.c_int64),
        "hylu_service_stats": ([s, ctypes.POINTER(ServiceStats)], ctypes.c_int32),
        "hylu_service_health": ([s, ctypes.c_uint64], ctypes.c_int32),
        "hylu_service_last_error": ([s], ctypes.c_char_p),
        "hylu_service_free": ([s], None),
    }
    for name, (argtypes, restype) in decls.items():
        fn = getattr(lib, name)
        fn.argtypes = argtypes
        fn.restype = restype
    return lib


_LIB = None


def load(path=None):
    """Load (and memoize) the hylu cdylib."""
    global _LIB
    if path is None and _LIB is not None:
        return _LIB
    libpath = path or find_library()
    if not libpath:
        raise OSError(
            "libhylu not found: build with `cargo build --release --features ffi` "
            "or point HYLU_LIB at the cdylib"
        )
    lib = _declare(ctypes.CDLL(libpath))
    if path is None:
        _LIB = lib
    return lib


def _f64_array(values):
    return (ctypes.c_double * len(values))(*values)


def _i64_array(values):
    return (ctypes.c_int64 * len(values))(*values)


class _Csr:
    """Validated-enough CSR triple marshalled to ctypes arrays (the Rust
    side re-validates thoroughly)."""

    def __init__(self, n, ap, ai, ax):
        if len(ap) != n + 1:
            raise ValueError(f"ap must have n+1 = {n + 1} entries, got {len(ap)}")
        if len(ai) != ap[n] or len(ax) != ap[n]:
            raise ValueError(f"ai/ax must have ap[n] = {ap[n]} entries")
        self.n = n
        self.ap = _i64_array(ap)
        self.ai = _i64_array(ai)
        self.ax = _f64_array(ax)


class Handle:
    """The one-system lifecycle handle (`hylu_handle`)."""

    def __init__(self, threads=0, repeated=True, lib=None, path=None):
        self._lib = lib or load(path)
        self._h = ctypes.c_void_p()
        code = self._lib.hylu_create(threads, 1 if repeated else 0, ctypes.byref(self._h))
        if code != HYLU_OK:
            raise HyluError(code)

    def _check(self, code):
        if code != HYLU_OK:
            raise HyluError(code, self._lib.hylu_last_error(self._h).decode())

    def analyze(self, n, ap, ai, ax):
        a = _Csr(n, ap, ai, ax)
        self._check(self._lib.hylu_analyze(self._h, a.n, a.ap, a.ai, a.ax))

    def factorize(self):
        self._check(self._lib.hylu_factorize(self._h))

    def refactorize(self, ax):
        self._check(self._lib.hylu_refactorize(self._h, _f64_array(ax)))

    def reanalyze(self, n, ap, ai, ax):
        a = _Csr(n, ap, ai, ax)
        self._check(self._lib.hylu_reanalyze(self._h, a.n, a.ap, a.ai, a.ax))

    def solve(self, b):
        n = self._lib.hylu_n(self._h)
        x = (ctypes.c_double * n)()
        self._check(self._lib.hylu_solve(self._h, _f64_array(b), x))
        return list(x)

    @property
    def n(self):
        return self._lib.hylu_n(self._h)

    @property
    def nnz(self):
        return self._lib.hylu_nnz(self._h)

    def close(self):
        if self._h:
            self._lib.hylu_free(self._h)
            self._h = ctypes.c_void_p()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class Service:
    """The elastic solve-service handle (`hylu_service`).

    Not thread-safe (the ABI contract): serialize calls per instance.
    The *service behind it* is concurrent — batched submission through
    `solve_many` still coalesces across its requests.
    """

    def __init__(self, shards=1, threads=0, lib=None, path=None):
        self._lib = lib or load(path)
        self._s = ctypes.c_void_p()
        self._dims = {}
        code = self._lib.hylu_service_create(shards, threads, ctypes.byref(self._s))
        if code != HYLU_OK:
            raise HyluError(code)

    def _check(self, code):
        if code != HYLU_OK:
            raise HyluError(code, self._lib.hylu_service_last_error(self._s).decode())

    def register(self, n, ap, ai, ax):
        """Analyze + factorize a 0-based CSR matrix and admit it on the
        live service; returns the routing id."""
        a = _Csr(n, ap, ai, ax)
        out = ctypes.c_uint64()
        self._check(self._lib.hylu_service_register(
            self._s, a.n, a.ap, a.ai, a.ax, ctypes.byref(out)))
        self._dims[out.value] = n
        return out.value

    def retire(self, sid):
        self._check(self._lib.hylu_service_retire(self._s, sid))
        self._dims.pop(sid, None)

    def _dim(self, sid):
        try:
            return self._dims[sid]
        except KeyError:
            raise HyluError(HYLU_ERR_INVALID, f"unknown system id {sid}") from None

    def solve(self, sid, b):
        """Blocking solve on the bulk lane; returns the solution list."""
        x = (ctypes.c_double * self._dim(sid))()
        self._check(self._lib.hylu_service_solve(self._s, sid, _f64_array(b), x))
        return list(x)

    def solve_deadline(self, sid, b, deadline_us):
        """Blocking solve on the deadline lane; `deadline_us` is relative
        to now. May raise `HyluError` with `HYLU_ERR_DEADLINE_EXPIRED`
        when the service expires deadlines."""
        x = (ctypes.c_double * self._dim(sid))()
        self._check(self._lib.hylu_service_solve_deadline(
            self._s, sid, _f64_array(b), x, deadline_us))
        return list(x)

    def solve_opts(self, sid, b, opts):
        """Blocking solve with per-call `SolveOpts` overrides."""
        x = (ctypes.c_double * self._dim(sid))()
        self._check(self._lib.hylu_service_solve_opts(
            self._s, sid, _f64_array(b), x, ctypes.byref(opts)))
        return list(x)

    def solve_many(self, sid, bs):
        """Submit every right-hand side before waiting on any, so the
        batch coalesces into wide block dispatches; returns one solution
        list per input."""
        n = self._dim(sid)
        k = len(bs)
        flat = (ctypes.c_double * (n * k))()
        for q, b in enumerate(bs):
            flat[q * n:(q + 1) * n] = list(b)
        x = (ctypes.c_double * (n * k))()
        self._check(self._lib.hylu_service_solve_many(self._s, sid, k, flat, x))
        return [list(x[q * n:(q + 1) * n]) for q in range(k)]

    def rebalance(self):
        moved = ctypes.c_int64()
        self._check(self._lib.hylu_service_rebalance(self._s, ctypes.byref(moved)))
        return moved.value

    def grow(self, k):
        """Add `k` dispatcher shards on the live service; returns the new
        shard count."""
        out = ctypes.c_int64()
        self._check(self._lib.hylu_service_grow(self._s, k, ctypes.byref(out)))
        return out.value

    def shrink(self, k):
        """Drain and remove `k` dispatcher shards (at least one must
        remain); returns the new shard count."""
        out = ctypes.c_int64()
        self._check(self._lib.hylu_service_shrink(self._s, k, ctypes.byref(out)))
        return out.value

    def shards(self):
        return self._lib.hylu_service_shards(self._s)

    def health(self, sid):
        """HEALTH_* code for a registered system, or None for unknown."""
        h = self._lib.hylu_service_health(self._s, sid)
        return None if h < 0 else h

    def stats(self):
        """Aggregate serving counters as a dict (see `ServiceStats`)."""
        st = ServiceStats()
        self._check(self._lib.hylu_service_stats(self._s, ctypes.byref(st)))
        return st.as_dict()

    def close(self):
        if self._s:
            self._lib.hylu_service_free(self._s)
            self._s = ctypes.c_void_p()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

#!/usr/bin/env python3
"""Compare a gauntlet BENCH artifact against the committed kernel A/B baseline.

Usage:
    bench_diff.py BASELINE.json NEW.json [--tolerance 0.15]
    bench_diff.py --update BASELINE.json NEW.json    # rewrite baseline from NEW

`hylu gauntlet` writes a `kernel_ab` array of {name, t_default,
t_variant, ratio} rows, where ratio = t_default / t_variant is the
acceptance ratio of an enumerated kernel variant over the tier-default
kernel (>1 means the variant wins and the autotuner would accept it).
This script fails loudly (exit 1) when any variant's ratio regresses by
more than --tolerance (default 15%) against the committed baseline, so a
kernel-dispatch or packing regression can't slip through a green build.

Row names embed the dispatch tier the run happened to select ("gemm
8x16k4 vs native"); tiers differ across runners, so names are normalized
("vs <tier>", "(<tier>)") before matching. Rows present in only one file
are reported but never fail the diff — a new variant space needs a
deliberate --update, not a broken gate.

Stdlib only: CI runners need nothing beyond python3.
"""

import argparse
import json
import re
import sys

TIER = re.compile(r"\b(scalar|portable|native|avx512)\b")


def norm(name):
    """Tier-agnostic row key: the tier is a runner property, not a baseline."""
    return TIER.sub("<tier>", name)


def load(path):
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for row in doc.get("kernel_ab", []):
        rows[norm(row["name"])] = float(row["ratio"])
    return doc, rows


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("baseline", help="committed baseline (ci/bench_baseline.json)")
    ap.add_argument("new", help="freshly generated BENCH_<date>.json artifact")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.15,
        help="allowed fractional ratio regression before failing (default 0.15)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite BASELINE from NEW instead of diffing",
    )
    args = ap.parse_args()

    new_doc, new_rows = load(args.new)
    if not new_rows:
        print(f"FAIL: {args.new} has no kernel_ab rows", file=sys.stderr)
        return 1

    if args.update:
        slim = {
            "schema": "hylu-bench-baseline-v1",
            "source_schema": new_doc.get("schema", "?"),
            "tolerance": args.tolerance,
            "kernel_ab": [
                {"name": k, "ratio": round(v, 4)} for k, v in sorted(new_rows.items())
            ],
        }
        with open(args.baseline, "w") as f:
            json.dump(slim, f, indent=2)
            f.write("\n")
        print(f"rewrote {args.baseline} from {args.new} ({len(new_rows)} kernel A/B rows)")
        return 0

    _, base_rows = load(args.baseline)
    if not base_rows:
        print(f"FAIL: {args.baseline} has no kernel_ab rows", file=sys.stderr)
        return 1

    failures = []
    checked = 0
    for name in sorted(base_rows):
        if name not in new_rows:
            print(f"MISSING   {name}: in baseline but not in new run")
            continue
        base, new = base_rows[name], new_rows[name]
        checked += 1
        floor = base * (1.0 - args.tolerance)
        if new < floor:
            failures.append((name, base, new))
            verdict = "REGRESSED"
        else:
            verdict = "ok"
        print(f"{verdict:9s} {name}: baseline {base:.3f} -> new {new:.3f} (floor {floor:.3f})")
    for name in sorted(set(new_rows) - set(base_rows)):
        print(f"NEW       {name}: ratio {new_rows[name]:.3f} (no baseline; --update to adopt)")

    if failures:
        print(
            f"\nFAIL: {len(failures)} of {checked} kernel A/B acceptance ratios "
            f"regressed by more than {args.tolerance:.0%}:",
            file=sys.stderr,
        )
        for name, base, new in failures:
            print(
                f"  {name}: {base:.3f} -> {new:.3f} ({new / base - 1.0:+.1%})",
                file=sys.stderr,
            )
        return 1
    print(f"\nOK: {checked} kernel A/B ratios within {args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Compare a gauntlet BENCH artifact against the committed kernel A/B baseline.

Usage:
    bench_diff.py BASELINE.json NEW.json [--tolerance 0.15]
    bench_diff.py --update BASELINE.json NEW.json    # rewrite baseline from NEW

`hylu gauntlet` writes a `kernel_ab` array of {name, t_default,
t_variant, ratio} rows, where ratio = t_default / t_variant is the
acceptance ratio of an enumerated kernel variant over the tier-default
kernel (>1 means the variant wins and the autotuner would accept it).
This script fails loudly (exit 1) when any variant's ratio regresses by
more than --tolerance (default 15%) against the committed baseline, so a
kernel-dispatch or packing regression can't slip through a green build.

Schema v4 artifacts additionally carry a `dynamic` array of per-matrix
timing trajectories over perturbed-pattern sequences: {name, class, n,
steps, t_cold, t_warm, t_delta (per-step arrays), delta_steps,
escalation}. The diff reports each matrix's cold/delta mean speedup and
its per-step delta trajectory against the baseline, failing when the
speedup regresses by more than --dynamic-tolerance (default 50%; pattern
re-analysis timings are far noisier than the kernel microbenchmarks).

Row names embed the dispatch tier the run happened to select ("gemm
8x16k4 vs native"); tiers differ across runners, so names are normalized
("vs <tier>", "(<tier>)") before matching. Rows present in only one file
— including every dynamic row when the baseline predates schema v4 —
are reported but never fail the diff: a new variant or section needs a
deliberate --update, not a broken gate.

Stdlib only: CI runners need nothing beyond python3.
"""

import argparse
import json
import re
import sys

TIER = re.compile(r"\b(scalar|portable|native|avx512)\b")


def norm(name):
    """Tier-agnostic row key: the tier is a runner property, not a baseline."""
    return TIER.sub("<tier>", name)


def mean(xs):
    return sum(xs) / len(xs) if xs else 0.0


def load(path):
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for row in doc.get("kernel_ab", []):
        rows[norm(row["name"])] = float(row["ratio"])
    dyn = {}
    for row in doc.get("dynamic", []):
        t_cold = [float(t) for t in row.get("t_cold", [])]
        t_delta = [float(t) for t in row.get("t_delta", [])]
        # pre-summarized baseline rows (slim --update output) carry the
        # speedup directly instead of raw trajectories
        if "speedup" in row:
            speedup = float(row["speedup"])
        elif t_delta and mean(t_delta) > 0.0:
            speedup = mean(t_cold) / mean(t_delta)
        else:
            speedup = 0.0
        dyn[row["name"]] = {"speedup": speedup, "t_delta": t_delta}
    return doc, rows, dyn


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("baseline", help="committed baseline (ci/bench_baseline.json)")
    ap.add_argument("new", help="freshly generated BENCH_<date>.json artifact")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.15,
        help="allowed fractional ratio regression before failing (default 0.15)",
    )
    ap.add_argument(
        "--dynamic-tolerance",
        type=float,
        default=0.5,
        help="allowed fractional cold/delta speedup regression per matrix "
        "(default 0.5; re-analysis timings are noisy)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite BASELINE from NEW instead of diffing",
    )
    args = ap.parse_args()

    new_doc, new_rows, new_dyn = load(args.new)
    if not new_rows:
        print(f"FAIL: {args.new} has no kernel_ab rows", file=sys.stderr)
        return 1

    if args.update:
        slim = {
            "schema": "hylu-bench-baseline-v1",
            "source_schema": new_doc.get("schema", "?"),
            "tolerance": args.tolerance,
            "kernel_ab": [
                {"name": k, "ratio": round(v, 4)} for k, v in sorted(new_rows.items())
            ],
        }
        if new_dyn:
            slim["dynamic"] = [
                {"name": k, "speedup": round(v["speedup"], 4)}
                for k, v in sorted(new_dyn.items())
            ]
        with open(args.baseline, "w") as f:
            json.dump(slim, f, indent=2)
            f.write("\n")
        print(
            f"rewrote {args.baseline} from {args.new} "
            f"({len(new_rows)} kernel A/B rows, {len(new_dyn)} dynamic rows)"
        )
        return 0

    _, base_rows, base_dyn = load(args.baseline)
    if not base_rows:
        print(f"FAIL: {args.baseline} has no kernel_ab rows", file=sys.stderr)
        return 1

    failures = []
    checked = 0
    for name in sorted(base_rows):
        if name not in new_rows:
            print(f"MISSING   {name}: in baseline but not in new run")
            continue
        base, new = base_rows[name], new_rows[name]
        checked += 1
        floor = base * (1.0 - args.tolerance)
        if new < floor:
            failures.append((name, base, new))
            verdict = "REGRESSED"
        else:
            verdict = "ok"
        print(f"{verdict:9s} {name}: baseline {base:.3f} -> new {new:.3f} (floor {floor:.3f})")
    for name in sorted(set(new_rows) - set(base_rows)):
        print(f"NEW       {name}: ratio {new_rows[name]:.3f} (no baseline; --update to adopt)")

    # dynamic per-matrix trajectories (schema v4): shared rows gate on the
    # cold/delta speedup; rows in only one file never fail (a v3-era
    # baseline has none, and stays green until a deliberate --update)
    dyn_failures = []
    dyn_checked = 0
    for name in sorted(base_dyn):
        if name not in new_dyn:
            print(f"MISSING   dynamic {name}: in baseline but not in new run")
            continue
        base, new = base_dyn[name]["speedup"], new_dyn[name]["speedup"]
        dyn_checked += 1
        floor = base * (1.0 - args.dynamic_tolerance)
        if new < floor:
            dyn_failures.append((name, base, new))
            verdict = "REGRESSED"
        else:
            verdict = "ok"
        traj = new_dyn[name]["t_delta"]
        traj_s = ", ".join(f"{t:.2e}" for t in traj) if traj else "summary only"
        print(
            f"{verdict:9s} dynamic {name}: cold/delta {base:.3f} -> {new:.3f} "
            f"(floor {floor:.3f}; delta trajectory [{traj_s}])"
        )
    for name in sorted(set(new_dyn) - set(base_dyn)):
        print(
            f"NEW       dynamic {name}: cold/delta {new_dyn[name]['speedup']:.3f} "
            f"(no baseline; --update to adopt)"
        )

    if failures or dyn_failures:
        if failures:
            print(
                f"\nFAIL: {len(failures)} of {checked} kernel A/B acceptance ratios "
                f"regressed by more than {args.tolerance:.0%}:",
                file=sys.stderr,
            )
            for name, base, new in failures:
                print(
                    f"  {name}: {base:.3f} -> {new:.3f} ({new / base - 1.0:+.1%})",
                    file=sys.stderr,
                )
        if dyn_failures:
            print(
                f"\nFAIL: {len(dyn_failures)} of {dyn_checked} dynamic cold/delta "
                f"speedups regressed by more than {args.dynamic_tolerance:.0%}:",
                file=sys.stderr,
            )
            for name, base, new in dyn_failures:
                print(
                    f"  {name}: {base:.3f} -> {new:.3f} ({new / base - 1.0:+.1%})",
                    file=sys.stderr,
                )
        return 1
    summary = f"\nOK: {checked} kernel A/B ratios within {args.tolerance:.0%} of baseline"
    if dyn_checked:
        summary += (
            f"; {dyn_checked} dynamic speedups within {args.dynamic_tolerance:.0%}"
        )
    print(summary)
    return 0


if __name__ == "__main__":
    sys.exit(main())

//! Stub XLA backend for builds without the `xla` feature (the offline
//! registry has no PJRT bindings). Mirrors the API of [`super::pjrt`] so
//! downstream code typechecks identically; every entry point reports that
//! the backend is unavailable.

use std::path::Path;

use crate::numeric::factor::GemmBackend;
use crate::{Error, Result};

/// Placeholder for the PJRT-backed GEMM engine.
pub struct XlaGemm {
    _private: (),
}

fn unavailable() -> Error {
    Error::Runtime(
        "XLA/PJRT backend not compiled in (add a vendored `xla` dependency \
         to Cargo.toml, then build with `--features xla`)"
            .into(),
    )
}

impl XlaGemm {
    /// Always fails: the backend is not compiled into this build.
    pub fn load(_dir: &Path, _min_dim: usize) -> Result<Self> {
        Err(unavailable())
    }

    /// Mirrors [`super::pjrt::XlaGemm::gemm_update`]; unreachable in
    /// practice because `load` never succeeds.
    pub fn gemm_update(
        &self,
        _c: &[f64],
        _a: &[f64],
        _b: &[f64],
        _m: usize,
        _k: usize,
        _n: usize,
    ) -> Result<Vec<f64>> {
        Err(unavailable())
    }

    /// Mirrors [`super::pjrt::XlaGemm::trsm_unit_lower`].
    pub fn trsm_unit_lower(
        &self,
        _l: &[f64],
        _b: &[f64],
        _w: usize,
        _n: usize,
    ) -> Result<Vec<f64>> {
        Err(unavailable())
    }
}

impl GemmBackend for XlaGemm {
    #[allow(clippy::too_many_arguments)]
    fn gemm_sub(
        &self,
        _c: &mut [f64],
        _a: &[f64],
        _lda: usize,
        _b: &[f64],
        _ldb: usize,
        _m: usize,
        _k: usize,
        _n: usize,
    ) -> bool {
        false
    }
}

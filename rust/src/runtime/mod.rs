//! XLA/PJRT runtime: loads the AOT-compiled Pallas/JAX artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and exposes them
//! as a [`crate::numeric::factor::GemmBackend`] for the sup-sup kernel,
//! plus standalone entry points used by the integration tests and the
//! ablation bench.
//!
//! The PJRT bindings (`xla` crate) are not present in the offline build
//! image, so the real implementation is gated behind the `xla` cargo
//! feature (`pjrt` module); the default build ships a stub with the same
//! API whose `load` reports a runtime error. Enabling the real backend
//! takes two steps: add a vendored `xla` dependency to `Cargo.toml`
//! (`xla = { path = "vendor/xla" }`) *and* build with `--features xla` —
//! the feature deliberately carries no dependency of its own so the
//! default build resolves offline. Integration tests and the `--xla`
//! CLI/bench paths degrade gracefully either way: they skip when the
//! artifacts (or the backend) are unavailable.

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::XlaGemm;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::XlaGemm;

/// Tile classes lowered by `python/compile/aot.py`.
pub const TILE_CLASSES: [usize; 4] = [16, 32, 64, 128];

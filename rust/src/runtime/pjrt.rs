//! The real PJRT-backed [`GemmBackend`] (requires the `xla` cargo feature
//! and a vendored `xla` crate).
//!
//! Interchange is HLO *text* (the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos — see /opt/xla-example/README.md). Each tile
//! class `s ∈ {16, 32, 64, 128}` ([`super::TILE_CLASSES`]) has one compiled
//! executable computing `C(s×2s) − A(s×s)·B(s×2s)`; blocks are zero-padded
//! up to class shape (zero padding is exact for this update). Python never
//! runs here — the artifacts are self-contained.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use crate::numeric::factor::GemmBackend;
use crate::{Error, Result};

/// The xla crate's handles are `Rc`-based (single-threaded by default).
/// We confine every handle inside this struct and only touch it under the
/// one [`Mutex`] in [`XlaGemm`], so reference counts can never race —
/// that confinement is what justifies the `unsafe impl Send`.
struct Inner {
    _client: xla::PjRtClient,
    gemm: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    trsm: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

// Safety: see `Inner` docs — all access is serialized by XlaGemm's mutex,
// and no handle ever escapes it.
unsafe impl Send for Inner {}

/// PJRT-backed GEMM engine (and TRSM, for tests/benches).
pub struct XlaGemm {
    inner: Mutex<Inner>,
    classes: Vec<usize>,
    min_dim: usize,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| Error::Io("bad artifact path".into()))?,
    )
    .map_err(|e| Error::Runtime(format!("parse {path:?}: {e}")))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| Error::Runtime(format!("compile {path:?}: {e}")))
}

impl XlaGemm {
    /// Load and compile the f64 artifacts from `dir` (reads
    /// `manifest.txt`). `min_dim`: blocks with any dimension below this
    /// stay on the native microkernel (PJRT call overhead dominates).
    pub fn load(dir: &Path, min_dim: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        let manifest = std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
            Error::Io(format!(
                "artifacts manifest missing (run `make artifacts`): {e}"
            ))
        })?;
        let mut gemm = BTreeMap::new();
        let mut trsm = BTreeMap::new();
        for line in manifest.lines() {
            let mut it = line.split('\t');
            let (name, file) = match (it.next(), it.next()) {
                (Some(n), Some(f)) => (n, f),
                _ => continue,
            };
            if let Some(s) = name.strip_prefix("gemm_update_f64_") {
                let s: usize = s.parse().map_err(|_| Error::Io("bad manifest".into()))?;
                gemm.insert(s, compile(&client, &dir.join(file))?);
            } else if let Some(s) = name.strip_prefix("trsm_f64_") {
                let s: usize = s.parse().map_err(|_| Error::Io("bad manifest".into()))?;
                trsm.insert(s, compile(&client, &dir.join(file))?);
            }
        }
        if gemm.is_empty() {
            return Err(Error::Runtime(
                "no gemm_update_f64_* artifacts in manifest".into(),
            ));
        }
        let classes: Vec<usize> = gemm.keys().copied().collect();
        Ok(XlaGemm {
            inner: Mutex::new(Inner {
                _client: client,
                gemm,
                trsm,
            }),
            classes,
            min_dim,
        })
    }

    /// Smallest tile class fitting `(m, k, n)`; classes are `(s, s, 2s)`.
    fn pick_class(&self, m: usize, k: usize, n: usize) -> Option<usize> {
        self.classes
            .iter()
            .copied()
            .find(|&s| m <= s && k <= s && n <= 2 * s)
    }

    /// Run `C − A·B` through a padded artifact; shapes `(m,k)·(k,n)`,
    /// row-major contiguous inputs. Public for tests/benches.
    pub fn gemm_update(
        &self,
        c: &[f64],
        a: &[f64],
        b: &[f64],
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<Vec<f64>> {
        let s = self
            .pick_class(m, k, n)
            .ok_or_else(|| Error::Runtime(format!("no tile class fits {m}x{k}x{n}")))?;
        // pad
        let mut cp = vec![0.0f64; s * 2 * s];
        let mut ap = vec![0.0f64; s * s];
        let mut bp = vec![0.0f64; s * 2 * s];
        for i in 0..m {
            cp[i * 2 * s..i * 2 * s + n].copy_from_slice(&c[i * n..(i + 1) * n]);
            ap[i * s..i * s + k].copy_from_slice(&a[i * k..(i + 1) * k]);
        }
        for p in 0..k {
            bp[p * 2 * s..p * 2 * s + n].copy_from_slice(&b[p * n..(p + 1) * n]);
        }
        let full = {
            let inner = self.inner.lock().unwrap();
            let lc = lit2(&cp, s, 2 * s)?;
            let la = lit2(&ap, s, s)?;
            let lb = lit2(&bp, s, 2 * s)?;
            let out = inner.gemm[&s]
                .execute::<xla::Literal>(&[lc, la, lb])
                .map_err(|er| Error::Runtime(format!("execute: {er}")))?[0][0]
                .to_literal_sync()
                .map_err(|er| Error::Runtime(format!("to_literal: {er}")))?;
            out.to_tuple1()
                .map_err(|er| Error::Runtime(format!("tuple: {er}")))?
                .to_vec::<f64>()
                .map_err(|er| Error::Runtime(format!("to_vec: {er}")))?
        };
        let mut res = vec![0.0f64; m * n];
        for i in 0..m {
            res[i * n..(i + 1) * n].copy_from_slice(&full[i * 2 * s..i * 2 * s + n]);
        }
        Ok(res)
    }

    /// Unit-lower TRSM through a padded artifact: solves `L X = B` with
    /// `L (w×w)` (strictly-lower part read), `B (w×n)`. Padding with an
    /// implicit-identity tail block is exact.
    pub fn trsm_unit_lower(&self, l: &[f64], b: &[f64], w: usize, n: usize) -> Result<Vec<f64>> {
        let s = self
            .classes
            .iter()
            .copied()
            .find(|&s| w <= s && n <= 2 * s)
            .ok_or_else(|| Error::Runtime(format!("no trsm class fits {w}x{n}")))?;
        let mut lp = vec![0.0f64; s * s];
        let mut bp = vec![0.0f64; s * 2 * s];
        for i in 0..w {
            lp[i * s..i * s + w].copy_from_slice(&l[i * w..(i + 1) * w]);
            bp[i * 2 * s..i * 2 * s + n].copy_from_slice(&b[i * n..(i + 1) * n]);
        }
        let full = {
            let inner = self.inner.lock().unwrap();
            let exe = inner
                .trsm
                .get(&s)
                .ok_or_else(|| Error::Runtime("trsm artifact missing".into()))?;
            let ll = lit2(&lp, s, s)?;
            let lb = lit2(&bp, s, 2 * s)?;
            let out = exe
                .execute::<xla::Literal>(&[ll, lb])
                .map_err(|er| Error::Runtime(format!("execute: {er}")))?[0][0]
                .to_literal_sync()
                .map_err(|er| Error::Runtime(format!("to_literal: {er}")))?;
            out.to_tuple1()
                .map_err(|er| Error::Runtime(format!("tuple: {er}")))?
                .to_vec::<f64>()
                .map_err(|er| Error::Runtime(format!("to_vec: {er}")))?
        };
        let mut res = vec![0.0f64; w * n];
        for i in 0..w {
            res[i * n..(i + 1) * n].copy_from_slice(&full[i * 2 * s..i * 2 * s + n]);
        }
        Ok(res)
    }
}

fn lit2(v: &[f64], r: usize, c: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[r as i64, c as i64])
        .map_err(|e| Error::Runtime(format!("literal: {e}")))
}

impl GemmBackend for XlaGemm {
    fn gemm_sub(
        &self,
        c: &mut [f64],
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        m: usize,
        k: usize,
        n: usize,
    ) -> bool {
        if m < self.min_dim || k < self.min_dim || n < self.min_dim {
            return false;
        }
        if self.pick_class(m, k, n).is_none() {
            return false;
        }
        // compact strided inputs (c is contiguous ldc == n by contract)
        let mut ac = vec![0.0f64; m * k];
        for i in 0..m {
            ac[i * k..(i + 1) * k].copy_from_slice(&a[i * lda..i * lda + k]);
        }
        // B now arrives pre-packed contiguous (ldb == n) from the factor
        // kernel's pack_rows; only re-compact if a caller ever strides it
        let bc_storage;
        let bc: &[f64] = if ldb == n {
            &b[..k * n]
        } else {
            let mut tmp = vec![0.0f64; k * n];
            for p in 0..k {
                tmp[p * n..(p + 1) * n].copy_from_slice(&b[p * ldb..p * ldb + n]);
            }
            bc_storage = tmp;
            &bc_storage
        };
        match self.gemm_update(c, &ac, bc, m, k, n) {
            Ok(res) => {
                c.copy_from_slice(&res[..m * n]);
                true
            }
            Err(_) => false,
        }
    }
}

//! Approximate minimum degree ordering (paper ref [9], Amestoy–Davis–Duff).
//!
//! Quotient-graph minimum degree with the AMD *approximate* external degree
//! bound `d(u) ≈ |A_u| + |L_p \ u| + Σ_e |L_e \ L_p|`, element absorption,
//! and redundant-edge pruning. Supervariable detection is omitted (a
//! quality/perf refinement, not a correctness requirement) — DESIGN.md §2.
//!
//! Input: symmetrized pattern (no diagonal). Output: elimination order,
//! `order[k] = the original vertex eliminated at step k`.

/// Compute the AMD elimination ordering of a symmetric graph given in
/// CSR-ish `(ptr, idx)` form *without* diagonal entries.
pub fn amd(n: usize, ptr: &[usize], idx: &[usize]) -> Vec<usize> {
    if n == 0 {
        return Vec::new();
    }
    // adjacency: variable -> still-uneliminated neighbour variables
    let mut adj_var: Vec<Vec<u32>> = (0..n)
        .map(|i| idx[ptr[i]..ptr[i + 1]].iter().map(|&j| j as u32).collect())
        .collect();
    // variable -> adjacent elements (cliques created by elimination)
    let mut adj_el: Vec<Vec<u32>> = vec![Vec::new(); n];
    // element -> boundary variables (alive members only, lazily filtered)
    let mut members: Vec<Vec<u32>> = Vec::new();
    let mut el_alive: Vec<bool> = Vec::new();

    let mut alive = vec![true; n];
    let mut deg: Vec<usize> = (0..n).map(|i| ptr[i + 1] - ptr[i]).collect();

    // degree buckets: doubly-linked lists
    let mut head = vec![u32::MAX; n + 1];
    let mut next = vec![u32::MAX; n];
    let mut prev = vec![u32::MAX; n];
    let mut in_list = vec![false; n];
    let cap = n; // max degree slot
    let push = |head: &mut [u32],
                    next: &mut [u32],
                    prev: &mut [u32],
                    in_list: &mut [bool],
                    d: usize,
                    v: usize| {
        let d = d.min(cap);
        next[v] = head[d];
        prev[v] = u32::MAX;
        if head[d] != u32::MAX {
            prev[head[d] as usize] = v as u32;
        }
        head[d] = v as u32;
        in_list[v] = true;
    };
    let unlink = |head: &mut [u32],
                  next: &mut [u32],
                  prev: &mut [u32],
                  in_list: &mut [bool],
                  d: usize,
                  v: usize| {
        let d = d.min(cap);
        if !in_list[v] {
            return;
        }
        if prev[v] != u32::MAX {
            next[prev[v] as usize] = next[v];
        } else {
            head[d] = next[v];
        }
        if next[v] != u32::MAX {
            prev[next[v] as usize] = prev[v];
        }
        in_list[v] = false;
    };

    for v in 0..n {
        push(&mut head, &mut next, &mut prev, &mut in_list, deg[v], v);
    }

    let mut order = Vec::with_capacity(n);
    let mut mindeg = 0usize;
    let mut mark = vec![u64::MAX; n]; // scratch marker for set ops
    let mut stamp = 0u64;
    let mut wel: Vec<i64> = Vec::new(); // |Le \ Lp| scratch per element

    while order.len() < n {
        // find current minimum-degree alive variable
        while mindeg <= cap && head[mindeg] == u32::MAX {
            mindeg += 1;
        }
        if mindeg > cap {
            break; // all buckets empty (shouldn't happen)
        }
        let p = head[mindeg] as usize;
        unlink(&mut head, &mut next, &mut prev, &mut in_list, deg[p], p);
        debug_assert!(alive[p]);
        alive[p] = false;
        order.push(p);

        // Build Lp = (adj_var[p] ∪ ⋃_{e ∈ adj_el[p]} members[e]) ∩ alive
        stamp += 1;
        let mut lp: Vec<u32> = Vec::new();
        for &u in &adj_var[p] {
            let u = u as usize;
            if alive[u] && mark[u] != stamp {
                mark[u] = stamp;
                lp.push(u as u32);
            }
        }
        let absorbed: Vec<u32> = std::mem::take(&mut adj_el[p]);
        for &e in &absorbed {
            if !el_alive[e as usize] {
                continue;
            }
            for &u in &members[e as usize] {
                let u = u as usize;
                if alive[u] && mark[u] != stamp {
                    mark[u] = stamp;
                    lp.push(u as u32);
                }
            }
        }
        adj_var[p] = Vec::new(); // free

        // create new element
        let pe = members.len() as u32;
        members.push(lp.clone());
        el_alive.push(true);
        wel.resize(members.len(), -1);
        for &e in &absorbed {
            el_alive[e as usize] = false; // absorbed into pe
        }

        // compute |Le \ Lp| for elements adjacent to Lp members
        // (wel[e] < 0 means uninitialized this round)
        let mut touched_els: Vec<u32> = Vec::new();
        for &uq in &lp {
            let u = uq as usize;
            for &e in &adj_el[u] {
                let e = e as usize;
                if !el_alive[e] {
                    continue;
                }
                if wel[e] < 0 {
                    // count alive members lazily
                    let cnt = members[e].iter().filter(|&&w| alive[w as usize]).count();
                    wel[e] = cnt as i64;
                    touched_els.push(e as u32);
                }
                wel[e] -= 1; // u ∈ Lp ∩ Le
            }
        }

        // update each member of Lp
        let lp_size = lp.len();
        for &uq in &lp {
            let u = uq as usize;
            let old_d = deg[u];
            unlink(&mut head, &mut next, &mut prev, &mut in_list, old_d, u);

            // prune adj_var[u]: drop p, dead vars, and members of Lp
            // (now covered by element pe)
            adj_var[u].retain(|&w| {
                let w = w as usize;
                w != p && alive[w] && mark[w] != stamp
            });
            // prune dead/absorbed elements; keep alive ones
            adj_el[u].retain(|&e| el_alive[e as usize]);
            adj_el[u].push(pe);

            // approximate external degree (AMD bound)
            let mut d = adj_var[u].len() + (lp_size - 1);
            for &e in &adj_el[u] {
                let e = e as usize;
                if e == pe as usize {
                    continue;
                }
                d += if wel[e] >= 0 {
                    wel[e] as usize
                } else {
                    members[e].iter().filter(|&&w| alive[w as usize]).count()
                };
            }
            let d = d.min(n - order.len()).max(adj_var[u].len());
            deg[u] = d;
            push(&mut head, &mut next, &mut prev, &mut in_list, d, u);
            if d < mindeg {
                mindeg = d;
            }
        }

        // reset wel for touched elements
        for &e in &touched_els {
            wel[e as usize] = -1;
        }

        // periodic compaction of member lists (drop dead vars) to bound work
        if order.len() % 2048 == 0 {
            for (e, m) in members.iter_mut().enumerate() {
                if el_alive[e] {
                    m.retain(|&w| alive[w as usize]);
                }
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::Csr;
    use crate::sparse::gen;
    use crate::sparse::perm::Perm;
    use crate::testutil::{for_each_seed, Prng};

    fn sym(a: &Csr) -> (Vec<usize>, Vec<usize>) {
        a.symmetrized_pattern()
    }

    /// Count fill of a Cholesky-style symbolic factorization under order.
    fn fill_count(n: usize, ptr: &[usize], idx: &[usize], order: &[usize]) -> usize {
        // simple O(n^2-ish) symbolic elimination for small test graphs
        let inv = {
            let mut inv = vec![0usize; n];
            for (k, &v) in order.iter().enumerate() {
                inv[v] = k;
            }
            inv
        };
        let mut rows: Vec<std::collections::BTreeSet<usize>> = (0..n)
            .map(|i| {
                idx[ptr[i]..ptr[i + 1]]
                    .iter()
                    .map(|&j| inv[j])
                    .filter(|&j| j > inv[i])
                    .collect()
            })
            .collect();
        // reindex: rows by elimination step
        let mut by_step: Vec<std::collections::BTreeSet<usize>> =
            vec![Default::default(); n];
        for i in 0..n {
            by_step[inv[i]] = std::mem::take(&mut rows[i]);
        }
        let mut fill = 0usize;
        for k in 0..n {
            let higher: Vec<usize> = by_step[k].iter().copied().collect();
            fill += higher.len();
            if let Some((&first, rest)) = higher.split_first() {
                let add: Vec<usize> = rest.to_vec();
                for &j in &add {
                    by_step[first].insert(j);
                }
            }
        }
        fill
    }

    #[test]
    fn amd_returns_valid_permutation() {
        for a in [
            gen::grid2d(9, 11),
            gen::circuit(300, 1),
            gen::power_network(200, 2),
        ] {
            let (ptr, idx) = sym(&a);
            let order = amd(a.n, &ptr, &idx);
            Perm::from_map(order).unwrap();
        }
    }

    #[test]
    fn amd_beats_natural_order_on_grid() {
        let a = gen::grid2d(14, 14);
        let (ptr, idx) = sym(&a);
        let order = amd(a.n, &ptr, &idx);
        let natural: Vec<usize> = (0..a.n).collect();
        let f_amd = fill_count(a.n, &ptr, &idx, &order);
        let f_nat = fill_count(a.n, &ptr, &idx, &natural);
        assert!(
            (f_amd as f64) < 0.8 * f_nat as f64,
            "amd fill {f_amd} vs natural {f_nat}"
        );
    }

    #[test]
    fn amd_beats_random_order_on_circuit() {
        let a = gen::circuit(400, 5);
        let (ptr, idx) = sym(&a);
        let order = amd(a.n, &ptr, &idx);
        let mut rng = Prng::new(1);
        let random = rng.permutation(a.n);
        let f_amd = fill_count(a.n, &ptr, &idx, &order);
        let f_rnd = fill_count(a.n, &ptr, &idx, &random);
        assert!(
            (f_amd as f64) < 0.7 * f_rnd as f64,
            "amd fill {f_amd} vs random {f_rnd}"
        );
    }

    #[test]
    fn handles_empty_and_tiny_graphs() {
        assert_eq!(amd(0, &[0], &[]), Vec::<usize>::new());
        assert_eq!(amd(1, &[0, 0], &[]), vec![0]);
        // two disconnected vertices
        assert_eq!(amd(2, &[0, 0, 0], &[]).len(), 2);
    }

    #[test]
    fn property_always_a_permutation() {
        for_each_seed(10, |rng| {
            let n = rng.range(2, 80);
            let mut edges = std::collections::BTreeSet::new();
            for _ in 0..3 * n {
                let i = rng.below(n);
                let j = rng.below(n);
                if i != j {
                    edges.insert((i.min(j), i.max(j)));
                }
            }
            let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
            for &(i, j) in &edges {
                adj[i].push(j);
                adj[j].push(i);
            }
            let mut ptr = vec![0usize];
            let mut idx = Vec::new();
            for l in &mut adj {
                l.sort_unstable();
                idx.extend_from_slice(l);
                ptr.push(idx.len());
            }
            let order = amd(n, &ptr, &idx);
            Perm::from_map(order).unwrap();
        });
    }
}

//! Static pivoting: maximum weighted (product) bipartite matching with
//! dual-variable scaling — the Duff–Koster algorithm the paper cites as [8]
//! (HSL MC64, job 5).
//!
//! Finds a row permutation σ and diagonal scalings `Dr`, `Dc` such that the
//! scaled, permuted matrix has |diagonal| = 1 and all entries bounded in
//! [-1, 1]. This makes static (pattern-preserving) pivoting safe during
//! numeric factorization, which is what lets HYLU fix the fill pattern at
//! symbolic time.
//!
//! Method: successive shortest augmenting paths (sparse Jonker–Volgenant)
//! on the assignment problem with costs `c_ij = log(max_i |a_ij|) −
//! log |a_ij| ≥ 0`, maintaining LP duals `u` (rows), `v` (cols) with
//! `u_i + v_j ≤ c_ij` and equality on matched edges. The duals *are* the
//! log-scalings: `Dr[i] = exp(u_i)`, `Dc[j] = exp(v_j) / colmax_j`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::sparse::csr::Csr;
use crate::{Error, Result};

/// Result of the matching: permutation plus scalings.
#[derive(Clone, Debug)]
pub struct Matching {
    /// `row_for_col[j]` = the row matched to (placed on the diagonal of)
    /// column `j`.
    pub row_for_col: Vec<usize>,
    /// Row scaling `Dr` (multiply row `i` by `dr[i]`).
    pub dr: Vec<f64>,
    /// Column scaling `Dc` (multiply column `j` by `dc[j]`).
    pub dc: Vec<f64>,
}

#[derive(PartialEq)]
struct HeapEntry {
    dist: f64,
    row: usize,
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on dist
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.row.cmp(&self.row))
    }
}

/// Run maximum-product matching + scaling on `a`.
///
/// Errors with [`Error::StructurallySingular`] if no perfect matching
/// exists. Zero-valued stored entries are treated as absent.
pub fn max_weight_matching(a: &Csr) -> Result<Matching> {
    let n = a.n;
    let at = a.transpose(); // column access: at.row(j) = column j of a

    // costs: c_ij = log(cmax_j) - log|a_ij|
    let mut logcmax = vec![f64::NEG_INFINITY; n];
    for j in 0..n {
        for &v in at.row_vals(j) {
            let av = v.abs();
            if av > 0.0 {
                logcmax[j] = logcmax[j].max(av.ln());
            }
        }
    }
    for (j, &m) in logcmax.iter().enumerate() {
        if m == f64::NEG_INFINITY {
            return Err(Error::Invalid(format!("column {j} has no nonzeros")));
        }
    }

    let cost = |j: usize, k: usize| -> Option<f64> {
        let v = at.row_vals(j)[k].abs();
        if v > 0.0 {
            Some(logcmax[j] - v.ln())
        } else {
            None
        }
    };

    let mut u = vec![0.0f64; n]; // row duals
    let mut v = vec![0.0f64; n]; // col duals
    let mut match_col_of_row = vec![usize::MAX; n];
    let mut match_row_of_col = vec![usize::MAX; n];

    // Cheap initialization (MC64 does the same): for each column, try to
    // match its max-magnitude (zero-cost) entry if the row is free.
    for j in 0..n {
        for (k, &i) in at.row_indices(j).iter().enumerate() {
            if match_col_of_row[i] == usize::MAX {
                if let Some(c) = cost(j, k) {
                    if c <= 1e-15 {
                        match_col_of_row[i] = j;
                        match_row_of_col[j] = i;
                        break;
                    }
                }
            }
        }
    }

    // Per-search scratch
    let mut dist = vec![f64::INFINITY; n];
    let mut prev_col = vec![usize::MAX; n]; // for rows on the search tree
    let mut finalized = vec![false; n];
    let mut touched_rows: Vec<usize> = Vec::new();
    let mut tree_cols: Vec<(usize, f64)> = Vec::new(); // (col, dist at col)
    let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();

    for j0 in 0..n {
        if match_row_of_col[j0] != usize::MAX {
            continue;
        }
        heap.clear();
        tree_cols.clear();
        // Dijkstra from j0 over alternating paths.
        let mut cur_j = j0;
        let mut path_dist = 0.0f64;
        let (endpoint, delta) = loop {
            tree_cols.push((cur_j, path_dist));
            for (k, &i) in at.row_indices(cur_j).iter().enumerate() {
                if finalized[i] {
                    continue;
                }
                if let Some(c) = cost(cur_j, k) {
                    let nd = path_dist + c - u[i] - v[cur_j];
                    if nd < dist[i] - 1e-15 {
                        if dist[i] == f64::INFINITY {
                            touched_rows.push(i);
                        }
                        dist[i] = nd;
                        prev_col[i] = cur_j;
                        heap.push(HeapEntry { dist: nd, row: i });
                    }
                }
            }
            // pop nearest unfinalized row
            let (d, i) = loop {
                match heap.pop() {
                    None => {
                        // reset scratch before erroring
                        for &r in &touched_rows {
                            dist[r] = f64::INFINITY;
                            finalized[r] = false;
                            prev_col[r] = usize::MAX;
                        }
                        touched_rows.clear();
                        let matched = match_row_of_col
                            .iter()
                            .filter(|&&r| r != usize::MAX)
                            .count();
                        return Err(Error::StructurallySingular { matched, n });
                    }
                    Some(e) => {
                        if !finalized[e.row] {
                            break (e.dist, e.row);
                        }
                    }
                }
            };
            finalized[i] = true;
            if match_col_of_row[i] == usize::MAX {
                break (i, d);
            }
            cur_j = match_col_of_row[i];
            path_dist = d;
        };

        // Dual updates keep feasibility and make the augmenting path tight.
        for &(j, dj) in &tree_cols {
            v[j] += delta - dj;
        }
        for &i in &touched_rows {
            if finalized[i] {
                u[i] -= delta - dist[i];
            }
        }

        // Augment along prev_col chain.
        let mut i = endpoint;
        loop {
            let j = prev_col[i];
            let next_i = match_row_of_col[j];
            match_row_of_col[j] = i;
            match_col_of_row[i] = j;
            if j == j0 {
                break;
            }
            i = next_i;
        }

        // Reset scratch.
        for &r in &touched_rows {
            dist[r] = f64::INFINITY;
            finalized[r] = false;
            prev_col[r] = usize::MAX;
        }
        touched_rows.clear();
    }

    // scalings from duals
    let dr: Vec<f64> = u.iter().map(|&ui| ui.exp()).collect();
    let dc: Vec<f64> = (0..n).map(|j| (v[j] - logcmax[j]).exp()).collect();
    Ok(Matching {
        row_for_col: match_row_of_col,
        dr,
        dc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen;
    use crate::sparse::perm::Perm;
    use crate::testutil::{for_each_seed, Prng};

    /// Check the MC64 contract: after permute+scale, |diag| == 1 and all
    /// entries in [-1, 1] (up to roundoff).
    fn check_contract(a: &Csr, m: &Matching) {
        let n = a.n;
        // matching is a permutation
        Perm::from_map(m.row_for_col.clone()).unwrap();
        let p = Perm::from_map(m.row_for_col.clone()).unwrap();
        let q = Perm::identity(n);
        let b = a.permute_scale(&p, &q, &m.dr, &m.dc);
        for i in 0..n {
            let mut diag = None;
            for (k, &j) in b.row_indices(i).iter().enumerate() {
                let v = b.row_vals(i)[k].abs();
                assert!(v <= 1.0 + 1e-9, "entry ({i},{j}) = {v} > 1");
                if j == i {
                    diag = Some(v);
                }
            }
            let d = diag.expect("diagonal entry missing after matching");
            assert!((d - 1.0).abs() < 1e-9, "diag {i} = {d} != 1");
        }
    }

    #[test]
    fn identity_matrix_matches_trivially() {
        let a = Csr::identity(6);
        let m = max_weight_matching(&a).unwrap();
        assert_eq!(m.row_for_col, vec![0, 1, 2, 3, 4, 5]);
        check_contract(&a, &m);
    }

    #[test]
    fn permuted_diagonal_is_recovered() {
        let mut rng = Prng::new(17);
        let n = 30;
        let perm = rng.permutation(n);
        let mut c = Coo::new(n);
        for j in 0..n {
            c.push(perm[j], j, 5.0); // huge entries off-diagonal positions
            c.push(j, j, 1e-6); // tiny diagonal decoys (skip where same)
        }
        let a = c.to_csr();
        let m = max_weight_matching(&a).unwrap();
        for j in 0..n {
            assert_eq!(m.row_for_col[j], perm[j], "col {j}");
        }
        check_contract(&a, &m);
    }

    #[test]
    fn structurally_singular_is_detected() {
        // column 2 empty except duplicated dependence: make rows 0 and 1
        // both only reach column 0 => no perfect matching.
        let mut c = Coo::new(3);
        c.push(0, 0, 1.0);
        c.push(1, 0, 1.0);
        c.push(0, 1, 1.0);
        c.push(1, 1, 1.0);
        c.push(2, 1, 1.0);
        // column 2 has a single zero-value entry -> treated absent
        c.push(2, 2, 0.0);
        let a = c.to_csr();
        match max_weight_matching(&a) {
            Err(Error::Invalid(_)) | Err(Error::StructurallySingular { .. }) => {}
            other => panic!("expected singular, got {other:?}"),
        }
    }

    #[test]
    fn contract_holds_on_generated_classes() {
        for a in [
            gen::circuit(400, 2),
            gen::power_network(300, 3),
            gen::grid2d(15, 15),
            gen::kkt(150, 40, 4),
            gen::ill_conditioned(120, 5),
            gen::random_sparse(200, 4, 6),
        ] {
            let m = max_weight_matching(&a).unwrap();
            check_contract(&a, &m);
        }
    }

    #[test]
    fn property_random_matrices_satisfy_contract() {
        for_each_seed(15, |rng| {
            let n = rng.range(5, 60);
            let mut c = Coo::new(n);
            // random entries + guaranteed transversal on a random perm
            let perm = rng.permutation(n);
            for j in 0..n {
                c.push(perm[j], j, rng.nonzero() * 10f64.powf(rng.range_f64(-3.0, 3.0)));
            }
            for _ in 0..3 * n {
                let i = rng.below(n);
                let j = rng.below(n);
                c.push(i, j, rng.nonzero() * 10f64.powf(rng.range_f64(-3.0, 3.0)));
            }
            let a = c.to_csr();
            let m = max_weight_matching(&a).unwrap();
            check_contract(&a, &m);
        });
    }

    #[test]
    fn matching_maximizes_diagonal_product_vs_natural() {
        // the matched diagonal product must beat (or equal) the natural one
        let mut rng = Prng::new(99);
        let n = 25;
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, rng.range_f64(1e-6, 1e-3));
            for _ in 0..4 {
                c.push(i, rng.below(n), rng.range_f64(0.1, 10.0));
            }
        }
        let a = c.to_csr();
        let m = max_weight_matching(&a).unwrap();
        let d = a.to_dense();
        let nat: f64 = (0..n).map(|i| d.get(i, i).abs().max(1e-300).ln()).sum();
        let mat: f64 = (0..n)
            .map(|j| d.get(m.row_for_col[j], j).abs().max(1e-300).ln())
            .sum();
        assert!(mat >= nat - 1e-9);
    }
}

//! Nested-dissection ordering (paper ref [11], METIS-lite).
//!
//! Recursive level-set bisection: BFS from a pseudo-peripheral vertex,
//! split at the median level, shrink the separator to the vertices actually
//! adjacent to the near side, recurse on both halves, order the separator
//! last. Leaves are ordered with AMD. This is the "modified nested
//! dissection based on METIS" role in HYLU's preprocessing — same
//! asymptotics on mesh-class graphs, no external dependency (DESIGN.md §2).

use crate::ordering::amd;

const LEAF: usize = 96;

/// Compute a nested-dissection elimination order (`map[new] = old`) of a
/// symmetric graph in CSR-ish `(ptr, idx)` form without diagonal entries.
pub fn nested_dissection(n: usize, ptr: &[usize], idx: &[usize]) -> Vec<usize> {
    let mut order = Vec::with_capacity(n);
    let all: Vec<u32> = (0..n as u32).collect();
    let mut local = vec![u32::MAX; n]; // global -> local map scratch
    let mut levels = vec![u32::MAX; n];
    dissect(
        ptr,
        idx,
        all,
        &mut order,
        &mut local,
        &mut levels,
        0,
    );
    debug_assert_eq!(order.len(), n);
    order
}

/// BFS from `start` within `verts` (membership via `levels` stamped to
/// `u32::MAX-1`); returns (visited vertices in BFS order, their levels).
fn bfs(
    ptr: &[usize],
    idx: &[usize],
    verts: &[u32],
    start: u32,
    in_set: &[u32],
    stamp: u32,
    levels: &mut [u32],
) -> Vec<u32> {
    let _ = verts;
    let mut queue = vec![start];
    levels[start as usize] = 0;
    let mut qi = 0;
    while qi < queue.len() {
        let v = queue[qi] as usize;
        qi += 1;
        let lv = levels[v];
        for &w in &idx[ptr[v]..ptr[v + 1]] {
            if in_set[w] == stamp && levels[w] == u32::MAX {
                levels[w] = lv + 1;
                queue.push(w as u32);
            }
        }
    }
    queue
}

fn dissect(
    ptr: &[usize],
    idx: &[usize],
    verts: Vec<u32>,
    order: &mut Vec<usize>,
    local: &mut Vec<u32>,
    levels: &mut Vec<u32>,
    depth: u32,
) {
    let sz = verts.len();
    if sz == 0 {
        return;
    }
    if sz <= LEAF || depth > 48 {
        order_leaf(ptr, idx, &verts, order, local);
        return;
    }
    // membership stamp: local[] doubles as the in-set marker using a unique
    // stamp value per call: we use local[v] = stamp while levels[] holds BFS
    // levels. Reset on exit paths below.
    let stamp = depth.wrapping_add(0xBEEF0000);
    for &v in &verts {
        local[v as usize] = stamp;
        levels[v as usize] = u32::MAX;
    }

    // pseudo-peripheral: BFS from first vertex, then from the farthest.
    let bfs1 = bfs(ptr, idx, &verts, verts[0], local, stamp, levels);
    let far = *bfs1.last().unwrap();
    for &v in &bfs1 {
        levels[v as usize] = u32::MAX;
    }
    let bfs2 = bfs(ptr, idx, &verts, far, local, stamp, levels);

    if bfs2.len() < sz {
        // disconnected: component vs rest, no separator needed
        let comp: Vec<u32> = bfs2.clone();
        let rest: Vec<u32> = verts
            .iter()
            .copied()
            .filter(|&v| levels[v as usize] == u32::MAX)
            .collect();
        for &v in &verts {
            local[v as usize] = u32::MAX;
            levels[v as usize] = u32::MAX;
        }
        dissect(ptr, idx, comp, order, local, levels, depth + 1);
        dissect(ptr, idx, rest, order, local, levels, depth + 1);
        return;
    }

    // split level: median vertex's level (ensures both sides non-empty)
    let maxlev = levels[*bfs2.last().unwrap() as usize];
    if maxlev < 2 {
        // graph too tightly connected to bisect by levels; fall back to AMD
        for &v in &verts {
            local[v as usize] = u32::MAX;
            levels[v as usize] = u32::MAX;
        }
        order_leaf(ptr, idx, &verts, order, local);
        return;
    }
    let split = {
        let med = bfs2[sz / 2];
        levels[med as usize].clamp(1, maxlev - 1).max(1)
    };

    // A: level < split, candidate separator: level == split, B: > split.
    // Shrink separator: only split-level vertices adjacent to A stay; the
    // rest join B.
    let mut a_side: Vec<u32> = Vec::new();
    let mut b_side: Vec<u32> = Vec::new();
    let mut sep: Vec<u32> = Vec::new();
    for &v in &bfs2 {
        let lv = levels[v as usize];
        if lv < split {
            a_side.push(v);
        } else if lv > split {
            b_side.push(v);
        } else {
            let touches_a = idx[ptr[v as usize]..ptr[v as usize + 1]]
                .iter()
                .any(|&w| local[w] == stamp && levels[w] != u32::MAX && levels[w] < split);
            if touches_a {
                sep.push(v);
            } else {
                b_side.push(v);
            }
        }
    }
    // reset scratch before recursing
    for &v in &verts {
        local[v as usize] = u32::MAX;
        levels[v as usize] = u32::MAX;
    }

    dissect(ptr, idx, a_side, order, local, levels, depth + 1);
    dissect(ptr, idx, b_side, order, local, levels, depth + 1);
    order_leaf(ptr, idx, &sep, order, local);
}

/// Order a vertex subset with AMD on the induced subgraph and append to
/// `order`.
fn order_leaf(
    ptr: &[usize],
    idx: &[usize],
    verts: &[u32],
    order: &mut Vec<usize>,
    local: &mut Vec<u32>,
) {
    let m = verts.len();
    if m == 0 {
        return;
    }
    if m == 1 {
        order.push(verts[0] as usize);
        return;
    }
    for (k, &v) in verts.iter().enumerate() {
        local[v as usize] = k as u32;
    }
    let mut lptr = Vec::with_capacity(m + 1);
    let mut lidx = Vec::new();
    lptr.push(0usize);
    for &v in verts {
        for &w in &idx[ptr[v as usize]..ptr[v as usize + 1]] {
            if local[w] != u32::MAX && w != v as usize {
                lidx.push(local[w] as usize);
            }
        }
        lptr.push(lidx.len());
    }
    let sub_order = amd::amd(m, &lptr, &lidx);
    for &k in &sub_order {
        order.push(verts[k] as usize);
    }
    for &v in verts {
        local[v as usize] = u32::MAX;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::sparse::perm::Perm;

    #[test]
    fn nd_returns_valid_permutation() {
        for a in [
            gen::grid2d(20, 20),
            gen::grid3d(7, 7, 7),
            gen::circuit(500, 1),
            gen::power_network(300, 2),
        ] {
            let (ptr, idx) = a.symmetrized_pattern();
            let order = nested_dissection(a.n, &ptr, &idx);
            Perm::from_map(order).unwrap();
        }
    }

    #[test]
    fn nd_handles_disconnected_graph() {
        // two disjoint paths
        let n = 10;
        let mut ptr = vec![0usize];
        let mut idx = Vec::new();
        for i in 0..n {
            if i % 5 > 0 {
                idx.push(i - 1);
            }
            if i % 5 < 4 {
                idx.push(i + 1);
            }
            ptr.push(idx.len());
        }
        let order = nested_dissection(n, &ptr, &idx);
        Perm::from_map(order).unwrap();
    }

    #[test]
    fn nd_separator_goes_last_on_grid() {
        // On a path graph 0-1-2-...-99, ND should not order an interior
        // separator vertex first.
        let n = 100;
        let mut ptr = vec![0usize];
        let mut idx = Vec::new();
        for i in 0..n {
            if i > 0 {
                idx.push(i - 1);
            }
            if i + 1 < n {
                idx.push(i + 1);
            }
            ptr.push(idx.len());
        }
        let order = nested_dissection(n, &ptr, &idx);
        Perm::from_map(order.clone()).unwrap();
        // last-ordered vertex should be an interior (separator) vertex
        let last = order[n - 1];
        assert!(last > 5 && last < n - 5, "last={last} not interior");
    }

    #[test]
    fn nd_empty_graph() {
        assert_eq!(nested_dissection(0, &[0], &[]), Vec::<usize>::new());
    }
}

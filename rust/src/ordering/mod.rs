//! Preprocessing orderings: static pivoting (maximum weighted matching with
//! scaling, MC64-style) and fill-reducing orderings (AMD and a METIS-lite
//! nested dissection), plus the sparsity-driven auto-selection between them
//! — HYLU selects its ordering like it selects its numeric kernel.

pub mod amd;
pub mod mwm;
pub mod nd;

use crate::sparse::csr::Csr;

/// Which fill-reducing ordering to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrderingChoice {
    /// Approximate minimum degree — wins on circuit-class (very sparse,
    /// irregular) graphs.
    Amd,
    /// Nested dissection — wins on mesh-class (regular, higher-degree)
    /// graphs.
    NestedDissection,
    /// Pick from graph statistics (default; the paper's "smart selection"
    /// spirit applied to the ordering stage).
    Auto,
    /// Keep the input order (testing / pre-ordered matrices).
    Natural,
}

impl Default for OrderingChoice {
    fn default() -> Self {
        OrderingChoice::Auto
    }
}

/// Statistics the auto-selector uses (also reported to the user).
#[derive(Clone, Copy, Debug, Default)]
pub struct GraphStats {
    /// Mean degree of the symmetrized graph (off-diagonal).
    pub avg_degree: f64,
    /// Fraction of rows whose degree is within ±1 of the mean (regularity).
    pub regularity: f64,
    /// Maximum degree.
    pub max_degree: usize,
}

/// Compute the selector statistics on the symmetrized pattern.
pub fn graph_stats(a: &Csr) -> GraphStats {
    let (ptr, _idx) = a.symmetrized_pattern();
    let n = a.n.max(1);
    let degs: Vec<usize> = (0..a.n).map(|i| ptr[i + 1] - ptr[i]).collect();
    let avg = degs.iter().sum::<usize>() as f64 / n as f64;
    let near = degs
        .iter()
        .filter(|&&d| (d as f64 - avg).abs() <= 1.5)
        .count();
    GraphStats {
        avg_degree: avg,
        regularity: near as f64 / n as f64,
        max_degree: degs.into_iter().max().unwrap_or(0),
    }
}

/// Resolve `Auto` to a concrete choice.
///
/// Mesh-class graphs (PDE stencils) are regular with moderate degree; ND
/// gives asymptotically better fill there. Circuit-class graphs are
/// irregular, bounded-degree with hub rows; AMD is both faster and better.
pub fn resolve(choice: OrderingChoice, a: &Csr) -> OrderingChoice {
    match choice {
        OrderingChoice::Auto => {
            let s = graph_stats(a);
            if s.avg_degree >= 3.5 && s.regularity >= 0.8 && a.n >= 512 {
                OrderingChoice::NestedDissection
            } else {
                OrderingChoice::Amd
            }
        }
        c => c,
    }
}

/// Run the (resolved) ordering, returning the symmetric permutation as an
/// elimination order: position `k` of the output holds the original index
/// eliminated at step `k` (i.e., `map[new] = old`).
pub fn order(choice: OrderingChoice, a: &Csr) -> Vec<usize> {
    match resolve(choice, a) {
        OrderingChoice::Amd => {
            let (ptr, idx) = a.symmetrized_pattern();
            amd::amd(a.n, &ptr, &idx)
        }
        OrderingChoice::NestedDissection => {
            let (ptr, idx) = a.symmetrized_pattern();
            nd::nested_dissection(a.n, &ptr, &idx)
        }
        OrderingChoice::Natural => (0..a.n).collect(),
        OrderingChoice::Auto => unreachable!("resolved above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn auto_picks_nd_for_meshes_amd_for_circuits() {
        let mesh = gen::grid2d(40, 40);
        assert_eq!(
            resolve(OrderingChoice::Auto, &mesh),
            OrderingChoice::NestedDissection
        );
        let ckt = gen::circuit(2000, 1);
        assert_eq!(resolve(OrderingChoice::Auto, &ckt), OrderingChoice::Amd);
    }

    #[test]
    fn order_returns_valid_permutation() {
        use crate::sparse::perm::Perm;
        for choice in [
            OrderingChoice::Amd,
            OrderingChoice::NestedDissection,
            OrderingChoice::Natural,
        ] {
            let a = gen::grid2d(12, 9);
            let p = order(choice, &a);
            Perm::from_map(p).unwrap();
        }
    }
}

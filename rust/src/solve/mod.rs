//! Forward/backward substitution (sequential, partition-based parallel,
//! and batched multi-RHS block variants) and iterative refinement.

pub mod substitution;

pub use substitution::{
    backward, backward_block, backward_block_with, backward_parallel, backward_parallel_pooled,
    forward, forward_block, forward_block_with, forward_parallel, forward_parallel_pooled,
    solve_block_parallel_pooled,
};

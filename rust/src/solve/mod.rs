//! Forward/backward substitution (sequential and partition-based parallel)
//! and iterative refinement.

pub mod substitution;

pub use substitution::{backward, backward_parallel, forward, forward_parallel};

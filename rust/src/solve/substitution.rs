//! Parallel forward-backward substitution (paper §2.3, Fig. 3).
//!
//! The triangular solves reuse the factorization DAG: HYLU's "bulk-
//! sequential" dual mode processes wide levels in parallel with a barrier
//! per level (nonzeros balanced across threads by node weights) and the
//! remaining long dependent chain sequentially on one thread — per-node
//! spin-waiting is not worth it for the tiny per-node solve work. Backward
//! substitution uses the *reverse* levelization.
//!
//! All routines operate in factor-row space: the caller (coordinator) has
//! already applied the static + supernode pivot permutations and scalings.

use std::sync::Barrier;

use crate::numeric::LuFactors;
use crate::par::balanced_chunks;
use crate::symbolic::{NodeSym, Symbolic};

/// Forward solve `y <- L^{-1} y` for one node.
#[inline]
fn forward_node(nd: &NodeSym, sym: &Symbolic, fac: &LuFactors, id: usize, y: &mut [f64]) {
    let first = nd.first as usize;
    let w = nd.width as usize;
    let nl = nd.nl();
    let lcols = &sym.lcols[nd.l_start..nd.l_end];
    if nd.is_super {
        let stride = nd.panel_width();
        let p = fac.panel(id);
        for r in 0..w {
            let base = r * stride;
            let mut s = y[first + r];
            for (c, &j) in lcols.iter().enumerate() {
                s -= p[base + c] * y[j as usize];
            }
            for kk in 0..r {
                s -= p[base + nl + kk] * y[first + kk];
            }
            y[first + r] = s;
        }
    } else {
        let mut s = y[first];
        for (c, &j) in lcols.iter().enumerate() {
            s -= fac.lvals[nd.l_start + c] * y[j as usize];
        }
        y[first] = s;
    }
}

/// Backward solve `y <- U^{-1} y` for one node.
#[inline]
fn backward_node(nd: &NodeSym, sym: &Symbolic, fac: &LuFactors, id: usize, y: &mut [f64]) {
    let first = nd.first as usize;
    let w = nd.width as usize;
    let nl = nd.nl();
    let ucols = &sym.ucols[nd.u_start..nd.u_end];
    if nd.is_super {
        let stride = nd.panel_width();
        let p = fac.panel(id);
        for r in (0..w).rev() {
            let base = r * stride;
            let mut s = y[first + r];
            let utail = &p[base + nl + w..base + stride];
            for (c, &j) in ucols.iter().enumerate() {
                s -= utail[c] * y[j as usize];
            }
            for kk in r + 1..w {
                s -= p[base + nl + kk] * y[first + kk];
            }
            y[first + r] = s / p[base + nl + r];
        }
    } else {
        let mut s = y[first];
        for (c, &j) in ucols.iter().enumerate() {
            s -= fac.uvals[nd.u_start + c] * y[j as usize];
        }
        y[first] = s / fac.diag[first];
    }
}

/// Sequential forward substitution: `y <- L^{-1} y`.
pub fn forward(sym: &Symbolic, fac: &LuFactors, y: &mut [f64]) {
    for (id, nd) in sym.nodes.iter().enumerate() {
        forward_node(nd, sym, fac, id, y);
    }
}

/// Sequential backward substitution: `y <- U^{-1} y`.
pub fn backward(sym: &Symbolic, fac: &LuFactors, y: &mut [f64]) {
    for (id, nd) in sym.nodes.iter().enumerate().rev() {
        backward_node(nd, sym, fac, id, y);
    }
}

/// Shared-mutable solution vector for the level-parallel solves.
/// Safety: nodes in one level write disjoint `y` rows and only read rows
/// finished in earlier levels (barrier-separated).
struct YPtr(*mut f64);
unsafe impl Send for YPtr {}
unsafe impl Sync for YPtr {}

/// Parallel forward substitution (bulk-sequential dual mode).
pub fn forward_parallel(sym: &Symbolic, fac: &LuFactors, y: &mut [f64], nthreads: usize) {
    let sched = &sym.schedule;
    if nthreads <= 1 || sched.bulk_levels == 0 {
        return forward(sym, fac, y);
    }
    let yp = YPtr(y.as_mut_ptr());
    let ylen = y.len();
    let barrier = Barrier::new(nthreads);
    std::thread::scope(|scope| {
        for t in 0..nthreads {
            let ypr = &yp;
            let barrierr = &barrier;
            scope.spawn(move || {
                let y = unsafe { std::slice::from_raw_parts_mut(ypr.0, ylen) };
                for lv in 0..sched.bulk_levels {
                    let ids = sched.nodes_at(lv);
                    let weights: Vec<f64> = ids
                        .iter()
                        .map(|&id| (sym.nodes[id as usize].nl() + 1) as f64)
                        .collect();
                    let (s, e) = balanced_chunks(&weights, nthreads)[t];
                    for &id in &ids[s..e] {
                        forward_node(&sym.nodes[id as usize], sym, fac, id as usize, y);
                    }
                    barrierr.wait();
                }
                // sequential tail on thread 0
                if t == 0 {
                    for lv in sched.bulk_levels..sched.nlevels() {
                        for &id in sched.nodes_at(lv) {
                            forward_node(&sym.nodes[id as usize], sym, fac, id as usize, y);
                        }
                    }
                }
            });
        }
    });
}

/// Parallel backward substitution (bulk-sequential dual mode on the
/// reverse levelization).
pub fn backward_parallel(sym: &Symbolic, fac: &LuFactors, y: &mut [f64], nthreads: usize) {
    let sched = &sym.schedule;
    if nthreads <= 1 || sched.rbulk_levels == 0 {
        return backward(sym, fac, y);
    }
    let yp = YPtr(y.as_mut_ptr());
    let ylen = y.len();
    let barrier = Barrier::new(nthreads);
    let nrlev = sched.rlevel_ptr.len() - 1;
    std::thread::scope(|scope| {
        for t in 0..nthreads {
            let ypr = &yp;
            let barrierr = &barrier;
            scope.spawn(move || {
                let y = unsafe { std::slice::from_raw_parts_mut(ypr.0, ylen) };
                for lv in 0..sched.rbulk_levels {
                    let ids =
                        &sched.rlevel_nodes[sched.rlevel_ptr[lv]..sched.rlevel_ptr[lv + 1]];
                    let weights: Vec<f64> = ids
                        .iter()
                        .map(|&id| (sym.nodes[id as usize].nu() + 1) as f64)
                        .collect();
                    let (s, e) = balanced_chunks(&weights, nthreads)[t];
                    for &id in &ids[s..e] {
                        backward_node(&sym.nodes[id as usize], sym, fac, id as usize, y);
                    }
                    barrierr.wait();
                }
                if t == 0 {
                    for lv in sched.rbulk_levels..nrlev {
                        for &id in
                            &sched.rlevel_nodes[sched.rlevel_ptr[lv]..sched.rlevel_ptr[lv + 1]]
                        {
                            backward_node(&sym.nodes[id as usize], sym, fac, id as usize, y);
                        }
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::factor::{factor, NativeGemm};
    use crate::numeric::select::KernelMode;
    use crate::numeric::PivotConfig;
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};
    use crate::testutil::max_abs_diff;

    /// Factor + substitute must invert P·A for a matrix that needs no
    /// global pivoting (diagonally dominant).
    fn check_solve(a: &crate::sparse::csr::Csr, mode: KernelMode, tol: f64) {
        let policy = match mode {
            KernelMode::RowRow => MergePolicy::None,
            _ => MergePolicy::Exact { max_width: 16 },
        };
        let sym = analyze_pattern(a, policy, 4);
        let cfg = PivotConfig::default();
        let mut fac = LuFactors::alloc(&sym);
        factor(a, &sym, mode, &cfg, &mut fac, false, &NativeGemm);
        // true solution of A x = b with x* = ramp
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        // apply pivot perm: y[i] = b[pivot_perm[i]]
        let mut y: Vec<f64> = (0..a.n).map(|i| b[fac.pivot_perm[i] as usize]).collect();
        forward(&sym, &fac, &mut y);
        backward(&sym, &fac, &mut y);
        assert!(
            max_abs_diff(&y, &xt) < tol,
            "solve error {} (mode {mode})",
            max_abs_diff(&y, &xt)
        );
        // parallel variants must agree with sequential exactly
        for threads in [2usize, 4] {
            let mut y2: Vec<f64> = (0..a.n).map(|i| b[fac.pivot_perm[i] as usize]).collect();
            forward_parallel(&sym, &fac, &mut y2, threads);
            backward_parallel(&sym, &fac, &mut y2, threads);
            assert_eq!(y, y2, "parallel solve mismatch t={threads}");
        }
    }

    #[test]
    fn solves_identity() {
        check_solve(&crate::sparse::csr::Csr::identity(20), KernelMode::RowRow, 1e-14);
    }

    #[test]
    fn solves_grid_all_modes() {
        let a = gen::grid2d(9, 9);
        for mode in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
            check_solve(&a, mode, 1e-8);
        }
    }

    #[test]
    fn solves_banded_and_power() {
        check_solve(&gen::banded(80, 3, 2), KernelMode::SupSup, 1e-7);
        check_solve(&gen::power_network(150, 3), KernelMode::SupRow, 1e-7);
    }

    #[test]
    fn solves_circuit() {
        check_solve(&gen::circuit(300, 4), KernelMode::RowRow, 1e-7);
    }
}

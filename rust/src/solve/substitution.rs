//! Parallel forward-backward substitution (paper §2.3, Fig. 3) on the
//! persistent worker pool, plus the batched multi-RHS block variants.
//!
//! The triangular solves reuse the factorization DAG: HYLU's "bulk-
//! sequential" dual mode processes wide levels in parallel with a barrier
//! per level (nonzeros balanced across threads by node weights) and the
//! remaining long dependent chain sequentially on one thread — per-node
//! spin-waiting is not worth it for the tiny per-node solve work. Backward
//! substitution uses the *reverse* levelization.
//!
//! The pooled entry points ([`forward_parallel_pooled`],
//! [`backward_parallel_pooled`], [`solve_block_parallel_pooled`]) run as
//! jobs on a [`WorkerPool`] with level chunks precomputed in an
//! [`ExecPlan`]; the legacy `*_parallel` signatures build a temporary
//! pool per call for standalone use. The block (`*_block`) variants sweep
//! `k` right-hand sides laid out as a dense row-major `n×k` matrix in a
//! single pass — one pool dispatch covers forward *and* backward over all
//! `k` columns, with the per-node inner loops vectorized across the `k`
//! lanes by the [`crate::numeric::kernels`] lane kernels (wide supernode
//! diagonal blocks route through the panel TRSM+GEMM shape). Per column
//! they perform exactly the same operations in exactly the same order as
//! the single-RHS code — on every dispatch tier — so a block solve is
//! bit-identical to `k` independent solves.
//!
//! All routines operate in factor-row space: the caller (coordinator) has
//! already applied the static + supernode pivot permutations and scalings.

use std::sync::Barrier;

use crate::exec::{ExecPlan, WorkerPool};
use crate::numeric::kernels::{self, KernelTier};
use crate::numeric::{LuFactors, Scalar};
use crate::symbolic::{NodeSym, Symbolic};

/// Forward solve `y <- L^{-1} y` for one node. Generic over the factor
/// element type: the right-hand side stays `f64`; each factor entry is
/// widened once (`to_f64`, exact) and the multiply/subtract runs in
/// `f64` — for `T = f64` this is bit-identical to the historical code.
#[inline]
fn forward_node<T: Scalar>(nd: &NodeSym, sym: &Symbolic, fac: &LuFactors<T>, id: usize, y: &mut [f64]) {
    let first = nd.first as usize;
    let w = nd.width as usize;
    let nl = nd.nl();
    let lcols = &sym.lcols[nd.l_start..nd.l_end];
    if nd.is_super {
        let stride = nd.panel_width();
        let p = fac.panel(id);
        for r in 0..w {
            let base = r * stride;
            let mut s = y[first + r];
            for (c, &j) in lcols.iter().enumerate() {
                s -= p[base + c].to_f64() * y[j as usize];
            }
            for kk in 0..r {
                s -= p[base + nl + kk].to_f64() * y[first + kk];
            }
            y[first + r] = s;
        }
    } else {
        let mut s = y[first];
        for (c, &j) in lcols.iter().enumerate() {
            s -= fac.lvals[nd.l_start + c].to_f64() * y[j as usize];
        }
        y[first] = s;
    }
}

/// Backward solve `y <- U^{-1} y` for one node (see [`forward_node`] for
/// the mixed-precision widening convention).
#[inline]
fn backward_node<T: Scalar>(nd: &NodeSym, sym: &Symbolic, fac: &LuFactors<T>, id: usize, y: &mut [f64]) {
    let first = nd.first as usize;
    let w = nd.width as usize;
    let nl = nd.nl();
    let ucols = &sym.ucols[nd.u_start..nd.u_end];
    if nd.is_super {
        let stride = nd.panel_width();
        let p = fac.panel(id);
        for r in (0..w).rev() {
            let base = r * stride;
            let mut s = y[first + r];
            let utail = &p[base + nl + w..base + stride];
            for (c, &j) in ucols.iter().enumerate() {
                s -= utail[c].to_f64() * y[j as usize];
            }
            for kk in r + 1..w {
                s -= p[base + nl + kk].to_f64() * y[first + kk];
            }
            y[first + r] = s / p[base + nl + r].to_f64();
        }
    } else {
        let mut s = y[first];
        for (c, &j) in ucols.iter().enumerate() {
            s -= fac.uvals[nd.u_start + c].to_f64() * y[j as usize];
        }
        y[first] = s / fac.diag[first].to_f64();
    }
}

/// Forward solve for one node over a dense row-major `n×k` RHS block,
/// vectorized across the `k` lanes ([`kernels::lanes_axpy_sub`]).
/// Column-for-column identical (same operations, same order) to
/// [`forward_node`] on every dispatch tier — the lane kernels keep each
/// lane's multiply/subtract sequence exactly the scalar one. Supernodes
/// at least [`kernels::BLOCK_PANEL_MIN_W`] wide route through the panel
/// TRSM+GEMM kernel, which preserves the same per-lane order.
#[inline]
fn forward_node_block<T: Scalar>(
    nd: &NodeSym,
    sym: &Symbolic,
    fac: &LuFactors<T>,
    id: usize,
    y: &mut [f64],
    k: usize,
    tier: KernelTier,
) {
    let first = nd.first as usize;
    let w = nd.width as usize;
    let nl = nd.nl();
    let lcols = &sym.lcols[nd.l_start..nd.l_end];
    if nd.is_super {
        let stride = nd.panel_width();
        let p = fac.panel(id);
        if w >= kernels::BLOCK_PANEL_MIN_W {
            kernels::forward_panel_block(tier, y, k, first, w, stride, p, lcols);
            return;
        }
        for r in 0..w {
            let base = r * stride;
            // rows before `first + r` are sources only (lcols < first,
            // in-block rows < r): split keeps the borrows disjoint
            let (done, rest) = y.split_at_mut((first + r) * k);
            let row = &mut rest[..k];
            for (c, &j) in lcols.iter().enumerate() {
                let src = j as usize * k;
                kernels::lanes_axpy_sub(tier, row, &done[src..src + k], p[base + c].to_f64());
            }
            for kk in 0..r {
                let src = (first + kk) * k;
                kernels::lanes_axpy_sub(tier, row, &done[src..src + k], p[base + nl + kk].to_f64());
            }
        }
    } else {
        let (done, rest) = y.split_at_mut(first * k);
        let row = &mut rest[..k];
        for (c, &j) in lcols.iter().enumerate() {
            let src = j as usize * k;
            kernels::lanes_axpy_sub(
                tier,
                row,
                &done[src..src + k],
                fac.lvals[nd.l_start + c].to_f64(),
            );
        }
    }
}

/// Backward solve for one node over a dense row-major `n×k` RHS block,
/// vectorized across the `k` lanes. Column-for-column identical to
/// [`backward_node`] on every dispatch tier; wide supernodes route
/// through the panel TRSM+GEMM kernel (see [`forward_node_block`]).
#[inline]
fn backward_node_block<T: Scalar>(
    nd: &NodeSym,
    sym: &Symbolic,
    fac: &LuFactors<T>,
    id: usize,
    y: &mut [f64],
    k: usize,
    tier: KernelTier,
) {
    let first = nd.first as usize;
    let w = nd.width as usize;
    let nl = nd.nl();
    let ucols = &sym.ucols[nd.u_start..nd.u_end];
    if nd.is_super {
        let stride = nd.panel_width();
        let p = fac.panel(id);
        if w >= kernels::BLOCK_PANEL_MIN_W {
            kernels::backward_panel_block(tier, y, k, first, w, nl, stride, p, ucols);
            return;
        }
        for r in (0..w).rev() {
            let base = r * stride;
            let utail = &p[base + nl + w..base + stride];
            // rows after `first + r` are sources only (ucols >= first + w,
            // in-block rows > r): split keeps the borrows disjoint
            let (head, rest) = y.split_at_mut((first + r + 1) * k);
            let row = &mut head[(first + r) * k..];
            for (c, &j) in ucols.iter().enumerate() {
                let src = (j as usize - first - r - 1) * k;
                kernels::lanes_axpy_sub(tier, row, &rest[src..src + k], utail[c].to_f64());
            }
            for kk in r + 1..w {
                let src = (kk - r - 1) * k;
                kernels::lanes_axpy_sub(tier, row, &rest[src..src + k], p[base + nl + kk].to_f64());
            }
            kernels::lanes_div(tier, row, p[base + nl + r].to_f64());
        }
    } else {
        let (head, rest) = y.split_at_mut((first + 1) * k);
        let row = &mut head[first * k..];
        for (c, &j) in ucols.iter().enumerate() {
            let src = (j as usize - first - 1) * k;
            kernels::lanes_axpy_sub(
                tier,
                row,
                &rest[src..src + k],
                fac.uvals[nd.u_start + c].to_f64(),
            );
        }
        kernels::lanes_div(tier, row, fac.diag[first].to_f64());
    }
}

/// Sequential forward substitution: `y <- L^{-1} y`.
pub fn forward<T: Scalar>(sym: &Symbolic, fac: &LuFactors<T>, y: &mut [f64]) {
    for (id, nd) in sym.nodes.iter().enumerate() {
        forward_node(nd, sym, fac, id, y);
    }
}

/// Sequential backward substitution: `y <- U^{-1} y`.
pub fn backward<T: Scalar>(sym: &Symbolic, fac: &LuFactors<T>, y: &mut [f64]) {
    for (id, nd) in sym.nodes.iter().enumerate().rev() {
        backward_node(nd, sym, fac, id, y);
    }
}

/// Sequential block forward substitution over a row-major `n×k` block
/// (active dispatch tier).
pub fn forward_block<T: Scalar>(sym: &Symbolic, fac: &LuFactors<T>, y: &mut [f64], k: usize) {
    forward_block_with(kernels::active_tier(), sym, fac, y, k);
}

/// Sequential block backward substitution over a row-major `n×k` block
/// (active dispatch tier).
pub fn backward_block<T: Scalar>(sym: &Symbolic, fac: &LuFactors<T>, y: &mut [f64], k: usize) {
    backward_block_with(kernels::active_tier(), sym, fac, y, k);
}

/// [`forward_block`] on an explicit dispatch tier (A/B benching; every
/// tier produces bit-identical blocks).
pub fn forward_block_with<T: Scalar>(
    tier: KernelTier,
    sym: &Symbolic,
    fac: &LuFactors<T>,
    y: &mut [f64],
    k: usize,
) {
    if k == 0 {
        return;
    }
    for (id, nd) in sym.nodes.iter().enumerate() {
        forward_node_block(nd, sym, fac, id, y, k, tier);
    }
}

/// [`backward_block`] on an explicit dispatch tier.
pub fn backward_block_with<T: Scalar>(
    tier: KernelTier,
    sym: &Symbolic,
    fac: &LuFactors<T>,
    y: &mut [f64],
    k: usize,
) {
    if k == 0 {
        return;
    }
    for (id, nd) in sym.nodes.iter().enumerate().rev() {
        backward_node_block(nd, sym, fac, id, y, k, tier);
    }
}

/// Shared-mutable solution vector for the level-parallel solves.
/// Safety: nodes in one level write disjoint `y` rows and only read rows
/// finished in earlier levels (barrier-separated).
struct YPtr(*mut f64);
unsafe impl Send for YPtr {}
unsafe impl Sync for YPtr {}

/// Parallel forward substitution (bulk-sequential dual mode) as a job on a
/// persistent pool, with level chunks from the plan.
pub fn forward_parallel_pooled<T: Scalar>(
    sym: &Symbolic,
    fac: &LuFactors<T>,
    y: &mut [f64],
    pool: &WorkerPool,
    plan: &ExecPlan,
) {
    let sched = &sym.schedule;
    if pool.nthreads() <= 1 || sched.bulk_levels == 0 {
        return forward(sym, fac, y);
    }
    let mut plan_storage = None;
    let plan = plan.for_width(sym, pool.nthreads(), &mut plan_storage);
    let yp = YPtr(y.as_mut_ptr());
    let ylen = y.len();
    let barrier = Barrier::new(pool.nthreads());
    pool.run(
        || {},
        |t, _ctx| {
            // Safety: see `YPtr` — disjoint row writes, barrier-separated
            // level reads.
            let y = unsafe { std::slice::from_raw_parts_mut(yp.0, ylen) };
            for (lv, chunks) in plan.fwd_chunks.iter().enumerate() {
                let ids = sched.nodes_at(lv);
                let (s, e) = chunks[t];
                for &id in &ids[s..e] {
                    forward_node(&sym.nodes[id as usize], sym, fac, id as usize, y);
                }
                barrier.wait();
            }
            // sequential tail on worker 0
            if t == 0 {
                for lv in sched.bulk_levels..sched.nlevels() {
                    for &id in sched.nodes_at(lv) {
                        forward_node(&sym.nodes[id as usize], sym, fac, id as usize, y);
                    }
                }
            }
        },
    );
}

/// Parallel backward substitution (bulk-sequential dual mode on the
/// reverse levelization) as a job on a persistent pool.
pub fn backward_parallel_pooled<T: Scalar>(
    sym: &Symbolic,
    fac: &LuFactors<T>,
    y: &mut [f64],
    pool: &WorkerPool,
    plan: &ExecPlan,
) {
    let sched = &sym.schedule;
    if pool.nthreads() <= 1 || sched.rbulk_levels == 0 {
        return backward(sym, fac, y);
    }
    let mut plan_storage = None;
    let plan = plan.for_width(sym, pool.nthreads(), &mut plan_storage);
    let yp = YPtr(y.as_mut_ptr());
    let ylen = y.len();
    let barrier = Barrier::new(pool.nthreads());
    let nrlev = sched.rlevel_ptr.len() - 1;
    pool.run(
        || {},
        |t, _ctx| {
            // Safety: see `YPtr`.
            let y = unsafe { std::slice::from_raw_parts_mut(yp.0, ylen) };
            for (lv, chunks) in plan.bwd_chunks.iter().enumerate() {
                let ids = &sched.rlevel_nodes[sched.rlevel_ptr[lv]..sched.rlevel_ptr[lv + 1]];
                let (s, e) = chunks[t];
                for &id in &ids[s..e] {
                    backward_node(&sym.nodes[id as usize], sym, fac, id as usize, y);
                }
                barrier.wait();
            }
            if t == 0 {
                for lv in sched.rbulk_levels..nrlev {
                    for &id in &sched.rlevel_nodes[sched.rlevel_ptr[lv]..sched.rlevel_ptr[lv + 1]]
                    {
                        backward_node(&sym.nodes[id as usize], sym, fac, id as usize, y);
                    }
                }
            }
        },
    );
}

/// Batched forward + backward substitution over a row-major `n×k` RHS
/// block in **one** pool dispatch: bulk levels run chunked across workers
/// with barriers, the dependent tails run on worker 0, and a barrier
/// separates the forward sweep from the backward sweep.
pub fn solve_block_parallel_pooled<T: Scalar>(
    sym: &Symbolic,
    fac: &LuFactors<T>,
    y: &mut [f64],
    k: usize,
    pool: &WorkerPool,
    plan: &ExecPlan,
) {
    let sched = &sym.schedule;
    if pool.nthreads() <= 1 || (sched.bulk_levels == 0 && sched.rbulk_levels == 0) {
        forward_block(sym, fac, y, k);
        backward_block(sym, fac, y, k);
        return;
    }
    if k == 0 {
        return;
    }
    let tier = kernels::active_tier();
    let mut plan_storage = None;
    let plan = plan.for_width(sym, pool.nthreads(), &mut plan_storage);
    let yp = YPtr(y.as_mut_ptr());
    let ylen = y.len();
    let barrier = Barrier::new(pool.nthreads());
    let nrlev = sched.rlevel_ptr.len() - 1;
    pool.run(
        || {},
        |t, _ctx| {
            // Safety: see `YPtr` — each node owns k-column row slices.
            let y = unsafe { std::slice::from_raw_parts_mut(yp.0, ylen) };
            // forward sweep
            for (lv, chunks) in plan.fwd_chunks.iter().enumerate() {
                let ids = sched.nodes_at(lv);
                let (s, e) = chunks[t];
                for &id in &ids[s..e] {
                    forward_node_block(&sym.nodes[id as usize], sym, fac, id as usize, y, k, tier);
                }
                barrier.wait();
            }
            if t == 0 {
                for lv in sched.bulk_levels..sched.nlevels() {
                    for &id in sched.nodes_at(lv) {
                        forward_node_block(
                            &sym.nodes[id as usize],
                            sym,
                            fac,
                            id as usize,
                            y,
                            k,
                            tier,
                        );
                    }
                }
            }
            // forward tail must be visible to every worker before backward
            barrier.wait();
            // backward sweep
            for (lv, chunks) in plan.bwd_chunks.iter().enumerate() {
                let ids = &sched.rlevel_nodes[sched.rlevel_ptr[lv]..sched.rlevel_ptr[lv + 1]];
                let (s, e) = chunks[t];
                for &id in &ids[s..e] {
                    backward_node_block(&sym.nodes[id as usize], sym, fac, id as usize, y, k, tier);
                }
                barrier.wait();
            }
            if t == 0 {
                for lv in sched.rbulk_levels..nrlev {
                    for &id in &sched.rlevel_nodes[sched.rlevel_ptr[lv]..sched.rlevel_ptr[lv + 1]]
                    {
                        backward_node_block(
                            &sym.nodes[id as usize],
                            sym,
                            fac,
                            id as usize,
                            y,
                            k,
                            tier,
                        );
                    }
                }
            }
        },
    );
}

/// Parallel forward substitution with a temporary pool (legacy signature;
/// repeated-solve callers use [`forward_parallel_pooled`] via the
/// coordinator's persistent engine).
pub fn forward_parallel<T: Scalar>(sym: &Symbolic, fac: &LuFactors<T>, y: &mut [f64], nthreads: usize) {
    let sched = &sym.schedule;
    if nthreads <= 1 || sched.bulk_levels == 0 {
        return forward(sym, fac, y);
    }
    let pool = WorkerPool::new(nthreads);
    let plan = ExecPlan::build(sym, nthreads);
    forward_parallel_pooled(sym, fac, y, &pool, &plan);
}

/// Parallel backward substitution with a temporary pool (legacy
/// signature).
pub fn backward_parallel<T: Scalar>(sym: &Symbolic, fac: &LuFactors<T>, y: &mut [f64], nthreads: usize) {
    let sched = &sym.schedule;
    if nthreads <= 1 || sched.rbulk_levels == 0 {
        return backward(sym, fac, y);
    }
    let pool = WorkerPool::new(nthreads);
    let plan = ExecPlan::build(sym, nthreads);
    backward_parallel_pooled(sym, fac, y, &pool, &plan);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::factor::{factor, NativeGemm};
    use crate::numeric::select::KernelMode;
    use crate::numeric::PivotConfig;
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};
    use crate::testutil::max_abs_diff;

    /// Factor + substitute must invert P·A for a matrix that needs no
    /// global pivoting (diagonally dominant).
    fn check_solve(a: &crate::sparse::csr::Csr, mode: KernelMode, tol: f64) {
        let policy = match mode {
            KernelMode::RowRow => MergePolicy::None,
            _ => MergePolicy::Exact { max_width: 16 },
        };
        let sym = analyze_pattern(a, policy, 4);
        let cfg = PivotConfig::default();
        let mut fac: LuFactors = LuFactors::alloc(&sym);
        factor(a, &sym, mode, &cfg, &mut fac, false, &NativeGemm);
        // true solution of A x = b with x* = ramp
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        // apply pivot perm: y[i] = b[pivot_perm[i]]
        let mut y: Vec<f64> = (0..a.n).map(|i| b[fac.pivot_perm[i] as usize]).collect();
        forward(&sym, &fac, &mut y);
        backward(&sym, &fac, &mut y);
        assert!(
            max_abs_diff(&y, &xt) < tol,
            "solve error {} (mode {mode})",
            max_abs_diff(&y, &xt)
        );
        // parallel variants must agree with sequential exactly
        for threads in [2usize, 4] {
            let mut y2: Vec<f64> = (0..a.n).map(|i| b[fac.pivot_perm[i] as usize]).collect();
            forward_parallel(&sym, &fac, &mut y2, threads);
            backward_parallel(&sym, &fac, &mut y2, threads);
            assert_eq!(y, y2, "parallel solve mismatch t={threads}");
        }
        // pooled variants on a persistent pool must agree exactly too
        let pool = WorkerPool::new(3);
        let plan = ExecPlan::build(&sym, 3);
        let mut y3: Vec<f64> = (0..a.n).map(|i| b[fac.pivot_perm[i] as usize]).collect();
        forward_parallel_pooled(&sym, &fac, &mut y3, &pool, &plan);
        backward_parallel_pooled(&sym, &fac, &mut y3, &pool, &plan);
        assert_eq!(y, y3, "pooled solve mismatch");
        // block variants (k = 3, identical columns) must match column-wise
        let k = 3usize;
        let mut yb = vec![0.0; a.n * k];
        for i in 0..a.n {
            for q in 0..k {
                yb[i * k + q] = b[fac.pivot_perm[i] as usize];
            }
        }
        solve_block_parallel_pooled(&sym, &fac, &mut yb, k, &pool, &plan);
        for q in 0..k {
            for i in 0..a.n {
                assert_eq!(yb[i * k + q], y[i], "block mismatch col {q} row {i}");
            }
        }
        // sequential block path agrees as well
        let mut ys = vec![0.0; a.n * k];
        for i in 0..a.n {
            for q in 0..k {
                ys[i * k + q] = b[fac.pivot_perm[i] as usize];
            }
        }
        forward_block(&sym, &fac, &mut ys, k);
        backward_block(&sym, &fac, &mut ys, k);
        assert_eq!(ys, yb, "sequential vs pooled block mismatch");
    }

    #[test]
    fn solves_identity() {
        check_solve(&crate::sparse::csr::Csr::identity(20), KernelMode::RowRow, 1e-14);
    }

    #[test]
    fn solves_grid_all_modes() {
        let a = gen::grid2d(9, 9);
        for mode in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
            check_solve(&a, mode, 1e-8);
        }
    }

    #[test]
    fn solves_banded_and_power() {
        check_solve(&gen::banded(80, 3, 2), KernelMode::SupSup, 1e-7);
        check_solve(&gen::power_network(150, 3), KernelMode::SupRow, 1e-7);
    }

    #[test]
    fn solves_circuit() {
        check_solve(&gen::circuit(300, 4), KernelMode::RowRow, 1e-7);
    }

    #[test]
    fn f32_factors_solve_and_keep_block_bit_identity() {
        let a = gen::grid2d(8, 8);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let cfg = PivotConfig::default();
        let mut fac: LuFactors<f32> = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut fac, false, &NativeGemm);
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 5) as f64 - 2.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let mut y: Vec<f64> = (0..a.n).map(|i| b[fac.pivot_perm[i] as usize]).collect();
        forward(&sym, &fac, &mut y);
        backward(&sym, &fac, &mut y);
        // f32 factors solve to roughly single precision
        assert!(max_abs_diff(&y, &xt) < 1e-3, "err {}", max_abs_diff(&y, &xt));
        // batched-vs-scalar bit identity holds with f32 factors too: the
        // lane kernels consume the same widened multipliers in the same
        // order as the scalar path
        let k = 3usize;
        let mut yb = vec![0.0; a.n * k];
        for i in 0..a.n {
            for q in 0..k {
                yb[i * k + q] = b[fac.pivot_perm[i] as usize];
            }
        }
        forward_block(&sym, &fac, &mut yb, k);
        backward_block(&sym, &fac, &mut yb, k);
        for q in 0..k {
            for i in 0..a.n {
                assert_eq!(yb[i * k + q], y[i], "f32 block mismatch col {q} row {i}");
            }
        }
    }

    #[test]
    fn block_with_distinct_columns_matches_independent_solves() {
        let a = gen::grid2d(10, 10);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let cfg = PivotConfig::default();
        let mut fac: LuFactors = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut fac, false, &NativeGemm);
        let k = 4usize;
        let n = a.n;
        let cols: Vec<Vec<f64>> = (0..k)
            .map(|q| (0..n).map(|i| ((i * (q + 2)) % 11) as f64 - 5.0).collect())
            .collect();
        let mut yb = vec![0.0; n * k];
        for i in 0..n {
            for (q, col) in cols.iter().enumerate() {
                yb[i * k + q] = col[i];
            }
        }
        forward_block(&sym, &fac, &mut yb, k);
        backward_block(&sym, &fac, &mut yb, k);
        for (q, col) in cols.iter().enumerate() {
            let mut y = col.clone();
            forward(&sym, &fac, &mut y);
            backward(&sym, &fac, &mut y);
            for i in 0..n {
                assert_eq!(yb[i * k + q], y[i], "col {q} row {i}");
            }
        }
        // every dispatch tier must reproduce the block bit-for-bit (the
        // lane kernels never fuse or reorder per-lane operations)
        for tier in [
            crate::numeric::kernels::KernelTier::Scalar,
            crate::numeric::kernels::KernelTier::Portable,
            crate::numeric::kernels::KernelTier::Native,
        ] {
            if !tier.available() {
                continue;
            }
            let mut yt = vec![0.0; n * k];
            for i in 0..n {
                for (q, col) in cols.iter().enumerate() {
                    yt[i * k + q] = col[i];
                }
            }
            forward_block_with(tier, &sym, &fac, &mut yt, k);
            backward_block_with(tier, &sym, &fac, &mut yt, k);
            assert_eq!(yt, yb, "tier {tier} block mismatch");
        }
    }
}

//! Test utilities: deterministic PRNG, dense LU oracle, and a tiny
//! property-testing harness (proptest is unavailable in the offline
//! registry, so we hand-roll the 20% of it we need).

/// xorshift64* PRNG — deterministic, seedable, no dependencies.
#[derive(Clone, Debug)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Create a PRNG; a zero seed is remapped to a fixed odd constant.
    pub fn new(seed: u64) -> Self {
        Prng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`; `n` must be nonzero.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard-normal-ish value (sum of uniforms, Irwin–Hall 12).
    pub fn normal(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.uniform();
        }
        s - 6.0
    }

    /// Random nonzero value bounded away from 0 (for matrix entries).
    pub fn nonzero(&mut self) -> f64 {
        let v = self.range_f64(0.1, 1.0);
        if self.next_u64() & 1 == 0 {
            v
        } else {
            -v
        }
    }

    /// Random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            p.swap(i, self.below(i + 1));
        }
        p
    }
}

/// Run `f` over `cases` deterministic seeds; on failure, report the seed so
/// the case replays exactly. Poor-man's proptest.
pub fn for_each_seed(cases: u64, mut f: impl FnMut(&mut Prng)) {
    for seed in 1..=cases {
        let mut rng = Prng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Dense column-major matrix oracle for small-n checks.
#[derive(Clone, Debug)]
pub struct Dense {
    pub n: usize,
    pub a: Vec<f64>, // row-major n*n
}

impl Dense {
    pub fn zeros(n: usize) -> Self {
        Dense {
            n,
            a: vec![0.0; n * n],
        }
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    /// Dense `A x` for residual checks.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += self.a[i * n + j] * x[j];
            }
            y[i] = s;
        }
        y
    }

    /// Solve `A x = b` by dense partial-pivoted LU. Returns None if singular
    /// to working precision. The ground-truth oracle for solver tests.
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let n = self.n;
        let mut a = self.a.clone();
        let mut x = b.to_vec();
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // pivot search
            let mut p = k;
            let mut best = a[piv[k] * n + k].abs();
            for r in k + 1..n {
                let v = a[piv[r] * n + k].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < 1e-300 {
                return None;
            }
            piv.swap(k, p);
            let akk = a[piv[k] * n + k];
            for r in k + 1..n {
                let f = a[piv[r] * n + k] / akk;
                a[piv[r] * n + k] = f;
                for c in k + 1..n {
                    a[piv[r] * n + c] -= f * a[piv[k] * n + c];
                }
            }
        }
        // forward
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = x[piv[i]];
            for j in 0..i {
                s -= a[piv[i] * n + j] * y[j];
            }
            y[i] = s;
        }
        // backward
        for i in (0..n).rev() {
            let mut s = y[i];
            for j in i + 1..n {
                s -= a[piv[i] * n + j] * x[j];
            }
            x[i] = s / a[piv[i] * n + i];
        }
        Some(x)
    }
}

/// `max_i |x_i - y_i|`.
pub fn max_abs_diff(x: &[f64], y: &[f64]) -> f64 {
    x.iter()
        .zip(y)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max)
}

/// `‖Ax − b‖₁ / ‖b‖₁` with a dense reference matvec.
pub fn relative_residual_dense(a: &Dense, x: &[f64], b: &[f64]) -> f64 {
    let ax = a.matvec(x);
    let num: f64 = ax.iter().zip(b).map(|(p, q)| (p - q).abs()).sum();
    let den: f64 = b.iter().map(|v| v.abs()).sum();
    num / den.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prng_is_deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn prng_uniform_in_range() {
        let mut r = Prng::new(7);
        for _ in 0..1000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Prng::new(3);
        for n in [1usize, 2, 5, 33, 100] {
            let p = r.permutation(n);
            let mut seen = vec![false; n];
            for &v in &p {
                assert!(!seen[v]);
                seen[v] = true;
            }
        }
    }

    #[test]
    fn dense_lu_solves_identity() {
        let mut a = Dense::zeros(4);
        for i in 0..4 {
            a.set(i, i, 1.0);
        }
        let x = a.solve(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn dense_lu_matches_matvec_roundtrip() {
        let mut rng = Prng::new(11);
        for n in [2usize, 3, 8, 17] {
            let mut a = Dense::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    a.set(i, j, rng.normal());
                }
                a.set(i, i, a.get(i, i) + 4.0); // diagonally dominant-ish
            }
            let xt: Vec<f64> = (0..n).map(|i| i as f64 - 1.5).collect();
            let b = a.matvec(&xt);
            let x = a.solve(&b).unwrap();
            assert!(max_abs_diff(&x, &xt) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn dense_lu_detects_singular() {
        let a = Dense::zeros(3);
        assert!(a.solve(&[1.0, 1.0, 1.0]).is_none());
    }
}

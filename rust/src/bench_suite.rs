//! The 37-matrix benchmark suite — the offline stand-in for the paper's 37
//! SuiteSparse matrices (dimensions 525,825–5,558,326 in the paper; scaled
//! to laptop size here, same sparsity classes — DESIGN.md §2).
//!
//! Class mix mirrors the paper's set: circuit simulation (ASIC_680k,
//! circuit5M, rajat, memchip-like), power networks, 2-D/3-D PDE meshes
//! (G3_circuit, thermal, apache-like), KKT/optimization (nlpkkt80-like),
//! structured bands, unstructured random, and one ill-conditioned case
//! (Hamrle3-like).

use crate::sparse::csr::Csr;
use crate::sparse::gen;

/// One suite entry.
pub struct BenchMatrix {
    /// Paper-evocative name.
    pub name: &'static str,
    /// Sparsity class label.
    pub class: &'static str,
    /// Builder (deterministic).
    pub build: fn() -> Csr,
}

macro_rules! m {
    ($name:literal, $class:literal, $body:expr) => {
        BenchMatrix {
            name: $name,
            class: $class,
            build: || $body,
        }
    };
}

/// The full 37-matrix suite.
pub fn suite37() -> Vec<BenchMatrix> {
    vec![
        // --- circuit simulation (10) ---
        m!("asic680_a", "circuit", gen::circuit(12000, 11)),
        m!("asic680_b", "circuit", gen::circuit(16000, 12)),
        m!("circuit5M_s", "circuit", gen::circuit(16000, 13)),
        m!("rajat_a", "circuit", gen::circuit(6000, 14)),
        m!("rajat_b", "circuit", gen::circuit(9000, 15)),
        m!("memchip_s", "circuit", gen::circuit(14000, 16)),
        m!("freescale_s", "circuit", gen::circuit(10000, 17)),
        m!("hvdc_like", "circuit", gen::circuit(4000, 18)),
        m!("onetone_like", "circuit", gen::circuit(8000, 19)),
        m!("twotone_like", "circuit", gen::circuit(10000, 20)),
        // --- power networks (4) ---
        m!("tsc_opf_a", "power", gen::power_network(8000, 21)),
        m!("tsc_opf_b", "power", gen::power_network(12000, 22)),
        m!("case39_like", "power", gen::power_network(5000, 23)),
        m!("powergrid_s", "power", gen::power_network(16000, 24)),
        // --- 2-D meshes / PDE (6) ---
        m!("g3_circuit_s", "mesh2d", gen::grid2d(90, 90)),
        m!("thermal1_s", "mesh2d", gen::grid2d(70, 70)),
        m!("thermal2_s", "mesh2d", gen::grid2d(100, 100)),
        m!("ecology_s", "mesh2d", gen::grid2d(80, 120)),
        m!("convdiff_a", "mesh2d", gen::convdiff2d(80, 80, 4.0, 25)),
        m!("convdiff_b", "mesh2d", gen::convdiff2d(100, 60, 12.0, 26)),
        // --- 3-D meshes (4) ---
        m!("apache_s", "mesh3d", gen::grid3d(16, 16, 16)),
        m!("parabolic_s", "mesh3d", gen::grid3d(14, 14, 20)),
        m!("torso_like", "mesh3d", gen::grid3d(18, 14, 14)),
        m!("stomach_like", "mesh3d", gen::grid3d(12, 12, 24)),
        // --- KKT / optimization (4) ---
        m!("nlpkkt80_s", "kkt", gen::kkt(4000, 1400, 27)),
        m!("nlpkkt120_s", "kkt", gen::kkt(5000, 1700, 28)),
        m!("opt_kkt_a", "kkt", gen::kkt(2500, 900, 29)),
        m!("opt_kkt_b", "kkt", gen::kkt(3200, 1100, 30)),
        // --- structured bands (4) ---
        m!("band_narrow", "banded", gen::banded(8000, 4, 31)),
        m!("band_medium", "banded", gen::banded(5000, 12, 32)),
        m!("band_wide", "banded", gen::banded(3000, 24, 33)),
        m!("band_xwide", "banded", gen::banded(1600, 48, 34)),
        // --- unstructured random (3) ---
        m!("rand_sparse_a", "random", gen::random_sparse(4500, 3, 35)),
        m!("rand_sparse_b", "random", gen::random_sparse(7000, 3, 36)),
        m!("rand_dense_row", "random", gen::random_sparse(2200, 6, 37)),
        // --- ill-conditioned (2) ---
        m!("hamrle3_s", "illcond", gen::ill_conditioned(4000, 38)),
        m!("illcond_b", "illcond", gen::ill_conditioned(2000, 39)),
    ]
}

/// A small fast subset for smoke benches / CI.
pub fn suite_small() -> Vec<BenchMatrix> {
    vec![
        m!("circuit_s", "circuit", gen::circuit(3000, 1)),
        m!("power_s", "power", gen::power_network(2500, 2)),
        m!("mesh2d_s", "mesh2d", gen::grid2d(45, 45)),
        m!("mesh3d_s", "mesh3d", gen::grid3d(10, 10, 10)),
        m!("kkt_s", "kkt", gen::kkt(1200, 400, 3)),
        m!("band_s", "banded", gen::banded(2000, 8, 4)),
        // the accuracy-sensitive cases (Fig 11 needs perturbation +
        // refinement to matter; well-conditioned matrices solve to machine
        // epsilon either way)
        m!("illcond_s", "illcond", gen::ill_conditioned(1500, 5)),
        m!("convdiff_s", "mesh2d", gen::convdiff2d(40, 40, 24.0, 6)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_37_unique_valid_matrices() {
        let s = suite37();
        assert_eq!(s.len(), 37);
        let mut names = std::collections::BTreeSet::new();
        for b in &s {
            assert!(names.insert(b.name), "dup {}", b.name);
        }
        // spot-build a few from each class
        for b in s.iter().step_by(6) {
            let a = (b.build)();
            a.validate().unwrap();
            assert!(a.n >= 1000, "{} too small", b.name);
        }
    }

    #[test]
    fn class_mix_matches_design() {
        let s = suite37();
        let count = |c: &str| s.iter().filter(|b| b.class == c).count();
        assert_eq!(count("circuit"), 10);
        assert_eq!(count("power"), 4);
        assert_eq!(count("mesh2d"), 6);
        assert_eq!(count("mesh3d"), 4);
        assert_eq!(count("kkt"), 4);
        assert_eq!(count("banded"), 4);
        assert_eq!(count("random"), 3);
        assert_eq!(count("illcond"), 2);
    }
}

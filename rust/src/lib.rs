//! # HYLU — Hybrid Parallel Sparse LU Factorization
//!
//! A reproduction of *"HYLU: Hybrid Parallel Sparse LU Factorization"*
//! (Xiaoming Chen, 2025) as a three-layer Rust + JAX/Pallas stack.
//!
//! HYLU is a general-purpose direct solver for sparse `A x = b` on
//! shared-memory multicores. Its key idea: no single numeric kernel wins
//! across sparsity patterns, so it integrates three **hybrid up-looking
//! kernels** — row-row (scalar, KLU-like), sup-row (level-2, supernode
//! sources updating one row), and sup-sup (level-3, supernode panels with
//! TRSM+GEMM) — and picks between them from symbolic-analysis statistics.
//!
//! ## Pipeline
//!
//! ```text
//! analyze:  MC64 static pivoting + scaling -> AMD / nested-dissection
//!           ordering -> up-looking symbolic factorization -> supernode
//!           detection -> dependency DAG levelization -> kernel selection
//! factor:   hybrid numeric kernels, supernode diagonal pivoting +
//!           perturbation; dual-mode (bulk | pipeline) parallelism
//! refactor: pattern-reusing numeric-only fast path (repeated solve)
//! solve:    partition/level-based parallel fwd/bwd substitution;
//!           iterative refinement (automatic after pivot perturbation)
//! serve:    sharded, request-coalescing [`service::SolverService`]
//!           front door for concurrent callers (batched block solves)
//! ```
//!
//! The public surface is the typestate handle API in [`api`]
//! ([`api::SolverBuilder`] → [`api::Solver::analyze`] →
//! [`api::LinearSystem`]); a stable C ABI over the same handles lives
//! behind the `ffi` feature (`include/hylu.h`).
//!
//! See `DESIGN.md` for the paper-to-module map (including the persistent
//! execution engine in [`exec`]) and `benches/` for the reproduction of
//! the paper's evaluation figures.

pub mod api;
pub mod baseline;
pub mod bench_harness;
pub mod bench_suite;
pub mod cli;
pub mod coordinator;
pub mod exec;
#[cfg(feature = "ffi")]
pub mod ffi;
pub mod numeric;
pub mod ordering;
pub mod par;
pub mod runtime;
pub mod service;
pub mod solve;
pub mod sparse;
pub mod symbolic;
pub mod testutil;

/// Common imports for downstream users.
///
/// `Solver` here is the handle-based [`crate::api::Solver`]; the legacy
/// triple-threading solver stays importable as
/// [`crate::coordinator::Solver`] (deprecated).
pub mod prelude {
    pub use crate::api::{Analyzed, Factored, LinearSystem, SolveOpts, Solver, SolverBuilder};
    pub use crate::coordinator::{
        EscalationController, FactorStats, Fault, FaultPlan, Precision, ReanalyzeKind,
        RefactorTier, RefineOutcome, SolveStats, SolverConfig, SymbolicStats,
    };
    pub use crate::numeric::kernels::{KernelPlan, KernelTier, Tuning};
    pub use crate::numeric::select::KernelMode;
    pub use crate::ordering::OrderingChoice;
    pub use crate::service::{
        Health, Priority, QuarantineReason, ServiceConfig, ServiceStats, SolverService, SystemId,
        SystemLoad,
    };
    pub use crate::sparse::csr::Csr;
    pub use crate::sparse::input::{CscInput, MatrixInput};
    pub use crate::sparse::Coo;
}

/// Crate-wide error type.
///
/// Marked `#[non_exhaustive]`: downstream matches need a wildcard arm, so
/// future variants are not a breaking change. Every variant carries a
/// stable numeric code ([`Error::code`]) shared by the C ABI
/// (`include/hylu.h`) and the `hylu` CLI's process exit status.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub enum Error {
    /// The matrix is structurally singular (no full transversal exists).
    StructurallySingular { matched: usize, n: usize },
    /// A zero/tiny pivot could not be perturbed (perturbation disabled).
    ZeroPivot { row: usize },
    /// Input validation failure.
    Invalid(String),
    /// I/O or parse failure (MatrixMarket, artifacts, ...).
    Io(String),
    /// XLA/PJRT runtime failure.
    Runtime(String),
    /// A shard dispatcher caught a panic while this request was in
    /// flight. The shard survived (scrubbed + restarted its drain loop);
    /// the request did not. Resubmitting is safe.
    ShardPanicked { shard: usize },
    /// A deadline-lane request's deadline passed before dispatch (the
    /// service was configured to expire stale deadline work).
    DeadlineExpired,
    /// The target system is quarantined after a numeric failure (zero
    /// pivot, singular refactor, excessive pivot growth, or a caught
    /// panic mid-refactor); the message names the reason. The service
    /// auto-escalates to a full re-pivot factorization — retry later.
    Quarantined(String),
}

impl Error {
    /// Stable numeric code for this error, shared across the library, the
    /// C ABI (`include/hylu.h`, `HYLU_ERR_*`), and the CLI exit status.
    ///
    /// | code | meaning                              |
    /// |------|--------------------------------------|
    /// | 0    | success (never returned by `code`)   |
    /// | 2    | invalid input ([`Error::Invalid`])   |
    /// | 3    | I/O or parse failure ([`Error::Io`]) |
    /// | 4    | structurally singular                |
    /// | 5    | zero pivot (perturbation disabled)   |
    /// | 6    | runtime/backend failure              |
    /// | 7    | shard caught a panic in flight       |
    /// | 8    | deadline expired before dispatch     |
    /// | 9    | system quarantined                   |
    ///
    /// Codes are append-only: existing assignments never change, new
    /// variants get new codes. Code 1 is reserved (generic failure in
    /// shells and test harnesses).
    pub fn code(&self) -> i32 {
        match self {
            Error::Invalid(_) => 2,
            Error::Io(_) => 3,
            Error::StructurallySingular { .. } => 4,
            Error::ZeroPivot { .. } => 5,
            Error::Runtime(_) => 6,
            Error::ShardPanicked { .. } => 7,
            Error::DeadlineExpired => 8,
            Error::Quarantined(_) => 9,
        }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::StructurallySingular { matched, n } => write!(
                f,
                "structurally singular: maximum transversal matched {matched} of {n} rows"
            ),
            Error::ZeroPivot { row } => write!(f, "zero pivot at row {row}"),
            Error::Invalid(m) => write!(f, "invalid input: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::ShardPanicked { shard } => {
                write!(f, "shard {shard} caught a panic while the request was in flight")
            }
            Error::DeadlineExpired => write!(f, "deadline passed before the request was dispatched"),
            Error::Quarantined(m) => write!(f, "system quarantined: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::Error;

    /// Every variant must have a stable, distinct `code()`. The `match`
    /// below is exhaustive *inside* the crate (no wildcard), so adding a
    /// variant without deciding its ABI code fails this test's build —
    /// the FFI-side mirror (`ffi::tests`) then pins the `HYLU_ERR_*`
    /// constants to the same values.
    #[test]
    fn error_codes_are_stable_and_exhaustive() {
        let samples = [
            Error::Invalid(String::new()),
            Error::Io(String::new()),
            Error::StructurallySingular { matched: 0, n: 1 },
            Error::ZeroPivot { row: 0 },
            Error::Runtime(String::new()),
            Error::ShardPanicked { shard: 0 },
            Error::DeadlineExpired,
            Error::Quarantined(String::new()),
        ];
        for e in &samples {
            let expect = match e {
                Error::Invalid(_) => 2,
                Error::Io(_) => 3,
                Error::StructurallySingular { .. } => 4,
                Error::ZeroPivot { .. } => 5,
                Error::Runtime(_) => 6,
                Error::ShardPanicked { .. } => 7,
                Error::DeadlineExpired => 8,
                Error::Quarantined(_) => 9,
            };
            assert_eq!(e.code(), expect, "code drifted for {e}");
        }
        // distinct and never colliding with 0 (success) / 1 (FFI panic)
        let mut codes: Vec<i32> = samples.iter().map(Error::code).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), samples.len(), "duplicate error codes");
        assert!(codes.iter().all(|&c| c >= 2), "codes 0/1 are reserved");
    }
}

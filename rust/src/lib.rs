//! # HYLU — Hybrid Parallel Sparse LU Factorization
//!
//! A reproduction of *"HYLU: Hybrid Parallel Sparse LU Factorization"*
//! (Xiaoming Chen, 2025) as a three-layer Rust + JAX/Pallas stack.
//!
//! HYLU is a general-purpose direct solver for sparse `A x = b` on
//! shared-memory multicores. Its key idea: no single numeric kernel wins
//! across sparsity patterns, so it integrates three **hybrid up-looking
//! kernels** — row-row (scalar, KLU-like), sup-row (level-2, supernode
//! sources updating one row), and sup-sup (level-3, supernode panels with
//! TRSM+GEMM) — and picks between them from symbolic-analysis statistics.
//!
//! ## Pipeline
//!
//! ```text
//! analyze:  MC64 static pivoting + scaling -> AMD / nested-dissection
//!           ordering -> up-looking symbolic factorization -> supernode
//!           detection -> dependency DAG levelization -> kernel selection
//! factor:   hybrid numeric kernels, supernode diagonal pivoting +
//!           perturbation; dual-mode (bulk | pipeline) parallelism
//! refactor: pattern-reusing numeric-only fast path (repeated solve)
//! solve:    partition/level-based parallel fwd/bwd substitution;
//!           iterative refinement (automatic after pivot perturbation)
//! serve:    sharded, request-coalescing [`service::SolverService`]
//!           front door for concurrent callers (batched block solves)
//! ```
//!
//! The public surface is the typestate handle API in [`api`]
//! ([`api::SolverBuilder`] → [`api::Solver::analyze`] →
//! [`api::LinearSystem`]); a stable C ABI over the same handles lives
//! behind the `ffi` feature (`include/hylu.h`).
//!
//! See `DESIGN.md` for the paper-to-module map (including the persistent
//! execution engine in [`exec`]) and `benches/` for the reproduction of
//! the paper's evaluation figures.

pub mod api;
pub mod baseline;
pub mod bench_harness;
pub mod bench_suite;
pub mod cli;
pub mod coordinator;
pub mod exec;
#[cfg(feature = "ffi")]
pub mod ffi;
pub mod numeric;
pub mod ordering;
pub mod par;
pub mod runtime;
pub mod service;
pub mod solve;
pub mod sparse;
pub mod symbolic;
pub mod testutil;

/// Common imports for downstream users.
///
/// `Solver` here is the handle-based [`crate::api::Solver`]; the legacy
/// triple-threading solver stays importable as
/// [`crate::coordinator::Solver`] (deprecated).
pub mod prelude {
    pub use crate::api::{Analyzed, Factored, LinearSystem, SolveOpts, Solver, SolverBuilder};
    pub use crate::coordinator::{
        FactorStats, Precision, RefineOutcome, SolveStats, SolverConfig, SymbolicStats,
    };
    pub use crate::numeric::kernels::{KernelPlan, KernelTier, Tuning};
    pub use crate::numeric::select::KernelMode;
    pub use crate::ordering::OrderingChoice;
    pub use crate::service::{
        Priority, ServiceConfig, ServiceStats, SolverService, SystemId, SystemLoad,
    };
    pub use crate::sparse::csr::Csr;
    pub use crate::sparse::input::{CscInput, MatrixInput};
    pub use crate::sparse::Coo;
}

/// Crate-wide error type.
///
/// Marked `#[non_exhaustive]`: downstream matches need a wildcard arm, so
/// future variants are not a breaking change. Every variant carries a
/// stable numeric code ([`Error::code`]) shared by the C ABI
/// (`include/hylu.h`) and the `hylu` CLI's process exit status.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub enum Error {
    /// The matrix is structurally singular (no full transversal exists).
    StructurallySingular { matched: usize, n: usize },
    /// A zero/tiny pivot could not be perturbed (perturbation disabled).
    ZeroPivot { row: usize },
    /// Input validation failure.
    Invalid(String),
    /// I/O or parse failure (MatrixMarket, artifacts, ...).
    Io(String),
    /// XLA/PJRT runtime failure.
    Runtime(String),
}

impl Error {
    /// Stable numeric code for this error, shared across the library, the
    /// C ABI (`include/hylu.h`, `HYLU_ERR_*`), and the CLI exit status.
    ///
    /// | code | meaning                              |
    /// |------|--------------------------------------|
    /// | 0    | success (never returned by `code`)   |
    /// | 2    | invalid input ([`Error::Invalid`])   |
    /// | 3    | I/O or parse failure ([`Error::Io`]) |
    /// | 4    | structurally singular                |
    /// | 5    | zero pivot (perturbation disabled)   |
    /// | 6    | runtime/backend failure              |
    ///
    /// Codes are append-only: existing assignments never change, new
    /// variants get new codes. Code 1 is reserved (generic failure in
    /// shells and test harnesses).
    pub fn code(&self) -> i32 {
        match self {
            Error::Invalid(_) => 2,
            Error::Io(_) => 3,
            Error::StructurallySingular { .. } => 4,
            Error::ZeroPivot { .. } => 5,
            Error::Runtime(_) => 6,
        }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::StructurallySingular { matched, n } => write!(
                f,
                "structurally singular: maximum transversal matched {matched} of {n} rows"
            ),
            Error::ZeroPivot { row } => write!(f, "zero pivot at row {row}"),
            Error::Invalid(m) => write!(f, "invalid input: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

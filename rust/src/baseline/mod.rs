//! Baseline comparators (stand-ins for the paper's Intel MKL PARDISO and
//! the KLU family it contrasts against — DESIGN.md §2).
//!
//! Both baselines run through the *same* engine with forced policies, so
//! the comparison isolates exactly the paper's claim — the hybrid
//! kernel-selection strategy — rather than unrelated implementation
//! quality:
//!
//! - [`pardiso_like`]: always-BLAS supernodal solver. Nested-dissection
//!   ordering unconditionally, forced supernode amalgamation (min width 8),
//!   sup-sup kernels everywhere. On circuit-class matrices the forced
//!   panels fill with explicit zeros and the level-3 kernels do wasted
//!   work — the failure mode the paper shows for PARDISO on ASIC_680k,
//!   circuit5M, nlpkkt80.
//! - [`klu_like`]: pure row-row Gilbert–Peierls (no supernodes at all),
//!   AMD ordering. Wins on circuit matrices, loses badly on mesh/KKT
//!   classes where flops dominate.

use crate::coordinator::SolverConfig;
use crate::numeric::select::KernelMode;
use crate::ordering::OrderingChoice;
use crate::symbolic::MergePolicy;

/// PARDISO-like always-BLAS supernodal configuration.
///
/// Uses the *same* auto ordering as HYLU so the comparison isolates the
/// kernel strategy (forced amalgamation + always level-3), which is the
/// paper's claim. (Forcing ND everywhere — PARDISO's actual default —
/// makes the circuit-class gap explode to >1000x on this suite; see
/// EXPERIMENTS.md for that variant.)
pub fn pardiso_like(threads: usize) -> SolverConfig {
    SolverConfig {
        ordering: OrderingChoice::Auto,
        kernel: Some(KernelMode::SupSup),
        merge_policy: Some(MergePolicy::Forced {
            min_width: 8,
            max_width: 128,
        }),
        threads,
        ..SolverConfig::default()
    }
}

/// KLU-like pure row-row configuration.
pub fn klu_like(threads: usize) -> SolverConfig {
    SolverConfig {
        ordering: OrderingChoice::Amd,
        kernel: Some(KernelMode::RowRow),
        merge_policy: Some(MergePolicy::None),
        threads,
        ..SolverConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Solver;
    use crate::sparse::gen;
    use crate::testutil::max_abs_diff;

    fn roundtrip(cfg: SolverConfig, a: &crate::sparse::csr::Csr) -> f64 {
        let s = Solver::from_config(cfg).unwrap();
        let sys = s.analyze(a).unwrap().factor().unwrap();
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 9) as f64 - 4.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let x = sys.solve(&b).unwrap();
        max_abs_diff(&x, &xt)
    }

    #[test]
    fn both_baselines_solve_correctly() {
        for a in [gen::grid2d(12, 12), gen::circuit(400, 2)] {
            assert!(roundtrip(pardiso_like(1), &a) < 1e-7);
            assert!(roundtrip(klu_like(1), &a) < 1e-7);
        }
    }

    #[test]
    fn pardiso_like_pads_heavily_on_circuits() {
        let a = gen::circuit(1500, 3);
        let sp = Solver::from_config(pardiso_like(1)).unwrap();
        let sk = Solver::from_config(klu_like(1)).unwrap();
        let ap = sp.analyze(&a).unwrap();
        let ak = sk.analyze(&a).unwrap();
        // the PARDISO-like baseline stores far more (padded) entries —
        // the fill explosion the paper reports
        assert!(
            ap.symbolic_stats().lu_entries as f64 > 3.0 * ak.symbolic_stats().lu_entries as f64,
            "pardiso {} vs klu {}",
            ap.symbolic_stats().lu_entries,
            ak.symbolic_stats().lu_entries
        );
    }
}

//! Hand-rolled CLI (clap is not in the offline registry).
//!
//! ```text
//! hylu solve  --matrix FILE.mtx | --gen CLASS:N [--threads T] [--kernel K]
//!             [--repeated] [--xla] [--rhs K]
//! hylu inspect --matrix FILE.mtx | --gen CLASS:N
//! hylu gen    --gen CLASS:N --out FILE.mtx
//! hylu bench  [--suite small|full] [--threads T]
//!             [--kernel scalar|portable|native|avx512|auto]
//!             [--tuning off|quick|full] [--precision f64|mixed] [--dynamic]
//! hylu tune   --matrix FILE.mtx | --gen CLASS:N [--tuning quick|full]
//!             [--threads T]
//! hylu gauntlet [--suite small|full] [--threads T] [--reps R]
//!             [--tuning quick|full] [--out FILE.json]
//! hylu serve  --matrix FILE.mtx | --gen CLASS:N [--systems M] [--shards S]
//!             [--rhs-workers C] [--requests R] [--max-batch B] [--tick-us U]
//!             [--tick-max-us U] [--elastic] [--grow-to G] [--chaos]
//! ```
//!
//! `tune` runs the per-pattern kernel autotuner on one matrix and prints
//! the searched [`KernelPlan`](crate::numeric::kernels::KernelPlan).
//! `gauntlet` runs the fig4–fig11 bench suite once with autotuning and
//! once without (repeated refactor+solve per matrix), a mixed-vs-f64
//! precision section (refactor+solve speedup, refinement iterations
//! added, fallback count per matrix), plus the kernel-variant A/B micro
//! rows, a fault-tolerance chaos drill (injected panics / forced zero
//! pivots against a small sharded service, reporting the recovery
//! counters), a dynamic-topology section (cold vs warm vs delta
//! re-analysis trajectories on perturbed-pattern sequences plus the
//! pivot-stability escalation counts), and writes the whole trajectory
//! to a single `BENCH_<date>.json` artifact (schema `hylu-bench-v4`,
//! documented in DESIGN.md §5). `bench --dynamic` runs the
//! dynamic-topology smoke alone.
//!
//! `--rhs K` batches K right-hand sides through the engine's multi-RHS
//! path ([`LinearSystem::solve_many`]) — the traffic-serving scenario.
//! `serve` runs the full front door: a sharded
//! [`SolverService`](crate::service::SolverService) under C concurrent
//! callers, reporting solves/sec and coalescing statistics against the
//! serialized single-front-door baseline. `--tick-max-us` enables the
//! adaptive coalescing window (stretches toward the ceiling under
//! sustained arrivals, collapses to zero when a shard idles);
//! `--elastic` additionally runs a churn thread that registers, solves,
//! retires, and rebalances systems *while* the callers hammer the
//! stable ones — the live-topology scenario. `--grow-to G` exercises
//! shard-set elasticity: a grower thread stretches the shard set from
//! `--shards` up to `G` one shard at a time (rebalancing load onto each
//! new shard) and drains it back down, repeatedly, under the same
//! traffic. `--chaos` arms a
//! deterministic [`FaultPlan`] (the `HYLU_FAULT` spec when set, a
//! built-in plan otherwise): dispatchers absorb injected panics and
//! forced zero pivots, quarantined systems recover by escalated full
//! factorization, stale deadline probes expire, and the report gains a
//! `faults` line with the panic/quarantine/recovery/expiry counters;
//! the serialized baseline comparison is skipped (a clean baseline
//! against faulted traffic is not a meaningful ratio).
//!
//! Note the two meanings of `--kernel`: for `solve` it forces the numeric
//! kernel *family* (row-row / sup-row / sup-sup); for `bench` it pins the
//! dense microkernel *dispatch tier* (scalar / portable / native) for A/B
//! runs, reported alongside the one-shot throughput probe.

use std::path::Path;

use crate::api::{Factored, LinearSystem, Solver, SolverBuilder};
use crate::baseline;
use crate::coordinator::{Fault, FaultPlan, Precision};
use crate::bench_harness::{environment, fmt_time, time_best, Table};
use crate::bench_suite;
use crate::numeric::kernels::{self, tuner, KernelTier, Tuning};
use crate::numeric::select::KernelMode;
use crate::service::{Health, Priority, ServiceConfig, ServiceStats, SolverService, SystemId};
use crate::sparse::csr::Csr;
use crate::sparse::{gen, io};
use crate::{Error, Result};

/// Parsed command line.
pub struct Args {
    flags: Vec<(String, Option<String>)>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `--key value` / `--switch` style arguments.
    pub fn parse(argv: &[String]) -> Args {
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let has_val = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if has_val {
                    flags.push((name.to_string(), Some(argv[i + 1].clone())));
                    i += 2;
                } else {
                    flags.push((name.to_string(), None));
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    /// Value of `--key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    /// Presence of `--switch`.
    pub fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    /// Subcommand (first positional).
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

/// Build a matrix from `--matrix FILE` or `--gen CLASS:N[:SEED]`.
pub fn load_matrix(args: &Args) -> Result<(String, Csr)> {
    if let Some(path) = args.get("matrix") {
        let a = io::read_matrix_market(Path::new(path))?;
        return Ok((path.to_string(), a));
    }
    if let Some(spec) = args.get("gen") {
        let parts: Vec<&str> = spec.split(':').collect();
        let class = parts[0];
        let n: usize = parts
            .get(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or(10_000);
        let seed: u64 = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(1);
        let side = (n as f64).sqrt().ceil() as usize;
        let cube = (n as f64).cbrt().ceil() as usize;
        let a = match class {
            "circuit" => gen::circuit(n, seed),
            "power" => gen::power_network(n, seed),
            "mesh2d" | "grid2d" => gen::grid2d(side, side),
            "mesh3d" | "grid3d" => gen::grid3d(cube, cube, cube),
            "banded" => gen::banded(n, 8, seed),
            "random" => gen::random_sparse(n, 4, seed),
            "kkt" => gen::kkt(n * 3 / 4, n / 4, seed),
            "illcond" => gen::ill_conditioned(n, seed),
            other => return Err(Error::Invalid(format!("unknown class {other}"))),
        };
        return Ok((format!("{class}:n={}", a.n), a));
    }
    Err(Error::Invalid(
        "need --matrix FILE.mtx or --gen CLASS:N".into(),
    ))
}

/// Build a [`SolverBuilder`] from common flags.
pub fn config_from(args: &Args) -> Result<SolverBuilder> {
    let mut b = SolverBuilder::new();
    if let Some(t) = args.get("threads") {
        b = b.threads(
            t.parse()
                .map_err(|_| Error::Invalid("bad --threads".into()))?,
        );
    }
    if let Some(k) = args.get("kernel") {
        match k {
            "row-row" | "rowrow" => b = b.kernel(KernelMode::RowRow),
            "sup-row" | "suprow" => b = b.kernel(KernelMode::SupRow),
            "sup-sup" | "supsup" => b = b.kernel(KernelMode::SupSup),
            "auto" => {}
            other => return Err(Error::Invalid(format!("unknown kernel {other}"))),
        }
    }
    if args.has("repeated") {
        b = b.repeated();
    }
    if args.has("xla") {
        b = b.configure(|cfg| cfg.use_xla = true);
    }
    if let Some(t) = tuning_from(args, Tuning::Off)? {
        b = b.tuning(t);
    }
    if let Some(p) = precision_from(args)? {
        b = b.precision(p);
    }
    Ok(b)
}

/// Parse `--precision f64|mixed`. Returns `None` when the flag is absent.
fn precision_from(args: &Args) -> Result<Option<Precision>> {
    if !args.has("precision") {
        return Ok(None);
    }
    match args.get("precision") {
        None => Err(Error::Invalid(
            "--precision needs a value (f64|mixed)".into(),
        )),
        Some(v) => Precision::parse(v)
            .map(Some)
            .ok_or_else(|| Error::Invalid(format!("unknown precision {v} (f64|mixed)"))),
    }
}

/// Parse `--tuning off|quick|full`; a bare `--tuning` means `default`.
/// Returns `None` when the flag is absent.
fn tuning_from(args: &Args, default: Tuning) -> Result<Option<Tuning>> {
    if !args.has("tuning") {
        return Ok(None);
    }
    match args.get("tuning") {
        None => Ok(Some(default)),
        Some(v) => Tuning::parse(v)
            .map(Some)
            .ok_or_else(|| Error::Invalid(format!("unknown tuning level {v} (off|quick|full)"))),
    }
}

/// Run the CLI; returns the process exit code.
///
/// Exit statuses are the stable [`Error::code`] values shared with the
/// C ABI (`include/hylu.h`): 0 success, 2 invalid input/usage, 3 I/O,
/// 4 structurally singular, 5 zero pivot, 6 runtime failure, 7 shard
/// panic, 8 deadline expired, 9 quarantined (the service codes surface
/// through `serve`).
pub fn run(argv: &[String]) -> i32 {
    let args = Args::parse(argv);
    let result = match args.command() {
        Some("solve") => cmd_solve(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("gen") => cmd_gen(&args),
        Some("bench") => cmd_bench(&args),
        Some("tune") => cmd_tune(&args),
        Some("gauntlet") => cmd_gauntlet(&args),
        Some("serve") => cmd_serve(&args),
        _ => {
            eprintln!(
                "usage: hylu <solve|inspect|gen|bench|tune|gauntlet|serve> \
                 [--matrix F | --gen CLASS:N] \
                 [--threads T] [--kernel auto|row-row|sup-row|sup-sup] [--repeated] [--xla] \
                 [--rhs K] [--suite small|full] [--out F] [--systems M] [--shards S] \
                 [--rhs-workers C] [--requests R] [--max-batch B] [--tick-us U] \
                 [--tick-max-us U] [--elastic] [--grow-to G] [--chaos] \
                 [--tuning off|quick|full] [--reps R] \
                 [--precision f64|mixed] [--dynamic] \
                 (bench: --kernel scalar|portable|native|avx512|auto pins the dispatch tier)"
            );
            // usage errors share Error::Invalid's stable code
            return Error::Invalid(String::new()).code();
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            e.code()
        }
    }
}

fn cmd_solve(args: &Args) -> Result<()> {
    let (name, a) = load_matrix(args)?;
    let nrhs: usize = match args.get("rhs") {
        Some(v) => v.parse().map_err(|_| Error::Invalid("bad --rhs".into()))?,
        None => 1,
    };
    let solver = config_from(args)?.build()?;
    let sys = solver.analyze(a)?.factor()?;
    let (a, an) = (sys.matrix(), sys.analysis());
    let b = gen::rhs_for_ones(a);
    let (x, st) = sys.solve_with_stats(&b)?;
    let err = x
        .iter()
        .map(|v| (v - 1.0).abs())
        .fold(0.0f64, f64::max);
    println!("matrix       : {name} (n={}, nnz={})", a.n, a.nnz());
    println!(
        "preprocess   : {} (match {}, order {}, symbolic {})",
        fmt_time(an.stats.t_total),
        fmt_time(an.stats.t_match),
        fmt_time(an.stats.t_order),
        fmt_time(an.stats.t_symbolic)
    );
    println!(
        "kernel       : {} (coverage {:.2}, avg width {:.1}, fill {:.2}x)",
        an.mode, an.stats.supernode_coverage, an.stats.avg_super_width, an.stats.fill_ratio
    );
    let fs = sys.factor_stats();
    println!(
        "factor       : {} ({:.2} GFLOP/s, {} perturbed pivots, {} threads)",
        fmt_time(fs.t_factor),
        fs.gflops,
        fs.perturbed,
        fs.threads
    );
    println!(
        "solve        : {} (residual {:.3e}, {} refinement iters, {}, precision {}{})",
        fmt_time(st.t_solve),
        st.residual,
        st.refine_iters,
        st.outcome,
        st.precision,
        if st.fallbacks > 0 {
            format!(", {} precision fallbacks", st.fallbacks)
        } else {
            String::new()
        }
    );
    println!("x==1 max err : {err:.3e}");
    if nrhs > 1 {
        // batched path: scaled copies of b have known solutions q+1
        let bs: Vec<Vec<f64>> = (1..=nrhs)
            .map(|q| b.iter().map(|v| v * q as f64).collect())
            .collect();
        let (xs, stm) = sys.solve_many_with_stats(&bs)?;
        let mut err_many = 0.0f64;
        for (q, xq) in xs.iter().enumerate() {
            let want = (q + 1) as f64;
            for v in xq {
                err_many = err_many.max((v - want).abs());
            }
        }
        println!(
            "solve_many   : {} for {} rhs ({} per rhs, worst residual {:.3e}, max err {:.3e})",
            fmt_time(stm.t_solve),
            stm.nrhs,
            fmt_time(stm.t_solve / stm.nrhs.max(1) as f64),
            stm.residual,
            err_many
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let (name, a) = load_matrix(args)?;
    let solver = config_from(args)?.build()?;
    let sys = solver.analyze(a)?;
    let s = *sys.symbolic_stats();
    println!("matrix   : {name}");
    println!("n        : {}", s.n);
    println!("nnz      : {}", s.nnz);
    println!("kernel   : {}", s.mode);
    println!("lu nnz   : {} (fill {:.2}x)", s.lu_entries, s.fill_ratio);
    println!("flops    : {:.3e}", s.flops);
    println!("coverage : {:.3}", s.supernode_coverage);
    println!("avg width: {:.2} ({:.2} over panels only)", s.avg_super_width, s.avg_panel_width);
    println!("nodes    : {} over {} levels ({} bulk)", s.nodes, s.levels, s.bulk_levels);
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let (name, a) = load_matrix(args)?;
    let out = args
        .get("out")
        .ok_or_else(|| Error::Invalid("need --out FILE.mtx".into()))?;
    io::write_matrix_market(Path::new(out), &a)?;
    println!("wrote {name} to {out}");
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    // For `bench`, --kernel pins the dense-microkernel DISPATCH TIER
    // (scalar|portable|native|auto), not the factor kernel family.
    if let Some(k) = args.get("kernel") {
        if k != "auto" {
            let tier = KernelTier::parse(k).ok_or_else(|| {
                Error::Invalid(format!(
                    "unknown kernel tier {k} (scalar|portable|native|avx512|auto)"
                ))
            })?;
            kernels::set_tier(tier);
        }
    }
    let tuning = tuning_from(args, Tuning::Quick)?;
    let precision = precision_from(args)?;
    let threads = flag_usize(args, "threads", 0)?;
    let suite = match args.get("suite").unwrap_or("small") {
        "full" => bench_suite::suite37(),
        _ => bench_suite::suite_small(),
    };
    println!("{}", environment());
    let p = kernels::probe();
    println!(
        "kernel tier  : {} (probe: gemm {:.2} GFLOP/s vs scalar {:.2} GFLOP/s, \
         advantage {:.2}x, selection calibration {:.2})",
        kernels::active_tier(),
        p.gemm_gflops,
        p.scalar_gflops,
        p.advantage(),
        kernels::calibration()
    );
    if let Some(p) = precision {
        println!("precision    : {p} (hylu side; baseline stays f64)");
    }
    if args.has("dynamic") {
        // dynamic-topology smoke: perturbed-pattern sequences, cold
        // analyze+factor vs warm / delta incremental re-analysis
        let mut table = Table::new(
            "dynamic re-analysis: cold analyze+factor vs warm / delta (mean per step)",
            &["matrix", "class", "n", "cold", "warm", "delta", "cold/delta"],
        );
        for bm in &suite {
            let a = (bm.build)();
            let mut hb = SolverBuilder::new().repeated().threads(threads);
            if let Some(t) = tuning {
                hb = hb.tuning(t);
            }
            let solver = hb.build()?;
            let (t_cold, t_warm, t_delta, _) = dynamic_cycle(&solver, &a, 3)?;
            let (mc, mw, md) = (mean(&t_cold), mean(&t_warm), mean(&t_delta));
            let ratio = mc / md.max(1e-12);
            table.row(
                vec![
                    bm.name.into(),
                    bm.class.into(),
                    a.n.to_string(),
                    fmt_time(mc),
                    fmt_time(mw),
                    fmt_time(md),
                    format!("{ratio:.2}x"),
                ],
                ratio,
            );
        }
        table.print();
        return Ok(());
    }
    let mut table = Table::new(
        "one-time solve: HYLU vs PARDISO-like baseline",
        &["matrix", "class", "n", "hylu", "baseline", "speedup"],
    );
    for bm in &suite {
        let a = (bm.build)();
        let mut hb = SolverBuilder::new().threads(threads);
        if let Some(t) = tuning {
            hb = hb.tuning(t);
        }
        if let Some(p) = precision {
            hb = hb.precision(p);
        }
        let hylu = hb.build()?;
        let base = Solver::from_config(baseline::pardiso_like(threads))?;
        let b = gen::rhs_for_ones(&a);
        let t_h = run_once(&hylu, &a, &b)?;
        let t_b = run_once(&base, &a, &b)?;
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_h),
                fmt_time(t_b),
                format!("{:.2}x", t_b / t_h),
            ],
            t_b / t_h,
        );
    }
    table.print();
    Ok(())
}

fn run_once(s: &Solver, a: &Csr, b: &[f64]) -> Result<f64> {
    let t = std::time::Instant::now();
    let sys = s.analyze(a)?.factor()?;
    let _ = sys.solve(b)?;
    Ok(t.elapsed().as_secs_f64())
}

fn flag_usize(args: &Args, key: &str, default: usize) -> Result<usize> {
    match args.get(key) {
        Some(v) => v
            .parse()
            .map_err(|_| Error::Invalid(format!("bad --{key}"))),
        None => Ok(default),
    }
}

/// Run the per-pattern autotuner on one matrix and report the winning
/// kernel plan (and what it was searched against).
fn cmd_tune(args: &Args) -> Result<()> {
    let (name, a) = load_matrix(args)?;
    let tuning = tuning_from(args, Tuning::Quick)?.unwrap_or(Tuning::Quick);
    let solver = config_from(args)?.tuning(tuning).build()?;
    let tier = kernels::active_tier();
    let t0 = std::time::Instant::now();
    let sys = solver.analyze(a)?;
    let t_analyze = t0.elapsed().as_secs_f64();
    let an = sys.analysis();
    println!("matrix     : {name} (n={}, nnz={})", an.stats.n, an.stats.nnz);
    println!("tier       : {tier}");
    println!("tuning     : {tuning}");
    println!("analyze    : {} (autotune included)", fmt_time(t_analyze));
    let hist = tuner::shape_histogram(&an.sym, 8);
    if hist.is_empty() {
        println!("histogram  : no supernode GEMM shapes (plan defaults)");
    } else {
        println!("histogram  : top sup-sup GEMM shapes (m x k x n, weight)");
        for s in &hist {
            println!("             {:>4} x {:>4} x {:>4}  {:.3e}", s.m, s.k, s.n, s.weight);
        }
    }
    println!("plan       : {}", an.plan.kernel);
    match std::env::var("HYLU_TUNE_CACHE") {
        Ok(dir) if !dir.is_empty() => println!("disk cache : {dir}"),
        _ => println!("disk cache : off (set HYLU_TUNE_CACHE=dir to persist plans)"),
    }
    Ok(())
}

/// One analyze+factor, then best-of-`reps` timed refactor+solve cycles —
/// the repeated-solve figure of merit. Returns (best cycle seconds,
/// rendered kernel plan).
fn repeated_cycle(
    solver: &Solver,
    a: &Csr,
    b: &[f64],
    reps: usize,
) -> Result<(f64, String)> {
    let vals = a.vals.clone();
    let mut sys = solver.analyze(a)?.factor()?;
    let plan = sys.analysis().plan.kernel.to_string();
    let mut x = Vec::new();
    sys.solve_into(b, &mut x)?; // warm-up: grow every arena once
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        sys.refactor(&vals)?;
        sys.solve_into(b, &mut x)?;
        best = best.min(t.elapsed().as_secs_f64());
    }
    Ok((best, plan))
}

/// Mixed-vs-f64 figure of merit for the gauntlet: one analyze+factor,
/// then best-of-`reps` timed refactor+solve cycles. Returns the best
/// cycle seconds, the refinement iterations of the final cycle's solve,
/// and the precision-fallback events accumulated on the handle (always 0
/// for a pure-`f64` solver).
fn precision_cycle(
    solver: &Solver,
    a: &Csr,
    b: &[f64],
    reps: usize,
) -> Result<(f64, usize, u64)> {
    let vals = a.vals.clone();
    let mut sys = solver.analyze(a)?.factor()?;
    let mut x = Vec::new();
    sys.solve_into(b, &mut x)?; // warm-up: grow every arena once
    let mut best = f64::INFINITY;
    let mut iters = 0usize;
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        sys.refactor(&vals)?;
        iters = sys.solve_into(b, &mut x)?.refine_iters;
        best = best.min(t.elapsed().as_secs_f64());
    }
    Ok((best, iters, sys.fallback_events()))
}

/// Insert one absent off-diagonal entry into row `i` of the pattern
/// (small value, so the numerics stay benign); returns the edited
/// matrix. Used by the dynamic-topology drills to grow a
/// perturbed-pattern sequence one local edit at a time.
fn add_pattern_entry(a: &Csr, i: usize, seed: usize) -> Csr {
    let n = a.n;
    let cols = a.row_indices(i);
    let mut j = (i + 1 + seed) % n;
    let mut tries = 0usize;
    while (j == i || cols.contains(&j)) && tries < n {
        j = (j + 1) % n;
        tries += 1;
    }
    if tries >= n {
        return a.clone(); // row already dense: nothing to add
    }
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices = Vec::with_capacity(a.nnz() + 1);
    let mut vals = Vec::with_capacity(a.nnz() + 1);
    indptr.push(0usize);
    for r in 0..n {
        let rc = a.row_indices(r);
        let rv = a.row_vals(r);
        if r == i {
            let mut done = false;
            for (c, v) in rc.iter().zip(rv) {
                if !done && *c > j {
                    indices.push(j);
                    vals.push(1e-3);
                    done = true;
                }
                indices.push(*c);
                vals.push(*v);
            }
            if !done {
                indices.push(j);
                vals.push(1e-3);
            }
        } else {
            indices.extend_from_slice(rc);
            vals.extend_from_slice(rv);
        }
        indptr.push(indices.len());
    }
    Csr { n, indptr, indices, vals }
}

/// Dynamic-topology figure of merit: a perturbed-pattern sequence over
/// one matrix. Each step grows the pattern by one entry in a late row;
/// the handle re-analyzes incrementally (delta patch) while a fresh cold
/// analyze+factor of the same pattern is timed for comparison, and a
/// warm unchanged-pattern re-analysis rides along. Returns the per-step
/// `(cold, warm, delta)` timing trajectories plus how many steps
/// actually took the delta path (the rest fell back to a full
/// re-analysis — still bit-identical, just not incremental).
fn dynamic_cycle(
    solver: &Solver,
    a: &Csr,
    steps: usize,
) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, usize)> {
    use crate::coordinator::ReanalyzeKind;
    let mut sys = solver.analyze(a)?.factor()?;
    let b = gen::rhs_for_ones(a);
    let mut x = Vec::new();
    sys.solve_into(&b, &mut x)?; // warm-up: grow every arena once
    let (mut t_cold, mut t_warm, mut t_delta) = (Vec::new(), Vec::new(), Vec::new());
    let mut deltas = 0usize;
    let mut cur = a.clone();
    for k in 0..steps {
        let row = cur.n - 1 - (k % (cur.n / 2).max(1));
        let next = add_pattern_entry(&cur, row, 3 * k + 1);
        // warm: pattern unchanged — symbolic, plan, arenas reused
        // wholesale. The O(nnz) clones happen outside the Instant
        // windows so the trajectories measure re-analysis cost only.
        let m = cur.clone();
        let t = std::time::Instant::now();
        sys.reanalyze_matrix(m)?;
        t_warm.push(t.elapsed().as_secs_f64());
        // delta: one-entry pattern edit — the symbolic DAG is patched
        // from the first changed permuted row
        let m = next.clone();
        let t = std::time::Instant::now();
        sys.reanalyze_matrix(m)?;
        t_delta.push(t.elapsed().as_secs_f64());
        if sys.reanalysis_kind() == Some(ReanalyzeKind::Delta) {
            deltas += 1;
        }
        // cold oracle: fresh analyze+factor of the same pattern
        let t = std::time::Instant::now();
        let _ = solver.analyze(&next)?.factor()?;
        t_cold.push(t.elapsed().as_secs_f64());
        cur = next;
    }
    Ok((t_cold, t_warm, t_delta, deltas))
}

/// Escalation drill for the dynamic section: a same-pattern value
/// sequence (gentle drift) replayed through the adaptive pivot-stability
/// controller. Returns the `(replays, reorders, repivots)` the
/// controller decided; the always-full-pivot policy it replaces would
/// perform `steps` full re-pivots on the same sequence by construction.
fn escalation_drill(a: &Csr, threads: usize, steps: usize) -> Result<(u64, u64, u64)> {
    let solver = SolverBuilder::new()
        .repeated()
        .threads(threads)
        .adaptive_refactor(true)
        .build()?;
    let mut sys = solver.analyze(a)?.factor()?;
    let mut vals = a.vals.clone();
    for k in 0..steps {
        let f = 1.0 + 0.01 * (k + 1) as f64;
        for (v, v0) in vals.iter_mut().zip(&a.vals) {
            *v = v0 * f;
        }
        sys.refactor(&vals)?;
    }
    Ok(sys.escalation().map(|e| e.counts()).unwrap_or_default())
}

/// Mean of a timing trajectory (0 when empty).
fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// Render a timing trajectory as a JSON array body.
fn json_traj(v: &[f64]) -> String {
    v.iter()
        .map(|t| format!("{t:e}"))
        .collect::<Vec<_>>()
        .join(", ")
}

/// Deterministic fill for kernel A/B operands (no RNG dependency).
fn ab_fill(len: usize, phase: usize) -> Vec<f64> {
    (0..len)
        .map(|i| ((i * 7 + phase * 13) % 23) as f64 * 0.125 - 1.375)
        .collect()
}

/// Tuned-vs-default microkernel A/B rows on a representative sup-sup
/// shape: every enumerated GEMM tile variant against the tier kernel,
/// plus packed-A vs strided-A. Returns `(label, t_default, t_variant)`.
fn kernel_ab_rows(tier: KernelTier) -> Vec<(String, f64, f64)> {
    let (m, k, n) = (48usize, 32usize, 96usize);
    let lda = k + 8; // strided A, like a panel read in place
    let a = ab_fill(m * lda, 1);
    let b = ab_fill(k * n, 2);
    let mut c = vec![0.0; m * n];
    let reps = 30;
    let t_tier = time_best(reps, || {
        kernels::gemm_sub(tier, &mut c, n, &a, lda, &b, n, m, k, n);
    });
    let mut rows = Vec::new();
    for &(mr, nr, ku) in tuner::TILE_VARIANTS.iter() {
        let plan = kernels::KernelPlan {
            gemm: kernels::GemmVariant::Tiled { mr, nr, ku },
            ..Default::default()
        };
        let t_var = time_best(reps, || {
            kernels::gemm_sub_planned(tier, &plan, &mut c, n, &a, lda, &b, n, m, k, n);
        });
        rows.push((format!("gemm {mr}x{nr}k{ku} vs {tier}"), t_tier, t_var));
    }
    // packed-A vs strided-A through the same tier kernel
    let mut packed = Vec::new();
    let t_packed = time_best(reps, || {
        kernels::pack_rows(&mut packed, &a, lda, m, k);
        kernels::gemm_sub(tier, &mut c, n, &packed, k, &b, n, m, k, n);
    });
    rows.push((format!("gemm packed-A vs strided-A ({tier})"), t_tier, t_packed));
    rows
}

/// Days-from-epoch to civil date (Howard Hinnant's algorithm; avoids a
/// chrono dependency for the artifact filename).
fn civil_today() -> (i64, u32, u32) {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0);
    let z = secs.div_euclid(86_400) + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    let y = yoe + era * 400 + i64::from(m <= 2);
    (y, m, d)
}

/// Minimal JSON string escape (the strings involved are ASCII labels).
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// The gauntlet's fault-tolerance drill: a 2-shard service over two
/// mesh systems under a deterministic [`FaultPlan`] (injected panics on
/// both streams plus forced zero pivots), callers retrying through the
/// failures, refactors feeding the factor stream, and one
/// guaranteed-expired deadline probe. Returns `(faults injected, final
/// stats, clean)` where `clean` means every solve eventually succeeded
/// bit-exactly, the probe expired, and every system ended `Healthy`.
fn chaos_drill() -> Result<(u64, ServiceStats, bool)> {
    let a = gen::grid2d(20, 20);
    let b = gen::rhs_for_ones(&a);
    // period 5 clears the two registration factorizations (factor steps
    // 0 and 1 run on this thread, outside shard supervision)
    let plan = std::sync::Arc::new(FaultPlan::new(
        7,
        5,
        vec![Fault::PanicInFactor, Fault::PanicInSolve, Fault::ForceZeroPivot],
    ));
    let service = SolverService::new(
        ServiceConfig {
            shards: 2,
            solver: SolverBuilder::new().repeated().threads(1).into_config(),
            expire_deadlines: true,
            fault: Some(plan.clone()),
            ..ServiceConfig::default()
        },
        vec![a.clone(), a.clone()],
    )?;
    let ids = service.system_ids();
    let past = std::time::Instant::now() - std::time::Duration::from_millis(1);
    let probe = service.submit_with(ids[0], b.clone(), Priority::Deadline(past))?;
    let expired = matches!(probe.wait(), Err(Error::DeadlineExpired));
    let mut solved = 0usize;
    for r in 0..60 {
        let id = ids[r % 2];
        if r % 6 == 5 {
            // same values re-shipped: injected failures quarantine the
            // system without ever changing the correct solution
            let _ = service.refactor(id, a.clone());
        }
        for _ in 0..200 {
            match service.solve(id, b.clone()) {
                Ok(x) => {
                    let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
                    if err > 1e-6 {
                        return Err(Error::Runtime(format!(
                            "chaos drill solution drifted: |x-1| = {err:.3e}"
                        )));
                    }
                    solved += 1;
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_micros(100)),
            }
        }
    }
    let healthy = ids
        .iter()
        .all(|id| matches!(service.health(*id), Some(Health::Healthy)));
    let st = service.stats();
    drop(service);
    Ok((plan.injected(), st, expired && healthy && solved == 60))
}

/// The perf-trajectory gauntlet: tuned-vs-untuned repeated refactor+solve
/// over the bench suite, a mixed-vs-f64 precision section (cycle speedup,
/// refinement iterations added, fallback count per matrix), the
/// kernel-variant A/B micro rows, the dynamic-topology section
/// ([`dynamic_cycle`] trajectories + [`escalation_drill`] counts), plus
/// the [`chaos_drill`] fault counters, written to one
/// `BENCH_<date>.json` artifact (schema `hylu-bench-v4`, documented in
/// DESIGN.md §5).
fn cmd_gauntlet(args: &Args) -> Result<()> {
    let tuning = tuning_from(args, Tuning::Quick)?.unwrap_or(Tuning::Quick);
    if tuning == Tuning::Off {
        return Err(Error::Invalid(
            "gauntlet compares tuned vs untuned; use --tuning quick|full".into(),
        ));
    }
    let threads = flag_usize(args, "threads", 0)?;
    let reps = flag_usize(args, "reps", 5)?.max(1);
    let suite_name = if args.get("suite") == Some("full") {
        "full"
    } else {
        "small"
    };
    let suite = if suite_name == "full" {
        bench_suite::suite37()
    } else {
        bench_suite::suite_small()
    };
    let env = environment();
    let tier = kernels::active_tier();
    println!("{env}");
    let mut table = Table::new(
        "gauntlet: autotuned vs default repeated refactor+solve",
        &["matrix", "class", "n", "untuned", "tuned", "speedup", "plan"],
    );
    let mut mats = Vec::new();
    for bm in &suite {
        let a = (bm.build)();
        let b = gen::rhs_for_ones(&a);
        let untuned = SolverBuilder::new().repeated().threads(threads).build()?;
        let (t_un, _) = repeated_cycle(&untuned, &a, &b, reps)?;
        let tuned = SolverBuilder::new()
            .repeated()
            .threads(threads)
            .tuning(tuning)
            .build()?;
        let (t_tu, plan) = repeated_cycle(&tuned, &a, &b, reps)?;
        let speedup = t_un / t_tu.max(1e-12);
        table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_un),
                fmt_time(t_tu),
                format!("{speedup:.2}x"),
                plan.clone(),
            ],
            speedup,
        );
        mats.push(format!(
            "    {{\"name\": \"{}\", \"class\": \"{}\", \"n\": {}, \"nnz\": {}, \
             \"t_untuned\": {:e}, \"t_tuned\": {:e}, \"speedup\": {:.4}, \"plan\": \"{}\"}}",
            json_escape(bm.name),
            json_escape(bm.class),
            a.n,
            a.nnz(),
            t_un,
            t_tu,
            speedup,
            json_escape(&plan),
        ));
    }
    table.print();
    let mut prec_table = Table::new(
        "precision: mixed (f32 factor + f64 refinement) vs f64 repeated refactor+solve",
        &["matrix", "class", "n", "f64", "mixed", "speedup", "iters+", "fallbacks"],
    );
    let mut prec_json = Vec::new();
    for bm in &suite {
        let a = (bm.build)();
        let b = gen::rhs_for_ones(&a);
        let full = SolverBuilder::new().repeated().threads(threads).build()?;
        let (t_f64, it_f64, _) = precision_cycle(&full, &a, &b, reps)?;
        let mixed = SolverBuilder::new()
            .repeated()
            .threads(threads)
            .precision(Precision::Mixed)
            .build()?;
        let (t_mx, it_mx, fb) = precision_cycle(&mixed, &a, &b, reps)?;
        let speedup = t_f64 / t_mx.max(1e-12);
        let extra = it_mx as i64 - it_f64 as i64;
        prec_table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(t_f64),
                fmt_time(t_mx),
                format!("{speedup:.2}x"),
                format!("{extra:+}"),
                fb.to_string(),
            ],
            speedup,
        );
        prec_json.push(format!(
            "    {{\"name\": \"{}\", \"class\": \"{}\", \"n\": {}, \"t_f64\": {:e}, \
             \"t_mixed\": {:e}, \"speedup\": {:.4}, \"refine_iters_f64\": {}, \
             \"refine_iters_mixed\": {}, \"fallbacks\": {}}}",
            json_escape(bm.name),
            json_escape(bm.class),
            a.n,
            t_f64,
            t_mx,
            speedup,
            it_f64,
            it_mx,
            fb,
        ));
    }
    prec_table.print();
    let ab = kernel_ab_rows(tier);
    let mut ab_table = Table::new(
        "kernel A/B: enumerated variants vs tier default (48x32x96)",
        &["variant", "default", "variant", "ratio"],
    );
    let mut ab_json = Vec::new();
    for (label, t_def, t_var) in &ab {
        let ratio = t_def / t_var.max(1e-12);
        ab_table.row(
            vec![
                label.clone(),
                fmt_time(*t_def),
                fmt_time(*t_var),
                format!("{ratio:.2}x"),
            ],
            ratio,
        );
        ab_json.push(format!(
            "    {{\"name\": \"{}\", \"t_default\": {:e}, \"t_variant\": {:e}, \
             \"ratio\": {:.4}}}",
            json_escape(label),
            t_def,
            t_var,
            ratio
        ));
    }
    ab_table.print();

    // dynamic-topology section: perturbed-pattern sequences per matrix
    // (cold analyze+factor vs warm / delta re-analysis trajectories) and
    // the pivot-stability escalation counts vs the always-full-pivot
    // baseline (which re-pivots on every step by construction)
    let dyn_steps = 4usize;
    let mut dyn_table = Table::new(
        "dynamic: cold analyze+factor vs warm / delta re-analysis (mean per step)",
        &["matrix", "class", "n", "cold", "warm", "delta", "cold/delta", "delta/steps", "repivots"],
    );
    let mut dyn_json = Vec::new();
    for bm in &suite {
        let a = (bm.build)();
        let solver = SolverBuilder::new().repeated().threads(threads).build()?;
        let (t_cold, t_warm, t_delta, deltas) = dynamic_cycle(&solver, &a, dyn_steps)?;
        let (replays, reorders, repivots) = escalation_drill(&a, threads, dyn_steps)?;
        let (mc, mw, md) = (mean(&t_cold), mean(&t_warm), mean(&t_delta));
        let ratio = mc / md.max(1e-12);
        dyn_table.row(
            vec![
                bm.name.into(),
                bm.class.into(),
                a.n.to_string(),
                fmt_time(mc),
                fmt_time(mw),
                fmt_time(md),
                format!("{ratio:.2}x"),
                format!("{deltas}/{dyn_steps}"),
                format!("{repivots} vs {dyn_steps}"),
            ],
            ratio,
        );
        dyn_json.push(format!(
            "    {{\"name\": \"{}\", \"class\": \"{}\", \"n\": {}, \"steps\": {}, \
             \"t_cold\": [{}], \"t_warm\": [{}], \"t_delta\": [{}], \"delta_steps\": {}, \
             \"escalation\": {{\"replays\": {}, \"reorders\": {}, \"repivots\": {}, \
             \"baseline_repivots\": {}}}}}",
            json_escape(bm.name),
            json_escape(bm.class),
            a.n,
            dyn_steps,
            json_traj(&t_cold),
            json_traj(&t_warm),
            json_traj(&t_delta),
            deltas,
            replays,
            reorders,
            repivots,
            dyn_steps,
        ));
    }
    dyn_table.print();

    let (injected, chaos_stats, chaos_clean) = chaos_drill()?;
    println!(
        "\nchaos drill  : {} injected; {} panics caught, {} quarantines, \
         {}/{} recoveries, {} expired (clean: {})",
        injected,
        chaos_stats.panics_caught,
        chaos_stats.quarantines,
        chaos_stats.recoveries,
        chaos_stats.recovery_attempts,
        chaos_stats.expired,
        chaos_clean,
    );
    let faults_json = format!(
        "{{\"injected\": {}, \"panics_caught\": {}, \"quarantines\": {}, \
         \"recovery_attempts\": {}, \"recoveries\": {}, \"expired\": {}, \
         \"shed\": {}, \"clean\": {}}}",
        injected,
        chaos_stats.panics_caught,
        chaos_stats.quarantines,
        chaos_stats.recovery_attempts,
        chaos_stats.recoveries,
        chaos_stats.expired,
        chaos_stats.shed,
        chaos_clean,
    );

    let (y, mo, d) = civil_today();
    let date = format!("{y:04}-{mo:02}-{d:02}");
    let path = match args.get("out") {
        Some(p) => p.to_string(),
        None => format!("BENCH_{date}.json"),
    };
    let gm = table.geomean_speedup();
    let json = format!(
        "{{\n  \"schema\": \"hylu-bench-v4\",\n  \"date\": \"{date}\",\n  \
         \"suite\": \"{suite_name}\",\n  \"threads\": {threads},\n  \
         \"reps\": {reps},\n  \"tier\": \"{tier}\",\n  \"tuning\": \"{tuning}\",\n  \
         \"environment\": \"{}\",\n  \"matrices\": [\n{}\n  ],\n  \
         \"geomean_speedup\": {gm:.4},\n  \"precision\": [\n{}\n  ],\n  \
         \"kernel_ab\": [\n{}\n  ],\n  \"dynamic\": [\n{}\n  ],\n  \
         \"faults\": {faults_json}\n}}\n",
        json_escape(&env),
        mats.join(",\n"),
        prec_json.join(",\n"),
        ab_json.join(",\n"),
        dyn_json.join(",\n"),
    );
    std::fs::write(&path, json)?;
    println!(
        "\nwrote {path} (geomean tuned/untuned speedup {gm:.2}x over {} matrices)",
        suite.len()
    );
    Ok(())
}

/// Drive `requests` solves from `callers` concurrent threads, round-robin
/// over `nsys` systems with known all-ones solutions; returns the worst
/// `|x − 1|` observed.
fn drive_callers<F>(callers: usize, requests: usize, nsys: usize, solve: F) -> Result<f64>
where
    F: Fn(usize) -> Result<Vec<f64>> + Sync,
{
    let worst = std::sync::Mutex::new(0.0f64);
    let failed: std::sync::Mutex<Option<Error>> = std::sync::Mutex::new(None);
    std::thread::scope(|sc| {
        for w in 0..callers {
            let (solve, worst, failed) = (&solve, &worst, &failed);
            sc.spawn(move || {
                let per = requests / callers + usize::from(w < requests % callers);
                let mut local = 0.0f64;
                for r in 0..per {
                    let sys = (w + r) % nsys;
                    match solve(sys) {
                        Ok(x) => {
                            for v in &x {
                                local = local.max((v - 1.0).abs());
                            }
                        }
                        Err(e) => {
                            *failed.lock().unwrap() = Some(e);
                            return;
                        }
                    }
                }
                let mut g = worst.lock().unwrap();
                if local > *g {
                    *g = local;
                }
            });
        }
    });
    if let Some(e) = failed.lock().unwrap().take() {
        return Err(e);
    }
    Ok(worst.into_inner().unwrap())
}

/// Serving-throughput mode: C concurrent callers hammer a sharded
/// [`SolverService`], then the same workload runs through the serialized
/// single-front-door baseline (one solver behind one mutex) for
/// comparison. With `--elastic`, a churn thread registers / solves /
/// retires extra systems and rebalances placement while the callers run.
fn cmd_serve(args: &Args) -> Result<()> {
    let (name, a) = load_matrix(args)?;
    let mut builder = config_from(args)?.repeated();
    if args.get("threads").is_none() {
        // per-shard pool width: default to 1 so shards + callers provide
        // the parallelism instead of oversubscribing cores
        builder = builder.threads(1);
    }
    let cfg = builder.into_config();
    let nsys = flag_usize(args, "systems", 1)?.max(1);
    let shards = flag_usize(args, "shards", 1)?.max(1);
    let callers = flag_usize(args, "rhs-workers", 4)?.max(1);
    let requests = flag_usize(args, "requests", 256)?.max(1);
    let max_batch = flag_usize(args, "max-batch", 32)?.max(1);
    let tick_us = flag_usize(args, "tick-us", 200)? as u64;
    let tick_max_us = flag_usize(args, "tick-max-us", 0)? as u64;
    let elastic = args.has("elastic");
    let grow_to = flag_usize(args, "grow-to", 0)?;
    let chaos = args.has("chaos");
    if grow_to > 0 && grow_to < shards {
        return Err(Error::Invalid(format!(
            "--grow-to {grow_to} is below --shards {shards}"
        )));
    }

    // --chaos arms a deterministic fault plan: the HYLU_FAULT spec when
    // set, otherwise a built-in mix whose period clears the `nsys`
    // registration factorizations (those run on this thread, outside
    // shard supervision, so they must not draw a fault)
    let plan = if chaos {
        Some(FaultPlan::from_env().unwrap_or_else(|| {
            std::sync::Arc::new(FaultPlan::new(
                42,
                (2 * nsys as u64).max(5),
                vec![Fault::PanicInFactor, Fault::PanicInSolve, Fault::ForceZeroPivot],
            ))
        }))
    } else {
        None
    };

    // parameter sweep: same pattern, scaled values per system; each
    // system's RHS is built so its exact solution is all-ones
    let systems: Vec<Csr> = (0..nsys)
        .map(|s| {
            let mut m = a.clone();
            let f = 1.0 + 0.1 * s as f64;
            for v in &mut m.vals {
                *v *= f;
            }
            m
        })
        .collect();
    let bs: Vec<Vec<f64>> = systems.iter().map(gen::rhs_for_ones).collect();

    let service = SolverService::new(
        ServiceConfig {
            shards,
            solver: cfg.clone(),
            max_batch,
            queue_cap: 4096,
            tick: std::time::Duration::from_micros(tick_us),
            tick_max: std::time::Duration::from_micros(tick_max_us),
            expire_deadlines: chaos,
            fault: plan.clone(),
            ..ServiceConfig::default()
        },
        systems.clone(),
    )?;
    let ids = service.system_ids();
    println!(
        "serve        : {name} (n={}, nnz={}), {} systems over {} shards, \
         {} callers x {} requests{}{}",
        a.n,
        a.nnz(),
        service.system_count(),
        service.shard_count(),
        callers,
        requests,
        if tick_max_us > 0 { " [adaptive tick]" } else { "" },
        if elastic { " [elastic churn]" } else { "" },
    );
    if grow_to > shards {
        println!("elastic      : shard set will breathe {shards} <-> {grow_to} under load");
    }
    if chaos {
        println!("chaos        : fault plan armed, dispatchers supervised");
    }
    // guaranteed-expired deadline probes: the deadline is already past
    // at submission, so whichever tick drains them must expire them
    let expiry_probes: Vec<_> = if chaos {
        let past = std::time::Instant::now() - std::time::Duration::from_millis(5);
        (0..4)
            .map(|k| service.submit_with(ids[k % nsys], bs[k % nsys].clone(), Priority::Deadline(past)))
            .collect::<Result<_>>()?
    } else {
        Vec::new()
    };
    let stop = std::sync::atomic::AtomicBool::new(false);
    let churn_cycles = std::sync::atomic::AtomicUsize::new(0);
    let breath_cycles = std::sync::atomic::AtomicUsize::new(0);
    let retries = std::sync::atomic::AtomicUsize::new(0);
    let refactor_errors = std::sync::atomic::AtomicUsize::new(0);
    let t0 = std::time::Instant::now();
    type ServeOutcome = (f64, Result<()>, Result<()>);
    let (worst, churn_result, grow_result) = std::thread::scope(|sc| -> Result<ServeOutcome> {
        let grower = if grow_to > shards {
            let (service, stop, breath_cycles) = (&service, &stop, &breath_cycles);
            Some(sc.spawn(move || -> Result<()> {
                // shard-set breathing: stretch the set one shard at a
                // time up to --grow-to (rebalancing load onto each new
                // shard), then drain back down to --shards, under the
                // same traffic the callers are generating. Tickets must
                // never be lost across either transition.
                let pause = std::time::Duration::from_micros(500);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    while service.shard_count() < grow_to
                        && !stop.load(std::sync::atomic::Ordering::Relaxed)
                    {
                        service.grow(1)?;
                        service.rebalance()?;
                        std::thread::sleep(pause);
                    }
                    while service.shard_count() > shards
                        && !stop.load(std::sync::atomic::Ordering::Relaxed)
                    {
                        service.shrink(1)?;
                        std::thread::sleep(pause);
                    }
                    breath_cycles.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                Ok(())
            }))
        } else {
            None
        };
        let churn = if elastic {
            let (service, a, stop, churn_cycles) = (&service, &a, &stop, &churn_cycles);
            Some(sc.spawn(move || -> Result<()> {
                // live-topology churn: register a fresh system, serve it
                // once, retire it, rebalance — repeatedly, against the
                // same service the callers are hammering
                // pin the plan empty: an HYLU_FAULT panic on this
                // thread would be uncontained (no shard supervision)
                let churn_solver = SolverBuilder::new()
                    .repeated()
                    .threads(1)
                    .configure(|cfg| cfg.pin_fault = true)
                    .build()?;
                let b = gen::rhs_for_ones(a);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let sys = churn_solver.analyze(a)?.factor()?;
                    let id = service.register(sys)?;
                    let x = service.solve(id, b.clone())?;
                    let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
                    if err > 1e-6 {
                        return Err(Error::Runtime(format!(
                            "churn system drifted: |x-1| = {err:.3e}"
                        )));
                    }
                    let _ = service.retire(id)?;
                    service.rebalance()?;
                    churn_cycles.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                Ok(())
            }))
        } else {
            None
        };
        let faulter = if chaos {
            let (service, systems, ids, stop, refactor_errors) =
                (&service, &systems, &ids, &stop, &refactor_errors);
            Some(sc.spawn(move || {
                // refactor traffic feeds the plan's factor stream: the
                // same values are re-shipped, so served solutions stay
                // all-ones while injected zero pivots / panics drive
                // systems through quarantine and escalated recovery
                let mut k = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    if service.refactor(ids[k % nsys], systems[k % nsys].clone()).is_err() {
                        refactor_errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    k += 1;
                    std::thread::sleep(std::time::Duration::from_micros(300));
                }
            }))
        } else {
            None
        };
        let worst = drive_callers(callers, requests, nsys, |sys| {
            if !chaos {
                return service.solve(ids[sys], bs[sys].clone());
            }
            // chaos callers ride through injected failures: retry until
            // the shard's supervision and recovery escalation let the
            // request through again
            let mut last = Error::Runtime("chaos retry budget exhausted".into());
            for _ in 0..1000 {
                match service.solve(ids[sys], bs[sys].clone()) {
                    Ok(x) => return Ok(x),
                    Err(e) => {
                        retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        last = e;
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                }
            }
            Err(last)
        });
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = faulter {
            let _ = h.join();
        }
        let churn_result = match churn {
            Some(h) => h.join().unwrap_or_else(|_| {
                Err(Error::Runtime("elastic churn thread panicked".into()))
            }),
            None => Ok(()),
        };
        let grow_result = match grower {
            Some(h) => h.join().unwrap_or_else(|_| {
                Err(Error::Runtime("shard grower thread panicked".into()))
            }),
            None => Ok(()),
        };
        Ok((worst?, churn_result, grow_result))
    })?;
    churn_result?;
    grow_result?;
    let t_service = t0.elapsed().as_secs_f64();
    if grow_to > shards {
        // settle back to the configured width so the report reflects a
        // fully drained set; every system must have survived the drains
        while service.shard_count() > shards {
            service.shrink(1)?;
        }
    }
    let mut expired_seen = 0u64;
    for t in expiry_probes {
        if matches!(t.wait(), Err(Error::DeadlineExpired)) {
            expired_seen += 1;
        }
    }
    if chaos {
        // leave no system quarantined: keep soliciting each one until a
        // dispatch-time recovery escalation restores it
        for (k, id) in ids.iter().enumerate() {
            let mut ok = false;
            for _ in 0..500 {
                if service.solve(*id, bs[k].clone()).is_ok() {
                    ok = true;
                    break;
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            if !ok || !matches!(service.health(*id), Some(Health::Healthy)) {
                return Err(Error::Runtime(format!(
                    "system {id} did not recover from chaos"
                )));
            }
        }
    }
    let st = service.stats();
    if elastic {
        println!(
            "elasticity   : {} churn cycles ({} registers, {} retires, {} moves, \
             {} forwarded, route epoch {})",
            churn_cycles.load(std::sync::atomic::Ordering::Relaxed),
            st.registers,
            st.retires,
            st.moves,
            st.forwarded,
            service.route_epoch()
        );
    }
    if grow_to > shards {
        println!(
            "shard set    : {} breath cycles {shards} <-> {grow_to}, settled at {} shards \
             (shard epoch {}, {} moves, {} forwarded)",
            breath_cycles.load(std::sync::atomic::Ordering::Relaxed),
            service.shard_count(),
            service.shard_epoch(),
            st.moves,
            st.forwarded,
        );
    }
    if let Some(p) = &plan {
        println!(
            "faults       : {} injected; {} panics caught, {} quarantines, \
             {}/{} recoveries, {} expired ({} probes), {} shed, \
             {} caller retries, {} refactor errors",
            p.injected(),
            st.panics_caught,
            st.quarantines,
            st.recoveries,
            st.recovery_attempts,
            st.expired,
            expired_seen,
            st.shed,
            retries.load(std::sync::atomic::Ordering::Relaxed),
            refactor_errors.load(std::sync::atomic::Ordering::Relaxed),
        );
    }
    drop(service);
    if chaos {
        println!(
            "service      : {} total, {:.0} solves/s (worst |x-1| {:.2e})",
            fmt_time(t_service),
            requests as f64 / t_service.max(1e-12),
            worst
        );
        println!(
            "coalescing   : {} dispatches for {} requests (mean batch {:.2}, max {})",
            st.dispatches,
            st.requests,
            st.mean_batch(),
            st.max_batch
        );
        println!(
            "chaos        : all {nsys} systems healthy at exit \
             (serialized baseline skipped under fault injection)"
        );
        if worst > 1e-6 {
            return Err(Error::Invalid(format!(
                "served solutions drifted under chaos: {worst:.3e}"
            )));
        }
        return Ok(());
    }

    // serialized baseline: the pre-service front door (one solver, one
    // mutex, one in-flight solve). Pin its fault plan empty: an
    // HYLU_FAULT panic here would be uncontained (no shard supervision).
    let mut base_cfg = cfg;
    base_cfg.pin_fault = true;
    let base = Solver::from_config(base_cfg)?;
    let mut states: Vec<LinearSystem<Factored>> = Vec::with_capacity(nsys);
    for m in &systems {
        states.push(base.analyze(m)?.factor()?);
    }
    let lock = std::sync::Mutex::new(());
    let t1 = std::time::Instant::now();
    let worst_base = drive_callers(callers, requests, nsys, |sys| {
        let _g = lock.lock().unwrap();
        states[sys].solve(&bs[sys])
    })?;
    let t_base = t1.elapsed().as_secs_f64();

    println!(
        "service      : {} total, {:.0} solves/s (worst |x-1| {:.2e})",
        fmt_time(t_service),
        requests as f64 / t_service.max(1e-12),
        worst
    );
    println!(
        "coalescing   : {} dispatches for {} requests (mean batch {:.2}, max {})",
        st.dispatches,
        st.requests,
        st.mean_batch(),
        st.max_batch
    );
    println!(
        "baseline     : {} total, {:.0} solves/s (worst |x-1| {:.2e})",
        fmt_time(t_base),
        requests as f64 / t_base.max(1e-12),
        worst_base
    );
    println!(
        "speedup      : {:.2}x vs serialized single front door",
        t_base / t_service.max(1e-12)
    );
    if worst > 1e-6 || worst_base > 1e-6 {
        return Err(Error::Invalid(format!(
            "served solutions drifted: service {worst:.3e}, baseline {worst_base:.3e}"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_positional() {
        let a = Args::parse(&sv(&["solve", "--gen", "circuit:100", "--repeated", "--threads", "2"]));
        assert_eq!(a.command(), Some("solve"));
        assert_eq!(a.get("gen"), Some("circuit:100"));
        assert_eq!(a.get("threads"), Some("2"));
        assert!(a.has("repeated"));
        assert!(!a.has("xla"));
    }

    #[test]
    fn load_matrix_gen_specs() {
        for spec in ["circuit:500", "mesh2d:400", "kkt:400:7", "banded:300"] {
            let a = Args::parse(&sv(&["solve", "--gen", spec]));
            let (_, m) = load_matrix(&a).unwrap();
            m.validate().unwrap();
        }
    }

    #[test]
    fn config_kernel_parse() {
        let a = Args::parse(&sv(&["solve", "--kernel", "sup-sup"]));
        assert_eq!(
            config_from(&a).unwrap().config().kernel,
            Some(KernelMode::SupSup)
        );
        let bad = Args::parse(&sv(&["solve", "--kernel", "bogus"]));
        assert!(config_from(&bad).is_err());
        // flags after `--kernel auto` must still apply
        let auto = Args::parse(&sv(&["solve", "--kernel", "auto", "--repeated"]));
        let cfg = config_from(&auto).unwrap().into_config();
        assert_eq!(cfg.kernel, None);
        assert!(cfg.repeated);
    }

    #[test]
    fn solve_command_end_to_end() {
        let code = run(&sv(&["solve", "--gen", "mesh2d:900", "--threads", "1"]));
        assert_eq!(code, 0);
    }

    #[test]
    fn solve_command_with_batched_rhs() {
        let code = run(&sv(&[
            "solve", "--gen", "mesh2d:400", "--threads", "2", "--rhs", "4",
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn bad_rhs_flag_is_rejected() {
        // exit status is the stable Error::Invalid code
        let code = run(&sv(&["solve", "--gen", "mesh2d:100", "--rhs", "four"]));
        assert_eq!(code, Error::Invalid(String::new()).code());
    }

    #[test]
    fn unknown_command_usage() {
        // usage errors share Error::Invalid's stable code (2)
        assert_eq!(run(&sv(&["frobnicate"])), 2);
    }

    #[test]
    fn io_errors_exit_with_the_io_code() {
        let code = run(&sv(&["solve", "--matrix", "/no/such/file.mtx"]));
        assert_eq!(code, Error::Io(String::new()).code());
        assert_eq!(code, 3);
    }

    #[test]
    fn bench_rejects_bad_kernel_tier() {
        // bench interprets --kernel as the dispatch tier; bad names fail
        // fast before any suite work
        assert_eq!(run(&sv(&["bench", "--kernel", "bogus"])), 2);
    }

    #[test]
    fn tune_command_end_to_end() {
        let code = run(&sv(&[
            "tune", "--gen", "mesh2d:400", "--tuning", "quick", "--threads", "1",
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn tune_rejects_bad_level() {
        let code = run(&sv(&["tune", "--gen", "mesh2d:100", "--tuning", "turbo"]));
        assert_eq!(code, Error::Invalid(String::new()).code());
    }

    #[test]
    fn gauntlet_rejects_tuning_off() {
        // the whole point is tuned-vs-untuned; off has nothing to compare
        assert_eq!(run(&sv(&["gauntlet", "--tuning", "off"])), 2);
    }

    #[test]
    fn gauntlet_writes_artifact() {
        let out = std::env::temp_dir().join(format!("hylu-gauntlet-{}.json", std::process::id()));
        let code = run(&sv(&[
            "gauntlet",
            "--reps",
            "1",
            "--threads",
            "1",
            "--tuning",
            "quick",
            "--out",
            out.to_str().unwrap(),
        ]));
        assert_eq!(code, 0);
        let s = std::fs::read_to_string(&out).unwrap();
        assert!(s.contains("\"schema\": \"hylu-bench-v4\""));
        assert!(s.contains("\"geomean_speedup\""));
        assert!(s.contains("\"kernel_ab\""));
        assert!(s.contains("\"matrices\""));
        assert!(s.contains("\"precision\""));
        assert!(s.contains("\"refine_iters_mixed\""));
        assert!(s.contains("\"dynamic\""));
        assert!(s.contains("\"t_delta\""));
        assert!(s.contains("\"baseline_repivots\""));
        assert!(s.contains("\"faults\""));
        assert!(s.contains("\"panics_caught\""));
        assert!(s.contains("\"clean\": true"));
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn pattern_entry_insertion_keeps_csr_valid() {
        let a = gen::grid2d(8, 8);
        let row = a.n - 3;
        let edited = add_pattern_entry(&a, row, 5);
        edited.validate().unwrap();
        assert_eq!(edited.nnz(), a.nnz() + 1);
        // only the targeted row changed structure
        for r in 0..a.n {
            if r != row {
                assert_eq!(edited.row_indices(r), a.row_indices(r));
            }
        }
        assert_eq!(edited.row_indices(row).len(), a.row_indices(row).len() + 1);
    }

    #[test]
    fn solve_command_with_mixed_precision() {
        let code = run(&sv(&[
            "solve", "--gen", "mesh2d:400", "--threads", "1", "--precision", "mixed",
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn bad_precision_flag_is_rejected() {
        let code = run(&sv(&["solve", "--gen", "mesh2d:100", "--precision", "f16"]));
        assert_eq!(code, Error::Invalid(String::new()).code());
    }

    #[test]
    fn civil_today_is_sane() {
        let (y, m, d) = civil_today();
        assert!((2024..3000).contains(&y));
        assert!((1..=12).contains(&m));
        assert!((1..=31).contains(&d));
    }

    #[test]
    fn serve_command_end_to_end() {
        let code = run(&sv(&[
            "serve",
            "--gen",
            "mesh2d:400",
            "--systems",
            "2",
            "--shards",
            "2",
            "--rhs-workers",
            "3",
            "--requests",
            "24",
            "--threads",
            "1",
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn serve_chaos_end_to_end() {
        // fault injection armed: panics are caught by shard supervision,
        // quarantined systems recover, the deadline probes expire, and
        // the command still exits 0 with bit-exact served solutions
        if std::env::var("HYLU_FAULT").is_ok() {
            // an external plan may fire during registration (outside
            // shard supervision); this test pins the built-in plan
            return;
        }
        let code = run(&sv(&[
            "serve",
            "--gen",
            "mesh2d:225",
            "--systems",
            "2",
            "--shards",
            "2",
            "--rhs-workers",
            "2",
            "--requests",
            "32",
            "--threads",
            "1",
            "--chaos",
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn serve_rejects_bad_flags() {
        // flag parse failures share Error::Invalid's stable code
        let code = run(&sv(&["serve", "--gen", "mesh2d:100", "--requests", "many"]));
        assert_eq!(code, Error::Invalid(String::new()).code());
    }

    #[test]
    fn serve_elastic_end_to_end() {
        // live churn (register/solve/retire/rebalance) against caller
        // traffic, plus the adaptive coalescing window
        let code = run(&sv(&[
            "serve",
            "--gen",
            "mesh2d:225",
            "--systems",
            "2",
            "--shards",
            "2",
            "--rhs-workers",
            "2",
            "--requests",
            "24",
            "--threads",
            "1",
            "--elastic",
            "--tick-max-us",
            "500",
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn serve_grow_to_end_to_end() {
        // shard-set breathing: the grower thread stretches 2 -> 4 and
        // drains back while callers hammer the service; every request
        // must still resolve bit-exact and the command exits 0
        let code = run(&sv(&[
            "serve",
            "--gen",
            "mesh2d:225",
            "--systems",
            "3",
            "--shards",
            "2",
            "--rhs-workers",
            "3",
            "--requests",
            "48",
            "--threads",
            "1",
            "--grow-to",
            "4",
            "--tick-max-us",
            "500",
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn serve_rejects_grow_to_below_shards() {
        let code = run(&sv(&[
            "serve", "--gen", "mesh2d:100", "--shards", "4", "--grow-to", "2",
        ]));
        assert_eq!(code, Error::Invalid(String::new()).code());
    }
}

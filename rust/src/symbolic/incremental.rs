//! Pattern-delta symbolic patching for incremental re-analysis.
//!
//! The up-looking row loop in [`analyze`](super::analyze) is strictly
//! sequential: row `i`'s reach is a function of row `i`'s own pattern and
//! the *finalized* nodes covering rows `< i` only. So when a re-analyzed
//! matrix differs from the cached pattern only in rows `>= r0`, every
//! node that ends before the node containing `r0` is byte-for-byte
//! identical in the cold analysis of the new pattern. The patcher
//! exploits that: it truncates the previous [`Symbolic`] at the node
//! containing the first changed permuted row (one node earlier when the
//! changed row starts its node — the cold run still has the preceding
//! node open as a merge candidate there), reconstructs the builder
//! state for the retained prefix, and replays the identical row loop for
//! the suffix. The result is **bit-identical** to a cold
//! [`analyze_pattern`](super::analyze_pattern) of the new pattern under
//! the same [`MergePolicy`] — not approximately equal: the same `Vec`
//! contents, the same flop accumulation order, the same schedule.
//!
//! The caller (coordinator) decides *whether* to patch: when the edit
//! touches too many rows the replay saves nothing, and the coordinator
//! falls back to a full `analyze_pattern` (same inputs, so the fallback
//! is trivially identical too).

use crate::sparse::csr::Csr;
use crate::symbolic::analyze::{self, Builder};
use crate::symbolic::{MergePolicy, Symbolic};

/// Structural diff of two same-dimension permuted patterns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatternDelta {
    /// First (permuted) row whose column set differs; `None` when the
    /// structures are identical.
    pub first_changed: Option<usize>,
    /// Number of rows whose column sets differ — the "locality" measure
    /// the coordinator's delta-fraction knob is applied to.
    pub changed_rows: usize,
}

/// Compare the structure (indices only, values ignored) of two permuted
/// patterns row by row. Panics if dimensions differ — the coordinator
/// routes dimension changes to a full cold analysis before diffing.
pub fn diff_patterns(old: &Csr, new: &Csr) -> PatternDelta {
    assert_eq!(old.n, new.n, "diff_patterns requires equal dimensions");
    let mut first_changed = None;
    let mut changed_rows = 0usize;
    for i in 0..old.n {
        if old.row_indices(i) != new.row_indices(i) {
            changed_rows += 1;
            if first_changed.is_none() {
                first_changed = Some(i);
            }
        }
    }
    PatternDelta {
        first_changed,
        changed_rows,
    }
}

/// Result of a successful delta patch, with the replay extent for stats
/// and gauntlet reporting.
#[derive(Clone, Debug)]
pub struct PatchOutcome {
    /// The patched symbolic analysis (bit-identical to cold).
    pub sym: Symbolic,
    /// First row the patcher re-ran the row loop from: the first row of
    /// the node containing the first changed row, or of that node's
    /// predecessor when the changed row starts its node.
    pub replay_start: usize,
    /// Rows replayed (`n - replay_start`).
    pub replayed_rows: usize,
}

/// Patch `prev` for the new permuted pattern `pa`, replaying the row
/// loop from the node containing `first_changed`.
///
/// `policy` and `bulk_threshold` must be the values that produced
/// `prev` — the coordinator caches them per analysis. The retained
/// prefix is spliced verbatim; counters (`flops`, `lu_entries`,
/// `rows_in_supers`) are re-accumulated over the retained nodes in their
/// original order so even the floating-point flop total matches the cold
/// run's sequential accumulation exactly.
pub fn patch_pattern(
    prev: &Symbolic,
    pa: &Csr,
    policy: MergePolicy,
    bulk_threshold: usize,
    first_changed: usize,
) -> PatchOutcome {
    let n = pa.n;
    assert_eq!(prev.n, n, "patch_pattern requires equal dimensions");
    assert!(first_changed < n, "first_changed out of range");

    // The node containing the first changed row is the first node whose
    // output could differ; everything before it is untouched prefix —
    // with one wrinkle. When the changed row IS its node's first row,
    // the cold analysis of the new pattern still has the *preceding*
    // node open as the merge candidate at that row, and the row's new
    // structure may now pass the merge test the old structure failed.
    // Back up one node so the replay rebuilds that candidate as the
    // in-progress supernode. One node suffices: the preceding node's
    // own start decision was made against unchanged earlier rows, so
    // the cold run reproduces it verbatim.
    let mut cut = prev.row_node[first_changed] as usize;
    if cut > 0 && prev.nodes[cut].first as usize == first_changed {
        cut -= 1;
    }
    let cut_node = &prev.nodes[cut];
    let replay_start = cut_node.first as usize;

    let mut b = if cut == 0 {
        Builder::new(n)
    } else {
        // Allocation in the builder is monotone, so the discarded node's
        // start offsets are exactly the retained prefix's lengths.
        let mut row_node = prev.row_node.clone();
        for r in &mut row_node[replay_start..] {
            *r = u32::MAX;
        }
        let nodes = prev.nodes[..cut].to_vec();
        let (mut lu_entries, mut flops, mut rows_in_supers) = (0usize, 0.0f64, 0usize);
        for nd in &nodes {
            let (w, nl, nu) = (nd.width as usize, nd.nl(), nd.nu());
            lu_entries += if nd.is_super { w * (nl + w + nu) } else { nl + 1 + nu };
            flops += nd.flops;
            if nd.is_super {
                rows_in_supers += w;
            }
        }
        Builder {
            nodes,
            row_node,
            lcols: prev.lcols[..cut_node.l_start].to_vec(),
            ucols: prev.ucols[..cut_node.u_start].to_vec(),
            groups: prev.groups[..cut_node.g_start].to_vec(),
            lu_entries,
            flops,
            rows_in_supers,
        }
    };

    analyze::run_rows(&mut b, pa, policy, replay_start);
    PatchOutcome {
        sym: analyze::finish(b, n, bulk_threshold),
        replay_start,
        replayed_rows: n - replay_start,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen;
    use crate::symbolic::analyze_pattern;
    use crate::testutil::for_each_seed;

    /// Rebuild `a` with the entry `(i, j)` added (or removed when
    /// `remove` is set). Keeps every value at 1.0 — the diff and the
    /// patch only look at structure.
    fn edit(a: &Csr, i: usize, j: usize, remove: bool) -> Csr {
        let mut c = Coo::new(a.n);
        for r in 0..a.n {
            for &col in a.row_indices(r) {
                if remove && r == i && col == j {
                    continue;
                }
                c.push(r, col, 1.0);
            }
        }
        if !remove {
            c.push(i, j, 1.0);
        }
        c.to_csr()
    }

    fn with_diag(a: &Csr) -> Csr {
        let mut c = Coo::new(a.n);
        for r in 0..a.n {
            for &col in a.row_indices(r) {
                c.push(r, col, 1.0);
            }
            c.push(r, r, 1.0);
        }
        c.to_csr()
    }

    fn check_patch(a0: &Csr, a1: &Csr, policy: MergePolicy) {
        let prev = analyze_pattern(a0, policy, 4);
        let delta = diff_patterns(a0, a1);
        let Some(r0) = delta.first_changed else {
            assert_eq!(a0.indices, a1.indices);
            return;
        };
        let patched = patch_pattern(&prev, a1, policy, 4, r0);
        let cold = analyze_pattern(a1, policy, 4);
        assert_eq!(patched.sym, cold, "patched symbolic differs from cold");
        assert!(patched.replay_start <= r0);
        assert_eq!(patched.replayed_rows, a1.n - patched.replay_start);
    }

    #[test]
    fn identical_patterns_diff_to_empty_delta() {
        let a = with_diag(&gen::grid2d(6, 6));
        let d = diff_patterns(&a, &a);
        assert_eq!(d.first_changed, None);
        assert_eq!(d.changed_rows, 0);
    }

    #[test]
    fn single_added_entry_patches_bit_identical() {
        let a0 = with_diag(&gen::grid2d(8, 8));
        let a1 = edit(&a0, 40, 3, false);
        for policy in [
            MergePolicy::None,
            MergePolicy::Exact { max_width: 16 },
            MergePolicy::Relaxed {
                max_width: 16,
                budget_frac: 0.25,
                budget_abs: 8,
            },
        ] {
            check_patch(&a0, &a1, policy);
        }
    }

    #[test]
    fn removed_entry_patches_bit_identical() {
        let a0 = with_diag(&gen::circuit(80, 4));
        // remove the last off-diagonal entry of a late row
        let mut target = None;
        for r in (0..a0.n).rev() {
            if let Some(&c) = a0.row_indices(r).iter().find(|&&c| c != r) {
                target = Some((r, c));
                break;
            }
        }
        let (r, c) = target.expect("pattern has an off-diagonal entry");
        let a1 = edit(&a0, r, c, true);
        check_patch(&a0, &a1, MergePolicy::Exact { max_width: 16 });
    }

    #[test]
    fn edit_in_row_zero_degenerates_to_full_replay() {
        let a0 = with_diag(&gen::grid2d(5, 5));
        let a1 = edit(&a0, 0, a0.n - 1, false);
        let prev = analyze_pattern(&a0, MergePolicy::Exact { max_width: 8 }, 4);
        let patched = patch_pattern(&prev, &a1, MergePolicy::Exact { max_width: 8 }, 4, 0);
        assert_eq!(patched.replay_start, 0);
        assert_eq!(patched.sym, analyze_pattern(&a1, MergePolicy::Exact { max_width: 8 }, 4));
    }

    #[test]
    fn edit_matching_open_predecessor_merges_across_the_cut() {
        // Regression: when the first changed row is the FIRST row of its
        // node, the cold analysis of the edited pattern still has the
        // preceding node open as the merge candidate at that row. Here
        // row 2's edit makes it exactly match row 1's U structure under
        // Exact merging, so cold analysis fuses rows 1..=2 — a patch
        // that replays from row 2 against a finalized prefix can never
        // reproduce that merge.
        let mut c = Coo::new(5);
        c.push(0, 0, 1.0);
        c.push(0, 3, 1.0);
        c.push(1, 1, 1.0);
        c.push(1, 2, 1.0);
        c.push(1, 4, 1.0);
        c.push(2, 2, 1.0);
        c.push(2, 3, 1.0);
        c.push(3, 3, 1.0);
        c.push(4, 4, 1.0);
        let a0 = c.to_csr();
        // row 2: {2,3} -> {2,4}, identical to row 1's tail at row 2
        let a1 = edit(&edit(&a0, 2, 3, true), 2, 4, false);
        let policy = MergePolicy::Exact { max_width: 8 };

        let prev = analyze_pattern(&a0, policy, 4);
        let nd = &prev.nodes[prev.row_node[2] as usize];
        assert_eq!(nd.first, 2, "setup: row 2 must start its node in prev");
        let cold = analyze_pattern(&a1, policy, 4);
        assert_eq!(
            cold.row_node[1], cold.row_node[2],
            "setup: cold analysis must merge rows 1 and 2"
        );

        let patched = patch_pattern(&prev, &a1, policy, 4, 2);
        assert_eq!(patched.replay_start, 1, "replay must back up one node");
        assert_eq!(patched.sym, cold, "patched symbolic differs from cold");
        check_patch(&a0, &a1, policy);
    }

    #[test]
    fn property_random_edits_patch_bit_identical() {
        for_each_seed(10, |rng| {
            let n = rng.range(15, 50);
            let mut c = Coo::new(n);
            for i in 0..n {
                c.push(i, i, 4.0);
                for _ in 0..rng.range(1, 4) {
                    c.push(i, rng.below(n), 1.0);
                }
            }
            let a0 = c.to_csr();
            // a batch of random structural edits clustered in the tail
            let mut a1 = a0.clone();
            for _ in 0..rng.range(1, 5) {
                let i = rng.range(n / 2, n);
                let j = rng.below(n);
                if i == j {
                    continue; // keep the structural diagonal
                }
                let has = a1.row_indices(i).contains(&j);
                a1 = edit(&a1, i, j, has);
            }
            for policy in [
                MergePolicy::None,
                MergePolicy::Exact { max_width: 16 },
                MergePolicy::Forced {
                    min_width: 4,
                    max_width: 16,
                },
            ] {
                check_patch(&a0, &a1, policy);
            }
        });
    }
}

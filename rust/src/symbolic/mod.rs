//! Symbolic analysis: up-looking symbolic LU factorization with integrated
//! supernode detection, plus the dependency-DAG levelization that drives the
//! dual-mode parallel schedule.
//!
//! HYLU fixes the fill pattern *once* here (static-pivoting regime: MC64 has
//! already put large entries on the diagonal, and numeric pivoting is
//! restricted to row swaps inside supernode diagonal blocks, which preserve
//! the pattern). Numeric factorization and refactorization replay these
//! patterns without any symbolic work — the key to the paper's
//! repeated-solve speedups.

pub mod analyze;
pub mod dag;
pub mod incremental;

pub use analyze::{analyze_pattern, MergePolicy};
pub use dag::Schedule;
pub use incremental::{diff_patterns, patch_pattern, PatchOutcome, PatternDelta};

/// One node of the factorization: a standalone row (`width == 1` and not
/// `is_super`) or a supernode panel (consecutive rows with identical —
/// possibly relaxation-padded — U structure and identical off-block L
/// structure).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSym {
    /// First (permuted) row of the node.
    pub first: u32,
    /// Number of rows.
    pub width: u32,
    /// True if stored as a dense panel (supernode); standalone rows store
    /// sparse L/U rows instead.
    pub is_super: bool,
    /// Start of range into [`Symbolic::lcols`]: shared L pattern, columns
    /// `< first`, sorted ascending.
    pub l_start: usize,
    /// End of L range.
    pub l_end: usize,
    /// Start of range into [`Symbolic::ucols`]: shared U tail pattern,
    /// columns `>= first + width`, sorted ascending. (The dense diagonal
    /// block is implicit.)
    pub u_start: usize,
    /// End of U range.
    pub u_end: usize,
    /// Start of range into [`Symbolic::groups`]: runs of the L pattern by
    /// source node, in ascending column order.
    pub g_start: usize,
    /// End of group range.
    pub g_end: usize,
    /// Estimated factorization flops for this node (scheduling weight).
    pub flops: f64,
}

impl NodeSym {
    /// Number of shared L-pattern columns.
    pub fn nl(&self) -> usize {
        self.l_end - self.l_start
    }

    /// Number of U-tail columns.
    pub fn nu(&self) -> usize {
        self.u_end - self.u_start
    }

    /// Dense panel width (supernodes): L part + diagonal block + U tail.
    pub fn panel_width(&self) -> usize {
        self.nl() + self.width as usize + self.nu()
    }
}

/// A run of a node's L pattern coming from one source node: columns
/// `lcols[l_start + offset .. offset + len]` are a *tail segment* of the
/// source node's rows (guaranteed by reach semantics; asserted in debug
/// builds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Group {
    /// Source node id.
    pub src: u32,
    /// Offset of the run inside this node's L pattern.
    pub offset: u32,
    /// Run length (number of source rows used).
    pub len: u32,
}

/// Output of symbolic analysis on the permuted pattern.
#[derive(Clone, Debug, PartialEq)]
pub struct Symbolic {
    /// Dimension.
    pub n: usize,
    /// Nodes in ascending row order.
    pub nodes: Vec<NodeSym>,
    /// Row -> node id.
    pub row_node: Vec<u32>,
    /// Concatenated shared L patterns.
    pub lcols: Vec<u32>,
    /// Concatenated shared U tail patterns.
    pub ucols: Vec<u32>,
    /// Concatenated update groups.
    pub groups: Vec<Group>,
    /// Total flop estimate.
    pub flops: f64,
    /// nnz(L) + nnz(U) including padding (panel cells for supernodes).
    pub lu_entries: usize,
    /// Fraction of rows living in supernodes of width >= 2.
    pub supernode_coverage: f64,
    /// The dual-mode schedule.
    pub schedule: Schedule,
}

impl Symbolic {
    /// Iterate a row's U-structure: the implicit in-block columns
    /// `(row, first+width)` followed by the shared U tail. Used by tests
    /// and the row-mode numeric kernel.
    pub fn row_u_pattern(&self, row: usize) -> impl Iterator<Item = u32> + '_ {
        let node = &self.nodes[self.row_node[row] as usize];
        let block_end = node.first + node.width;
        ((row as u32 + 1)..block_end).chain(self.ucols[node.u_start..node.u_end].iter().copied())
    }

    /// Total panel memory (f64 cells) across supernodes.
    pub fn panel_cells(&self) -> usize {
        self.nodes
            .iter()
            .filter(|nd| nd.is_super)
            .map(|nd| nd.width as usize * nd.panel_width())
            .sum()
    }
}

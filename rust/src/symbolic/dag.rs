//! Dependency-DAG levelization for the dual-mode parallel schedule
//! (paper ref [14], Fig. 2).
//!
//! Each node (standalone row or supernode) depends on the source nodes its
//! L pattern pulls from. Levelizing the DAG gives independent level sets:
//! front levels are wide (many nodes) and run in **bulk mode** — all nodes
//! of a level in parallel, barrier between levels; the tail of the DAG is a
//! long dependent chain and runs in **pipeline mode** — workers claim nodes
//! in topological order and spin on per-dependency done-flags, overlapping
//! dependent nodes at sub-node granularity.

use crate::symbolic::{Group, NodeSym};

/// Levelized dual-mode schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// Level of each node (0 = no dependencies).
    pub level: Vec<u32>,
    /// CSR pointer into `level_nodes` per level.
    pub level_ptr: Vec<usize>,
    /// Node ids grouped by level, ascending id within a level.
    pub level_nodes: Vec<u32>,
    /// Levels `[0, bulk_levels)` run in bulk mode; the rest in pipeline
    /// mode.
    pub bulk_levels: usize,
    /// Total flops in bulk levels (load-balancing statistics).
    pub bulk_flops: f64,
    /// Reverse levels (backward-substitution DAG: a node depends on the
    /// owners of its U-tail columns).
    pub rlevel: Vec<u32>,
    /// CSR pointer into `rlevel_nodes` per reverse level.
    pub rlevel_ptr: Vec<usize>,
    /// Node ids grouped by reverse level.
    pub rlevel_nodes: Vec<u32>,
    /// Reverse levels `[0, rbulk_levels)` run in bulk mode during backward
    /// substitution ("bulk-sequential" dual mode, paper §2.3).
    pub rbulk_levels: usize,
}

impl Schedule {
    /// Number of levels.
    pub fn nlevels(&self) -> usize {
        self.level_ptr.len().saturating_sub(1)
    }

    /// Node ids at `level`.
    pub fn nodes_at(&self, level: usize) -> &[u32] {
        &self.level_nodes[self.level_ptr[level]..self.level_ptr[level + 1]]
    }
}

/// Levelize: group node ids by `level`, CSR-style.
fn levelize(level: &[u32], bulk_threshold: usize) -> (Vec<usize>, Vec<u32>, usize) {
    let nn = level.len();
    let maxlev = level.iter().copied().max().unwrap_or(0);
    let nlev = if nn == 0 { 0 } else { maxlev as usize + 1 };
    let mut level_ptr = vec![0usize; nlev + 1];
    for &lv in level {
        level_ptr[lv as usize + 1] += 1;
    }
    for i in 0..nlev {
        level_ptr[i + 1] += level_ptr[i];
    }
    let mut level_nodes = vec![0u32; nn];
    let mut next = level_ptr.clone();
    for (id, &lv) in level.iter().enumerate() {
        level_nodes[next[lv as usize]] = id as u32;
        next[lv as usize] += 1;
    }
    // bulk/pipeline split: stay bulk while levels are wide
    let mut bulk_levels = 0usize;
    while bulk_levels < nlev && level_ptr[bulk_levels + 1] - level_ptr[bulk_levels] >= bulk_threshold
    {
        bulk_levels += 1;
    }
    (level_ptr, level_nodes, bulk_levels)
}

/// Build the levelized schedule. `bulk_threshold`: a level stays in bulk
/// mode while it (and every level before it) has at least this many nodes.
pub fn build_schedule(
    nodes: &[NodeSym],
    groups: &[Group],
    ucols: &[u32],
    row_node: &[u32],
    bulk_threshold: usize,
) -> Schedule {
    let nn = nodes.len();
    // forward levels (factorization + forward substitution)
    let mut level = vec![0u32; nn];
    for (id, nd) in nodes.iter().enumerate() {
        let mut lv = 0u32;
        for g in &groups[nd.g_start..nd.g_end] {
            lv = lv.max(level[g.src as usize] + 1);
        }
        level[id] = lv;
    }
    let (level_ptr, level_nodes, bulk_levels) = levelize(&level, bulk_threshold);
    let mut bulk_flops = 0.0;
    for lv in 0..bulk_levels {
        for &id in &level_nodes[level_ptr[lv]..level_ptr[lv + 1]] {
            bulk_flops += nodes[id as usize].flops;
        }
    }

    // reverse levels (backward substitution): node depends on the owners of
    // its U-tail columns, processed descending
    let mut rlevel = vec![0u32; nn];
    for (id, nd) in nodes.iter().enumerate().rev() {
        let mut lv = 0u32;
        for &j in &ucols[nd.u_start..nd.u_end] {
            lv = lv.max(rlevel[row_node[j as usize] as usize] + 1);
        }
        rlevel[id] = lv;
    }
    let (rlevel_ptr, rlevel_nodes, rbulk_levels) = levelize(&rlevel, bulk_threshold);

    Schedule {
        level,
        level_ptr,
        level_nodes,
        bulk_levels,
        bulk_flops,
        rlevel,
        rlevel_ptr,
        rlevel_nodes,
        rbulk_levels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};

    fn check_schedule(nodes: &[NodeSym], groups: &[Group], s: &Schedule) {
        // every dependency has a strictly smaller level
        for (id, nd) in nodes.iter().enumerate() {
            for g in &groups[nd.g_start..nd.g_end] {
                assert!(
                    s.level[g.src as usize] < s.level[id],
                    "dep level violated: {} -> {}",
                    g.src,
                    id
                );
            }
        }
        // level_nodes is a permutation of node ids, grouped correctly
        let mut seen = vec![false; nodes.len()];
        for lv in 0..s.nlevels() {
            for &id in s.nodes_at(lv) {
                assert!(!seen[id as usize]);
                seen[id as usize] = true;
                assert_eq!(s.level[id as usize] as usize, lv);
            }
        }
        assert!(seen.into_iter().all(|b| b));
    }

    #[test]
    fn schedule_levels_are_topological() {
        for a in [
            gen::grid2d(12, 12),
            gen::circuit(300, 2),
            gen::banded(100, 2, 3),
        ] {
            let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 32 }, 4);
            check_schedule(&sym.nodes, &sym.groups, &sym.schedule);
        }
    }

    #[test]
    fn diagonal_matrix_is_single_level() {
        let a = crate::sparse::csr::Csr::identity(20);
        let sym = analyze_pattern(&a, MergePolicy::None, 4);
        assert_eq!(sym.schedule.nlevels(), 1);
        assert_eq!(sym.schedule.nodes_at(0).len(), 20);
        assert_eq!(sym.schedule.bulk_levels, 1);
    }

    #[test]
    fn banded_chain_goes_pipeline() {
        // a dense-band matrix forms a long dependent chain: few nodes per
        // level => pipeline mode from the start (with threshold > 1)
        let a = gen::banded(60, 3, 1);
        let sym = analyze_pattern(&a, MergePolicy::None, 8);
        assert!(sym.schedule.nlevels() > 10);
        assert!(sym.schedule.bulk_levels < sym.schedule.nlevels());
    }

    #[test]
    fn bulk_prefix_is_wide() {
        let a = gen::grid2d(20, 20);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 32 }, 4);
        let s = &sym.schedule;
        for lv in 0..s.bulk_levels {
            assert!(s.nodes_at(lv).len() >= 4);
        }
    }
}

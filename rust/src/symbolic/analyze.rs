//! Up-looking symbolic LU factorization with on-the-fly supernode
//! detection.
//!
//! For each row `i` of the permuted matrix, the fill pattern is the reach of
//! the row's column set in the DAG whose edges are `k -> j` for `u_kj != 0`,
//! `k < i` (Gilbert–Peierls, transposed to rows). Supernodes are grown
//! greedily while rows match the current shared pattern under the active
//! [`MergePolicy`]; relaxation *pads* patterns (explicit zeros) which keeps
//! all later reaches consistent because rows are processed in order and
//! padded patterns only ever grow (see DESIGN.md §5).

use crate::sparse::csr::Csr;
use crate::symbolic::{dag, Group, NodeSym, Symbolic};

/// Supernode merge policy — the knob that turns one engine into HYLU's
/// three kernels and both baselines.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MergePolicy {
    /// No supernodes at all (row-row / KLU-like mode).
    None,
    /// Merge only rows with exactly identical structure (paper default for
    /// one-time solving).
    Exact {
        /// Maximum supernode width (tile-class cap).
        max_width: usize,
    },
    /// Allow padding up to a budget (paper's repeated-solve preprocessing:
    /// costlier analysis, bigger supernodes, faster refactorization).
    Relaxed {
        /// Maximum supernode width.
        max_width: usize,
        /// Padded cells allowed, as a fraction of the merged panel size.
        budget_frac: f64,
        /// Flat padded-cell allowance per merge.
        budget_abs: usize,
    },
    /// Force-amalgamate consecutive rows to at least `min_width` regardless
    /// of pattern match (the PARDISO-like always-BLAS baseline; generates
    /// the fill that supernodal codes suffer on circuit-class matrices).
    Forced {
        /// Merge unconditionally below this width.
        min_width: usize,
        /// Hard cap.
        max_width: usize,
    },
}

/// In-progress supernode state.
struct Current {
    first: usize,
    width: usize,
    /// shared L pattern, cols < first, sorted
    shared_l: Vec<u32>,
    /// shared U pattern, cols >= first (block diagonals + tail), sorted
    shared_u: Vec<u32>,
}

/// Builder that owns the finalized state. `pub(crate)` so the
/// incremental patcher (`symbolic/incremental.rs`) can resume the exact
/// same row loop from a truncated prefix of a previous analysis.
pub(crate) struct Builder {
    pub(crate) nodes: Vec<NodeSym>,
    pub(crate) row_node: Vec<u32>,
    pub(crate) lcols: Vec<u32>,
    pub(crate) ucols: Vec<u32>,
    pub(crate) groups: Vec<Group>,
    pub(crate) lu_entries: usize,
    pub(crate) flops: f64,
    pub(crate) rows_in_supers: usize,
}

impl Builder {
    pub(crate) fn new(n: usize) -> Builder {
        Builder {
            nodes: Vec::new(),
            row_node: vec![u32::MAX; n],
            lcols: Vec::new(),
            ucols: Vec::new(),
            groups: Vec::new(),
            lu_entries: 0,
            flops: 0.0,
            rows_in_supers: 0,
        }
    }

    /// U-structure of a *finalized* row `k`, for reach queries and flop
    /// counts: implicit in-block columns then the shared tail.
    fn row_u_len(&self, k: usize) -> usize {
        let nd = &self.nodes[self.row_node[k] as usize];
        (nd.first as usize + nd.width as usize - 1 - k) + (nd.u_end - nd.u_start)
    }

    fn finalize(&mut self, cur: Current) {
        let Current {
            first,
            width,
            shared_l,
            shared_u,
        } = cur;
        let block_end = first + width;
        let l_start = self.lcols.len();
        self.lcols.extend_from_slice(&shared_l);
        let l_end = self.lcols.len();
        let u_start = self.ucols.len();
        // tail = shared U beyond the block; width-1 rows store diag
        // separately so exclude it the same way
        for &c in &shared_u {
            if (c as usize) >= block_end {
                self.ucols.push(c);
            }
        }
        let u_end = self.ucols.len();
        let nl = l_end - l_start;
        let nu = u_end - u_start;
        let is_super = width >= 2;

        // update groups: runs of lcols by source node
        let g_start = self.groups.len();
        let node_id = self.nodes.len() as u32;
        {
            let lc = &self.lcols[l_start..l_end];
            let mut k = 0;
            while k < nl {
                let src = self.row_node[lc[k] as usize];
                let mut m = k + 1;
                while m < nl && self.row_node[lc[m] as usize] == src {
                    m += 1;
                }
                // tail-segment invariant: the run is contiguous columns
                // ending at the source node's last row
                #[cfg(debug_assertions)]
                {
                    let snd = &self.nodes[src as usize];
                    debug_assert_eq!(
                        lc[m - 1] as usize,
                        snd.first as usize + snd.width as usize - 1,
                        "group does not end at source node end"
                    );
                    for t in k..m - 1 {
                        debug_assert_eq!(lc[t] + 1, lc[t + 1], "group not contiguous");
                    }
                }
                self.groups.push(Group {
                    src,
                    offset: k as u32,
                    len: (m - k) as u32,
                });
                k = m;
            }
        }
        let g_end = self.groups.len();

        // flop estimate: each L column k contributes a division + 2*|U_k|
        // multiply-adds per target row; internal block factorization adds
        // ~2/3 w^3 + w^2 * nu.
        let w = width as f64;
        let mut fl = 0.0;
        for &k in &self.lcols[l_start..l_end] {
            fl += w * (1.0 + 2.0 * self.row_u_len(k as usize) as f64);
        }
        fl += (2.0 / 3.0) * w * w * w + w * w * nu as f64;
        self.flops += fl;

        self.lu_entries += if is_super {
            width * (nl + width + nu)
        } else {
            nl + 1 + nu
        };
        if is_super {
            self.rows_in_supers += width;
        }
        for r in first..block_end {
            self.row_node[r] = node_id;
        }
        self.nodes.push(NodeSym {
            first: first as u32,
            width: width as u32,
            is_super,
            l_start,
            l_end,
            u_start,
            u_end,
            g_start,
            g_end,
            flops: fl,
        });
    }
}

/// Sorted-set union size helpers for the merge budget.
fn count_not_in(a: &[u32], b: &[u32]) -> usize {
    // |a \ b| for sorted slices
    let mut i = 0;
    let mut j = 0;
    let mut cnt = 0;
    while i < a.len() {
        if j >= b.len() || a[i] < b[j] {
            cnt += 1;
            i += 1;
        } else if a[i] == b[j] {
            i += 1;
            j += 1;
        } else {
            j += 1;
        }
    }
    cnt
}

fn union_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        if j >= b.len() || (i < a.len() && a[i] < b[j]) {
            out.push(a[i]);
            i += 1;
        } else if i >= a.len() || b[j] < a[i] {
            out.push(b[j]);
            j += 1;
        } else {
            out.push(a[i]);
            i += 1;
            j += 1;
        }
    }
    out
}

/// Run symbolic analysis on the (already permuted & scaled) pattern.
///
/// `bulk_threshold` controls the dual-mode schedule split (nodes per level
/// required to stay in bulk mode).
pub fn analyze_pattern(a: &Csr, policy: MergePolicy, bulk_threshold: usize) -> Symbolic {
    let mut b = Builder::new(a.n);
    run_rows(&mut b, a, policy, 0);
    finish(b, a.n, bulk_threshold)
}

/// Run the up-looking row loop for rows `start..n` on top of whatever
/// finalized state `b` already holds for rows `< start`. Row `i`'s reach
/// depends only on the finalized nodes covering rows `< i`, so resuming
/// from a truncated prefix of an earlier analysis reproduces the cold
/// result bit for bit — the invariant the delta patcher relies on.
/// Requires that `b`'s nodes partition exactly the rows `0..start` (the
/// in-progress supernode, if any, must have been finalized).
pub(crate) fn run_rows(b: &mut Builder, a: &Csr, policy: MergePolicy, start: usize) {
    let n = a.n;
    debug_assert_eq!(
        b.nodes.last().map_or(0, |nd| nd.first as usize + nd.width as usize),
        start,
        "builder prefix does not end at the resume row"
    );

    // DFS scratch
    let mut mark = vec![u32::MAX; n];
    let mut work: Vec<u32> = Vec::new();
    let mut reach: Vec<u32> = Vec::new();

    let mut cur: Option<Current> = None;

    for i in start..n {
        // ---- reach of row i ----
        let stamp = i as u32;
        reach.clear();
        work.clear();
        for &j in a.row_indices(i) {
            if mark[j] != stamp {
                mark[j] = stamp;
                work.push(j as u32);
                reach.push(j as u32);
            }
        }
        if mark[i] != stamp {
            // always include the diagonal (pivot slot)
            mark[i] = stamp;
            work.push(i as u32);
            reach.push(i as u32);
        }
        while let Some(jq) = work.pop() {
            let j = jq as usize;
            if j >= i {
                continue; // sink: not yet factored
            }
            // expand through U-structure of row j
            if let Some(c) = &cur {
                if j >= c.first {
                    // row inside the in-progress supernode: shared pattern
                    for &jj in &c.shared_u {
                        if (jj as usize) > j && mark[jj as usize] != stamp {
                            mark[jj as usize] = stamp;
                            work.push(jj);
                            reach.push(jj);
                        }
                    }
                    continue;
                }
            }
            let nd = &b.nodes[b.row_node[j] as usize];
            let block_end = nd.first as usize + nd.width as usize;
            for jj in (j + 1)..block_end {
                if mark[jj] != stamp {
                    mark[jj] = stamp as u32;
                    work.push(jj as u32);
                    reach.push(jj as u32);
                }
            }
            for &jj in &b.ucols[nd.u_start..nd.u_end] {
                if mark[jj as usize] != stamp {
                    mark[jj as usize] = stamp;
                    work.push(jj);
                    reach.push(jj);
                }
            }
        }
        // split + sort
        let mut li: Vec<u32> = Vec::new();
        let mut ui: Vec<u32> = Vec::new();
        for &j in &reach {
            if (j as usize) < i {
                li.push(j);
            } else {
                ui.push(j);
            }
        }
        li.sort_unstable();
        ui.sort_unstable();

        // ---- merge decision ----
        let mut merged = false;
        if let Some(c) = &mut cur {
            let li_out_end = li.partition_point(|&j| (j as usize) < c.first);
            let li_out = &li[..li_out_end];
            let proposed_width = c.width + 1;
            // padding cost of a merge, in cells, relative to this ROW's
            // pattern size (a per-merge budget; panel-relative budgets
            // cascade into unbounded amalgamation)
            let su_tail_start = c.shared_u.partition_point(|&j| (j as usize) < i);
            let su_tail = &c.shared_u[su_tail_start..];
            let new_u = count_not_in(&ui, su_tail); // pads all prev rows
            let miss_u = count_not_in(su_tail, &ui); // pads new row
            let new_l = count_not_in(li_out, &c.shared_l);
            let miss_l = count_not_in(&c.shared_l, li_out);
            let l_pad = new_l * c.width + miss_l;
            let u_pad = new_u * c.width + miss_u;
            let row_cells = li.len() + ui.len() + proposed_width;
            let decision = match policy {
                MergePolicy::None => false,
                // Paper definition: supernode = consecutive rows with
                // identical structure in U. The L side is union-padded into
                // the dense panel (bounded: padding implies only in-panel
                // fill — DESIGN.md §5), with a modest budget so wildly
                // different rows don't amalgamate.
                MergePolicy::Exact { max_width } => {
                    proposed_width <= max_width
                        && new_u == 0
                        && miss_u == 0
                        && l_pad <= 16 + row_cells / 4
                }
                MergePolicy::Relaxed {
                    max_width,
                    budget_frac,
                    budget_abs,
                } => {
                    proposed_width <= max_width
                        && l_pad + u_pad
                            <= budget_abs + (budget_frac * row_cells as f64) as usize
                }
                MergePolicy::Forced {
                    min_width,
                    max_width,
                } => proposed_width <= max_width && c.width < min_width.max(1),
            };
            if decision {
                c.shared_l = union_sorted(&c.shared_l, li_out);
                c.shared_u = union_sorted(&c.shared_u, &ui);
                c.width += 1;
                merged = true;
            }
        }
        if !merged {
            if let Some(c) = cur.take() {
                b.finalize(c);
            }
            cur = Some(Current {
                first: i,
                width: 1,
                shared_l: li,
                shared_u: ui,
            });
        }
    }
    if let Some(c) = cur.take() {
        b.finalize(c);
    }
}

/// Assemble the finished [`Symbolic`] (schedule included) from a builder
/// whose row loop has run to completion.
pub(crate) fn finish(b: Builder, n: usize, bulk_threshold: usize) -> Symbolic {
    let schedule = dag::build_schedule(&b.nodes, &b.groups, &b.ucols, &b.row_node, bulk_threshold);
    Symbolic {
        n,
        supernode_coverage: if n == 0 {
            0.0
        } else {
            b.rows_in_supers as f64 / n as f64
        },
        nodes: b.nodes,
        row_node: b.row_node,
        lcols: b.lcols,
        ucols: b.ucols,
        groups: b.groups,
        flops: b.flops,
        lu_entries: b.lu_entries,
        schedule,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen;
    use crate::testutil::for_each_seed;

    fn diag_dominant(a: &Csr) -> Csr {
        // ensure structural diagonal for Natural-order analysis
        let mut c = Coo::new(a.n);
        for i in 0..a.n {
            for (k, &j) in a.row_indices(i).iter().enumerate() {
                c.push(i, j, a.row_vals(i)[k]);
            }
            c.push(i, i, 10.0);
        }
        c.to_csr()
    }

    /// Oracle: dense symbolic LU (no pivoting) fill pattern.
    fn dense_fill(a: &Csr) -> Vec<Vec<bool>> {
        let n = a.n;
        let mut f = vec![vec![false; n]; n];
        for i in 0..n {
            for &j in a.row_indices(i) {
                f[i][j] = true;
            }
            f[i][i] = true;
        }
        for k in 0..n {
            for i in k + 1..n {
                if f[i][k] {
                    for j in k + 1..n {
                        if f[k][j] {
                            f[i][j] = true;
                        }
                    }
                }
            }
        }
        f
    }

    /// Collect the symbolic's full per-row pattern (L + diag + U).
    fn sym_pattern(s: &Symbolic) -> Vec<Vec<bool>> {
        let n = s.n;
        let mut f = vec![vec![false; n]; n];
        for (_id, nd) in s.nodes.iter().enumerate() {
            for r in nd.first as usize..(nd.first + nd.width) as usize {
                for &c in &s.lcols[nd.l_start..nd.l_end] {
                    f[r][c as usize] = true;
                }
                for c in nd.first as usize..(nd.first + nd.width) as usize {
                    f[r][c] = true; // dense block (padding allowed)
                }
                for &c in &s.ucols[nd.u_start..nd.u_end] {
                    f[r][c as usize] = true;
                }
            }
        }
        f
    }

    fn check_covers(a: &Csr, s: &Symbolic) {
        // Symbolic pattern must be a superset of the true (no-pivot) fill.
        let want = dense_fill(a);
        let got = sym_pattern(s);
        for i in 0..a.n {
            for j in 0..a.n {
                // L side: only below-diagonal and upper (j>=i) both checked
                if want[i][j] {
                    assert!(got[i][j], "missing fill at ({i},{j})");
                }
            }
        }
    }

    fn check_invariants(s: &Symbolic) {
        let n = s.n;
        // node partition covers rows exactly once, ascending
        let mut row = 0usize;
        for (id, nd) in s.nodes.iter().enumerate() {
            assert_eq!(nd.first as usize, row, "node {id} first");
            assert!(nd.width >= 1);
            row += nd.width as usize;
            for r in nd.first as usize..row {
                assert_eq!(s.row_node[r] as usize, id);
            }
            // patterns sorted, in range
            let lc = &s.lcols[nd.l_start..nd.l_end];
            for w in lc.windows(2) {
                assert!(w[0] < w[1]);
            }
            if let Some(&last) = lc.last() {
                assert!((last as usize) < nd.first as usize);
            }
            let uc = &s.ucols[nd.u_start..nd.u_end];
            for w in uc.windows(2) {
                assert!(w[0] < w[1]);
            }
            if let Some(&first_u) = uc.first() {
                assert!(first_u as usize >= nd.first as usize + nd.width as usize);
            }
            // groups tile the L pattern
            let mut off = 0u32;
            for g in &s.groups[nd.g_start..nd.g_end] {
                assert_eq!(g.offset, off);
                off += g.len;
                assert!((g.src as usize) < id);
            }
            assert_eq!(off as usize, nd.nl());
        }
        assert_eq!(row, n);
    }

    #[test]
    fn tridiagonal_has_no_fill_and_full_supernode_chain() {
        let a = gen::banded(50, 1, 1);
        let s = analyze_pattern(&a, MergePolicy::Exact { max_width: 64 }, 4);
        check_invariants(&s);
        check_covers(&a, &s);
        // tridiagonal with exact merging: every row's tail is {i+1}, row
        // i's L is {i-1}: L-outside differs between consecutive rows, so
        // supernodes stay width <= 2; pattern must still be exact
        assert!(s.lu_entries <= 4 * 50);
    }

    #[test]
    fn dense_block_becomes_single_supernode() {
        // 8x8 fully dense matrix: one supernode of width 8
        let n = 8;
        let mut c = Coo::new(n);
        for i in 0..n {
            for j in 0..n {
                c.push(i, j, 1.0 + (i == j) as i32 as f64 * 8.0);
            }
        }
        let a = c.to_csr();
        let s = analyze_pattern(&a, MergePolicy::Exact { max_width: 64 }, 4);
        check_invariants(&s);
        assert_eq!(s.nodes.len(), 1);
        assert!(s.nodes[0].is_super);
        assert_eq!(s.nodes[0].width, 8);
        assert_eq!(s.supernode_coverage, 1.0);
    }

    #[test]
    fn policy_none_yields_all_row_nodes() {
        let a = gen::grid2d(8, 8);
        let s = analyze_pattern(&a, MergePolicy::None, 4);
        check_invariants(&s);
        assert!(s.nodes.iter().all(|nd| !nd.is_super && nd.width == 1));
        check_covers(&a, &s);
    }

    #[test]
    fn exact_pattern_covers_true_fill_on_classes() {
        for a in [
            gen::grid2d(7, 9),
            gen::circuit(60, 3),
            gen::random_sparse(40, 3, 5),
        ] {
            let a = diag_dominant(&a);
            let s = analyze_pattern(&a, MergePolicy::Exact { max_width: 32 }, 4);
            check_invariants(&s);
            check_covers(&a, &s);
        }
    }

    #[test]
    fn relaxed_supersedes_exact_coverage() {
        let a = diag_dominant(&gen::grid2d(10, 10));
        let se = analyze_pattern(&a, MergePolicy::Exact { max_width: 64 }, 4);
        let sr = analyze_pattern(
            &a,
            MergePolicy::Relaxed {
                max_width: 64,
                budget_frac: 0.2,
                budget_abs: 16,
            },
            4,
        );
        check_invariants(&sr);
        check_covers(&a, &sr);
        // relaxation must not reduce supernode coverage
        assert!(sr.supernode_coverage >= se.supernode_coverage - 1e-12);
        assert!(sr.nodes.len() <= se.nodes.len());
    }

    #[test]
    fn forced_amalgamation_builds_wide_supernodes() {
        let a = diag_dominant(&gen::circuit(200, 7));
        let s = analyze_pattern(
            &a,
            MergePolicy::Forced {
                min_width: 8,
                max_width: 32,
            },
            4,
        );
        check_invariants(&s);
        check_covers(&a, &s);
        assert!(s.supernode_coverage > 0.9, "coverage {}", s.supernode_coverage);
        // forced padding inflates storage vs exact
        let se = analyze_pattern(&a, MergePolicy::Exact { max_width: 32 }, 4);
        assert!(s.lu_entries > se.lu_entries);
    }

    #[test]
    fn property_partition_and_coverage_hold() {
        for_each_seed(8, |rng| {
            let n = rng.range(10, 60);
            let mut c = Coo::new(n);
            for i in 0..n {
                c.push(i, i, 4.0);
                for _ in 0..rng.range(1, 4) {
                    let j = rng.below(n);
                    c.push(i, j, rng.nonzero());
                }
            }
            let a = c.to_csr();
            for policy in [
                MergePolicy::None,
                MergePolicy::Exact { max_width: 16 },
                MergePolicy::Relaxed {
                    max_width: 16,
                    budget_frac: 0.25,
                    budget_abs: 8,
                },
                MergePolicy::Forced {
                    min_width: 4,
                    max_width: 16,
                },
            ] {
                let s = analyze_pattern(&a, policy, 4);
                check_invariants(&s);
                check_covers(&a, &s);
            }
        });
    }
}

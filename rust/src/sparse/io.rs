//! MatrixMarket I/O.
//!
//! The paper benchmarks 37 matrices from the SuiteSparse Matrix Collection,
//! distributed in MatrixMarket format. This reader lets real SuiteSparse
//! downloads run through the solver unchanged; the synthetic suite in
//! [`crate::sparse::gen`] is the offline stand-in (DESIGN.md §2).
//!
//! Supported: `matrix coordinate real|integer|pattern general|symmetric|
//! skew-symmetric`. `pattern` entries get value 1.0.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::{Error, Result};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a square MatrixMarket coordinate file into CSR.
///
/// Parse errors name the offending (1-based) line of the file —
/// `"foo.mtx: line 12: bad entry row"` — and unsupported headers
/// (`complex`, `hermitian`, `array`, …) are rejected up front with the
/// list of supported alternatives.
pub fn read_matrix_market(path: &Path) -> Result<Csr> {
    let file = std::fs::File::open(path)
        .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
    let mut reader = BufReader::new(file);
    let mut lineno = 0usize;
    let at = |lineno: usize, msg: String| Error::Io(format!("{}: line {lineno}: {msg}", path.display()));
    let mut header = String::new();
    reader
        .read_line(&mut header)
        .map_err(|e| at(1, format!("read error: {e}")))?;
    lineno += 1;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 5 || !h[0].starts_with("%%MatrixMarket") || h[1] != "matrix" {
        return Err(at(
            lineno,
            "not a MatrixMarket header (expected \
             '%%MatrixMarket matrix coordinate <field> <symmetry>')"
                .into(),
        ));
    }
    if h[2] != "coordinate" {
        return Err(at(
            lineno,
            format!("unsupported format '{}' (only 'coordinate' is supported)", h[2]),
        ));
    }
    let field = match h[3] {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        "complex" => {
            return Err(at(
                lineno,
                "complex matrices are not supported (this solver is real-valued; \
                 supported fields: real, integer, pattern)"
                    .into(),
            ))
        }
        other => {
            return Err(at(
                lineno,
                format!("unsupported field '{other}' (supported: real, integer, pattern)"),
            ))
        }
    };
    let symmetry = match h[4] {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        "hermitian" => {
            return Err(at(
                lineno,
                "hermitian symmetry implies a complex matrix, which is not supported \
                 (supported: general, symmetric, skew-symmetric)"
                    .into(),
            ))
        }
        other => {
            return Err(at(
                lineno,
                format!(
                    "unsupported symmetry '{other}' \
                     (supported: general, symmetric, skew-symmetric)"
                ),
            ))
        }
    };

    let mut line = String::new();
    // skip comments
    loop {
        line.clear();
        let read = reader
            .read_line(&mut line)
            .map_err(|e| at(lineno + 1, format!("read error: {e}")))?;
        if read == 0 {
            return Err(at(lineno, "missing size line".into()));
        }
        lineno += 1;
        let t = line.trim();
        if !t.is_empty() && !t.starts_with('%') {
            break;
        }
    }
    let dims: Vec<usize> = line
        .split_whitespace()
        .map(|s| {
            s.parse()
                .map_err(|_| at(lineno, format!("bad size line (unparsable '{s}')")))
        })
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(at(lineno, "size line needs 'rows cols nnz'".into()));
    }
    let (nr, nc, nnz) = (dims[0], dims[1], dims[2]);
    if nr != nc {
        return Err(at(lineno, format!("matrix not square: {nr}x{nc}")));
    }
    let mut coo = Coo::with_capacity(
        nr,
        if symmetry == Symmetry::General {
            nnz
        } else {
            nnz * 2
        },
    );
    let mut seen = 0usize;
    while seen < nnz {
        line.clear();
        let read = reader
            .read_line(&mut line)
            .map_err(|e| at(lineno + 1, format!("read error: {e}")))?;
        if read == 0 {
            return Err(at(
                lineno,
                format!("file ends after {seen} of {nnz} entries"),
            ));
        }
        lineno += 1;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| at(lineno, "bad entry row".into()))?;
        let j: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| at(lineno, "bad entry col".into()))?;
        let v: f64 = match field {
            Field::Pattern => 1.0,
            _ => it
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| at(lineno, "bad entry value".into()))?,
        };
        if i == 0 || j == 0 || i > nr || j > nc {
            return Err(at(
                lineno,
                format!("entry ({i},{j}) out of bounds (1-based, n={nr})"),
            ));
        }
        let (i, j) = (i - 1, j - 1);
        coo.push(i, j, v);
        if i != j {
            match symmetry {
                Symmetry::Symmetric => coo.push(j, i, v),
                Symmetry::SkewSymmetric => coo.push(j, i, -v),
                Symmetry::General => {}
            }
        }
        seen += 1;
    }
    Ok(coo.to_csr())
}

/// Write CSR as `matrix coordinate real general`.
pub fn write_matrix_market(path: &Path, a: &Csr) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by hylu")?;
    writeln!(f, "{} {} {}", a.n, a.n, a.nnz())?;
    for i in 0..a.n {
        for (k, &j) in a.row_indices(i).iter().enumerate() {
            writeln!(f, "{} {} {:.17e}", i + 1, j + 1, a.row_vals(i)[k])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn roundtrip_general() {
        let a = gen::random_sparse(50, 4, 77);
        let dir = std::env::temp_dir().join("hylu_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.mtx");
        write_matrix_market(&p, &a).unwrap();
        let b = read_matrix_market(&p).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn parses_symmetric_and_pattern() {
        let dir = std::env::temp_dir().join("hylu_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n% c\n3 3 4\n1 1 2.0\n2 2 2.0\n3 3 2.0\n3 1 -1.0\n",
        )
        .unwrap();
        let a = read_matrix_market(&p).unwrap();
        assert_eq!(a.nnz(), 5);
        let d = a.to_dense();
        assert_eq!(d.get(0, 2), -1.0);
        assert_eq!(d.get(2, 0), -1.0);

        let q = dir.join("pat.mtx");
        std::fs::write(
            &q,
            "%%MatrixMarket matrix coordinate pattern general\n2 2 3\n1 1\n2 2\n1 2\n",
        )
        .unwrap();
        let b = read_matrix_market(&q).unwrap();
        assert_eq!(b.nnz(), 3);
        assert!(b.vals.iter().all(|&v| v == 1.0));
    }

    fn parse_err(name: &str, contents: &str) -> String {
        let dir = std::env::temp_dir().join("hylu_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, contents).unwrap();
        match read_matrix_market(&p) {
            Err(crate::Error::Io(m)) => m,
            other => panic!("expected Error::Io, got {other:?}"),
        }
    }

    #[test]
    fn rejects_complex_and_unsupported_headers_clearly() {
        let m = parse_err(
            "cplx.mtx",
            "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1.0 0.0\n",
        );
        assert!(m.contains("line 1") && m.contains("complex"), "{m}");
        let m = parse_err(
            "herm.mtx",
            "%%MatrixMarket matrix coordinate real hermitian\n1 1 1\n1 1 1.0\n",
        );
        assert!(m.contains("hermitian"), "{m}");
        let m = parse_err(
            "arr.mtx",
            "%%MatrixMarket matrix array real general\n2 2\n1.0\n2.0\n3.0\n4.0\n",
        );
        assert!(m.contains("'array'") && m.contains("coordinate"), "{m}");
        let m = parse_err("nothdr.mtx", "hello world\n");
        assert!(m.contains("line 1"), "{m}");
    }

    #[test]
    fn malformed_entries_report_the_offending_line() {
        // entry lines start at line 4 here (header, comment, size line)
        let m = parse_err(
            "badrow.mtx",
            "%%MatrixMarket matrix coordinate real general\n% comment\n2 2 2\n1 1 1.0\nx 2 2.0\n",
        );
        assert!(m.contains("line 5") && m.contains("bad entry row"), "{m}");
        let m = parse_err(
            "badval.mtx",
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 nope\n",
        );
        assert!(m.contains("line 3") && m.contains("bad entry value"), "{m}");
        let m = parse_err(
            "oob.mtx",
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n",
        );
        assert!(m.contains("line 3") && m.contains("out of bounds"), "{m}");
        let m = parse_err(
            "badsize.mtx",
            "%%MatrixMarket matrix coordinate real general\n2 two 1\n1 1 1.0\n",
        );
        assert!(m.contains("line 2") && m.contains("size line"), "{m}");
        let m = parse_err(
            "short.mtx",
            "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n2 2 1.0\n",
        );
        assert!(m.contains("2 of 3 entries"), "{m}");
    }

    #[test]
    fn rejects_rectangular() {
        let dir = std::env::temp_dir().join("hylu_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rect.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 3 1\n1 1 1.0\n",
        )
        .unwrap();
        assert!(read_matrix_market(&p).is_err());
    }
}

//! MatrixMarket I/O.
//!
//! The paper benchmarks 37 matrices from the SuiteSparse Matrix Collection,
//! distributed in MatrixMarket format. This reader lets real SuiteSparse
//! downloads run through the solver unchanged; the synthetic suite in
//! [`crate::sparse::gen`] is the offline stand-in (DESIGN.md §2).
//!
//! Supported: `matrix coordinate real|integer|pattern general|symmetric|
//! skew-symmetric`. `pattern` entries get value 1.0.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::{Error, Result};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a square MatrixMarket coordinate file into CSR.
pub fn read_matrix_market(path: &Path) -> Result<Csr> {
    let file = std::fs::File::open(path)?;
    let mut reader = BufReader::new(file);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 5 || !h[0].starts_with("%%MatrixMarket") || h[1] != "matrix" {
        return Err(Error::Io("not a MatrixMarket file".into()));
    }
    if h[2] != "coordinate" {
        return Err(Error::Io(format!("unsupported format {}", h[2])));
    }
    let field = match h[3] {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        other => return Err(Error::Io(format!("unsupported field {other}"))),
    };
    let symmetry = match h[4] {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        other => return Err(Error::Io(format!("unsupported symmetry {other}"))),
    };

    let mut line = String::new();
    // skip comments
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Err(Error::Io("missing size line".into()));
        }
        let t = line.trim();
        if !t.is_empty() && !t.starts_with('%') {
            break;
        }
    }
    let dims: Vec<usize> = line
        .split_whitespace()
        .map(|s| s.parse().map_err(|_| Error::Io("bad size line".into())))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(Error::Io("size line needs rows cols nnz".into()));
    }
    let (nr, nc, nnz) = (dims[0], dims[1], dims[2]);
    if nr != nc {
        return Err(Error::Io(format!("matrix not square: {nr}x{nc}")));
    }
    let mut coo = Coo::with_capacity(
        nr,
        if symmetry == Symmetry::General {
            nnz
        } else {
            nnz * 2
        },
    );
    let mut seen = 0usize;
    while seen < nnz {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Err(Error::Io(format!("expected {nnz} entries, got {seen}")));
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Io("bad entry row".into()))?;
        let j: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Io("bad entry col".into()))?;
        let v: f64 = match field {
            Field::Pattern => 1.0,
            _ => it
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| Error::Io("bad entry value".into()))?,
        };
        if i == 0 || j == 0 || i > nr || j > nc {
            return Err(Error::Io(format!("entry ({i},{j}) out of bounds")));
        }
        let (i, j) = (i - 1, j - 1);
        coo.push(i, j, v);
        if i != j {
            match symmetry {
                Symmetry::Symmetric => coo.push(j, i, v),
                Symmetry::SkewSymmetric => coo.push(j, i, -v),
                Symmetry::General => {}
            }
        }
        seen += 1;
    }
    Ok(coo.to_csr())
}

/// Write CSR as `matrix coordinate real general`.
pub fn write_matrix_market(path: &Path, a: &Csr) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by hylu")?;
    writeln!(f, "{} {} {}", a.n, a.n, a.nnz())?;
    for i in 0..a.n {
        for (k, &j) in a.row_indices(i).iter().enumerate() {
            writeln!(f, "{} {} {:.17e}", i + 1, j + 1, a.row_vals(i)[k])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn roundtrip_general() {
        let a = gen::random_sparse(50, 4, 77);
        let dir = std::env::temp_dir().join("hylu_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.mtx");
        write_matrix_market(&p, &a).unwrap();
        let b = read_matrix_market(&p).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn parses_symmetric_and_pattern() {
        let dir = std::env::temp_dir().join("hylu_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n% c\n3 3 4\n1 1 2.0\n2 2 2.0\n3 3 2.0\n3 1 -1.0\n",
        )
        .unwrap();
        let a = read_matrix_market(&p).unwrap();
        assert_eq!(a.nnz(), 5);
        let d = a.to_dense();
        assert_eq!(d.get(0, 2), -1.0);
        assert_eq!(d.get(2, 0), -1.0);

        let q = dir.join("pat.mtx");
        std::fs::write(
            &q,
            "%%MatrixMarket matrix coordinate pattern general\n2 2 3\n1 1\n2 2\n1 2\n",
        )
        .unwrap();
        let b = read_matrix_market(&q).unwrap();
        assert_eq!(b.nnz(), 3);
        assert!(b.vals.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn rejects_rectangular() {
        let dir = std::env::temp_dir().join("hylu_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rect.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 3 1\n1 1 1.0\n",
        )
        .unwrap();
        assert!(read_matrix_market(&p).is_err());
    }
}

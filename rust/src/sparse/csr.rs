//! Compressed-sparse-row matrix — HYLU's primary format (the paper's
//! factorization is row-major up-looking).

use crate::sparse::perm::Perm;
use crate::testutil::Dense;
use crate::{Error, Result};

/// Square CSR matrix with sorted column indices per row.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// Dimension.
    pub n: usize,
    /// Row pointer array, length `n + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, sorted ascending within each row.
    pub indices: Vec<usize>,
    /// Values aligned with `indices`.
    pub vals: Vec<f64>,
}

impl Csr {
    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        Csr {
            n,
            indptr: (0..=n).collect(),
            indices: (0..n).collect(),
            vals: vec![1.0; n],
        }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Column indices of row `i`.
    pub fn row_indices(&self, i: usize) -> &[usize] {
        &self.indices[self.indptr[i]..self.indptr[i + 1]]
    }

    /// Values of row `i`.
    pub fn row_vals(&self, i: usize) -> &[f64] {
        &self.vals[self.indptr[i]..self.indptr[i + 1]]
    }

    /// Validate structural invariants (sorted, in-bounds, monotone indptr).
    pub fn validate(&self) -> Result<()> {
        if self.indptr.len() != self.n + 1 {
            return Err(Error::Invalid("indptr length".into()));
        }
        if *self.indptr.last().unwrap() != self.indices.len()
            || self.indices.len() != self.vals.len()
        {
            return Err(Error::Invalid("nnz mismatch".into()));
        }
        for i in 0..self.n {
            if self.indptr[i] > self.indptr[i + 1] {
                return Err(Error::Invalid(format!("indptr not monotone at {i}")));
            }
            let row = self.row_indices(i);
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::Invalid(format!("row {i} not strictly sorted")));
                }
            }
            if let Some(&last) = row.last() {
                if last >= self.n {
                    return Err(Error::Invalid(format!("row {i} column out of bounds")));
                }
            }
        }
        Ok(())
    }

    /// `y = A x`.
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n);
        for i in 0..self.n {
            let mut s = 0.0;
            for (k, &j) in self.row_indices(i).iter().enumerate() {
                s += self.row_vals(i)[k] * x[j];
            }
            y[i] = s;
        }
    }

    /// `‖Ax − b‖₁ / ‖b‖₁` — the paper's Fig. 11 residual metric.
    pub fn relative_residual(&self, x: &[f64], b: &[f64]) -> f64 {
        let mut ax = vec![0.0; self.n];
        self.relative_residual_into(x, b, &mut ax)
    }

    /// [`Csr::relative_residual`] into a caller-provided `A·x` buffer of
    /// length `n` (left holding `A·x` on return) — the allocation-free
    /// form used by the solve engine's refinement loop.
    pub fn relative_residual_into(&self, x: &[f64], b: &[f64], r: &mut [f64]) -> f64 {
        self.matvec(x, r);
        let num: f64 = r.iter().zip(b).map(|(p, q)| (p - q).abs()).sum();
        let den: f64 = b.iter().map(|v| v.abs()).sum();
        num / den.max(1e-300)
    }

    /// Transpose (also CSR; equals CSC view of self).
    pub fn transpose(&self) -> Csr {
        let n = self.n;
        let mut indptr = vec![0usize; n + 1];
        for &j in &self.indices {
            indptr[j + 1] += 1;
        }
        for i in 0..n {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0usize; self.nnz()];
        let mut vals = vec![0.0; self.nnz()];
        let mut next = indptr.clone();
        for i in 0..n {
            for (k, &j) in self.row_indices(i).iter().enumerate() {
                let p = next[j];
                indices[p] = i;
                vals[p] = self.row_vals(i)[k];
                next[j] += 1;
            }
        }
        Csr {
            n,
            indptr,
            indices,
            vals,
        }
    }

    /// Pattern of `A + Aᵀ` (no diagonal added), as index-only CSR.
    /// Used by the fill-reducing orderings, which need a symmetric graph.
    pub fn symmetrized_pattern(&self) -> (Vec<usize>, Vec<usize>) {
        let n = self.n;
        let at = self.transpose();
        let mut indptr = vec![0usize; n + 1];
        let mut indices = Vec::with_capacity(self.nnz() * 2);
        let mut mark = vec![usize::MAX; n];
        for i in 0..n {
            for &j in self.row_indices(i).iter().chain(at.row_indices(i)) {
                if j != i && mark[j] != i {
                    mark[j] = i;
                    indices.push(j);
                }
            }
            indptr[i + 1] = indices.len();
            indices[indptr[i]..].sort_unstable();
        }
        (indptr, indices)
    }

    /// Apply row permutation, column permutation and row/column scalings:
    /// returns `B = Dr · P · A · Q · Dc` where `B[i][j] = dr[p[i]] *
    /// A[p[i]][q[j]] * dc[q[j]]`, with `p[i]` = source row placed at `i`.
    pub fn permute_scale(&self, p: &Perm, q: &Perm, dr: &[f64], dc: &[f64]) -> Csr {
        let n = self.n;
        let mut indptr = vec![0usize; n + 1];
        for i in 0..n {
            let src = p.map[i];
            indptr[i + 1] = indptr[i] + (self.indptr[src + 1] - self.indptr[src]);
        }
        let mut indices = vec![0usize; self.nnz()];
        let mut vals = vec![0.0; self.nnz()];
        let mut buf: Vec<(usize, f64)> = Vec::new();
        for i in 0..n {
            let src = p.map[i];
            buf.clear();
            for (k, &j) in self.row_indices(src).iter().enumerate() {
                let newj = q.inv[j];
                buf.push((newj, dr[src] * self.row_vals(src)[k] * dc[j]));
            }
            buf.sort_unstable_by_key(|&(c, _)| c);
            let base = indptr[i];
            for (k, &(c, v)) in buf.iter().enumerate() {
                indices[base + k] = c;
                vals[base + k] = v;
            }
        }
        Csr {
            n,
            indptr,
            indices,
            vals,
        }
    }

    /// Dense copy (test oracle only; panics if `n` is large).
    pub fn to_dense(&self) -> Dense {
        assert!(self.n <= 4096, "to_dense is a test oracle for small n");
        let mut d = Dense::zeros(self.n);
        for i in 0..self.n {
            for (k, &j) in self.row_indices(i).iter().enumerate() {
                d.set(i, j, d.get(i, j) + self.row_vals(i)[k]);
            }
        }
        d
    }

    /// Max absolute value, per column. Used by MC64 scaling.
    pub fn col_max_abs(&self) -> Vec<f64> {
        let mut m = vec![0.0f64; self.n];
        for i in 0..self.n {
            for (k, &j) in self.row_indices(i).iter().enumerate() {
                m[j] = m[j].max(self.row_vals(i)[k].abs());
            }
        }
        m
    }

    /// Largest absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.vals.iter().fold(0.0, |a, &v| a.max(v.abs()))
    }

    /// 1-norm (max column sum of absolute values).
    pub fn norm1(&self) -> f64 {
        let mut s = vec![0.0f64; self.n];
        for i in 0..self.n {
            for (k, &j) in self.row_indices(i).iter().enumerate() {
                s[j] += self.row_vals(i)[k].abs();
            }
        }
        s.into_iter().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::testutil::Prng;

    fn sample() -> Csr {
        let mut c = Coo::new(4);
        for (i, j, v) in [
            (0, 0, 4.0),
            (0, 2, 1.0),
            (1, 1, 3.0),
            (2, 0, -1.0),
            (2, 2, 5.0),
            (2, 3, 2.0),
            (3, 3, 1.0),
        ] {
            c.push(i, j, v);
        }
        c.to_csr()
    }

    #[test]
    fn validate_accepts_good_matrix() {
        sample().validate().unwrap();
    }

    #[test]
    fn validate_rejects_unsorted() {
        let mut m = sample();
        m.indices.swap(4, 5); // makes row 2 unsorted
        assert!(m.validate().is_err());
    }

    #[test]
    fn matvec_identity() {
        let m = Csr::identity(5);
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut y = [0.0; 5];
        m.matvec(&x, &mut y);
        assert_eq!(y.to_vec(), x.to_vec());
    }

    #[test]
    fn transpose_twice_is_identity() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_matches_dense() {
        let mut rng = Prng::new(5);
        let mut c = Coo::new(8);
        for _ in 0..30 {
            c.push(rng.below(8), rng.below(8), rng.normal());
        }
        let m = c.to_csr();
        let t = m.transpose();
        let dm = m.to_dense();
        let dt = t.to_dense();
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(dm.get(i, j), dt.get(j, i));
            }
        }
    }

    #[test]
    fn permute_scale_matches_dense() {
        let mut rng = Prng::new(9);
        let n = 7;
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 2.0 + rng.uniform());
            for _ in 0..3 {
                c.push(i, rng.below(n), rng.normal());
            }
        }
        let m = c.to_csr();
        let p = Perm::from_map(rng.permutation(n)).unwrap();
        let q = Perm::from_map(rng.permutation(n)).unwrap();
        let dr: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 2.0)).collect();
        let dc: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 2.0)).collect();
        let b = m.permute_scale(&p, &q, &dr, &dc);
        b.validate().unwrap();
        let dm = m.to_dense();
        let db = b.to_dense();
        for i in 0..n {
            for j in 0..n {
                let want = dr[p.map[i]] * dm.get(p.map[i], q.map[j]) * dc[q.map[j]];
                assert!((db.get(i, j) - want).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn symmetrized_pattern_is_symmetric_and_sorted() {
        let m = sample();
        let (ptr, idx) = m.symmetrized_pattern();
        let n = m.n;
        let has = |i: usize, j: usize| idx[ptr[i]..ptr[i + 1]].contains(&j);
        for i in 0..n {
            let row = &idx[ptr[i]..ptr[i + 1]];
            for w in row.windows(2) {
                assert!(w[0] < w[1]);
            }
            for &j in row {
                assert_ne!(j, i);
                assert!(has(j, i), "asymmetric at ({i},{j})");
            }
        }
    }

    #[test]
    fn residual_zero_for_exact_solution() {
        let m = Csr::identity(3);
        let b = [1.0, -2.0, 3.0];
        assert_eq!(m.relative_residual(&b, &b), 0.0);
    }
}

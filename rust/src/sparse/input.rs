//! The one validated matrix-ingestion point.
//!
//! Every way a matrix enters the solver — CSR, COO triplets, CSC
//! triplets, a MatrixMarket file — funnels through [`MatrixInput`], which
//! converts the input into a validated [`Csr`] (square, sorted, in-bounds
//! indices, duplicates summed). [`crate::api::Solver::analyze`] accepts
//! any `impl MatrixInput`, so callers never pre-massage formats and never
//! skip validation.
//!
//! ```
//! use hylu::prelude::*;
//!
//! // COO triplets (duplicates are summed, order does not matter)
//! let mut coo = Coo::new(2);
//! coo.push(1, 1, 3.0);
//! coo.push(0, 0, 1.0);
//! coo.push(1, 1, -1.0);
//! let a = coo.into_csr().unwrap();
//! assert_eq!(a.vals, vec![1.0, 2.0]);
//!
//! // CSC triplets (colptr / rowind / vals)
//! let b = CscInput::new(&[0, 1, 2], &[0, 1], &[1.0, 2.0]).into_csr().unwrap();
//! assert_eq!(b.nnz(), 2);
//! ```

use std::path::{Path, PathBuf};

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::sparse::io::read_matrix_market;
use crate::{Error, Result};

/// A type that can be ingested as a square sparse matrix.
///
/// Implementations must return a **validated** CSR matrix (see
/// [`Csr::validate`]): square, monotone `indptr`, strictly sorted
/// in-bounds column indices per row. The conversion consumes `self`;
/// borrowed inputs (`&Csr`, `&Coo`, paths) copy.
pub trait MatrixInput {
    /// Convert into a validated CSR matrix.
    fn into_csr(self) -> Result<Csr>;
}

impl MatrixInput for Csr {
    fn into_csr(self) -> Result<Csr> {
        self.validate()?;
        Ok(self)
    }
}

impl MatrixInput for &Csr {
    fn into_csr(self) -> Result<Csr> {
        self.validate()?;
        Ok(self.clone())
    }
}

/// Bounds-check COO entries before the counting sort in `Coo::to_csr`
/// (which trusts its input) can index out of range.
fn coo_to_csr_checked(c: &Coo) -> Result<Csr> {
    if c.rows.len() != c.cols.len() || c.rows.len() != c.vals.len() {
        return Err(Error::Invalid(
            "coo arrays (rows/cols/vals) differ in length".into(),
        ));
    }
    for (e, (&i, &j)) in c.rows.iter().zip(&c.cols).enumerate() {
        if i >= c.n || j >= c.n {
            return Err(Error::Invalid(format!(
                "coo entry {e} at ({i},{j}) out of bounds for n={}",
                c.n
            )));
        }
    }
    let a = c.to_csr();
    a.validate()?;
    Ok(a)
}

impl MatrixInput for Coo {
    fn into_csr(self) -> Result<Csr> {
        coo_to_csr_checked(&self)
    }
}

impl MatrixInput for &Coo {
    fn into_csr(self) -> Result<Csr> {
        coo_to_csr_checked(self)
    }
}

/// Borrowed CSC (compressed sparse column) triplets: `colptr` of length
/// `n + 1`, `rowind`/`vals` of length `colptr[n]`. Row indices within a
/// column may be unsorted; duplicate row indices within a column are
/// rejected (ambiguous without a summing convention — pre-sum via
/// [`Coo`]).
#[derive(Clone, Copy, Debug)]
pub struct CscInput<'a> {
    /// Column pointer array (`n + 1` entries, monotone).
    pub colptr: &'a [usize],
    /// Row indices, aligned with `vals`.
    pub rowind: &'a [usize],
    /// Values.
    pub vals: &'a [f64],
}

impl<'a> CscInput<'a> {
    /// Bundle CSC triplets; dimension is `colptr.len() - 1`.
    pub fn new(colptr: &'a [usize], rowind: &'a [usize], vals: &'a [f64]) -> CscInput<'a> {
        CscInput {
            colptr,
            rowind,
            vals,
        }
    }
}

impl MatrixInput for CscInput<'_> {
    fn into_csr(self) -> Result<Csr> {
        if self.colptr.is_empty() {
            return Err(Error::Invalid("csc colptr must have n+1 entries".into()));
        }
        let n = self.colptr.len() - 1;
        let nnz = *self.colptr.last().unwrap();
        if self.colptr[0] != 0 {
            return Err(Error::Invalid("csc colptr must start at 0".into()));
        }
        for (j, w) in self.colptr.windows(2).enumerate() {
            if w[0] > w[1] {
                return Err(Error::Invalid(format!("csc colptr not monotone at {j}")));
            }
        }
        if self.rowind.len() != nnz || self.vals.len() != nnz {
            return Err(Error::Invalid(format!(
                "csc rowind/vals length {} / {} does not match colptr nnz {nnz}",
                self.rowind.len(),
                self.vals.len()
            )));
        }
        if let Some(&bad) = self.rowind.iter().find(|&&i| i >= n) {
            return Err(Error::Invalid(format!(
                "csc row index {bad} out of bounds for n={n}"
            )));
        }
        // CSC of A is CSR of Aᵀ: transposing sorts each output row even
        // when row indices within a column are unsorted.
        let at = Csr {
            n,
            indptr: self.colptr.to_vec(),
            indices: self.rowind.to_vec(),
            vals: self.vals.to_vec(),
        };
        let a = at.transpose();
        a.validate()
            .map_err(|_| Error::Invalid("csc input has duplicate entries within a column".into()))?;
        Ok(a)
    }
}

/// Raw `(colptr, rowind, vals)` CSC triplets.
impl MatrixInput for (&[usize], &[usize], &[f64]) {
    fn into_csr(self) -> Result<Csr> {
        CscInput::new(self.0, self.1, self.2).into_csr()
    }
}

impl MatrixInput for &Path {
    fn into_csr(self) -> Result<Csr> {
        let a = read_matrix_market(self)?;
        a.validate()?;
        Ok(a)
    }
}

impl MatrixInput for PathBuf {
    fn into_csr(self) -> Result<Csr> {
        self.as_path().into_csr()
    }
}

impl MatrixInput for &str {
    fn into_csr(self) -> Result<Csr> {
        Path::new(self).into_csr()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn csr_is_validated_not_trusted() {
        let good = gen::grid2d(4, 4);
        assert_eq!((&good).into_csr().unwrap(), good);
        let bad = Csr {
            n: 2,
            indptr: vec![0, 1, 2],
            indices: vec![0, 5], // out of bounds
            vals: vec![1.0, 1.0],
        };
        assert!(bad.into_csr().is_err());
    }

    #[test]
    fn coo_out_of_bounds_is_an_error_not_a_panic() {
        let c = Coo {
            n: 2,
            rows: vec![0, 7],
            cols: vec![0, 0],
            vals: vec![1.0, 1.0],
        };
        let err = c.into_csr().unwrap_err();
        assert!(matches!(err, Error::Invalid(_)));
    }

    #[test]
    fn csc_roundtrips_against_transpose() {
        let a = gen::random_sparse(30, 3, 9);
        let at = a.transpose();
        // CSC arrays of `a` are exactly the CSR arrays of `at`
        let b = CscInput::new(&at.indptr, &at.indices, &at.vals)
            .into_csr()
            .unwrap();
        assert_eq!(a, b);
        // the raw-tuple impl routes the same way
        let c = (&at.indptr[..], &at.indices[..], &at.vals[..])
            .into_csr()
            .unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn csc_tolerates_unsorted_rows_within_a_column() {
        // column 0 holds rows {2, 0} out of order
        let colptr = [0usize, 2, 3, 4];
        let rowind = [2usize, 0, 1, 2];
        let vals = [3.0, 1.0, 2.0, 4.0];
        let a = CscInput::new(&colptr, &rowind, &vals).into_csr().unwrap();
        let d = a.to_dense();
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(2, 0), 3.0);
        assert_eq!(d.get(1, 1), 2.0);
        assert_eq!(d.get(2, 2), 4.0);
    }

    #[test]
    fn csc_rejects_malformed_triplets() {
        assert!(CscInput::new(&[], &[], &[]).into_csr().is_err());
        assert!(CscInput::new(&[0, 2, 1], &[0, 0], &[1.0, 1.0])
            .into_csr()
            .is_err()); // non-monotone colptr
        assert!(CscInput::new(&[0, 1], &[3], &[1.0]).into_csr().is_err()); // row oob
        assert!(CscInput::new(&[0, 2], &[0], &[1.0]).into_csr().is_err()); // length mismatch
        assert!(CscInput::new(&[0, 2], &[0, 0], &[1.0, 2.0])
            .into_csr()
            .is_err()); // duplicate row in one column
    }

    #[test]
    fn matrix_market_path_ingestion() {
        let a = gen::grid2d(5, 5);
        let dir = std::env::temp_dir().join("hylu_input_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("in.mtx");
        crate::sparse::io::write_matrix_market(&p, &a).unwrap();
        assert_eq!(p.as_path().into_csr().unwrap(), a);
        assert_eq!(p.to_str().unwrap().into_csr().unwrap(), a);
        assert_eq!(p.clone().into_csr().unwrap(), a);
        assert!("/no/such/file.mtx".into_csr().is_err());
    }
}

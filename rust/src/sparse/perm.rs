//! Permutation vectors with their inverses, in the "map" convention:
//! `map[new] = old` (the source index placed at position `new`), and
//! `inv[old] = new`.

use crate::{Error, Result};

/// A validated permutation of `0..n` with cached inverse.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Perm {
    /// `map[new] = old`.
    pub map: Vec<usize>,
    /// `inv[old] = new`.
    pub inv: Vec<usize>,
}

impl Perm {
    /// Identity permutation.
    pub fn identity(n: usize) -> Self {
        Perm {
            map: (0..n).collect(),
            inv: (0..n).collect(),
        }
    }

    /// Build from a `map[new] = old` vector, validating bijectivity.
    pub fn from_map(map: Vec<usize>) -> Result<Self> {
        let n = map.len();
        let mut inv = vec![usize::MAX; n];
        for (newi, &old) in map.iter().enumerate() {
            if old >= n || inv[old] != usize::MAX {
                return Err(Error::Invalid(format!("not a permutation at {newi}")));
            }
            inv[old] = newi;
        }
        Ok(Perm { map, inv })
    }

    /// Build from an `inv[old] = new` vector.
    pub fn from_inv(inv: Vec<usize>) -> Result<Self> {
        let p = Perm::from_map(inv)?; // validates bijectivity
        Ok(Perm {
            map: p.inv,
            inv: p.map,
        })
    }

    /// Dimension.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Compose: apply `self` first, then `other` — the combined permutation
    /// `r` with `r.map[k] = self.map[other.map[k]]`.
    pub fn then(&self, other: &Perm) -> Perm {
        let map: Vec<usize> = other.map.iter().map(|&k| self.map[k]).collect();
        Perm::from_map(map).expect("composition of permutations is a permutation")
    }

    /// Apply to a vector: `out[new] = x[map[new]]`.
    pub fn gather(&self, x: &[f64]) -> Vec<f64> {
        self.map.iter().map(|&old| x[old]).collect()
    }

    /// Inverse-apply: `out[map[new]] = x[new]`.
    pub fn scatter(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; x.len()];
        for (newi, &old) in self.map.iter().enumerate() {
            out[old] = x[newi];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Prng;

    #[test]
    fn identity_roundtrip() {
        let p = Perm::identity(5);
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(p.gather(&x), x.to_vec());
        assert_eq!(p.scatter(&x), x.to_vec());
    }

    #[test]
    fn from_map_rejects_duplicates() {
        assert!(Perm::from_map(vec![0, 0, 2]).is_err());
        assert!(Perm::from_map(vec![0, 3]).is_err());
    }

    #[test]
    fn gather_scatter_are_inverse() {
        let mut rng = Prng::new(2);
        for n in [1usize, 2, 9, 40] {
            let p = Perm::from_map(rng.permutation(n)).unwrap();
            let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
            assert_eq!(p.scatter(&p.gather(&x)), x);
            assert_eq!(p.gather(&p.scatter(&x)), x);
        }
    }

    #[test]
    fn inv_is_inverse_map() {
        let mut rng = Prng::new(8);
        let p = Perm::from_map(rng.permutation(12)).unwrap();
        for newi in 0..12 {
            assert_eq!(p.inv[p.map[newi]], newi);
        }
    }

    #[test]
    fn then_composes() {
        let mut rng = Prng::new(4);
        let a = Perm::from_map(rng.permutation(9)).unwrap();
        let b = Perm::from_map(rng.permutation(9)).unwrap();
        let c = a.then(&b);
        let x: Vec<f64> = (0..9).map(|i| (i * i) as f64).collect();
        assert_eq!(c.gather(&x), b.gather(&a.gather(&x)));
    }
}

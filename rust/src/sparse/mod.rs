//! Sparse-matrix substrate: storage formats, permutations/scalings,
//! MatrixMarket I/O, and the synthetic workload generators that stand in
//! for the paper's SuiteSparse benchmark set (see DESIGN.md §2).

pub mod coo;
pub mod csr;
pub mod gen;
pub mod input;
pub mod io;
pub mod perm;

pub use coo::Coo;
pub use csr::Csr;
pub use input::{CscInput, MatrixInput};
pub use perm::Perm;

//! Synthetic sparse-matrix generators.
//!
//! The paper evaluates on 37 SuiteSparse matrices spanning circuit
//! simulation, power networks, PDE meshes, and optimization (KKT) problems.
//! This environment has no network access, so these generators produce the
//! same *sparsity classes* at laptop scale (DESIGN.md §2); the hybrid-kernel
//! claim varies exactly over this class axis, which is what matters for
//! reproducing the paper's comparisons. [`crate::bench_suite`] instantiates
//! the 37-matrix suite from these.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::testutil::Prng;

/// 5-point Laplacian on an `nx` × `ny` grid (G3_circuit / thermal-class:
/// symmetric pattern, large supernodes after ND ordering).
pub fn grid2d(nx: usize, ny: usize) -> Csr {
    let n = nx * ny;
    let mut c = Coo::with_capacity(n, 5 * n);
    let id = |x: usize, y: usize| y * nx + x;
    for y in 0..ny {
        for x in 0..nx {
            let i = id(x, y);
            c.push(i, i, 4.0);
            if x > 0 {
                c.push(i, id(x - 1, y), -1.0);
            }
            if x + 1 < nx {
                c.push(i, id(x + 1, y), -1.0);
            }
            if y > 0 {
                c.push(i, id(x, y - 1), -1.0);
            }
            if y + 1 < ny {
                c.push(i, id(x, y + 1), -1.0);
            }
        }
    }
    c.to_csr()
}

/// 7-point Laplacian on an `nx` × `ny` × `nz` grid (3-D mesh class: the
/// heaviest fill, where level-3 BLAS kernels dominate).
pub fn grid3d(nx: usize, ny: usize, nz: usize) -> Csr {
    let n = nx * ny * nz;
    let mut c = Coo::with_capacity(n, 7 * n);
    let id = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = id(x, y, z);
                c.push(i, i, 6.0);
                if x > 0 {
                    c.push(i, id(x - 1, y, z), -1.0);
                }
                if x + 1 < nx {
                    c.push(i, id(x + 1, y, z), -1.0);
                }
                if y > 0 {
                    c.push(i, id(x, y - 1, z), -1.0);
                }
                if y + 1 < ny {
                    c.push(i, id(x, y + 1, z), -1.0);
                }
                if z > 0 {
                    c.push(i, id(x, y, z - 1), -1.0);
                }
                if z + 1 < nz {
                    c.push(i, id(x, y, z + 1), -1.0);
                }
            }
        }
    }
    c.to_csr()
}

/// Convection-diffusion on a 2-D grid: like [`grid2d`] but with an
/// unsymmetric advection term (upwind), so values (not pattern) are
/// unsymmetric — exercises static pivoting.
pub fn convdiff2d(nx: usize, ny: usize, peclet: f64, seed: u64) -> Csr {
    let n = nx * ny;
    let mut rng = Prng::new(seed);
    let mut c = Coo::with_capacity(n, 5 * n);
    let id = |x: usize, y: usize| y * nx + x;
    for y in 0..ny {
        for x in 0..nx {
            let i = id(x, y);
            let wx = peclet * rng.range_f64(0.0, 1.0);
            let wy = peclet * rng.range_f64(0.0, 1.0);
            c.push(i, i, 4.0 + wx + wy);
            if x > 0 {
                c.push(i, id(x - 1, y), -1.0 - wx);
            }
            if x + 1 < nx {
                c.push(i, id(x + 1, y), -1.0);
            }
            if y > 0 {
                c.push(i, id(x, y - 1), -1.0 - wy);
            }
            if y + 1 < ny {
                c.push(i, id(x, y + 1), -1.0);
            }
        }
    }
    c.to_csr()
}

/// Circuit-simulation class (ASIC_680k / circuit5M / rajat-like): very
/// sparse bounded-degree rows plus a few nearly-dense rows/columns (power
/// and ground rails). Unsymmetric pattern; strong diagonal after MNA
/// stamping. This is the class where supernodal/BLAS solvers drown in fill.
pub fn circuit(n: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let mut c = Coo::with_capacity(n, 6 * n);
    let rails = (n / 2000).clamp(1, 8); // a few global nets
    for i in 0..n {
        // conductance stamp to a handful of "neighbouring" nets: locality
        // like a placed netlist — most connections are short-range.
        let deg = 1 + rng.below(4);
        let mut diag = 1e-3;
        for _ in 0..deg {
            let span = 1 + rng.below(50);
            let j = if rng.next_u64() & 1 == 0 {
                i.saturating_sub(span)
            } else {
                (i + span).min(n - 1)
            };
            if j != i {
                let g = rng.range_f64(0.1, 2.0);
                c.push(i, j, -g);
                diag += g;
                // MNA stamps are structurally symmetric but value-unsymmetric
                // (devices): add the mirror entry with a different value,
                // sometimes missing (controlled sources).
                if rng.uniform() < 0.85 {
                    c.push(j, i, -g * rng.range_f64(0.5, 1.5));
                }
            }
        }
        // rail connections
        if rng.uniform() < 0.3 {
            let r = rng.below(rails);
            let g = rng.range_f64(0.5, 3.0);
            c.push(i, r, -g);
            c.push(r, i, -g);
            diag += g;
        }
        c.push(i, i, diag + rng.range_f64(0.5, 2.0));
    }
    // beef up rail diagonals (they collected many stamps)
    for r in 0..rails {
        c.push(r, r, 50.0);
    }
    c.to_csr()
}

/// Power-network class: tree-like transmission grid (degree ≈ 2–3) with a
/// few loop-closing branches. Symmetric pattern, unsymmetric values.
pub fn power_network(n: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let mut c = Coo::with_capacity(n, 4 * n);
    let mut diag = vec![0.01f64; n];
    // spanning tree: each node i>0 attaches to a previous node biased local
    for i in 1..n {
        let span = 1 + rng.below(20.min(i));
        let j = i - span.min(i);
        let g = rng.range_f64(0.2, 2.0);
        c.push(i, j, -g);
        c.push(j, i, -g * rng.range_f64(0.9, 1.1));
        diag[i] += g;
        diag[j] += g;
    }
    // loop closures (~15% extra branches)
    for _ in 0..n / 7 {
        let i = rng.below(n);
        let j = rng.below(n);
        if i != j {
            let g = rng.range_f64(0.1, 1.0);
            c.push(i, j, -g);
            c.push(j, i, -g);
            diag[i] += g;
            diag[j] += g;
        }
    }
    for i in 0..n {
        c.push(i, i, diag[i] + 0.05);
    }
    c.to_csr()
}

/// Banded matrix with bandwidth `bw` (structured dense band: long
/// supernode chains, the pipeline-mode stress case).
pub fn banded(n: usize, bw: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let mut c = Coo::with_capacity(n, (2 * bw + 1) * n);
    for i in 0..n {
        let lo = i.saturating_sub(bw);
        let hi = (i + bw + 1).min(n);
        for j in lo..hi {
            if j == i {
                c.push(i, j, (2 * bw) as f64 + 1.0 + rng.uniform());
            } else {
                c.push(i, j, rng.nonzero());
            }
        }
    }
    c.to_csr()
}

/// Uniform random pattern with `per_row` off-diagonals per row and a
/// dominant diagonal. The "no structure at all" control case.
pub fn random_sparse(n: usize, per_row: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let mut c = Coo::with_capacity(n, (per_row + 1) * n);
    for i in 0..n {
        let mut rowsum = 0.0;
        for _ in 0..per_row {
            let j = rng.below(n);
            if j != i {
                let v = rng.nonzero();
                c.push(i, j, v);
                rowsum += v.abs();
            }
        }
        c.push(i, i, rowsum + 1.0 + rng.uniform());
    }
    c.to_csr()
}

/// KKT / saddle-point class (nlpkkt80-like): `[[H, Aᵀ], [A, -δI]]` with SPD
/// stencil `H` (size `nh`) and random sparse constraints `A` (`m` rows).
/// Small-magnitude (2,2) block: static pivoting (MC64) is essential — the
/// class where PARDISO's default ordering explodes in the paper.
pub fn kkt(nh: usize, m: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let n = nh + m;
    let mut c = Coo::with_capacity(n, 8 * n);
    // H: 1-D 3-point stencil (SPD)
    for i in 0..nh {
        c.push(i, i, 4.0 + rng.uniform());
        if i > 0 {
            c.push(i, i - 1, -1.0);
            c.push(i - 1, i, -1.0);
        }
    }
    // A: each constraint row touches ~4 H-variables
    for r in 0..m {
        let row = nh + r;
        for _ in 0..4 {
            let j = rng.below(nh);
            let v = rng.nonzero();
            c.push(row, j, v);
            c.push(j, row, v);
        }
        // small regularization keeps it factorizable yet hard
        c.push(row, row, -1e-4 * (1.0 + rng.uniform()));
    }
    c.to_csr()
}

/// Ill-conditioned Hamrle3-like case: circulant-ish unsymmetric pattern with
/// geometrically-graded values (condition number ~1e14). Both solvers are
/// expected to "fail" accuracy here, as in the paper's Fig. 11.
pub fn ill_conditioned(n: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let mut c = Coo::with_capacity(n, 4 * n);
    for i in 0..n {
        // grade diag from 1 down to ~1e-14 across rows
        let scale = 10f64.powf(-14.0 * (i as f64) / (n as f64 - 1.0).max(1.0));
        c.push(i, i, scale * (1.0 + rng.uniform()));
        let j1 = (i + 1) % n;
        let j2 = (i + n / 3) % n;
        if j1 != i {
            c.push(i, j1, scale * rng.nonzero());
        }
        if j2 != i && j2 != j1 {
            c.push(i, j2, scale * 0.5 * rng.nonzero());
        }
    }
    c.to_csr()
}

/// A right-hand side with known solution `x* = (1, …)ᵀ` for accuracy tests:
/// returns `b = A · 1`.
pub fn rhs_for_ones(a: &Csr) -> Vec<f64> {
    let x = vec![1.0; a.n];
    let mut b = vec![0.0; a.n];
    a.matvec(&x, &mut b);
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid2d_shape_and_symmetry() {
        let a = grid2d(5, 7);
        assert_eq!(a.n, 35);
        a.validate().unwrap();
        assert_eq!(a, a.transpose());
        assert_eq!(a.nnz(), 35 + 2 * (4 * 7 + 5 * 6));
    }

    #[test]
    fn grid3d_has_seven_point_interior() {
        let a = grid3d(4, 4, 4);
        a.validate().unwrap();
        // interior node (1,1,1)..(2,2,2) has 7 entries
        let interior = (1 * 4 + 1) * 4 + 1;
        assert_eq!(a.row_indices(interior).len(), 7);
    }

    #[test]
    fn generators_are_deterministic() {
        for (a, b) in [
            (circuit(500, 3), circuit(500, 3)),
            (power_network(300, 4), power_network(300, 4)),
            (random_sparse(200, 5, 5), random_sparse(200, 5, 5)),
        ] {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn circuit_is_very_sparse() {
        let a = circuit(2000, 1);
        a.validate().unwrap();
        let avg = a.nnz() as f64 / a.n as f64;
        assert!(avg < 10.0, "avg row nnz {avg} should be tiny");
        // diagonal fully present
        for i in 0..a.n {
            assert!(a.row_indices(i).contains(&i), "row {i} lost diagonal");
        }
    }

    #[test]
    fn power_network_pattern_symmetric() {
        let a = power_network(400, 9);
        let at = a.transpose();
        for i in 0..a.n {
            assert_eq!(a.row_indices(i), at.row_indices(i), "row {i}");
        }
    }

    #[test]
    fn banded_bandwidth_respected() {
        let a = banded(50, 3, 2);
        for i in 0..a.n {
            for &j in a.row_indices(i) {
                assert!(i.abs_diff(j) <= 3);
            }
        }
    }

    #[test]
    fn kkt_has_negative_bottom_block() {
        let a = kkt(100, 30, 6);
        assert_eq!(a.n, 130);
        for r in 100..130 {
            let d = a
                .row_indices(r)
                .iter()
                .position(|&j| j == r)
                .map(|k| a.row_vals(r)[k])
                .unwrap();
            assert!(d < 0.0 && d.abs() < 1e-3);
        }
    }

    #[test]
    fn ill_conditioned_grades_diagonal() {
        let a = ill_conditioned(100, 7);
        let d0 = a.row_vals(0)[a.row_indices(0).iter().position(|&j| j == 0).unwrap()];
        let dn = a
            .row_vals(99)
            [a.row_indices(99).iter().position(|&j| j == 99).unwrap()];
        assert!(d0.abs() / dn.abs() > 1e10);
    }

    #[test]
    fn rhs_for_ones_matches_rowsums() {
        let a = grid2d(4, 4);
        let b = rhs_for_ones(&a);
        for i in 0..a.n {
            let s: f64 = a.row_vals(i).iter().sum();
            assert!((b[i] - s).abs() < 1e-14);
        }
    }
}

//! Coordinate-format builder. Duplicate entries are summed on conversion,
//! matching MatrixMarket semantics.

use crate::sparse::csr::Csr;

/// Coordinate-format sparse matrix builder (square, f64).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    /// Dimension (rows == cols).
    pub n: usize,
    /// Row indices of entries.
    pub rows: Vec<usize>,
    /// Column indices of entries.
    pub cols: Vec<usize>,
    /// Values of entries.
    pub vals: Vec<f64>,
}

impl Coo {
    /// Empty builder for an `n x n` matrix.
    pub fn new(n: usize) -> Self {
        Coo {
            n,
            rows: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// With preallocated capacity.
    pub fn with_capacity(n: usize, cap: usize) -> Self {
        Coo {
            n,
            rows: Vec::with_capacity(cap),
            cols: Vec::with_capacity(cap),
            vals: Vec::with_capacity(cap),
        }
    }

    /// Append one entry. Duplicates are allowed and summed by `to_csr`.
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n && j < self.n, "({i},{j}) out of {0}", self.n);
        self.rows.push(i);
        self.cols.push(j);
        self.vals.push(v);
    }

    /// Number of raw (pre-dedup) entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Convert to CSR, summing duplicates and dropping explicit zeros that
    /// result from cancellation is NOT done (solvers want the full pattern).
    pub fn to_csr(&self) -> Csr {
        let n = self.n;
        let nnz = self.vals.len();
        // counting sort by row
        let mut count = vec![0usize; n + 1];
        for &r in &self.rows {
            count[r + 1] += 1;
        }
        for i in 0..n {
            count[i + 1] += count[i];
        }
        let mut order = vec![0usize; nnz];
        {
            let mut next = count.clone();
            for (e, &r) in self.rows.iter().enumerate() {
                order[next[r]] = e;
                next[r] += 1;
            }
        }
        // per-row: sort by column, merge duplicates
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        indptr.push(0usize);
        let mut rowbuf: Vec<(usize, f64)> = Vec::new();
        for r in 0..n {
            rowbuf.clear();
            for &e in &order[count[r]..count[r + 1]] {
                rowbuf.push((self.cols[e], self.vals[e]));
            }
            rowbuf.sort_unstable_by_key(|&(c, _)| c);
            let mut k = 0;
            while k < rowbuf.len() {
                let c = rowbuf[k].0;
                let mut v = rowbuf[k].1;
                let mut m = k + 1;
                while m < rowbuf.len() && rowbuf[m].0 == c {
                    v += rowbuf[m].1;
                    m += 1;
                }
                indices.push(c);
                vals.push(v);
                k = m;
            }
            indptr.push(indices.len());
        }
        Csr {
            n,
            indptr,
            indices,
            vals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_csr_sorts_and_sums_duplicates() {
        let mut c = Coo::new(3);
        c.push(0, 2, 1.0);
        c.push(0, 0, 2.0);
        c.push(0, 2, 3.0); // duplicate with first
        c.push(2, 1, -1.0);
        let m = c.to_csr();
        assert_eq!(m.indptr, vec![0, 2, 2, 3]);
        assert_eq!(m.indices, vec![0, 2, 1]);
        assert_eq!(m.vals, vec![2.0, 4.0, -1.0]);
    }

    /// Regression for the MatrixMarket footgun: files list entries in
    /// arbitrary order and repeat coordinates. `to_csr` must produce the
    /// same matrix regardless of push order, summing duplicates.
    #[test]
    fn unsorted_input_with_duplicates_matches_sorted_input() {
        let entries = [
            (2usize, 1usize, -1.0),
            (0, 2, 1.0),
            (1, 0, 5.0),
            (0, 0, 2.0),
            (2, 1, 0.5), // duplicate of (2,1), far from its twin
            (0, 2, 3.0), // duplicate of (0,2)
            (2, 0, 7.0),
        ];
        let mut shuffled = Coo::new(3);
        for &(i, j, v) in &entries {
            shuffled.push(i, j, v);
        }
        let mut sorted = Coo::new(3);
        let mut by_coord = entries;
        by_coord.sort_by_key(|&(i, j, _)| (i, j));
        for &(i, j, v) in &by_coord {
            sorted.push(i, j, v);
        }
        let a = shuffled.to_csr();
        assert_eq!(a, sorted.to_csr());
        a.validate().unwrap();
        let d = a.to_dense();
        assert_eq!(d.get(0, 2), 4.0);
        assert_eq!(d.get(2, 1), -0.5);
    }

    /// Duplicates that cancel must keep their (structural) entry: solvers
    /// analyze the pattern, and MatrixMarket semantics sum values only.
    #[test]
    fn cancelling_duplicates_keep_the_pattern_entry() {
        let mut c = Coo::new(2);
        c.push(0, 0, 1.0);
        c.push(0, 1, 2.5);
        c.push(0, 1, -2.5);
        c.push(1, 1, 1.0);
        let a = c.to_csr();
        assert_eq!(a.nnz(), 3, "cancelled duplicate must stay structural");
        assert_eq!(a.indices, vec![0, 1, 1]);
        assert_eq!(a.vals, vec![1.0, 0.0, 1.0]);
    }

    #[test]
    fn empty_rows_are_represented() {
        let c = Coo::new(4);
        let m = c.to_csr();
        assert_eq!(m.indptr, vec![0, 0, 0, 0, 0]);
        assert_eq!(m.nnz(), 0);
    }
}

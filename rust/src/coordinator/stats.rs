//! Per-phase statistics — what the paper's Figs. 4–11 plot.

use crate::numeric::select::KernelMode;

/// Preprocessing-phase statistics ([`crate::coordinator::Solver::analyze`]).
#[derive(Clone, Copy, Debug)]
pub struct SymbolicStats {
    /// Dimension.
    pub n: usize,
    /// Input nonzeros.
    pub nnz: usize,
    /// Static pivoting (MC64) seconds.
    pub t_match: f64,
    /// Fill-reducing ordering seconds.
    pub t_order: f64,
    /// Symbolic factorization + supernode detection + selection seconds.
    pub t_symbolic: f64,
    /// Whole preprocessing seconds.
    pub t_total: f64,
    /// Stored L+U entries (including supernode panel padding).
    pub lu_entries: usize,
    /// `lu_entries / nnz(A)`.
    pub fill_ratio: f64,
    /// Estimated factorization flops.
    pub flops: f64,
    /// Fraction of rows in supernodes.
    pub supernode_coverage: f64,
    /// Mean node width across all nodes (panels and singleton trailing
    /// columns alike).
    pub avg_super_width: f64,
    /// Mean width over supernode panels only (the wide-panel selection
    /// signal).
    pub avg_panel_width: f64,
    /// Node count (rows + supernodes).
    pub nodes: usize,
    /// DAG levels.
    pub levels: usize,
    /// Levels run in bulk mode.
    pub bulk_levels: usize,
    /// Selected kernel.
    pub mode: KernelMode,
}

/// Numeric-factorization statistics.
#[derive(Clone, Copy, Debug)]
pub struct FactorStats {
    /// Wall seconds.
    pub t_factor: f64,
    /// Perturbed pivots.
    pub perturbed: usize,
    /// Achieved GFLOP/s against the symbolic flop estimate.
    pub gflops: f64,
    /// Kernel used.
    pub mode: KernelMode,
    /// Threads used.
    pub threads: usize,
    /// Whether this was the refactorization fast path.
    pub refactor: bool,
}

/// Solve-phase statistics.
#[derive(Clone, Copy, Debug)]
pub struct SolveStats {
    /// Wall seconds (substitution + refinement).
    pub t_solve: f64,
    /// Final relative residual `‖Ax−b‖₁ / ‖b‖₁` (worst across RHS for
    /// batched solves).
    pub residual: f64,
    /// Iterative-refinement rounds executed (total across RHS).
    pub refine_iters: usize,
    /// Threads used.
    pub threads: usize,
    /// Right-hand sides solved in this call (1 for the scalar path).
    pub nrhs: usize,
}

//! Per-phase statistics — what the paper's Figs. 4–11 plot.

use crate::coordinator::config::Precision;
use crate::numeric::select::KernelMode;

/// How an iterative-refinement loop ended. Reported through
/// [`SolveStats::outcome`] for every solve (pure-`f64` refinement
/// included); the mixed-precision path additionally uses
/// `Stalled`/`BudgetExhausted` (with the residual still above tolerance)
/// as the trigger for the `f64` refactorization fallback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefineOutcome {
    /// The residual met the acceptance target (or never needed
    /// refinement: no perturbed pivots and already below tolerance).
    Converged,
    /// A refinement step failed to improve the residual (or, in mixed
    /// precision, the improvement ratio stagnated across consecutive
    /// accepted steps).
    Stalled,
    /// The iteration budget ran out with the residual still above the
    /// target.
    BudgetExhausted,
}

impl RefineOutcome {
    /// Severity rank for aggregating batched solves: worst wins.
    pub(crate) fn rank(self) -> u8 {
        match self {
            RefineOutcome::Converged => 0,
            RefineOutcome::BudgetExhausted => 1,
            RefineOutcome::Stalled => 2,
        }
    }

    /// The worse of two outcomes (batched solves report the worst column).
    pub fn worst(self, other: RefineOutcome) -> RefineOutcome {
        if other.rank() > self.rank() {
            other
        } else {
            self
        }
    }
}

impl std::fmt::Display for RefineOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RefineOutcome::Converged => "converged",
            RefineOutcome::Stalled => "stalled",
            RefineOutcome::BudgetExhausted => "budget-exhausted",
        })
    }
}

/// How an analysis was produced — cold, or one of the incremental
/// re-analysis tiers (see `symbolic/incremental.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReanalyzeKind {
    /// Pattern hash unchanged: permutations, symbolic, exec plan, and the
    /// tuned kernel plan all reused; only the permuted values rebuilt.
    Warm,
    /// Same dimension, local pattern change: the symbolic DAG was
    /// delta-patched (prefix splice + suffix replay).
    Delta,
    /// Pattern change too wide (or dimension changed): full re-analysis.
    /// Same-dimension fallbacks still reuse the cached permutations and
    /// scalings, so the result matches a delta patch bit for bit.
    Full,
}

impl std::fmt::Display for ReanalyzeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ReanalyzeKind::Warm => "warm",
            ReanalyzeKind::Delta => "delta",
            ReanalyzeKind::Full => "full",
        })
    }
}

/// Preprocessing-phase statistics ([`crate::coordinator::Solver::analyze`]).
#[derive(Clone, Copy, Debug)]
pub struct SymbolicStats {
    /// Dimension.
    pub n: usize,
    /// Input nonzeros.
    pub nnz: usize,
    /// Static pivoting (MC64) seconds.
    pub t_match: f64,
    /// Fill-reducing ordering seconds.
    pub t_order: f64,
    /// Symbolic factorization + supernode detection + selection seconds.
    pub t_symbolic: f64,
    /// Whole preprocessing seconds.
    pub t_total: f64,
    /// Stored L+U entries (including supernode panel padding).
    pub lu_entries: usize,
    /// `lu_entries / nnz(A)`.
    pub fill_ratio: f64,
    /// Estimated factorization flops.
    pub flops: f64,
    /// Fraction of rows in supernodes.
    pub supernode_coverage: f64,
    /// Mean node width across all nodes (panels and singleton trailing
    /// columns alike).
    pub avg_super_width: f64,
    /// Mean width over supernode panels only (the wide-panel selection
    /// signal).
    pub avg_panel_width: f64,
    /// Node count (rows + supernodes).
    pub nodes: usize,
    /// DAG levels.
    pub levels: usize,
    /// Levels run in bulk mode.
    pub bulk_levels: usize,
    /// Selected kernel.
    pub mode: KernelMode,
    /// `Some(kind)` when this analysis came from a `reanalyze` call;
    /// `None` for a cold `analyze`.
    pub reanalysis: Option<ReanalyzeKind>,
    /// Rows replayed by the delta patcher (0 unless
    /// `reanalysis == Some(ReanalyzeKind::Delta)`).
    pub replayed_rows: usize,
}

/// Numeric-factorization statistics.
#[derive(Clone, Copy, Debug)]
pub struct FactorStats {
    /// Wall seconds.
    pub t_factor: f64,
    /// Perturbed pivots.
    pub perturbed: usize,
    /// Pivot-growth estimate `max|U_ij| / max|A_ij|` from this
    /// factorization (0.0 when unavailable; non-finite when the factors
    /// contain Inf/NaN). The service quarantines a system whose growth
    /// exceeds `ServiceConfig::pivot_growth_limit`.
    pub pivot_growth: f64,
    /// Achieved GFLOP/s against the symbolic flop estimate.
    pub gflops: f64,
    /// Kernel used.
    pub mode: KernelMode,
    /// Threads used.
    pub threads: usize,
    /// Whether this was the refactorization fast path.
    pub refactor: bool,
    /// Precision the factors were computed in (`Mixed` = `f32` core).
    pub precision: Precision,
}

/// Solve-phase statistics.
#[derive(Clone, Copy, Debug)]
pub struct SolveStats {
    /// Wall seconds (substitution + refinement).
    pub t_solve: f64,
    /// Final relative residual `‖Ax−b‖₁ / ‖b‖₁` (worst across RHS for
    /// batched solves).
    pub residual: f64,
    /// Iterative-refinement rounds executed (total across RHS).
    pub refine_iters: usize,
    /// Threads used.
    pub threads: usize,
    /// Right-hand sides solved in this call (1 for the scalar path).
    pub nrhs: usize,
    /// How the refinement loop ended (worst across RHS for batched
    /// solves; `Converged` when refinement never ran because the initial
    /// residual was already acceptable).
    pub outcome: RefineOutcome,
    /// Precision of the factors that produced the reported solution: a
    /// mixed solve that fell back reports `F64`.
    pub precision: Precision,
    /// Precision-fallback events triggered by THIS call (0 or 1 for the
    /// scalar path; up to `nrhs` stalled columns re-solved against the
    /// `f64` recovery factors count once — the refactorization happens at
    /// most once per call).
    pub fallbacks: u64,
}

//! Deterministic fault injection for the chaos harness.
//!
//! A [`FaultPlan`] is a seeded, step-indexed schedule of failures that the
//! coordinator consults at well-defined points: once per factorization /
//! refactorization entry (the *factor stream*) and once per solve entry
//! (the *solve stream*). Each stream keeps its own atomic step counter;
//! whether step `k` fires — and which [`Fault`] it draws — is a pure
//! function of `(seed, stream, k)`, so a plan replays identically given
//! the same per-stream call counts regardless of thread scheduling. The
//! harness asserts *invariants* (no lost tickets, every quarantine
//! recovers), not exact event orders, so cross-stream interleaving is
//! free to vary.
//!
//! Injection points sit **before** any worker-pool dispatch: a panic
//! raised inside a bulk-mode barrier job would strand the other workers,
//! so the plan only ever panics on the calling (dispatcher) thread where
//! `service::shard` supervision — or the FFI `catch_unwind` guards — can
//! contain it.
//!
//! Plans are injected via `SolverBuilder::fault`, `ServiceConfig::fault`,
//! or the `HYLU_FAULT` environment variable
//! (`SEED:PERIOD:KINDS[:LIMIT]`, e.g. `7:11:panic-factor,zero-pivot:32`);
//! the absent case is a single `Option` check — zero cost on the hot
//! path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::{Error, Result};

/// One injectable failure kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Panic on the dispatcher thread at factor/refactor entry.
    PanicInFactor,
    /// Panic on the dispatcher thread at solve entry.
    PanicInSolve,
    /// Make the factor/refactor return [`Error::ZeroPivot`].
    ForceZeroPivot,
    /// Sleep this many microseconds (models a stalled kernel; fires on
    /// both streams).
    SlowKernel(u64),
}

/// A seeded, step-indexed fault schedule. See the module docs.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Every `period`-th step of a stream fires (0 disables the plan).
    period: u64,
    /// Kinds eligible on the factor stream (panic-factor / zero-pivot /
    /// slow).
    factor_kinds: Vec<Fault>,
    /// Kinds eligible on the solve stream (panic-solve / slow).
    solve_kinds: Vec<Fault>,
    /// Total faults this plan may ever fire (`u64::MAX` = unlimited).
    limit: u64,
    factor_steps: AtomicU64,
    solve_steps: AtomicU64,
    injected: AtomicU64,
}

/// splitmix64 finalizer: the draw hash.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Plan firing one fault from `kinds` every `period`-th step of each
    /// stream, forever.
    pub fn new(seed: u64, period: u64, kinds: Vec<Fault>) -> FaultPlan {
        FaultPlan::with_limit(seed, period, kinds, u64::MAX)
    }

    /// Like [`FaultPlan::new`] with a cap on the total faults ever fired
    /// (used by tests that need exactly-one failure, e.g. the FFI
    /// poisoned-handle contract).
    pub fn with_limit(seed: u64, period: u64, kinds: Vec<Fault>, limit: u64) -> FaultPlan {
        let factor_kinds = kinds
            .iter()
            .copied()
            .filter(|k| !matches!(k, Fault::PanicInSolve))
            .collect();
        let solve_kinds = kinds
            .iter()
            .copied()
            .filter(|k| matches!(k, Fault::PanicInSolve | Fault::SlowKernel(_)))
            .collect();
        FaultPlan {
            seed,
            period,
            factor_kinds,
            solve_kinds,
            limit,
            factor_steps: AtomicU64::new(0),
            solve_steps: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }

    /// Parse the `HYLU_FAULT` syntax: `SEED:PERIOD:KINDS[:LIMIT]` where
    /// `KINDS` is a comma list of `panic-factor` | `panic-solve` |
    /// `zero-pivot` | `slow=MICROS`.
    pub fn parse(s: &str) -> Option<FaultPlan> {
        let mut it = s.split(':');
        let seed = it.next()?.trim().parse().ok()?;
        let period = it.next()?.trim().parse().ok()?;
        let mut kinds = Vec::new();
        for k in it.next()?.split(',') {
            kinds.push(match k.trim() {
                "panic-factor" => Fault::PanicInFactor,
                "panic-solve" => Fault::PanicInSolve,
                "zero-pivot" => Fault::ForceZeroPivot,
                other => Fault::SlowKernel(other.strip_prefix("slow=")?.parse().ok()?),
            });
        }
        let limit = match it.next() {
            Some(v) => v.trim().parse().ok()?,
            None => u64::MAX,
        };
        if it.next().is_some() || kinds.is_empty() {
            return None;
        }
        Some(FaultPlan::with_limit(seed, period, kinds, limit))
    }

    /// The plan requested by the `HYLU_FAULT` environment variable, if
    /// set and parseable (mirrors `Precision::effective`: a malformed
    /// value falls back to "no plan" rather than failing construction).
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        let v = std::env::var("HYLU_FAULT").ok()?;
        FaultPlan::parse(v.trim()).map(Arc::new)
    }

    /// Total faults fired so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Deterministic draw for step `step` of a stream: `None` off the
    /// period grid, otherwise a seed/stream/step-hashed pick.
    fn draw(&self, step: u64, kinds: &[Fault], stream: u64) -> Option<Fault> {
        if self.period == 0 || kinds.is_empty() || (step + 1) % self.period != 0 {
            return None;
        }
        let h = mix(self.seed ^ stream.wrapping_mul(0xA5A5_A5A5_A5A5_A5A5) ^ step);
        Some(kinds[(h % kinds.len() as u64) as usize])
    }

    /// Claim one unit of the fault budget; `false` once `limit` is spent.
    fn claim(&self) -> bool {
        let mut cur = self.injected.load(Ordering::Relaxed);
        loop {
            if cur >= self.limit {
                return false;
            }
            match self.injected.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Factor-stream injection point (factorize *and* refactorize entry,
    /// before any pool dispatch). May panic, sleep, or return the forced
    /// zero-pivot error.
    pub fn at_factor(&self) -> Result<()> {
        let step = self.factor_steps.fetch_add(1, Ordering::Relaxed);
        match self.draw(step, &self.factor_kinds, 0) {
            Some(f) if self.claim() => match f {
                Fault::PanicInFactor => panic!("injected fault: panic in factor (step {step})"),
                Fault::ForceZeroPivot => Err(Error::ZeroPivot { row: 0 }),
                Fault::SlowKernel(us) => {
                    std::thread::sleep(Duration::from_micros(us));
                    Ok(())
                }
                Fault::PanicInSolve => Ok(()), // filtered out of this stream
            },
            _ => Ok(()),
        }
    }

    /// Solve-stream injection point (solve entry, before scratch checkout
    /// or pool dispatch). May panic or sleep; never returns an error.
    pub fn at_solve(&self) {
        let step = self.solve_steps.fetch_add(1, Ordering::Relaxed);
        if let Some(f) = self.draw(step, &self.solve_kinds, 1) {
            if self.claim() {
                match f {
                    Fault::PanicInSolve => panic!("injected fault: panic in solve (step {step})"),
                    Fault::SlowKernel(us) => std::thread::sleep(Duration::from_micros(us)),
                    _ => {}
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_syntax() {
        let p = FaultPlan::parse("7:11:panic-factor,panic-solve,zero-pivot,slow=50:32").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.period, 11);
        assert_eq!(p.limit, 32);
        assert_eq!(
            p.factor_kinds,
            vec![Fault::PanicInFactor, Fault::ForceZeroPivot, Fault::SlowKernel(50)]
        );
        assert_eq!(p.solve_kinds, vec![Fault::PanicInSolve, Fault::SlowKernel(50)]);
        // limit defaults to unlimited
        assert_eq!(FaultPlan::parse("1:5:zero-pivot").unwrap().limit, u64::MAX);
        for bad in ["", "1:5", "1:5:", "1:5:nope", "x:5:zero-pivot", "1:5:slow=abc", "1:5:zero-pivot:2:9"] {
            assert!(FaultPlan::parse(bad).is_none(), "{bad:?} parsed");
        }
    }

    #[test]
    fn streams_fire_on_the_period_grid_deterministically() {
        let p = FaultPlan::new(42, 3, vec![Fault::ForceZeroPivot]);
        let mut errs = Vec::new();
        for step in 0..9 {
            errs.push((step, p.at_factor().is_err()));
        }
        assert_eq!(
            errs.iter().filter(|(_, e)| *e).map(|(s, _)| *s).collect::<Vec<_>>(),
            vec![2, 5, 8]
        );
        assert_eq!(p.injected(), 3);
        // a second identical plan replays the identical schedule
        let q = FaultPlan::new(42, 3, vec![Fault::ForceZeroPivot]);
        let replay: Vec<bool> = (0..9).map(|_| q.at_factor().is_err()).collect();
        assert_eq!(replay, errs.iter().map(|(_, e)| *e).collect::<Vec<_>>());
    }

    #[test]
    fn limit_caps_total_injections() {
        let p = FaultPlan::with_limit(1, 1, vec![Fault::ForceZeroPivot], 2);
        let fired: usize = (0..10).map(|_| p.at_factor().is_err() as usize).sum();
        assert_eq!(fired, 2);
        assert_eq!(p.injected(), 2);
    }

    #[test]
    fn solve_stream_only_sees_solve_kinds() {
        // a zero-pivot-only plan never disturbs the solve stream, and a
        // slow-only plan disturbs neither stream's control flow
        let p = FaultPlan::new(3, 1, vec![Fault::ForceZeroPivot]);
        for _ in 0..5 {
            p.at_solve(); // must not panic
        }
        assert_eq!(p.injected(), 0);
        let s = FaultPlan::new(3, 1, vec![Fault::SlowKernel(1)]);
        assert!(s.at_factor().is_ok());
        s.at_solve();
        assert_eq!(s.injected(), 2);
    }

    #[test]
    fn panics_carry_the_injected_marker() {
        let p = FaultPlan::new(9, 1, vec![Fault::PanicInFactor]);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = p.at_factor();
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("injected fault"), "{msg}");
    }
}

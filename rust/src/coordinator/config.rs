//! Solver configuration.

use std::sync::Arc;

use crate::coordinator::fault::FaultPlan;
use crate::numeric::kernels::Tuning;
use crate::numeric::select::KernelMode;
use crate::numeric::PivotConfig;
use crate::ordering::OrderingChoice;
use crate::symbolic::MergePolicy;

/// Numeric-factorization precision policy.
///
/// `F64` is the classic double-precision pipeline. `Mixed` factors in
/// `f32` (roughly half the memory traffic through the panel kernels) and
/// recovers double accuracy inside the already-batched iterative
/// refinement loop: the residual matvec and the correction solves run in
/// `f64` against the `f32` factors. When refinement stalls (the residual
/// ratio stops improving) or exhausts its widened budget above the
/// acceptance tolerance, the solve escalates to a full `f64`
/// refactorization of the same values and the handle continues in `f64`
/// for subsequent refactors until the pattern changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Factor and solve entirely in double precision (default).
    F64,
    /// `f32` numeric core + `f64` refinement recovery with stall-driven
    /// fallback to `f64`.
    Mixed,
}

impl Precision {
    /// Parse a policy name as used by `HYLU_PRECISION` and the CLI
    /// (`f64` | `mixed`, case-insensitive).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "double" => Some(Precision::F64),
            "mixed" | "f32" => Some(Precision::Mixed),
            _ => None,
        }
    }

    /// The policy to use given a configured value: the `HYLU_PRECISION`
    /// environment variable overrides when set (and parseable), mirroring
    /// `HYLU_KERNEL` / `HYLU_TUNING`.
    pub fn effective(configured: Precision) -> Precision {
        match std::env::var("HYLU_PRECISION") {
            Ok(v) => Precision::parse(&v).unwrap_or(configured),
            Err(_) => configured,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Precision::F64 => write!(f, "f64"),
            Precision::Mixed => write!(f, "mixed"),
        }
    }
}

/// Configuration for [`crate::coordinator::Solver`].
///
/// The defaults reproduce the paper's one-time-solve setup; set
/// [`SolverConfig::repeated`] for the repeated-solve optimization
/// (relaxed supernodes: slower preprocessing, faster refactorization).
#[derive(Clone, Debug)]
pub struct SolverConfig {
    /// Fill-reducing ordering (default: auto-select AMD vs ND from graph
    /// statistics).
    pub ordering: OrderingChoice,
    /// Numeric kernel override (default: select from symbolic statistics).
    pub kernel: Option<KernelMode>,
    /// Per-pattern kernel autotuning level (default: off). `Quick`/`Full`
    /// search tile/pack/TRSM variants on the pattern's supernode shape
    /// histogram at analyze time; the winning plan is cached in the
    /// analysis (and optionally on disk via `HYLU_TUNE_CACHE`), so warm
    /// refactor+solve paths pay no tuning cost. The `HYLU_TUNING` env var
    /// overrides this setting when set.
    pub tuning: Tuning,
    /// Supernode merge-policy override (default: derived from kernel +
    /// `repeated`). Used by the baselines.
    pub merge_policy: Option<MergePolicy>,
    /// Worker threads; 0 = all available cores. The persistent worker
    /// pool is sized from this at [`crate::coordinator::Solver::try_new`]
    /// time; later mutation has no effect.
    pub threads: usize,
    /// Iterations a parked pool worker spins before sleeping on its
    /// condvar — keeps back-to-back repeated solves off the futex wakeup
    /// path. 0 parks immediately. This is the *maximum* budget: each
    /// worker adapts it downward (halving per condvar park, floored at
    /// `spin/16` so hot traffic can still be detected and the full
    /// budget restored) when dispatch inter-arrival time outgrows the
    /// spin window — an idle engine parks near-immediately instead of
    /// burning cores.
    pub worker_spin: u32,
    /// Solve-scratch checkout slots: the number of `solve`/`solve_many`
    /// calls that can be in flight concurrently on this solver before
    /// callers queue (each slot is an independent O(n) arena set).
    /// 0 = auto (`max(4, threads)`); clamped to 1..=64.
    pub scratch_slots: usize,
    /// Pivoting / perturbation.
    pub pivot: PivotConfig,
    /// MC64 static pivoting + scaling (disable only for pre-scaled
    /// diagonally-dominant inputs).
    pub static_pivoting: bool,
    /// Optimize preprocessing for repeated solves with a fixed pattern.
    pub repeated: bool,
    /// Maximum supernode width (tile-class cap).
    pub max_supernode: usize,
    /// Relaxed-merge padding budget, fraction of panel cells (repeated
    /// mode).
    pub relax_frac: f64,
    /// Relaxed-merge flat padding allowance per merge (repeated mode).
    pub relax_abs: usize,
    /// Minimum nodes per level to stay in bulk mode.
    pub bulk_threshold: usize,
    /// Numeric precision policy (default: [`Precision::F64`]). The
    /// `HYLU_PRECISION` env var overrides when set (unless
    /// [`SolverConfig::pin_precision`]). `Mixed` can also be requested
    /// per call via `SolveOpts`.
    pub precision: Precision,
    /// Ignore the `HYLU_PRECISION` env override and use
    /// [`SolverConfig::precision`] as configured. The C ABI sets this:
    /// `include/hylu.h` pins every FFI handle to `f64`.
    pub pin_precision: bool,
    /// Iterative-refinement iteration cap.
    pub refine_max_iter: usize,
    /// Residual above which refinement starts even without perturbation.
    pub refine_tol: f64,
    /// Refinement stops once the residual is below this.
    pub refine_target: f64,
    /// Skip parallel substitution below this dimension.
    pub parallel_solve_min_n: usize,
    /// Deterministic fault-injection plan for chaos testing (default:
    /// none — a single `Option` check on the factor/solve entry paths).
    /// When `None` and [`SolverConfig::pin_fault`] is unset, the
    /// `HYLU_FAULT` env var (`SEED:PERIOD:KINDS[:LIMIT]`) can supply one
    /// at `Solver` construction. Shared via `Arc` so cloned configs (and
    /// every system of a service) draw from one step-indexed schedule.
    pub fault: Option<Arc<FaultPlan>>,
    /// Ignore the `HYLU_FAULT` env override and use [`SolverConfig::fault`]
    /// as configured (the chaos soak's oracle solvers set this: oracles
    /// must stay fault-free even when the environment injects faults).
    pub pin_fault: bool,
    /// Delta-patch budget for [`crate::api::LinearSystem::reanalyze`]:
    /// the symbolic DAG is patched incrementally (instead of re-analyzed
    /// cold) when at most this fraction of permuted rows changed
    /// structure. 0 disables patching (every pattern change re-analyzes
    /// in full); the patched result is bit-identical either way, so the
    /// knob trades nothing but time.
    pub reanalyze_delta_frac: f64,
    /// Cold-restart threshold for `reanalyze`: when more than this
    /// fraction of rows changed structure, the cached ordering seeds
    /// (MC64 matching, scalings, fill ordering) are presumed stale and
    /// the re-analysis routes to a full cold `analyze` — fresh matching
    /// and ordering — instead of re-running the symbolic phase under the
    /// old permutations (which could leave structural zeros on the
    /// permuted diagonal and badly degraded fill). Must be ≥
    /// [`SolverConfig::reanalyze_delta_frac`] so the delta tier and its
    /// seed-reusing full fallback stay bit-comparable below the budget.
    pub reanalyze_cold_frac: f64,
    /// Enable the pivot-stability escalation controller on the
    /// repeated-refactor path: replay while pivot growth is stable,
    /// secondary within-block reorder when the growth EMA trends up,
    /// full re-pivoting factorization past the hard threshold. The
    /// `HYLU_ADAPTIVE` env var (`0`/`1`) overrides when set. Off by
    /// default — `refactor` stays a pure replay.
    pub adaptive_refactor: bool,
    /// Fast-EMA pivot-growth level that promotes a replay refactor to a
    /// secondary within-supernode-block reordering pass.
    pub escalate_reorder_growth: f64,
    /// Pivot growth past which the controller escalates straight to a
    /// full re-pivoting `factorize()`.
    pub escalate_repivot_growth: f64,
    /// Route large sup-sup GEMMs through the XLA/PJRT AOT artifacts
    /// (Pallas kernels). Ablation path; the native microkernel is default.
    pub use_xla: bool,
    /// Minimum GEMM dimension to hand to XLA (smaller blocks stay native).
    pub xla_min_dim: usize,
    /// Artifact directory for `use_xla`.
    pub artifacts_dir: String,
}

impl SolverConfig {
    /// Whether the adaptive refactor path is on for this config: the
    /// `HYLU_ADAPTIVE` env var (`1`/`true`/`on` vs `0`/`false`/`off`)
    /// overrides [`SolverConfig::adaptive_refactor`] when set and
    /// parseable, mirroring `HYLU_PRECISION` / `HYLU_TUNING`.
    pub fn adaptive_effective(&self) -> bool {
        match std::env::var("HYLU_ADAPTIVE") {
            Ok(v) => match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "on" => true,
                "0" | "false" | "off" => false,
                _ => self.adaptive_refactor,
            },
            Err(_) => self.adaptive_refactor,
        }
    }
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            ordering: OrderingChoice::Auto,
            kernel: None,
            tuning: Tuning::Off,
            merge_policy: None,
            threads: 0,
            worker_spin: crate::exec::DEFAULT_SPIN,
            scratch_slots: 0,
            pivot: PivotConfig::default(),
            static_pivoting: true,
            repeated: false,
            max_supernode: 128,
            relax_frac: 0.2,
            relax_abs: 24,
            bulk_threshold: 8,
            precision: Precision::F64,
            pin_precision: false,
            refine_max_iter: 3,
            refine_tol: 1e-10,
            refine_target: 1e-14,
            parallel_solve_min_n: 2048,
            fault: None,
            pin_fault: false,
            reanalyze_delta_frac: 0.25,
            reanalyze_cold_frac: 0.5,
            adaptive_refactor: false,
            escalate_reorder_growth: 1e4,
            escalate_repivot_growth: 1e8,
            use_xla: false,
            xla_min_dim: 16,
            artifacts_dir: "artifacts".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_one_time_mode() {
        let c = SolverConfig::default();
        assert!(!c.repeated);
        assert!(c.static_pivoting);
        assert!(c.kernel.is_none());
        assert_eq!(c.tuning, Tuning::Off);
        assert!(!c.use_xla);
        assert!(c.max_supernode <= 256);
        assert_eq!(c.precision, Precision::F64);
        assert!(c.fault.is_none());
        assert!(!c.pin_fault);
        assert!(!c.adaptive_refactor);
        assert!(c.reanalyze_delta_frac > 0.0 && c.reanalyze_delta_frac <= 1.0);
        assert!(c.reanalyze_cold_frac >= c.reanalyze_delta_frac);
        assert!(c.reanalyze_cold_frac <= 1.0);
        assert!(c.escalate_reorder_growth <= c.escalate_repivot_growth);
    }

    #[test]
    fn precision_parses_and_displays() {
        assert_eq!(Precision::parse("f64"), Some(Precision::F64));
        assert_eq!(Precision::parse("Mixed"), Some(Precision::Mixed));
        assert_eq!(Precision::parse("f32"), Some(Precision::Mixed));
        assert_eq!(Precision::parse("half"), None);
        assert_eq!(Precision::F64.to_string(), "f64");
        assert_eq!(Precision::Mixed.to_string(), "mixed");
    }
}

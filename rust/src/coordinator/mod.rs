//! The coordinator: HYLU's public solver API (`analyze` → `factor` /
//! `refactor` → `solve`), configuration, phase statistics, and the
//! composition of static pivoting, ordering, supernode pivoting and
//! scalings into one consistent permutation story.

pub mod config;
pub mod stats;

pub use config::SolverConfig;
pub use stats::{FactorStats, SolveStats, SymbolicStats};

use std::time::Instant;

use crate::numeric::factor::{GemmBackend, NativeGemm};
use crate::numeric::parallel::factor_parallel;
use crate::numeric::select::{select_kernel, selection_stats, KernelMode};
use crate::numeric::LuFactors;
use crate::ordering::{self, mwm};
use crate::par::effective_threads;
use crate::solve::{backward, backward_parallel, forward, forward_parallel};
use crate::sparse::csr::Csr;
use crate::sparse::perm::Perm;
use crate::symbolic::{analyze_pattern, MergePolicy, Symbolic};
use crate::{Error, Result};

/// The product of [`Solver::analyze`]: permutations, scalings, the symbolic
/// factorization, the selected kernel, and the permuted pattern with value
/// remapping tables for fast (re)factorization.
pub struct Analysis {
    /// Symbolic factorization of the permuted pattern.
    pub sym: Symbolic,
    /// Row permutation of the original matrix (`map[new] = old`),
    /// static-pivoting matching composed with the fill ordering.
    pub row_perm: Perm,
    /// Column permutation (the fill ordering).
    pub col_perm: Perm,
    /// Row scaling of the original matrix.
    pub dr: Vec<f64>,
    /// Column scaling of the original matrix.
    pub dc: Vec<f64>,
    /// Selected numeric kernel.
    pub mode: KernelMode,
    /// Permuted + scaled pattern (values from the analyzed matrix).
    pub pa: Csr,
    /// `pa.vals[k] = a.vals[src_idx[k]] * scale[k]` — the refactor remap.
    src_idx: Vec<usize>,
    scale: Vec<f64>,
    /// FNV hash of the analyzed pattern (guards value remapping).
    pattern_hash: u64,
    /// Phase statistics.
    pub stats: SymbolicStats,
}

/// FNV-1a over the structural pattern.
fn pattern_hash(a: &Csr) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |v: usize| {
        h ^= v as u64;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(a.n);
    for &p in &a.indptr {
        mix(p);
    }
    for &j in &a.indices {
        mix(j);
    }
    h
}

impl Analysis {
    /// Rebuild `pa` values from a same-pattern matrix (repeated solve).
    fn remap_values(&self, a: &Csr) -> Result<Csr> {
        if a.n != self.pa.n || a.nnz() != self.pa.nnz() || pattern_hash(a) != self.pattern_hash
        {
            return Err(Error::Invalid(
                "matrix pattern differs from the analyzed one".into(),
            ));
        }
        let mut pa = self.pa.clone();
        for (k, v) in pa.vals.iter_mut().enumerate() {
            *v = a.vals[self.src_idx[k]] * self.scale[k];
        }
        Ok(pa)
    }
}

/// The product of [`Solver::factor`]: numeric factors plus statistics.
pub struct Factorization {
    /// The numeric LU factors.
    pub fac: LuFactors,
    /// Statistics of the last (re)factorization.
    pub stats: FactorStats,
}

/// The HYLU solver handle. Holds configuration and the GEMM backend
/// (native microkernel by default; XLA/PJRT AOT artifacts when
/// [`SolverConfig::use_xla`] is set).
pub struct Solver {
    /// Active configuration.
    pub cfg: SolverConfig,
    gemm: Box<dyn GemmBackend + Sync + Send>,
}

impl Solver {
    /// Create a solver. If `cfg.use_xla` is set, loads the AOT artifacts
    /// from `cfg.artifacts_dir` (panics on failure — the artifacts are a
    /// build product; use [`Solver::try_new`] to handle errors).
    pub fn new(cfg: SolverConfig) -> Self {
        Self::try_new(cfg).expect("solver construction failed")
    }

    /// Fallible constructor.
    pub fn try_new(cfg: SolverConfig) -> Result<Self> {
        let gemm: Box<dyn GemmBackend + Sync + Send> = if cfg.use_xla {
            Box::new(crate::runtime::XlaGemm::load(
                std::path::Path::new(&cfg.artifacts_dir),
                cfg.xla_min_dim,
            )?)
        } else {
            Box::new(NativeGemm)
        };
        Ok(Solver { cfg, gemm })
    }

    /// Preprocessing phase: static pivoting (MC64), fill-reducing ordering,
    /// symbolic factorization with supernode detection, kernel selection,
    /// and schedule construction.
    pub fn analyze(&self, a: &Csr) -> Result<Analysis> {
        if a.n == 0 {
            return Err(Error::Invalid("empty matrix".into()));
        }
        a.validate()?;
        let t0 = Instant::now();

        // --- static pivoting + scaling ---
        let (match_perm, dr, dc) = if self.cfg.static_pivoting {
            let m = mwm::max_weight_matching(a)?;
            (Perm::from_map(m.row_for_col)?, m.dr, m.dc)
        } else {
            (Perm::identity(a.n), vec![1.0; a.n], vec![1.0; a.n])
        };
        let t_match = t0.elapsed().as_secs_f64();

        // --- fill-reducing ordering on the matched pattern ---
        let t1 = Instant::now();
        let matched = a.permute_scale(&match_perm, &Perm::identity(a.n), &dr, &dc);
        let fill_order = ordering::order(self.cfg.ordering, &matched);
        let col_perm = Perm::from_map(fill_order)?;
        // row_perm = match ∘ fill (rows follow the matching, then both
        // sides get the symmetric fill permutation)
        let row_perm = match_perm.then(&col_perm);
        let t_order = t1.elapsed().as_secs_f64();

        // --- permuted matrix + value remap tables ---
        let t2 = Instant::now();
        let (pa, src_idx, scale) = build_permuted(a, &row_perm, &col_perm, &dr, &dc);

        // --- symbolic + kernel selection ---
        let policy = self.one_time_policy();
        let mut sym = analyze_pattern(&pa, policy, self.cfg.bulk_threshold);
        let mut mode = self.cfg.kernel.unwrap_or_else(|| select_kernel(&sym));
        if self.cfg.kernel.is_none() || self.cfg.merge_policy.is_none() {
            // re-analyze when the selected kernel wants different supernodes
            if mode == KernelMode::RowRow && policy != MergePolicy::None {
                sym = analyze_pattern(&pa, MergePolicy::None, self.cfg.bulk_threshold);
            } else if self.cfg.repeated
                && mode != KernelMode::RowRow
                && self.cfg.merge_policy.is_none()
            {
                // repeated-solve mode: pay for relaxed supernodes once,
                // refactor faster forever (paper §3.2)
                sym = analyze_pattern(
                    &pa,
                    MergePolicy::Relaxed {
                        max_width: self.cfg.max_supernode,
                        budget_frac: self.cfg.relax_frac,
                        budget_abs: self.cfg.relax_abs,
                    },
                    self.cfg.bulk_threshold,
                );
                mode = self.cfg.kernel.unwrap_or_else(|| select_kernel(&sym));
            }
        }
        let t_symbolic = t2.elapsed().as_secs_f64();

        let sel = selection_stats(&sym);
        let stats = SymbolicStats {
            n: a.n,
            nnz: a.nnz(),
            t_match,
            t_order,
            t_symbolic,
            t_total: t0.elapsed().as_secs_f64(),
            lu_entries: sym.lu_entries,
            fill_ratio: sym.lu_entries as f64 / a.nnz().max(1) as f64,
            flops: sym.flops,
            supernode_coverage: sel.coverage,
            avg_super_width: sel.avg_super_width,
            nodes: sym.nodes.len(),
            levels: sym.schedule.nlevels(),
            bulk_levels: sym.schedule.bulk_levels,
            mode,
        };
        Ok(Analysis {
            sym,
            row_perm,
            col_perm,
            dr,
            dc,
            mode,
            pa,
            src_idx,
            scale,
            pattern_hash: pattern_hash(a),
            stats,
        })
    }

    fn one_time_policy(&self) -> MergePolicy {
        if let Some(p) = self.cfg.merge_policy {
            return p;
        }
        if self.cfg.kernel == Some(KernelMode::RowRow) {
            return MergePolicy::None;
        }
        MergePolicy::Exact {
            max_width: self.cfg.max_supernode,
        }
    }

    /// Numeric factorization (with supernode diagonal pivoting).
    pub fn factor(&self, a: &Csr, an: &Analysis) -> Result<Factorization> {
        let t0 = Instant::now();
        let pa = an.remap_values(a)?;
        let mut fac = LuFactors::alloc(&an.sym);
        let threads = effective_threads(self.cfg.threads);
        let perturbed = factor_parallel(
            &pa,
            &an.sym,
            an.mode,
            &self.cfg.pivot,
            &mut fac,
            false,
            self.gemm.as_ref(),
            threads,
        );
        let t = t0.elapsed().as_secs_f64();
        Ok(Factorization {
            fac,
            stats: FactorStats {
                t_factor: t,
                perturbed,
                gflops: an.sym.flops / t.max(1e-12) / 1e9,
                mode: an.mode,
                threads,
                refactor: false,
            },
        })
    }

    /// Refactorization: same pattern, new values, stored pivot order, no
    /// pivot search — the repeated-solve fast path.
    pub fn refactor(&self, a: &Csr, an: &Analysis, f: &mut Factorization) -> Result<()> {
        let t0 = Instant::now();
        let pa = an.remap_values(a)?;
        let threads = effective_threads(self.cfg.threads);
        let perturbed = factor_parallel(
            &pa,
            &an.sym,
            an.mode,
            &self.cfg.pivot,
            &mut f.fac,
            true,
            self.gemm.as_ref(),
            threads,
        );
        let t = t0.elapsed().as_secs_f64();
        f.stats = FactorStats {
            t_factor: t,
            perturbed,
            gflops: an.sym.flops / t.max(1e-12) / 1e9,
            mode: an.mode,
            threads,
            refactor: true,
        };
        Ok(())
    }

    /// Solve `A x = b` with the factorization; iterative refinement runs
    /// automatically when pivots were perturbed (or the residual exceeds
    /// the configured tolerance).
    pub fn solve(&self, a: &Csr, an: &Analysis, f: &Factorization, b: &[f64]) -> Result<Vec<f64>> {
        Ok(self.solve_with_stats(a, an, f, b)?.0)
    }

    /// [`Solver::solve`] with phase statistics.
    pub fn solve_with_stats(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        b: &[f64],
    ) -> Result<(Vec<f64>, SolveStats)> {
        if b.len() != a.n {
            return Err(Error::Invalid("rhs length mismatch".into()));
        }
        let t0 = Instant::now();
        let threads = effective_threads(self.cfg.threads);
        let mut x = self.substitute(an, f, b, threads);
        let mut residual = a.relative_residual(&x, b);
        let mut iters = 0usize;

        // iterative refinement (paper: automatic after pivot perturbation)
        if f.fac.perturbed > 0 || residual > self.cfg.refine_tol {
            let mut r = vec![0.0; a.n];
            while iters < self.cfg.refine_max_iter && residual > self.cfg.refine_target {
                a.matvec(&x, &mut r);
                for (ri, bi) in r.iter_mut().zip(b) {
                    *ri = bi - *ri;
                }
                let d = self.substitute(an, f, &r, threads);
                let mut x2 = x.clone();
                for (xi, di) in x2.iter_mut().zip(&d) {
                    *xi += di;
                }
                let res2 = a.relative_residual(&x2, b);
                iters += 1;
                if res2 < residual {
                    x = x2;
                    residual = res2;
                } else {
                    break;
                }
            }
        }
        let t = t0.elapsed().as_secs_f64();
        Ok((
            x,
            SolveStats {
                t_solve: t,
                residual,
                refine_iters: iters,
                threads,
            },
        ))
    }

    /// One triangular solve round: scale/permute b, forward, backward,
    /// unpermute/unscale x.
    fn substitute(&self, an: &Analysis, f: &Factorization, b: &[f64], threads: usize) -> Vec<f64> {
        let n = b.len();
        // y[i] = dr[row] * b[row], row = row_perm(map ∘ pivot)
        let mut y = vec![0.0; n];
        for i in 0..n {
            let pre = f.fac.pivot_perm[i] as usize; // analyzed-row
            let orig = an.row_perm.map[pre];
            y[i] = an.dr[orig] * b[orig];
        }
        if threads > 1 && n > self.cfg.parallel_solve_min_n {
            forward_parallel(&an.sym, &f.fac, &mut y, threads);
            backward_parallel(&an.sym, &f.fac, &mut y, threads);
        } else {
            forward(&an.sym, &f.fac, &mut y);
            backward(&an.sym, &f.fac, &mut y);
        }
        // x[orig col] = dc[orig col] * y[new col]
        let mut x = vec![0.0; n];
        for j in 0..n {
            let orig = an.col_perm.map[j];
            x[orig] = an.dc[orig] * y[j];
        }
        x
    }
}

/// Build the permuted+scaled matrix and the value remap tables.
fn build_permuted(
    a: &Csr,
    row_perm: &Perm,
    col_perm: &Perm,
    dr: &[f64],
    dc: &[f64],
) -> (Csr, Vec<usize>, Vec<f64>) {
    let n = a.n;
    let mut indptr = vec![0usize; n + 1];
    for i in 0..n {
        let src = row_perm.map[i];
        indptr[i + 1] = indptr[i] + (a.indptr[src + 1] - a.indptr[src]);
    }
    let nnz = a.nnz();
    let mut indices = vec![0usize; nnz];
    let mut vals = vec![0.0; nnz];
    let mut src_idx = vec![0usize; nnz];
    let mut scale = vec![0.0; nnz];
    let mut buf: Vec<(usize, usize)> = Vec::new();
    for i in 0..n {
        let src = row_perm.map[i];
        buf.clear();
        for k in a.indptr[src]..a.indptr[src + 1] {
            buf.push((col_perm.inv[a.indices[k]], k));
        }
        buf.sort_unstable_by_key(|&(c, _)| c);
        let base = indptr[i];
        for (off, &(c, k)) in buf.iter().enumerate() {
            indices[base + off] = c;
            let s = dr[src] * dc[a.indices[k]];
            scale[base + off] = s;
            src_idx[base + off] = k;
            vals[base + off] = a.vals[k] * s;
        }
    }
    (
        Csr {
            n,
            indptr,
            indices,
            vals,
        },
        src_idx,
        scale,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::testutil::{max_abs_diff, Prng};

    fn solve_roundtrip(a: &Csr, cfg: SolverConfig, tol: f64) {
        let solver = Solver::new(cfg);
        let an = solver.analyze(a).unwrap();
        let f = solver.factor(a, &an).unwrap();
        let xt: Vec<f64> = (0..a.n).map(|i| ((i * 13) % 11) as f64 - 5.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let (x, st) = solver.solve_with_stats(a, &an, &f, &b).unwrap();
        assert!(
            max_abs_diff(&x, &xt) < tol,
            "err {} residual {}",
            max_abs_diff(&x, &xt),
            st.residual
        );
    }

    #[test]
    fn end_to_end_grid() {
        solve_roundtrip(&gen::grid2d(15, 15), SolverConfig::default(), 1e-8);
    }

    #[test]
    fn end_to_end_circuit() {
        solve_roundtrip(&gen::circuit(500, 3), SolverConfig::default(), 1e-7);
    }

    #[test]
    fn end_to_end_kkt_requires_static_pivoting() {
        // saddle-point: tiny (2,2) block — fails without MC64, passes with
        solve_roundtrip(&gen::kkt(300, 100, 5), SolverConfig::default(), 1e-6);
    }

    #[test]
    fn end_to_end_all_kernel_overrides() {
        let a = gen::power_network(300, 7);
        for mode in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
            let cfg = SolverConfig {
                kernel: Some(mode),
                ..SolverConfig::default()
            };
            solve_roundtrip(&a, cfg, 1e-7);
        }
    }

    #[test]
    fn repeated_mode_refactor_loop() {
        let mut rng = Prng::new(4);
        let a = gen::grid2d(12, 12);
        let cfg = SolverConfig {
            repeated: true,
            ..SolverConfig::default()
        };
        let solver = Solver::new(cfg);
        let an = solver.analyze(&a).unwrap();
        let mut f = solver.factor(&a, &an).unwrap();
        for _ in 0..3 {
            let mut b2 = a.clone();
            for v in &mut b2.vals {
                *v *= rng.range_f64(0.8, 1.2);
            }
            solver.refactor(&b2, &an, &mut f).unwrap();
            let xt: Vec<f64> = (0..a.n).map(|i| (i % 5) as f64).collect();
            let mut b = vec![0.0; a.n];
            b2.matvec(&xt, &mut b);
            let x = solver.solve(&b2, &an, &f, &b).unwrap();
            assert!(max_abs_diff(&x, &xt) < 1e-7);
        }
    }

    #[test]
    fn rejects_pattern_change_on_refactor() {
        let a = gen::grid2d(5, 5);
        let solver = Solver::new(SolverConfig::default());
        let an = solver.analyze(&a).unwrap();
        let b = gen::grid2d(5, 6); // different pattern
        assert!(solver.factor(&b, &an).is_err());
    }

    #[test]
    fn rejects_bad_rhs_and_empty() {
        let a = gen::grid2d(4, 4);
        let solver = Solver::new(SolverConfig::default());
        let an = solver.analyze(&a).unwrap();
        let f = solver.factor(&a, &an).unwrap();
        assert!(solver.solve(&a, &an, &f, &[1.0]).is_err());
        let empty = Csr {
            n: 0,
            indptr: vec![0],
            indices: vec![],
            vals: vec![],
        };
        assert!(solver.analyze(&empty).is_err());
    }

    #[test]
    fn multithreaded_config_agrees_with_sequential() {
        let a = gen::grid2d(14, 14);
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 3) as f64 + 0.5).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let s1 = Solver::new(SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        });
        let s4 = Solver::new(SolverConfig {
            threads: 4,
            ..SolverConfig::default()
        });
        let an1 = s1.analyze(&a).unwrap();
        let an4 = s4.analyze(&a).unwrap();
        let f1 = s1.factor(&a, &an1).unwrap();
        let f4 = s4.factor(&a, &an4).unwrap();
        let x1 = s1.solve(&a, &an1, &f1, &b).unwrap();
        let x4 = s4.solve(&a, &an4, &f4, &b).unwrap();
        assert_eq!(x1, x4, "threaded result must be bit-identical");
    }
}

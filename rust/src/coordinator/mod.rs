//! The coordinator: HYLU's solver core (`analyze` → `factor` /
//! `refactor` → `solve` / `solve_many`), configuration, phase statistics,
//! and the composition of static pivoting, ordering, supernode pivoting
//! and scalings into one consistent permutation story.
//!
//! A [`Solver`] owns a persistent [`Engine`] (worker pool + scratch
//! arenas, see [`crate::exec`]) created once in [`Solver::try_new`]:
//! after one warm-up `factor` + `solve`, every `refactor` + `solve` cycle
//! runs on already-parked workers with zero O(n) scratch allocations.
//!
//! **This module's triple-threading methods are deprecated as a public
//! API.** Callers used to thread `(a, &Analysis, &Factorization)` through
//! every call themselves — the exact mismatched-analysis footgun the
//! engine's uid-keyed caches defend against. The supported public surface
//! is the owning, typestate handle API in [`crate::api`]
//! ([`crate::api::SolverBuilder`] → [`crate::api::Solver::analyze`] →
//! [`crate::api::LinearSystem`]), which makes stale pairings
//! unrepresentable at compile time. The deprecated wrappers remain as
//! thin shims over the same internals and produce bit-identical results
//! (asserted in `rust/tests/api_handles.rs`).

pub mod config;
pub mod escalate;
pub mod fault;
pub mod stats;

pub use config::{Precision, SolverConfig};
pub use escalate::{EscalationController, RefactorTier};
pub use fault::{Fault, FaultPlan};
pub use stats::{FactorStats, ReanalyzeKind, RefineOutcome, SolveStats, SymbolicStats};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exec::{self, Engine, ExecPlan, FactorScratch, PoolCounters, SolveScratch};
use crate::numeric::factor::{GemmBackend, NativeGemm};
use crate::numeric::kernels::{self, tuner, Tuning};
use crate::numeric::parallel::factor_parallel_pooled;
use crate::numeric::select::{select_kernel, selection_stats, KernelMode};
use crate::numeric::{LuFactors, Scalar};
use crate::ordering::{self, mwm};
use crate::par::{effective_threads, DoneFlags};
use crate::solve::{
    backward, backward_block, backward_parallel_pooled, forward, forward_block,
    forward_parallel_pooled, solve_block_parallel_pooled,
};
use crate::sparse::csr::Csr;
use crate::sparse::perm::Perm;
use crate::symbolic::{analyze_pattern, incremental, MergePolicy, Symbolic};
use crate::{Error, Result};

/// The product of [`Solver::analyze`]: permutations, scalings, the symbolic
/// factorization, the selected kernel, the permuted pattern with value
/// remapping tables for fast (re)factorization, and the cached execution
/// plan for the solver's worker pool.
pub struct Analysis {
    /// Symbolic factorization of the permuted pattern.
    pub sym: Symbolic,
    /// Row permutation of the original matrix (`map[new] = old`),
    /// static-pivoting matching composed with the fill ordering.
    pub row_perm: Perm,
    /// Column permutation (the fill ordering).
    pub col_perm: Perm,
    /// Row scaling of the original matrix.
    pub dr: Vec<f64>,
    /// Column scaling of the original matrix.
    pub dc: Vec<f64>,
    /// Selected numeric kernel.
    pub mode: KernelMode,
    /// Permuted + scaled pattern (values from the analyzed matrix).
    pub pa: Csr,
    /// `pa.vals[k] = a.vals[src_idx[k]] * scale[k]` — the refactor remap.
    src_idx: Vec<usize>,
    scale: Vec<f64>,
    /// FNV hash of the analyzed pattern (guards value remapping).
    pattern_hash: u64,
    /// Process-unique analysis id — keys the engine's permuted-matrix
    /// cache. Two analyses of same-pattern matrices can still carry
    /// *different* permutations (MC64 weighs values), so the pattern hash
    /// alone must never be used as a cache identity.
    uid: u64,
    /// Cached schedule state (bulk chunks, scratch bounds) for the owning
    /// solver's pool width.
    pub plan: ExecPlan,
    /// The merge policy that produced `sym` (the kernel-selection loop
    /// may override the configured one). The delta patcher must replay
    /// under exactly this policy to stay bit-identical.
    pub(crate) policy: MergePolicy,
    /// Phase statistics.
    pub stats: SymbolicStats,
}

/// Monotonic source for [`Analysis::uid`].
static ANALYSIS_UID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// FNV-1a over the structural pattern.
fn pattern_hash(a: &Csr) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |v: usize| {
        h ^= v as u64;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(a.n);
    for &p in &a.indptr {
        mix(p);
    }
    for &j in &a.indices {
        mix(j);
    }
    h
}

impl Analysis {
    /// Rebuild the permuted values from a same-pattern matrix into the
    /// engine's cached permuted matrix (repeated solve). The cache keeps
    /// the last [`PA_CACHE_CAP`] analyses (keyed by [`Analysis::uid`]), so
    /// a solver alternating between a few systems still pays the O(nnz)
    /// clone only once per analysis; afterwards only the value array is
    /// rewritten in place. On success this analysis' entry is the cache
    /// front (`cache[0]`), maintaining true MRU order for eviction.
    fn remap_values_into(
        &self,
        a: &Csr,
        cache: &mut Vec<(u64, Csr)>,
        counters: &PoolCounters,
    ) -> Result<()> {
        if a.n != self.pa.n || a.nnz() != self.pa.nnz() || pattern_hash(a) != self.pattern_hash {
            return Err(Error::Invalid(
                "matrix pattern differs from the analyzed one".into(),
            ));
        }
        match cache.iter().position(|(uid, _)| *uid == self.uid) {
            Some(i) => {
                // true MRU: rotate the hit to the front so eviction below
                // always drops the least-recently-used entry
                cache[..=i].rotate_right(1);
            }
            None => {
                if cache.len() >= PA_CACHE_CAP {
                    cache.truncate(PA_CACHE_CAP - 1);
                }
                cache.insert(0, (self.uid, self.pa.clone()));
                counters.note_alloc();
            }
        };
        let pa = &mut cache[0].1;
        for (k, v) in pa.vals.iter_mut().enumerate() {
            *v = a.vals[self.src_idx[k]] * self.scale[k];
        }
        Ok(())
    }
}

/// Number of recently used analyses whose permuted matrices the engine
/// keeps warm (older entries are evicted and re-cloned on next use).
const PA_CACHE_CAP: usize = 4;

/// Resolved iterative-refinement parameters for one solve call.
///
/// The legacy API always reads these from [`SolverConfig`]; the handle
/// API ([`crate::api::LinearSystem`]) lets callers override them per
/// solve through [`crate::api::SolveOpts`].
#[derive(Clone, Copy, Debug)]
pub struct RefineParams {
    /// Iteration cap (0 disables refinement entirely).
    pub max_iter: usize,
    /// Residual above which refinement starts even without perturbation.
    pub tol: f64,
    /// Refinement stops once the residual is below this.
    pub target: f64,
    /// Per-call precision override: `Some(Precision::F64)` forces this
    /// solve onto the `f64` recovery factors even when the factorization
    /// is mixed (building them on first use, without latching the stall
    /// fallback); `None` follows the factorization's own precision.
    /// `Some(Precision::Mixed)` against a pure-`f64` factorization is a
    /// no-op — there are no `f32` factors to use.
    pub precision: Option<Precision>,
}

impl RefineParams {
    /// The configured defaults of `cfg` (what the legacy API always uses).
    pub fn from_config(cfg: &SolverConfig) -> RefineParams {
        RefineParams {
            max_iter: cfg.refine_max_iter,
            tol: cfg.refine_tol,
            target: cfg.refine_target,
            precision: None,
        }
    }
}

/// Extra refinement iterations granted to the mixed-precision path: the
/// `f32` factors converge roughly one decimal digit per round slower
/// than `f64` factors, so the widened budget lets well-conditioned
/// systems reach the same target before the stall detector fires.
const MIXED_EXTRA_ITERS: usize = 4;
/// An accepted mixed-refinement step that shrinks the residual by less
/// than this factor counts as a "slow" round for the stall detector.
const MIXED_STALL_RATIO: f64 = 0.5;
/// Consecutive slow rounds before the mixed path declares a stall and
/// escalates to the `f64` recovery factors.
const MIXED_STALL_ROUNDS: u32 = 2;

/// The product of [`Solver::factor`]: numeric factors plus statistics.
///
/// Under [`Precision::F64`] (the default), `fac` holds the
/// double-precision factors and the mixed-precision fields stay inert.
/// Under [`Precision::Mixed`] the numeric core runs in `f32` (`fac32`);
/// `fac` is a zero-storage placeholder carrying only the pivot order,
/// and `f64` *recovery* factors of the same values are built lazily the
/// first time a solve's refinement stalls above tolerance (or a caller
/// forces `Precision::F64` per call). A stall latches `fell_back`:
/// later solves go straight to the recovery factors, and the next
/// [`Solver::refactor`] promotes the handle to pure `f64` permanently
/// (until the pattern is re-analyzed and re-factored).
#[derive(Debug)]
pub struct Factorization {
    /// The numeric LU factors (`f64`). In mixed mode this is a
    /// zero-storage placeholder (pivot order only) until fallback
    /// promotion.
    pub fac: LuFactors,
    /// The `f32` factors of the mixed numeric core (`None` in `F64`
    /// mode and after fallback promotion).
    pub(crate) fac32: Option<LuFactors<f32>>,
    /// Lazily built `f64` factors of the same values — stall recovery
    /// and forced-`f64` solves against a mixed factorization. Solves
    /// against the recovery factors serialize on this mutex.
    pub(crate) recovery: Mutex<Option<LuFactors>>,
    /// Latched once a stall escalated: later solves skip the mixed
    /// attempt, and the next refactor promotes to pure `f64`.
    pub(crate) fell_back: AtomicBool,
    /// Stall-driven fallback events over the factorization's lifetime.
    pub(crate) fallback_events: AtomicU64,
    /// Statistics of the last (re)factorization.
    pub stats: FactorStats,
}

impl Factorization {
    /// Precision of the factors a solve would use right now: `Mixed`
    /// while the `f32` core is active, `F64` otherwise (including after
    /// the stall fallback latched).
    pub fn precision(&self) -> Precision {
        if self.fac32.is_some() && !self.fell_back.load(Ordering::Relaxed) {
            Precision::Mixed
        } else {
            Precision::F64
        }
    }

    /// Total stall-driven `f64` fallback events recorded against this
    /// factorization.
    pub fn fallback_events(&self) -> u64 {
        self.fallback_events.load(Ordering::Relaxed)
    }
}

/// The HYLU solver handle. Holds configuration, the GEMM backend (native
/// microkernel by default; XLA/PJRT AOT artifacts when
/// [`SolverConfig::use_xla`] is set), and the persistent execution engine.
///
/// The worker-pool width is fixed at construction from
/// [`SolverConfig::threads`]; mutating `cfg.threads` afterwards has no
/// effect.
///
/// Concurrency note: a `&Solver` can be shared across threads and
/// `solve*` called concurrently — each call checks a private
/// [`SolveScratch`] arena out of the engine's pool (up to
/// [`SolverConfig::scratch_slots`] in flight; further callers queue), so
/// substitution and refinement overlap instead of serializing on one
/// mutex. Only pool *dispatches* (the parallel-substitution inner steps)
/// serialize. `factor`/`refactor` remain exclusive per call via the
/// engine's factor-side arenas. For the highest throughput under many
/// concurrent single-RHS callers, put a [`crate::service::SolverService`]
/// in front: it coalesces requests into batched [`Solver::solve_many`]
/// dispatches.
pub struct Solver {
    /// Active configuration.
    pub cfg: SolverConfig,
    gemm: Box<dyn GemmBackend + Sync + Send>,
    engine: Engine,
}

impl Solver {
    /// Create a solver. If `cfg.use_xla` is set, loads the AOT artifacts
    /// from `cfg.artifacts_dir` (panics on failure — the artifacts are a
    /// build product; use [`Solver::try_new`] to handle errors).
    pub fn new(cfg: SolverConfig) -> Self {
        Self::try_new(cfg).expect("solver construction failed")
    }

    /// Fallible constructor. Creates the engine; worker threads spawn
    /// lazily on the first numeric dispatch, so analyze-only use never
    /// spawns any.
    pub fn try_new(mut cfg: SolverConfig) -> Result<Self> {
        // env-driven chaos: HYLU_FAULT supplies a fault plan unless the
        // config already carries one or pins faults off (oracle solvers)
        if cfg.fault.is_none() && !cfg.pin_fault {
            cfg.fault = FaultPlan::from_env();
        }
        let gemm: Box<dyn GemmBackend + Sync + Send> = if cfg.use_xla {
            Box::new(crate::runtime::XlaGemm::load(
                std::path::Path::new(&cfg.artifacts_dir),
                cfg.xla_min_dim,
            )?)
        } else {
            Box::new(NativeGemm)
        };
        let threads = effective_threads(cfg.threads);
        let slots = if cfg.scratch_slots == 0 {
            threads.max(4)
        } else {
            cfg.scratch_slots
        };
        let engine = Engine::new(threads, cfg.worker_spin, slots);
        Ok(Solver { cfg, gemm, engine })
    }

    /// The persistent execution engine (pool + scratch arenas). Exposed
    /// for observability: its counters back the zero-spawn / zero-alloc
    /// guarantees of the warm path.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Preprocessing phase: static pivoting (MC64), fill-reducing ordering,
    /// symbolic factorization with supernode detection, kernel selection,
    /// and schedule construction (including the pool execution plan).
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: `hylu::api::Solver::analyze` \
                (see DESIGN.md §6 for the migration table)"
    )]
    pub fn analyze(&self, a: &Csr) -> Result<Analysis> {
        self.analyze_core(a)
    }

    pub(crate) fn analyze_core(&self, a: &Csr) -> Result<Analysis> {
        if a.n == 0 {
            return Err(Error::Invalid("empty matrix".into()));
        }
        a.validate()?;
        let t0 = Instant::now();

        // --- static pivoting + scaling ---
        let (match_perm, dr, dc) = if self.cfg.static_pivoting {
            let m = mwm::max_weight_matching(a)?;
            (Perm::from_map(m.row_for_col)?, m.dr, m.dc)
        } else {
            (Perm::identity(a.n), vec![1.0; a.n], vec![1.0; a.n])
        };
        let t_match = t0.elapsed().as_secs_f64();

        // --- fill-reducing ordering on the matched pattern ---
        let t1 = Instant::now();
        let matched = a.permute_scale(&match_perm, &Perm::identity(a.n), &dr, &dc);
        let fill_order = ordering::order(self.cfg.ordering, &matched);
        let col_perm = Perm::from_map(fill_order)?;
        // row_perm = match ∘ fill (rows follow the matching, then both
        // sides get the symmetric fill permutation)
        let row_perm = match_perm.then(&col_perm);
        let t_order = t1.elapsed().as_secs_f64();

        // --- permuted matrix + value remap tables ---
        let t2 = Instant::now();
        let (pa, src_idx, scale) = build_permuted(a, &row_perm, &col_perm, &dr, &dc);

        // --- symbolic + kernel selection ---
        let mut policy = self.one_time_policy();
        let mut sym = analyze_pattern(&pa, policy, self.cfg.bulk_threshold);
        let mut mode = self.cfg.kernel.unwrap_or_else(|| select_kernel(&sym));
        if self.cfg.kernel.is_none() || self.cfg.merge_policy.is_none() {
            // re-analyze when the selected kernel wants different supernodes
            if mode == KernelMode::RowRow && policy != MergePolicy::None {
                policy = MergePolicy::None;
                sym = analyze_pattern(&pa, policy, self.cfg.bulk_threshold);
            } else if self.cfg.repeated
                && mode != KernelMode::RowRow
                && self.cfg.merge_policy.is_none()
            {
                // repeated-solve mode: pay for relaxed supernodes once,
                // refactor faster forever (paper §3.2)
                policy = MergePolicy::Relaxed {
                    max_width: self.cfg.max_supernode,
                    budget_frac: self.cfg.relax_frac,
                    budget_abs: self.cfg.relax_abs,
                };
                sym = analyze_pattern(&pa, policy, self.cfg.bulk_threshold);
                mode = self.cfg.kernel.unwrap_or_else(|| select_kernel(&sym));
            }
        }
        let t_symbolic = t2.elapsed().as_secs_f64();

        // --- execution plan for the solver's pool width ---
        let mut plan = ExecPlan::build(&sym, self.engine.pool().nthreads());

        // --- per-pattern kernel autotuning (analyze-time only) ---
        // The winning plan rides inside the ExecPlan, so warm
        // refactor+solve paths replay it with zero probing. Keyed by the
        // input pattern hash: the in-process memo (and the optional disk
        // cache) guarantees every analysis of the same pattern in one
        // process uses one plan — factor bits stay deterministic across
        // solvers and pool widths.
        let phash = pattern_hash(a);
        let tuning = tuner::effective(self.cfg.tuning);
        if tuning != Tuning::Off {
            plan.kernel = tuner::tune_cached(&sym, kernels::active_tier(), tuning, phash);
        }

        let sel = selection_stats(&sym);
        let stats = SymbolicStats {
            n: a.n,
            nnz: a.nnz(),
            t_match,
            t_order,
            t_symbolic,
            t_total: t0.elapsed().as_secs_f64(),
            lu_entries: sym.lu_entries,
            fill_ratio: sym.lu_entries as f64 / a.nnz().max(1) as f64,
            flops: sym.flops,
            supernode_coverage: sel.coverage,
            avg_super_width: sel.avg_super_width,
            avg_panel_width: sel.avg_panel_width,
            nodes: sym.nodes.len(),
            levels: sym.schedule.nlevels(),
            bulk_levels: sym.schedule.bulk_levels,
            mode,
            reanalysis: None,
            replayed_rows: 0,
        };
        Ok(Analysis {
            sym,
            row_perm,
            col_perm,
            dr,
            dc,
            mode,
            pa,
            src_idx,
            scale,
            pattern_hash: phash,
            uid: ANALYSIS_UID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            plan,
            policy,
            stats,
        })
    }

    /// Incremental re-analysis: rebuild an [`Analysis`] for `a` reusing as
    /// much of `prev` as its pattern allows.
    ///
    /// - **Unchanged pattern hash** — the permutations, scalings, symbolic
    ///   factorization, execution plan, and tuned kernel plan are all
    ///   reused; only the permuted values and remap tables are rebuilt.
    /// - **Same dimension, changed pattern** — the cached matching,
    ///   scalings, and fill ordering still apply (the "ordering seeds");
    ///   the symbolic DAG is delta-patched when at most
    ///   [`SolverConfig::reanalyze_delta_frac`] of the permuted rows
    ///   changed structure, otherwise re-analyzed in full under the same
    ///   merge policy. Either way the result is bit-identical to the
    ///   other path on the same inputs.
    /// - **Changed dimension, or more than
    ///   [`SolverConfig::reanalyze_cold_frac`] of rows changed** — full
    ///   cold analysis with fresh matching and ordering (only the engine
    ///   and its arenas are warm): far-moved patterns would leave the
    ///   cached seeds with structural zeros on the permuted diagonal
    ///   and degraded fill.
    ///
    /// The returned analysis always carries a fresh [`Analysis::uid`], so
    /// the engine's permuted-value MRU can never serve a stale pattern.
    pub(crate) fn reanalyze_core(&self, a: &Csr, prev: &Analysis) -> Result<Analysis> {
        if a.n == 0 {
            return Err(Error::Invalid("empty matrix".into()));
        }
        a.validate()?;
        if a.n != prev.pa.n {
            let mut an = self.analyze_core(a)?;
            an.stats.reanalysis = Some(ReanalyzeKind::Full);
            return Ok(an);
        }
        let t0 = Instant::now();
        let phash = pattern_hash(a);
        let (pa, src_idx, scale) =
            build_permuted(a, &prev.row_perm, &prev.col_perm, &prev.dr, &prev.dc);

        if phash == prev.pattern_hash {
            // warm tier: identical structure, everything symbolic reused
            let mut stats = prev.stats;
            stats.t_match = 0.0;
            stats.t_order = 0.0;
            stats.t_symbolic = 0.0;
            stats.t_total = t0.elapsed().as_secs_f64();
            stats.reanalysis = Some(ReanalyzeKind::Warm);
            stats.replayed_rows = 0;
            return Ok(Analysis {
                sym: prev.sym.clone(),
                row_perm: prev.row_perm.clone(),
                col_perm: prev.col_perm.clone(),
                dr: prev.dr.clone(),
                dc: prev.dc.clone(),
                mode: prev.mode,
                pa,
                src_idx,
                scale,
                pattern_hash: phash,
                uid: ANALYSIS_UID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
                plan: prev.plan.clone(),
                policy: prev.policy,
                stats,
            });
        }

        // structural change at fixed dimension: diff the permuted
        // patterns and patch or fall back (bit-identical either way)
        let t2 = Instant::now();
        let delta = incremental::diff_patterns(&prev.pa, &pa);
        if delta.changed_rows as f64 > self.cfg.reanalyze_cold_frac * a.n as f64 {
            // the pattern moved too far for the cached matching/ordering
            // to stay meaningful (stale seeds risk structural zeros on
            // the permuted diagonal and degraded fill) — restart cold,
            // keeping only the warm engine and its arenas
            let mut an = self.analyze_core(a)?;
            an.stats.reanalysis = Some(ReanalyzeKind::Full);
            return Ok(an);
        }
        let budget = self.cfg.reanalyze_delta_frac * a.n as f64;
        let (sym, kind, replayed) = match delta.first_changed {
            Some(r0) if (delta.changed_rows as f64) <= budget => {
                let out = incremental::patch_pattern(
                    &prev.sym,
                    &pa,
                    prev.policy,
                    self.cfg.bulk_threshold,
                    r0,
                );
                (out.sym, ReanalyzeKind::Delta, out.replayed_rows)
            }
            _ => (
                analyze_pattern(&pa, prev.policy, self.cfg.bulk_threshold),
                ReanalyzeKind::Full,
                0,
            ),
        };
        let t_symbolic = t2.elapsed().as_secs_f64();

        // kernel seed: keep the previously selected kernel (the pattern
        // moved locally; a re-selection would force a fresh policy loop)
        let mode = prev.mode;
        let mut plan = ExecPlan::build(&sym, self.engine.pool().nthreads());
        let tuning = tuner::effective(self.cfg.tuning);
        if tuning != Tuning::Off {
            // keyed by the NEW pattern hash: the memo misses and retunes
            plan.kernel = tuner::tune_cached(&sym, kernels::active_tier(), tuning, phash);
        }

        let sel = selection_stats(&sym);
        let stats = SymbolicStats {
            n: a.n,
            nnz: a.nnz(),
            t_match: 0.0,
            t_order: 0.0,
            t_symbolic,
            t_total: t0.elapsed().as_secs_f64(),
            lu_entries: sym.lu_entries,
            fill_ratio: sym.lu_entries as f64 / a.nnz().max(1) as f64,
            flops: sym.flops,
            supernode_coverage: sel.coverage,
            avg_super_width: sel.avg_super_width,
            avg_panel_width: sel.avg_panel_width,
            nodes: sym.nodes.len(),
            levels: sym.schedule.nlevels(),
            bulk_levels: sym.schedule.bulk_levels,
            mode,
            reanalysis: Some(kind),
            replayed_rows: replayed,
        };
        Ok(Analysis {
            sym,
            row_perm: prev.row_perm.clone(),
            col_perm: prev.col_perm.clone(),
            dr: prev.dr.clone(),
            dc: prev.dc.clone(),
            mode,
            pa,
            src_idx,
            scale,
            pattern_hash: phash,
            uid: ANALYSIS_UID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            plan,
            policy: prev.policy,
            stats,
        })
    }

    fn one_time_policy(&self) -> MergePolicy {
        if let Some(p) = self.cfg.merge_policy {
            return p;
        }
        if self.cfg.kernel == Some(KernelMode::RowRow) {
            return MergePolicy::None;
        }
        MergePolicy::Exact {
            max_width: self.cfg.max_supernode,
        }
    }

    /// Numeric factorization (with supernode diagonal pivoting) as a job
    /// on the persistent pool.
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: `LinearSystem::<Analyzed>::factor` \
                owns the matrix/analysis pairing (see DESIGN.md §6)"
    )]
    pub fn factor(&self, a: &Csr, an: &Analysis) -> Result<Factorization> {
        self.factor_core(a, an)
    }

    pub(crate) fn factor_core(&self, a: &Csr, an: &Analysis) -> Result<Factorization> {
        // fault injection fires here, before any pool dispatch: a panic
        // inside a bulk-mode barrier job would strand the other workers
        if let Some(fp) = self.cfg.fault.as_deref() {
            fp.at_factor()?;
        }
        let precision = if self.cfg.pin_precision {
            self.cfg.precision
        } else {
            Precision::effective(self.cfg.precision)
        };
        let t0 = Instant::now();
        let mut scratch = self.engine.factor_scratch();
        an.remap_values_into(a, &mut scratch.pa, self.engine.counters())?;
        self.ensure_done_flags(&mut scratch, an);
        let pa = &scratch.pa[0].1;
        let threads = self.engine.pool().nthreads();
        let (fac, fac32, perturbed) = match precision {
            Precision::F64 => {
                let mut fac: LuFactors = LuFactors::alloc(&an.sym);
                let perturbed = factor_parallel_pooled(
                    pa,
                    &an.sym,
                    an.mode,
                    &self.cfg.pivot,
                    &mut fac,
                    false,
                    self.gemm.as_ref(),
                    self.engine.pool(),
                    &an.plan,
                    &scratch.done,
                );
                (fac, None, perturbed)
            }
            Precision::Mixed => {
                let mut fac32: LuFactors<f32> = LuFactors::alloc(&an.sym);
                let perturbed = factor_parallel_pooled(
                    pa,
                    &an.sym,
                    an.mode,
                    &self.cfg.pivot,
                    &mut fac32,
                    false,
                    self.gemm.as_ref(),
                    self.engine.pool(),
                    &an.plan,
                    &scratch.done,
                );
                // zero-storage stand-in carrying the pivot order, so
                // `Factorization::fac` keeps its type for existing
                // callers; solves route through `fac32`
                let mut fac: LuFactors = LuFactors::placeholder(an.sym.n);
                fac.pivot_perm.copy_from_slice(&fac32.pivot_perm);
                fac.perturbed = fac32.perturbed;
                fac.growth = fac32.growth;
                (fac, Some(fac32), perturbed)
            }
        };
        let t = t0.elapsed().as_secs_f64();
        let fac_growth = fac.growth;
        Ok(Factorization {
            fac,
            fac32,
            recovery: Mutex::new(None),
            fell_back: AtomicBool::new(false),
            fallback_events: AtomicU64::new(0),
            stats: FactorStats {
                t_factor: t,
                perturbed,
                pivot_growth: fac_growth,
                gflops: an.sym.flops / t.max(1e-12) / 1e9,
                mode: an.mode,
                threads,
                refactor: false,
                precision,
            },
        })
    }

    /// Refactorization: same pattern, new values, stored pivot order, no
    /// pivot search — the repeated-solve fast path. On a warm engine this
    /// spawns no threads and performs no O(n) scratch allocation.
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: `LinearSystem::<Factored>::refactor` \
                (see DESIGN.md §6)"
    )]
    pub fn refactor(&self, a: &Csr, an: &Analysis, f: &mut Factorization) -> Result<()> {
        self.refactor_core(a, an, f)
    }

    pub(crate) fn refactor_core(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &mut Factorization,
    ) -> Result<()> {
        self.refactor_core_tiered(a, an, f, false)
    }

    /// [`Solver::refactor_core`] with an optional secondary within-block
    /// reordering pass (the escalation controller's middle tier): before
    /// the replay, `pivot_perm` is refreshed per supernode diagonal block
    /// from the incoming values. Pattern-preserving, so the replay stays
    /// valid. Skipped for mixed-precision handles (the `f32` core keeps
    /// its own pivot order) — the call degenerates to a plain replay.
    pub(crate) fn refactor_core_tiered(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &mut Factorization,
        reorder: bool,
    ) -> Result<()> {
        // same pre-dispatch injection point as `factor_core`
        if let Some(fp) = self.cfg.fault.as_deref() {
            fp.at_factor()?;
        }
        let t0 = Instant::now();
        let mut scratch = self.engine.factor_scratch();
        an.remap_values_into(a, &mut scratch.pa, self.engine.counters())?;
        self.ensure_done_flags(&mut scratch, an);
        let pa = &scratch.pa[0].1;
        if reorder && f.fac32.is_none() {
            crate::numeric::factor::secondary_block_reorder(pa, &an.sym, &mut f.fac.pivot_perm);
        }
        let threads = self.engine.pool().nthreads();
        let (perturbed, precision) = if f.fac32.is_some() && f.fell_back.load(Ordering::Relaxed) {
            // A mixed handle whose refinement stalled: promote to pure
            // f64. Reuse the recovery factors' storage and pivot order
            // when present (the common case — the stall built them);
            // otherwise factor fresh with a pivot search.
            let rec = exec::lock_ignore_poison(&f.recovery).take();
            let perturbed = if let Some(mut rfac) = rec {
                let p = factor_parallel_pooled(
                    pa,
                    &an.sym,
                    an.mode,
                    &self.cfg.pivot,
                    &mut rfac,
                    true,
                    self.gemm.as_ref(),
                    self.engine.pool(),
                    &an.plan,
                    &scratch.done,
                );
                f.fac = rfac;
                p
            } else {
                let mut rfac: LuFactors = LuFactors::alloc(&an.sym);
                let p = factor_parallel_pooled(
                    pa,
                    &an.sym,
                    an.mode,
                    &self.cfg.pivot,
                    &mut rfac,
                    false,
                    self.gemm.as_ref(),
                    self.engine.pool(),
                    &an.plan,
                    &scratch.done,
                );
                f.fac = rfac;
                p
            };
            f.fac32 = None;
            (perturbed, Precision::F64)
        } else if let Some(fac32) = f.fac32.as_mut() {
            // still mixed: f32 refactor replay along the stored pivots
            let p = factor_parallel_pooled(
                pa,
                &an.sym,
                an.mode,
                &self.cfg.pivot,
                fac32,
                true,
                self.gemm.as_ref(),
                self.engine.pool(),
                &an.plan,
                &scratch.done,
            );
            // any recovery factors hold the previous values now — drop
            // them so the next stall rebuilds from the current matrix
            *exec::lock_ignore_poison(&f.recovery) = None;
            f.fac.perturbed = fac32.perturbed;
            f.fac.growth = fac32.growth;
            (p, Precision::Mixed)
        } else {
            let p = factor_parallel_pooled(
                pa,
                &an.sym,
                an.mode,
                &self.cfg.pivot,
                &mut f.fac,
                true,
                self.gemm.as_ref(),
                self.engine.pool(),
                &an.plan,
                &scratch.done,
            );
            (p, Precision::F64)
        };
        let t = t0.elapsed().as_secs_f64();
        f.stats = FactorStats {
            t_factor: t,
            perturbed,
            pivot_growth: f.fac.growth,
            gflops: an.sym.flops / t.max(1e-12) / 1e9,
            mode: an.mode,
            threads,
            refactor: true,
            precision,
        };
        Ok(())
    }

    /// Solve `A x = b` with the factorization; iterative refinement runs
    /// automatically when pivots were perturbed (or the residual exceeds
    /// the configured tolerance).
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: `LinearSystem::<Factored>::solve` \
                (see DESIGN.md §6)"
    )]
    pub fn solve(&self, a: &Csr, an: &Analysis, f: &Factorization, b: &[f64]) -> Result<Vec<f64>> {
        let mut x = Vec::new();
        self.solve_into_core(a, an, f, b, &mut x, &RefineParams::from_config(&self.cfg))?;
        Ok(x)
    }

    /// [`Solver::solve`] with phase statistics.
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: `LinearSystem::<Factored>::solve_with_stats` \
                (see DESIGN.md §6)"
    )]
    pub fn solve_with_stats(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        b: &[f64],
    ) -> Result<(Vec<f64>, SolveStats)> {
        let mut x = Vec::new();
        let st = self.solve_into_core(a, an, f, b, &mut x, &RefineParams::from_config(&self.cfg))?;
        Ok((x, st))
    }

    /// Solve into a caller-provided buffer (`x` is resized to `n`). With a
    /// reused buffer on a warm engine, the whole call performs no O(n)
    /// allocation — the repeated-solve inner loop.
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: `LinearSystem::<Factored>::solve_into` \
                (see DESIGN.md §6)"
    )]
    pub fn solve_into(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        b: &[f64],
        x: &mut Vec<f64>,
    ) -> Result<SolveStats> {
        self.solve_into_core(a, an, f, b, x, &RefineParams::from_config(&self.cfg))
    }

    pub(crate) fn solve_into_core(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        b: &[f64],
        x: &mut Vec<f64>,
        rp: &RefineParams,
    ) -> Result<SolveStats> {
        if b.len() != a.n {
            return Err(Error::Invalid("rhs length mismatch".into()));
        }
        if let Some(fp) = self.cfg.fault.as_deref() {
            fp.at_solve();
        }
        let t0 = Instant::now();
        let threads = self.engine.pool().nthreads();
        let mut guard = self.engine.scratch();
        let scratch = &mut *guard;

        if let Some(fac32) = f.fac32.as_ref() {
            let force_f64 = rp.precision == Some(Precision::F64);
            let mut iters_mixed = 0usize;
            let mut fallbacks = 0u64;
            if !force_f64 && !f.fell_back.load(Ordering::Relaxed) {
                // mixed attempt: f32 substitution, f64 refinement
                self.substitute_into(an, fac32, b, &mut scratch.y, x);
                let (residual, iters, outcome) =
                    self.refine_in_place(a, an, fac32, b, x, scratch, rp, true);
                if outcome == RefineOutcome::Converged || residual <= rp.tol {
                    return Ok(SolveStats {
                        t_solve: t0.elapsed().as_secs_f64(),
                        residual,
                        refine_iters: iters,
                        threads,
                        nrhs: 1,
                        outcome,
                        precision: Precision::Mixed,
                        fallbacks: 0,
                    });
                }
                // refinement stalled (or ran out of budget) above
                // tolerance: escalate to the f64 recovery factors and
                // latch the fallback for the rest of the handle's life
                iters_mixed = iters;
                self.ensure_recovery(a, an, f, true)?;
                fallbacks = 1;
            } else {
                self.ensure_recovery(a, an, f, false)?;
            }
            let rec = exec::lock_ignore_poison(&f.recovery);
            let rfac = rec.as_ref().expect("recovery factors present");
            self.substitute_into(an, rfac, b, &mut scratch.y, x);
            let (residual, iters, outcome) =
                self.refine_in_place(a, an, rfac, b, x, scratch, rp, false);
            return Ok(SolveStats {
                t_solve: t0.elapsed().as_secs_f64(),
                residual,
                refine_iters: iters_mixed + iters,
                threads,
                nrhs: 1,
                outcome,
                precision: Precision::F64,
                fallbacks,
            });
        }

        self.substitute_into(an, &f.fac, b, &mut scratch.y, x);
        let (residual, iters, outcome) =
            self.refine_in_place(a, an, &f.fac, b, x, scratch, rp, false);
        Ok(SolveStats {
            t_solve: t0.elapsed().as_secs_f64(),
            residual,
            refine_iters: iters,
            threads,
            nrhs: 1,
            outcome,
            precision: Precision::F64,
            fallbacks: 0,
        })
    }

    /// Batched repeated solve: `A x_q = b_q` for every right-hand side in
    /// `bs`, sweeping all of them through forward/backward substitution as
    /// one dense block with a single pool dispatch. Column `q` of the
    /// result is bit-identical to `solve(a, an, f, &bs[q])` — the block
    /// kernels perform the same operations in the same order per column,
    /// and batched refinement makes the same per-column accept/stop
    /// decisions on the same floating-point values as the scalar path.
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: `LinearSystem::<Factored>::solve_many` \
                (see DESIGN.md §6)"
    )]
    pub fn solve_many(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        bs: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>> {
        let mut xs = Vec::new();
        self.solve_many_into_core(a, an, f, bs, &mut xs, &RefineParams::from_config(&self.cfg))?;
        Ok(xs)
    }

    /// [`Solver::solve_many`] with aggregate statistics (`residual` is the
    /// worst per-RHS residual, `refine_iters` the total across RHS).
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: \
                `LinearSystem::<Factored>::solve_many_with_stats` (see DESIGN.md §6)"
    )]
    pub fn solve_many_with_stats(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        bs: &[Vec<f64>],
    ) -> Result<(Vec<Vec<f64>>, SolveStats)> {
        let mut xs = Vec::new();
        let st =
            self.solve_many_into_core(a, an, f, bs, &mut xs, &RefineParams::from_config(&self.cfg))?;
        Ok((xs, st))
    }

    /// Batched solve into caller-provided buffers: `xs` is resized to `k`
    /// vectors of length `n`. With reused buffers on a warm engine the
    /// whole call performs no O(n·k) allocation — the batched counterpart
    /// of [`Solver::solve_into`].
    #[deprecated(
        since = "0.2.0",
        note = "use the LinearSystem handle API: `LinearSystem::<Factored>::solve_many_into` \
                (see DESIGN.md §6)"
    )]
    pub fn solve_many_into(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        bs: &[Vec<f64>],
        xs: &mut Vec<Vec<f64>>,
    ) -> Result<SolveStats> {
        self.solve_many_into_core(a, an, f, bs, xs, &RefineParams::from_config(&self.cfg))
    }

    pub(crate) fn solve_many_into_core(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        bs: &[Vec<f64>],
        xs: &mut Vec<Vec<f64>>,
        rp: &RefineParams,
    ) -> Result<SolveStats> {
        let n = a.n;
        let k = bs.len();
        for b in bs {
            if b.len() != n {
                return Err(Error::Invalid("rhs length mismatch".into()));
            }
        }
        if let Some(fp) = self.cfg.fault.as_deref() {
            fp.at_solve();
        }
        let t0 = Instant::now();
        let threads = self.engine.pool().nthreads();
        let counters = self.engine.counters();
        xs.resize_with(k, Vec::new);
        if k == 0 {
            return Ok(SolveStats {
                t_solve: t0.elapsed().as_secs_f64(),
                residual: 0.0,
                refine_iters: 0,
                threads,
                nrhs: 0,
                outcome: RefineOutcome::Converged,
                precision: match f.fac32 {
                    Some(_) => Precision::Mixed,
                    None => Precision::F64,
                },
                fallbacks: 0,
            });
        }
        for x in xs.iter_mut() {
            if x.capacity() < n {
                counters.note_alloc();
            }
            x.resize(n, 0.0);
        }
        let mut guard = self.engine.scratch();
        let scratch = &mut *guard;

        if let Some(fac32) = f.fac32.as_ref() {
            let force_f64 = rp.precision == Some(Precision::F64);
            if !force_f64 && !f.fell_back.load(Ordering::Relaxed) {
                let (mut res, iters, mut outcomes) =
                    self.solve_many_pass(a, an, fac32, bs, xs, scratch, rp, true);
                // columns whose mixed refinement ended above tolerance
                // need the f64 recovery factors
                let bad: Vec<usize> = (0..k)
                    .filter(|&q| outcomes[q] != RefineOutcome::Converged && res[q] > rp.tol)
                    .collect();
                if bad.is_empty() {
                    let worst = res.iter().fold(0.0f64, |m, &v| m.max(v));
                    let outcome = outcomes
                        .iter()
                        .fold(RefineOutcome::Converged, |w, &o| w.worst(o));
                    return Ok(SolveStats {
                        t_solve: t0.elapsed().as_secs_f64(),
                        residual: worst,
                        refine_iters: iters,
                        threads,
                        nrhs: k,
                        outcome,
                        precision: Precision::Mixed,
                        fallbacks: 0,
                    });
                }
                self.ensure_recovery(a, an, f, true)?;
                let rec = exec::lock_ignore_poison(&f.recovery);
                let rfac = rec.as_ref().expect("recovery factors present");
                let mut total = iters;
                for &q in &bad {
                    // scalar f64 re-solve of the stalled column, same
                    // path as the scalar fallback (keeps batched and
                    // scalar mixed solves column-for-column identical)
                    self.substitute_into(an, rfac, &bs[q], &mut scratch.y, &mut xs[q]);
                    let (r2, it2, o2) =
                        self.refine_in_place(a, an, rfac, &bs[q], &mut xs[q], scratch, rp, false);
                    res[q] = r2;
                    total += it2;
                    outcomes[q] = o2;
                }
                let worst = res.iter().fold(0.0f64, |m, &v| m.max(v));
                let outcome = outcomes
                    .iter()
                    .fold(RefineOutcome::Converged, |w, &o| w.worst(o));
                return Ok(SolveStats {
                    t_solve: t0.elapsed().as_secs_f64(),
                    residual: worst,
                    refine_iters: total,
                    threads,
                    nrhs: k,
                    outcome,
                    precision: Precision::F64,
                    fallbacks: 1,
                });
            }
            self.ensure_recovery(a, an, f, false)?;
            let rec = exec::lock_ignore_poison(&f.recovery);
            let rfac = rec.as_ref().expect("recovery factors present");
            let (res, iters, outcomes) =
                self.solve_many_pass(a, an, rfac, bs, xs, scratch, rp, false);
            let worst = res.iter().fold(0.0f64, |m, &v| m.max(v));
            let outcome = outcomes
                .iter()
                .fold(RefineOutcome::Converged, |w, &o| w.worst(o));
            return Ok(SolveStats {
                t_solve: t0.elapsed().as_secs_f64(),
                residual: worst,
                refine_iters: iters,
                threads,
                nrhs: k,
                outcome,
                precision: Precision::F64,
                fallbacks: 0,
            });
        }

        let (res, iters, outcomes) =
            self.solve_many_pass(a, an, &f.fac, bs, xs, scratch, rp, false);
        let worst = res.iter().fold(0.0f64, |m, &v| m.max(v));
        let outcome = outcomes
            .iter()
            .fold(RefineOutcome::Converged, |w, &o| w.worst(o));
        Ok(SolveStats {
            t_solve: t0.elapsed().as_secs_f64(),
            residual: worst,
            refine_iters: iters,
            threads,
            nrhs: k,
            outcome,
            precision: Precision::F64,
            fallbacks: 0,
        })
    }

    /// One batched substitution + batched refinement pass against `fac`:
    /// the single-factor body of [`Solver::solve_many_into_core`].
    /// Returns per-column residuals, the total refinement iteration
    /// count, and per-column refinement outcomes.
    #[allow(clippy::too_many_arguments)]
    fn solve_many_pass<T: Scalar>(
        &self,
        a: &Csr,
        an: &Analysis,
        fac: &LuFactors<T>,
        bs: &[Vec<f64>],
        xs: &mut [Vec<f64>],
        scratch: &mut SolveScratch,
        rp: &RefineParams,
        mixed: bool,
    ) -> (Vec<f64>, usize, Vec<RefineOutcome>) {
        let n = a.n;
        let k = bs.len();
        let counters = self.engine.counters();
        exec::ensure_len(&mut scratch.yk, n * k, counters);
        let yk = &mut scratch.yk[..n * k];
        // pack: yk[i, q] = dr[row] * bs[q][row], row as in the scalar path
        for i in 0..n {
            let pre = fac.pivot_perm[i] as usize;
            let orig = an.row_perm.map[pre];
            let s = an.dr[orig];
            let row = i * k;
            for (q, b) in bs.iter().enumerate() {
                yk[row + q] = s * b[orig];
            }
        }
        let pool = self.engine.pool();
        if pool.nthreads() > 1 && n > self.cfg.parallel_solve_min_n {
            solve_block_parallel_pooled(&an.sym, fac, yk, k, pool, &an.plan);
        } else {
            forward_block(&an.sym, fac, yk, k);
            backward_block(&an.sym, fac, yk, k);
        }
        // unpack: x_q[orig col] = dc[orig col] * yk[new col, q]
        for j in 0..n {
            let orig = an.col_perm.map[j];
            let s = an.dc[orig];
            let row = j * k;
            for (q, x) in xs.iter_mut().enumerate() {
                x[orig] = s * yk[row + q];
            }
        }
        // batched refinement: residual matvec + correction substitution
        // run as a block over the active lanes, with per-column
        // accept/stop decisions identical to the scalar path
        self.refine_many_in_place(a, an, fac, bs, xs, scratch, rp, mixed)
    }

    /// Grow the engine's pipeline done-flag arena to this analysis' node
    /// count (high-water sizing; a growth event only during warm-up).
    fn ensure_done_flags(&self, scratch: &mut FactorScratch, an: &Analysis) {
        if scratch.done.len() < an.sym.nodes.len() {
            scratch.done = DoneFlags::new(an.sym.nodes.len());
            self.engine.counters().note_alloc();
        }
    }

    /// Build (once) the `f64` recovery factors for a mixed
    /// factorization: a fresh pivot-searching `f64` factorization of the
    /// analysis' current values — bit-identical to what a
    /// [`Precision::F64`] factor call on the same matrix produces.
    /// `count_event` latches the stall fallback and bumps the event
    /// counter; forced-`f64` solves pass `false` (building recovery on
    /// demand is not a stall).
    fn ensure_recovery(
        &self,
        a: &Csr,
        an: &Analysis,
        f: &Factorization,
        count_event: bool,
    ) -> Result<()> {
        {
            let mut rec = exec::lock_ignore_poison(&f.recovery);
            if rec.is_none() {
                let mut scratch = self.engine.factor_scratch();
                an.remap_values_into(a, &mut scratch.pa, self.engine.counters())?;
                self.ensure_done_flags(&mut scratch, an);
                let pa = &scratch.pa[0].1;
                let mut rfac: LuFactors = LuFactors::alloc(&an.sym);
                factor_parallel_pooled(
                    pa,
                    &an.sym,
                    an.mode,
                    &self.cfg.pivot,
                    &mut rfac,
                    false,
                    self.gemm.as_ref(),
                    self.engine.pool(),
                    &an.plan,
                    &scratch.done,
                );
                *rec = Some(rfac);
            }
        }
        if count_event {
            f.fell_back.store(true, Ordering::Relaxed);
            f.fallback_events.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// One triangular solve round into reusable buffers: scale/permute b
    /// into `y`, forward, backward, unpermute/unscale into `x`. Generic
    /// over the factor precision: the RHS and solution stay `f64`, the
    /// substitution kernels widen every factor entry on read.
    fn substitute_into<T: Scalar>(
        &self,
        an: &Analysis,
        fac: &LuFactors<T>,
        b: &[f64],
        y: &mut Vec<f64>,
        x: &mut Vec<f64>,
    ) {
        let n = b.len();
        let counters = self.engine.counters();
        exec::ensure_len(y, n, counters);
        if x.capacity() < n {
            counters.note_alloc();
        }
        x.resize(n, 0.0);
        let y = &mut y[..n];
        // y[i] = dr[row] * b[row], row = row_perm(map ∘ pivot)
        for i in 0..n {
            let pre = fac.pivot_perm[i] as usize; // analyzed-row
            let orig = an.row_perm.map[pre];
            y[i] = an.dr[orig] * b[orig];
        }
        let pool = self.engine.pool();
        if pool.nthreads() > 1 && n > self.cfg.parallel_solve_min_n {
            forward_parallel_pooled(&an.sym, fac, y, pool, &an.plan);
            backward_parallel_pooled(&an.sym, fac, y, pool, &an.plan);
        } else {
            forward(&an.sym, fac, y);
            backward(&an.sym, fac, y);
        }
        // x[orig col] = dc[orig col] * y[new col]
        for j in 0..n {
            let orig = an.col_perm.map[j];
            x[orig] = an.dc[orig] * y[j];
        }
    }

    /// Iterative refinement on `x` (paper: automatic after pivot
    /// perturbation) using the engine scratch arenas. The residual
    /// matvec and the accept/stop arithmetic always run in `f64`; only
    /// the correction substitution goes through `fac`'s precision. With
    /// `mixed` set, the iteration budget is widened by
    /// [`MIXED_EXTRA_ITERS`] and a ratio-based stall detector fires when
    /// [`MIXED_STALL_ROUNDS`] consecutive accepted steps each shrink the
    /// residual by less than [`MIXED_STALL_RATIO`]. Returns the final
    /// residual, the iteration count, and how the loop ended.
    #[allow(clippy::too_many_arguments)]
    fn refine_in_place<T: Scalar>(
        &self,
        a: &Csr,
        an: &Analysis,
        fac: &LuFactors<T>,
        b: &[f64],
        x: &mut Vec<f64>,
        scratch: &mut SolveScratch,
        rp: &RefineParams,
        mixed: bool,
    ) -> (f64, usize, RefineOutcome) {
        let n = a.n;
        let counters = self.engine.counters();
        let mut residual = residual_norm(a, &x[..n], b, &mut scratch.r, counters);
        let mut iters = 0usize;
        let mut outcome = RefineOutcome::Converged;
        let max_iter = if mixed {
            rp.max_iter + MIXED_EXTRA_ITERS
        } else {
            rp.max_iter
        };
        if fac.perturbed > 0 || residual > rp.tol {
            let mut slow = 0u32;
            loop {
                if residual <= rp.target {
                    break; // converged
                }
                if iters >= max_iter {
                    outcome = RefineOutcome::BudgetExhausted;
                    break;
                }
                // scratch.r holds A·x from the residual computation:
                // rewrite it into the correction RHS b − A·x
                for (ri, bi) in scratch.r[..n].iter_mut().zip(b) {
                    *ri = bi - *ri;
                }
                self.substitute_into(an, fac, &scratch.r[..n], &mut scratch.y, &mut scratch.d);
                if scratch.x2.capacity() < n {
                    counters.note_alloc();
                }
                scratch.x2.resize(n, 0.0);
                for i in 0..n {
                    scratch.x2[i] = x[i] + scratch.d[i];
                }
                let res2 = residual_norm(a, &scratch.x2[..n], b, &mut scratch.r, counters);
                iters += 1;
                if res2 < residual {
                    let slow_step = mixed && res2 > residual * MIXED_STALL_RATIO;
                    std::mem::swap(x, &mut scratch.x2);
                    residual = res2;
                    if slow_step {
                        slow += 1;
                        if slow >= MIXED_STALL_ROUNDS {
                            outcome = RefineOutcome::Stalled;
                            break;
                        }
                    } else {
                        slow = 0;
                    }
                } else {
                    outcome = RefineOutcome::Stalled;
                    break;
                }
            }
        }
        (residual, iters, outcome)
    }

    /// Batched iterative refinement over `k` solutions: the residual
    /// matvec and the correction substitution sweep all still-active
    /// columns as one dense block (one pool dispatch per round) instead
    /// of `k` scalar passes. Per column this performs exactly the
    /// operations of [`Solver::refine_in_place`] on exactly the same
    /// values — the block substitution kernels are column-for-column
    /// identical to the scalar ones — so accept/stop decisions and
    /// results are bit-identical to `k` independent scalar refinements.
    /// Returns per-column residuals, the total iteration count, and
    /// per-column outcomes.
    #[allow(clippy::too_many_arguments)]
    fn refine_many_in_place<T: Scalar>(
        &self,
        a: &Csr,
        an: &Analysis,
        fac: &LuFactors<T>,
        bs: &[Vec<f64>],
        xs: &mut [Vec<f64>],
        scratch: &mut SolveScratch,
        rp: &RefineParams,
        mixed: bool,
    ) -> (Vec<f64>, usize, Vec<RefineOutcome>) {
        let n = a.n;
        let k = bs.len();
        let counters = self.engine.counters();
        let SolveScratch { yk, rk, x2k, .. } = scratch;
        exec::ensure_len(rk, n * k, counters);
        let rk = &mut rk[..n * k];
        // initial residual block: rk[i,q] = (A·x_q)[i]; per column this is
        // Csr::matvec's accumulation order exactly
        for i in 0..n {
            let idx = a.row_indices(i);
            let vals = a.row_vals(i);
            let row = i * k;
            for (q, x) in xs.iter().enumerate() {
                let mut s = 0.0;
                for (p, &j) in idx.iter().enumerate() {
                    s += vals[p] * x[j];
                }
                rk[row + q] = s;
            }
        }
        // ‖Ax − b‖₁ / ‖b‖₁ per column (same summation order as
        // Csr::relative_residual_into)
        let mut res = vec![0.0f64; k];
        for (q, b) in bs.iter().enumerate() {
            let mut num = 0.0;
            for (i, bi) in b.iter().enumerate() {
                num += (rk[i * k + q] - bi).abs();
            }
            let den: f64 = b.iter().map(|v| v.abs()).sum();
            res[q] = num / den.max(1e-300);
        }
        let max_iter = if mixed {
            rp.max_iter + MIXED_EXTRA_ITERS
        } else {
            rp.max_iter
        };
        let mut iters = vec![0usize; k];
        let mut outcomes = vec![RefineOutcome::Converged; k];
        let mut slow = vec![0u32; k];
        // columns entering refinement: same gate as the scalar path's
        // outer `if` plus its first loop check
        let mut active: Vec<usize> = (0..k)
            .filter(|&q| {
                let gated = (fac.perturbed > 0 || res[q] > rp.tol) && res[q] > rp.target;
                if gated && max_iter == 0 {
                    outcomes[q] = RefineOutcome::BudgetExhausted;
                }
                gated && max_iter > 0
            })
            .collect();
        while !active.is_empty() {
            let ka = active.len();
            // correction RHS, packed and scaled directly into the block:
            // scalar path computes r = b − A·x then y[i] = dr·r[orig]
            for i in 0..n {
                let pre = fac.pivot_perm[i] as usize;
                let orig = an.row_perm.map[pre];
                let s = an.dr[orig];
                let row = i * ka;
                for (p, &q) in active.iter().enumerate() {
                    yk[row + p] = s * (bs[q][orig] - rk[orig * k + q]);
                }
            }
            let ykb = &mut yk[..n * ka];
            let pool = self.engine.pool();
            if pool.nthreads() > 1 && n > self.cfg.parallel_solve_min_n {
                solve_block_parallel_pooled(&an.sym, fac, ykb, ka, pool, &an.plan);
            } else {
                forward_block(&an.sym, fac, ykb, ka);
                backward_block(&an.sym, fac, ykb, ka);
            }
            exec::ensure_len(x2k, n * k, counters);
            // candidate block: x2_q = x_q + dc·y (scalar: d[orig] = dc·y[j],
            // then x2 = x + d)
            for j in 0..n {
                let orig = an.col_perm.map[j];
                let s = an.dc[orig];
                let row = j * ka;
                for (p, &q) in active.iter().enumerate() {
                    x2k[orig * k + q] = xs[q][orig] + s * ykb[row + p];
                }
            }
            // candidate residual block over the active lanes
            for i in 0..n {
                let idx = a.row_indices(i);
                let vals = a.row_vals(i);
                let row = i * k;
                for &q in active.iter() {
                    let mut s = 0.0;
                    for (p, &j) in idx.iter().enumerate() {
                        s += vals[p] * x2k[j * k + q];
                    }
                    rk[row + q] = s;
                }
            }
            // per-column accept/stop, exactly the scalar loop's logic
            active.retain(|&q| {
                let b = &bs[q];
                let mut num = 0.0;
                for (i, bi) in b.iter().enumerate() {
                    num += (rk[i * k + q] - bi).abs();
                }
                let den: f64 = b.iter().map(|v| v.abs()).sum();
                let res2 = num / den.max(1e-300);
                iters[q] += 1;
                if res2 < res[q] {
                    let slow_step = mixed && res2 > res[q] * MIXED_STALL_RATIO;
                    res[q] = res2;
                    let x = &mut xs[q];
                    for (i, xi) in x.iter_mut().enumerate() {
                        *xi = x2k[i * k + q];
                    }
                    if slow_step {
                        slow[q] += 1;
                        if slow[q] >= MIXED_STALL_ROUNDS {
                            outcomes[q] = RefineOutcome::Stalled;
                            return false;
                        }
                    } else {
                        slow[q] = 0;
                    }
                    if res[q] <= rp.target {
                        false // converged
                    } else if iters[q] >= max_iter {
                        outcomes[q] = RefineOutcome::BudgetExhausted;
                        false
                    } else {
                        true
                    }
                } else {
                    outcomes[q] = RefineOutcome::Stalled;
                    false
                }
            });
        }
        (res, iters.iter().sum(), outcomes)
    }
}

/// `‖Ax − b‖₁ / ‖b‖₁` with `r` as the reusable `A·x` buffer (left holding
/// `A·x` on return). The norm itself is [`Csr::relative_residual_into`] —
/// one residual definition shared with the rest of the crate.
fn residual_norm(
    a: &Csr,
    x: &[f64],
    b: &[f64],
    r: &mut Vec<f64>,
    counters: &PoolCounters,
) -> f64 {
    exec::ensure_len(r, a.n, counters);
    a.relative_residual_into(x, b, &mut r[..a.n])
}

/// Build the permuted+scaled matrix and the value remap tables.
fn build_permuted(
    a: &Csr,
    row_perm: &Perm,
    col_perm: &Perm,
    dr: &[f64],
    dc: &[f64],
) -> (Csr, Vec<usize>, Vec<f64>) {
    let n = a.n;
    let mut indptr = vec![0usize; n + 1];
    for i in 0..n {
        let src = row_perm.map[i];
        indptr[i + 1] = indptr[i] + (a.indptr[src + 1] - a.indptr[src]);
    }
    let nnz = a.nnz();
    let mut indices = vec![0usize; nnz];
    let mut vals = vec![0.0; nnz];
    let mut src_idx = vec![0usize; nnz];
    let mut scale = vec![0.0; nnz];
    let mut buf: Vec<(usize, usize)> = Vec::new();
    for i in 0..n {
        let src = row_perm.map[i];
        buf.clear();
        for k in a.indptr[src]..a.indptr[src + 1] {
            buf.push((col_perm.inv[a.indices[k]], k));
        }
        buf.sort_unstable_by_key(|&(c, _)| c);
        let base = indptr[i];
        for (off, &(c, k)) in buf.iter().enumerate() {
            indices[base + off] = c;
            let s = dr[src] * dc[a.indices[k]];
            scale[base + off] = s;
            src_idx[base + off] = k;
            vals[base + off] = a.vals[k] * s;
        }
    }
    (
        Csr {
            n,
            indptr,
            indices,
            vals,
        },
        src_idx,
        scale,
    )
}

#[cfg(test)]
mod tests {
    // these tests deliberately exercise the legacy `(a, an, f)` wrappers;
    // the handle API's coverage lives in rust/tests/api_handles.rs
    #![allow(deprecated)]

    use super::*;
    use crate::sparse::gen;
    use crate::testutil::{max_abs_diff, Prng};

    fn solve_roundtrip(a: &Csr, cfg: SolverConfig, tol: f64) {
        let solver = Solver::new(cfg);
        let an = solver.analyze(a).unwrap();
        let f = solver.factor(a, &an).unwrap();
        let xt: Vec<f64> = (0..a.n).map(|i| ((i * 13) % 11) as f64 - 5.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let (x, st) = solver.solve_with_stats(a, &an, &f, &b).unwrap();
        assert!(
            max_abs_diff(&x, &xt) < tol,
            "err {} residual {}",
            max_abs_diff(&x, &xt),
            st.residual
        );
    }

    #[test]
    fn end_to_end_grid() {
        solve_roundtrip(&gen::grid2d(15, 15), SolverConfig::default(), 1e-8);
    }

    #[test]
    fn end_to_end_circuit() {
        solve_roundtrip(&gen::circuit(500, 3), SolverConfig::default(), 1e-7);
    }

    #[test]
    fn end_to_end_kkt_requires_static_pivoting() {
        // saddle-point: tiny (2,2) block — fails without MC64, passes with
        solve_roundtrip(&gen::kkt(300, 100, 5), SolverConfig::default(), 1e-6);
    }

    #[test]
    fn end_to_end_all_kernel_overrides() {
        let a = gen::power_network(300, 7);
        for mode in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
            let cfg = SolverConfig {
                kernel: Some(mode),
                ..SolverConfig::default()
            };
            solve_roundtrip(&a, cfg, 1e-7);
        }
    }

    #[test]
    fn repeated_mode_refactor_loop() {
        let mut rng = Prng::new(4);
        let a = gen::grid2d(12, 12);
        let cfg = SolverConfig {
            repeated: true,
            ..SolverConfig::default()
        };
        let solver = Solver::new(cfg);
        let an = solver.analyze(&a).unwrap();
        let mut f = solver.factor(&a, &an).unwrap();
        for _ in 0..3 {
            let mut b2 = a.clone();
            for v in &mut b2.vals {
                *v *= rng.range_f64(0.8, 1.2);
            }
            solver.refactor(&b2, &an, &mut f).unwrap();
            let xt: Vec<f64> = (0..a.n).map(|i| (i % 5) as f64).collect();
            let mut b = vec![0.0; a.n];
            b2.matvec(&xt, &mut b);
            let x = solver.solve(&b2, &an, &f, &b).unwrap();
            assert!(max_abs_diff(&x, &xt) < 1e-7);
        }
    }

    #[test]
    fn rejects_pattern_change_on_refactor() {
        let a = gen::grid2d(5, 5);
        let solver = Solver::new(SolverConfig::default());
        let an = solver.analyze(&a).unwrap();
        let b = gen::grid2d(5, 6); // different pattern
        assert!(solver.factor(&b, &an).is_err());
    }

    #[test]
    fn rejects_bad_rhs_and_empty() {
        let a = gen::grid2d(4, 4);
        let solver = Solver::new(SolverConfig::default());
        let an = solver.analyze(&a).unwrap();
        let f = solver.factor(&a, &an).unwrap();
        assert!(solver.solve(&a, &an, &f, &[1.0]).is_err());
        assert!(solver.solve_many(&a, &an, &f, &[vec![1.0]]).is_err());
        let empty = Csr {
            n: 0,
            indptr: vec![0],
            indices: vec![],
            vals: vec![],
        };
        assert!(solver.analyze(&empty).is_err());
    }

    #[test]
    fn multithreaded_config_agrees_with_sequential() {
        let a = gen::grid2d(14, 14);
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 3) as f64 + 0.5).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let s1 = Solver::new(SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        });
        let s4 = Solver::new(SolverConfig {
            threads: 4,
            ..SolverConfig::default()
        });
        let an1 = s1.analyze(&a).unwrap();
        let an4 = s4.analyze(&a).unwrap();
        let f1 = s1.factor(&a, &an1).unwrap();
        let f4 = s4.factor(&a, &an4).unwrap();
        let x1 = s1.solve(&a, &an1, &f1, &b).unwrap();
        let x4 = s4.solve(&a, &an4, &f4, &b).unwrap();
        assert_eq!(x1, x4, "threaded result must be bit-identical");
    }

    #[test]
    fn solve_many_empty_and_basic() {
        let a = gen::grid2d(8, 8);
        let solver = Solver::new(SolverConfig::default());
        let an = solver.analyze(&a).unwrap();
        let f = solver.factor(&a, &an).unwrap();
        assert!(solver.solve_many(&a, &an, &f, &[]).unwrap().is_empty());
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 4) as f64 - 1.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let xs = solver.solve_many(&a, &an, &f, &[b.clone(), b.clone()]).unwrap();
        assert_eq!(xs.len(), 2);
        for x in &xs {
            assert!(max_abs_diff(x, &xt) < 1e-8);
        }
    }
}

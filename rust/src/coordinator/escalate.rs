//! Pivot-stability escalation controller for the adaptive refactor path.
//!
//! PR 8's serving layer treats pivot growth as a binary quarantine
//! signal. This controller turns it into a graduated policy on the
//! repeated-refactor path: while growth is stable, keep the cheap
//! pattern-reusing replay; when growth *trends* up, promote to a
//! secondary within-supernode-block reordering pass (CKTSO-style) before
//! the replay; and only past a hard threshold escalate to a full
//! re-pivoting `factorize()`. The trend detector is the fast/slow
//! exponential-moving-average pair idiom from SAT restart scheduling
//! (splr): the fast EMA chases recent growth, the slow EMA is the
//! long-run baseline, and escalation triggers on the fast EMA — which,
//! for a worsening sequence, always sits at or above the slow one.
//!
//! The controller is pure bookkeeping (no clocks, no I/O) so its policy
//! is property-testable: a stable trace never escalates, and along a
//! non-decreasing growth trace the chosen tier is monotone until a
//! repivot resets the state.

/// Smoothing factor of the fast (recent-window) EMA.
const ALPHA_FAST: f64 = 0.5;
/// Smoothing factor of the slow (baseline) EMA.
const ALPHA_SLOW: f64 = 0.1;

/// What the adaptive refactor path should do for the next factorization,
/// cheapest first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RefactorTier {
    /// Pattern- and pivot-reusing replay refactorization.
    Replay,
    /// Secondary within-block reordering, then replay.
    Reorder,
    /// Full re-pivoting factorization.
    Repivot,
}

/// EMA-driven escalation state. One controller lives per factored
/// handle; [`EscalationController::decide`] is fed the pivot growth of
/// the most recent factorization before each refactor.
#[derive(Clone, Debug)]
pub struct EscalationController {
    fast: f64,
    slow: f64,
    primed: bool,
    reorder_growth: f64,
    repivot_growth: f64,
    replays: u64,
    reorders: u64,
    repivots: u64,
}

impl EscalationController {
    /// Build a controller with the given escalation thresholds
    /// (`reorder_growth <= repivot_growth` is enforced by clamping).
    pub fn new(reorder_growth: f64, repivot_growth: f64) -> Self {
        EscalationController {
            fast: 0.0,
            slow: 0.0,
            primed: false,
            reorder_growth: reorder_growth.max(1.0),
            repivot_growth: repivot_growth.max(reorder_growth.max(1.0)),
            replays: 0,
            reorders: 0,
            repivots: 0,
        }
    }

    /// Fold the latest observed pivot growth into the EMAs and pick the
    /// tier for the refactorization about to run. Non-finite growth
    /// (overflowed factors) escalates straight to [`RefactorTier::Repivot`].
    pub fn decide(&mut self, growth: f64) -> RefactorTier {
        let g = if growth.is_finite() { growth.max(0.0) } else { f64::INFINITY };
        if !self.primed {
            self.primed = true;
            self.fast = g;
            self.slow = g;
        } else {
            self.fast = ALPHA_FAST * g + (1.0 - ALPHA_FAST) * self.fast;
            self.slow = ALPHA_SLOW * g + (1.0 - ALPHA_SLOW) * self.slow;
        }
        let tier = if !g.is_finite() || g >= self.repivot_growth || self.fast >= self.repivot_growth
        {
            RefactorTier::Repivot
        } else if self.fast >= self.reorder_growth && self.fast >= self.slow {
            RefactorTier::Reorder
        } else {
            RefactorTier::Replay
        };
        match tier {
            RefactorTier::Replay => self.replays += 1,
            RefactorTier::Reorder => self.reorders += 1,
            RefactorTier::Repivot => self.repivots += 1,
        }
        tier
    }

    /// Reset the EMAs after a full re-pivoting factorization: the pivot
    /// set is fresh, so the old trend no longer describes it. Counters
    /// are preserved.
    pub fn reset(&mut self) {
        self.primed = false;
        self.fast = 0.0;
        self.slow = 0.0;
    }

    /// `(replays, reorders, repivots)` decided so far.
    pub fn counts(&self) -> (u64, u64, u64) {
        (self.replays, self.reorders, self.repivots)
    }

    /// Current fast (recent) EMA of pivot growth.
    pub fn fast_ema(&self) -> f64 {
        self.fast
    }

    /// Current slow (baseline) EMA of pivot growth.
    pub fn slow_ema(&self) -> f64 {
        self.slow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_traces_never_escalate() {
        let mut c = EscalationController::new(100.0, 1e6);
        for i in 0..1000 {
            // bounded wobble well under the reorder threshold
            let g = 2.0 + (i % 7) as f64;
            assert_eq!(c.decide(g), RefactorTier::Replay);
        }
        let (replays, reorders, repivots) = c.counts();
        assert_eq!((replays, reorders, repivots), (1000, 0, 0));
    }

    #[test]
    fn monotone_growth_escalates_monotonically() {
        let mut c = EscalationController::new(50.0, 5000.0);
        let mut last = RefactorTier::Replay;
        let mut seen_reorder = false;
        let mut seen_repivot = false;
        for step in 0..200 {
            let g = 1.0 + step as f64 * 40.0; // non-decreasing ramp
            let t = c.decide(g);
            assert!(t >= last, "tier regressed from {last:?} to {t:?} at step {step}");
            seen_reorder |= t == RefactorTier::Reorder;
            seen_repivot |= t == RefactorTier::Repivot;
            if t == RefactorTier::Repivot {
                break;
            }
            last = t;
        }
        assert!(seen_reorder, "ramp never promoted to Reorder");
        assert!(seen_repivot, "ramp never reached Repivot");
    }

    #[test]
    fn non_finite_growth_forces_immediate_repivot() {
        let mut c = EscalationController::new(100.0, 1e6);
        assert_eq!(c.decide(2.0), RefactorTier::Replay);
        assert_eq!(c.decide(f64::INFINITY), RefactorTier::Repivot);
        assert_eq!(c.decide(f64::NAN), RefactorTier::Repivot);
    }

    #[test]
    fn reset_after_repivot_returns_to_replay() {
        let mut c = EscalationController::new(10.0, 100.0);
        for _ in 0..8 {
            c.decide(500.0);
        }
        assert_eq!(c.decide(500.0), RefactorTier::Repivot);
        c.reset();
        assert_eq!(c.decide(1.5), RefactorTier::Replay);
        let (_, _, repivots) = c.counts();
        assert!(repivots >= 1);
    }

    #[test]
    fn hard_threshold_skips_the_reorder_tier() {
        // a single catastrophic sample must not wait for the EMA to warm
        let mut c = EscalationController::new(10.0, 100.0);
        assert_eq!(c.decide(1.0), RefactorTier::Replay);
        assert_eq!(c.decide(1e9), RefactorTier::Repivot);
    }
}

//! The deterministic core of the coalescing queue: priority lanes and
//! the adaptive tick controller.
//!
//! Both pieces are pure state machines — no threads, no clocks of their
//! own — so the service's scheduling behavior is property-testable in
//! isolation (`rust/tests/service_props.rs`) and the concurrent shard
//! dispatcher (`service/shard.rs`) stays a thin driver around them.
//!
//! - [`LaneQueue`] holds queued solve requests in **two priority lanes**
//!   ([`Priority::Deadline`] | [`Priority::Bulk`]) and produces the
//!   per-tick dispatch order: deadline-lane requests first (earliest
//!   deadline first), FIFO within each lane, with a **starvation bound**
//!   — at most `starvation_bound` deadline-lane requests are dispatched
//!   between consecutive bulk-lane requests, so a saturated deadline
//!   lane can delay a bulk request by at most that many positions.
//! - [`AdaptiveTick`] replaces the static coalescing window: under
//!   sustained arrivals the window stretches (doubling per productive
//!   drain) toward `tick_max`, and it collapses to zero the moment the
//!   shard idles, trading latency for batch width only while there is
//!   traffic to batch. The window is invariantly within
//!   `[0, tick_max]`; with `tick_max` zero the controller degrades to
//!   the static window (`tick`) unchanged.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Scheduling class of one submitted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Latency-critical: drained before the bulk lane (earliest deadline
    /// first), subject to the bulk-lane starvation bound.
    Deadline(Instant),
    /// Throughput traffic: FIFO, yields to the deadline lane up to the
    /// starvation bound.
    Bulk,
}

/// One queued item annotated with its admission sequence number and
/// lane. Sequence numbers are assigned by the enclosing queue at push
/// time and are what the shard dispatcher uses to order solves against
/// barrier jobs (refactor / retire / migrate).
#[derive(Debug)]
pub struct Drained<T> {
    /// Admission order within the owning shard queue (monotone).
    pub seq: u64,
    /// `Some(deadline)` for deadline-lane items, `None` for bulk.
    pub deadline: Option<Instant>,
    /// The queued payload.
    pub item: T,
}

/// A two-lane priority queue with a starvation-bounded drain order. See
/// the [module docs](self) for the scheduling contract.
#[derive(Debug)]
pub struct LaneQueue<T> {
    /// Deadline lane, in arrival order; sorted by `(deadline, seq)` at
    /// drain time (drains are the hot path only once per tick).
    deadline: Vec<(Instant, u64, T)>,
    /// Bulk lane, FIFO.
    bulk: VecDeque<(u64, T)>,
}

impl<T> Default for LaneQueue<T> {
    fn default() -> Self {
        LaneQueue::new()
    }
}

impl<T> LaneQueue<T> {
    /// An empty queue.
    pub fn new() -> LaneQueue<T> {
        LaneQueue {
            deadline: Vec::new(),
            bulk: VecDeque::new(),
        }
    }

    /// Queued items across both lanes.
    pub fn len(&self) -> usize {
        self.deadline.len() + self.bulk.len()
    }

    /// Whether both lanes are empty.
    pub fn is_empty(&self) -> bool {
        self.deadline.is_empty() && self.bulk.is_empty()
    }

    /// Items waiting in the deadline lane.
    pub fn deadline_len(&self) -> usize {
        self.deadline.len()
    }

    /// The earliest deadline currently queued, if any — what the shard
    /// dispatcher clamps its coalescing sleep by, so a request admitted
    /// alive is dispatched a margin before it would expire instead of
    /// being slept past (the SLO-aware window).
    pub fn earliest_deadline(&self) -> Option<Instant> {
        self.deadline.iter().map(|(at, _, _)| *at).min()
    }

    /// Enqueue one item with its admission sequence number.
    pub fn push(&mut self, seq: u64, prio: Priority, item: T) {
        match prio {
            Priority::Deadline(at) => self.deadline.push((at, seq, item)),
            Priority::Bulk => self.bulk.push_back((seq, item)),
        }
    }

    /// Drain both lanes into dispatch order.
    ///
    /// Deadline-lane items come out earliest-deadline-first (ties by
    /// admission order); the bulk lane stays FIFO. The two lanes are
    /// interleaved so that at most `starvation_bound` (clamped to >= 1)
    /// deadline items are dispatched between consecutive bulk items —
    /// the documented bulk starvation bound.
    pub fn drain_ordered(&mut self, starvation_bound: usize) -> Vec<Drained<T>> {
        let bound = starvation_bound.max(1);
        let mut dl = std::mem::take(&mut self.deadline);
        dl.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut dl = dl.into_iter();
        let mut next_dl = dl.next();
        let mut out = Vec::with_capacity(dl.len() + 1 + self.bulk.len());
        let mut run = 0usize; // deadline items since the last bulk item
        loop {
            let take_deadline = match (&next_dl, self.bulk.front()) {
                (Some(_), Some(_)) => run < bound,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            if take_deadline {
                let (at, seq, item) = next_dl.take().expect("deadline item present");
                next_dl = dl.next();
                run += 1;
                out.push(Drained {
                    seq,
                    deadline: Some(at),
                    item,
                });
            } else {
                let (seq, item) = self.bulk.pop_front().expect("bulk item present");
                run = 0;
                out.push(Drained {
                    seq,
                    deadline: None,
                    item,
                });
            }
        }
        out
    }

    /// [`LaneQueue::drain_ordered`] with deadline expiry: deadline-lane
    /// items whose deadline is at or before `now` are split out of the
    /// dispatch order into the second vector (sorted `(deadline, seq)`
    /// like the lane itself) so the shard can fail them with
    /// [`crate::Error::DeadlineExpired`] instead of spending factor
    /// bandwidth on work nobody is waiting for. Bulk items never expire.
    pub fn drain_ordered_expiring(
        &mut self,
        now: Instant,
        starvation_bound: usize,
    ) -> (Vec<Drained<T>>, Vec<Drained<T>>) {
        let mut expired = Vec::new();
        let mut keep = Vec::new();
        for (at, seq, item) in self.deadline.drain(..) {
            if at <= now {
                expired.push((at, seq, item));
            } else {
                keep.push((at, seq, item));
            }
        }
        self.deadline = keep;
        expired.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let expired = expired
            .into_iter()
            .map(|(at, seq, item)| Drained {
                seq,
                deadline: Some(at),
                item,
            })
            .collect();
        (self.drain_ordered(starvation_bound), expired)
    }
}

/// Floor for the adaptive window's first stretch when the configured
/// base `tick` is zero: without it the doubling controller could never
/// leave zero.
const STEP_FLOOR: Duration = Duration::from_micros(25);

/// The coalescing-window controller. Static when `tick_max` is zero
/// (the window is the configured `tick`, always); adaptive otherwise:
///
/// - while sustained arrivals keep **widening** batches (this drain
///   coalesced >= 2 requests and more than the previous one), the
///   window doubles, starting from `max(tick, 25µs)` and saturating at
///   `tick_max` — growth is paid for with batch width;
/// - at a **plateau** (>= 2 coalesced, but no wider than last time) the
///   window holds: it already captures the concurrency on offer, and
///   stretching further would buy latency for nothing;
/// - an **unproductive drain** (<= 1 request) halves it — sleeping was
///   not batching anything;
/// - **idling** (the dispatcher parked on an empty queue) collapses it
///   to zero — the next lone request is served at minimum latency.
///
/// The window is invariantly within `[0, tick_max]` (asserted under
/// arbitrary traces in `rust/tests/service_props.rs`).
#[derive(Clone, Debug)]
pub struct AdaptiveTick {
    /// Current window, nanoseconds.
    window_ns: u64,
    /// First stretch target, nanoseconds (the configured `tick`, floored).
    step_ns: u64,
    /// Ceiling, nanoseconds; zero disables adaptation (static mode).
    max_ns: u64,
    /// Width of the previous drain (0 after idle) — growth requires the
    /// batches to still be widening.
    last_drained: usize,
}

impl AdaptiveTick {
    /// Controller for a static `tick` and an adaptive ceiling
    /// `tick_max` (zero ⇒ static mode).
    pub fn new(tick: Duration, tick_max: Duration) -> AdaptiveTick {
        let max_ns = tick_max.as_nanos().min(u64::MAX as u128) as u64;
        let tick_ns = tick.as_nanos().min(u64::MAX as u128) as u64;
        if max_ns == 0 {
            // static mode: the window is the configured tick, forever
            return AdaptiveTick {
                window_ns: tick_ns,
                step_ns: tick_ns,
                max_ns: 0,
                last_drained: 0,
            };
        }
        let step_ns = tick_ns.max(STEP_FLOOR.as_nanos() as u64).min(max_ns);
        AdaptiveTick {
            window_ns: 0,
            step_ns,
            max_ns,
            last_drained: 0,
        }
    }

    /// Whether the controller adapts (ceiling nonzero).
    pub fn is_adaptive(&self) -> bool {
        self.max_ns != 0
    }

    /// The current coalescing window.
    pub fn window(&self) -> Duration {
        Duration::from_nanos(self.window_ns)
    }

    /// Record one drain of `drained` solve requests. `max_batch` is the
    /// coalescing cap: a drain already at the cap holds the window
    /// steady (sleeping longer cannot widen a full batch).
    pub fn on_drain(&mut self, drained: usize, max_batch: usize) {
        if !self.is_adaptive() {
            return;
        }
        let widening = drained > self.last_drained;
        self.last_drained = drained;
        if drained >= max_batch.max(2) {
            return; // saturated: growing the window buys nothing
        }
        if drained >= 2 {
            if widening {
                self.window_ns = self
                    .window_ns
                    .saturating_mul(2)
                    .max(self.step_ns)
                    .min(self.max_ns);
            }
            // plateau: hold — this window already captures the offered
            // concurrency
        } else {
            self.window_ns /= 2;
        }
    }

    /// Record that the dispatcher parked on an empty queue: collapse the
    /// window so the next lone request is served immediately.
    pub fn on_idle(&mut self) {
        if self.is_adaptive() {
            self.window_ns = 0;
            self.last_drained = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_ids(q: &mut LaneQueue<u32>, bound: usize) -> Vec<u32> {
        q.drain_ordered(bound).into_iter().map(|d| d.item).collect()
    }

    #[test]
    fn bulk_alone_is_fifo() {
        let mut q = LaneQueue::new();
        for i in 0..5u32 {
            q.push(i as u64, Priority::Bulk, i);
        }
        assert_eq!(drain_ids(&mut q, 3), vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn deadline_sorts_by_deadline_then_seq() {
        let t0 = Instant::now();
        let mut q = LaneQueue::new();
        q.push(0, Priority::Deadline(t0 + Duration::from_millis(3)), 30u32);
        q.push(1, Priority::Deadline(t0 + Duration::from_millis(1)), 10);
        q.push(2, Priority::Deadline(t0 + Duration::from_millis(1)), 11);
        q.push(3, Priority::Deadline(t0 + Duration::from_millis(2)), 20);
        assert_eq!(drain_ids(&mut q, 8), vec![10, 11, 20, 30]);
    }

    #[test]
    fn starvation_bound_interleaves_bulk() {
        let t0 = Instant::now();
        let mut q = LaneQueue::new();
        for i in 0..6u32 {
            q.push(i as u64, Priority::Deadline(t0 + Duration::from_micros(i as u64)), i);
        }
        q.push(6, Priority::Bulk, 100);
        q.push(7, Priority::Bulk, 101);
        // bound 2: two deadline items, then a bulk item, repeating
        assert_eq!(drain_ids(&mut q, 2), vec![0, 1, 100, 2, 3, 101, 4, 5]);
    }

    #[test]
    fn bound_is_clamped_to_one() {
        let t0 = Instant::now();
        let mut q = LaneQueue::new();
        q.push(0, Priority::Deadline(t0), 0u32);
        q.push(1, Priority::Deadline(t0), 1);
        q.push(2, Priority::Bulk, 100);
        assert_eq!(drain_ids(&mut q, 0), vec![0, 100, 1]);
    }

    #[test]
    fn earliest_deadline_tracks_the_lane() {
        let t0 = Instant::now();
        let mut q = LaneQueue::new();
        assert_eq!(q.earliest_deadline(), None);
        q.push(0, Priority::Bulk, 0u32);
        assert_eq!(q.earliest_deadline(), None, "bulk items carry no deadline");
        q.push(1, Priority::Deadline(t0 + Duration::from_millis(5)), 1);
        q.push(2, Priority::Deadline(t0 + Duration::from_millis(2)), 2);
        q.push(3, Priority::Deadline(t0 + Duration::from_millis(9)), 3);
        assert_eq!(q.earliest_deadline(), Some(t0 + Duration::from_millis(2)));
        let _ = q.drain_ordered(8);
        assert_eq!(q.earliest_deadline(), None, "drained lanes clear the bound");
    }

    #[test]
    fn expiring_drain_splits_stale_deadlines() {
        let t0 = Instant::now();
        let mut q = LaneQueue::new();
        // two already-expired (one "now" exactly), two live, one bulk
        q.push(0, Priority::Deadline(t0 - Duration::from_millis(1)), 0u32);
        q.push(1, Priority::Deadline(t0), 1);
        q.push(2, Priority::Deadline(t0 + Duration::from_secs(60)), 2);
        q.push(3, Priority::Deadline(t0 + Duration::from_secs(30)), 3);
        q.push(4, Priority::Bulk, 100);
        let (dispatch, expired) = q.drain_ordered_expiring(t0, 8);
        assert_eq!(
            expired.iter().map(|d| d.item).collect::<Vec<_>>(),
            vec![0, 1],
            "at-or-before now expires, sorted by deadline"
        );
        assert!(expired.iter().all(|d| d.deadline.is_some()));
        assert_eq!(
            dispatch.iter().map(|d| d.item).collect::<Vec<_>>(),
            vec![3, 2, 100],
            "live items keep EDF order; bulk never expires"
        );
        assert!(q.is_empty());
    }

    #[test]
    fn static_tick_never_moves() {
        let mut t = AdaptiveTick::new(Duration::from_micros(200), Duration::ZERO);
        assert!(!t.is_adaptive());
        for _ in 0..10 {
            t.on_drain(64, 64);
            assert_eq!(t.window(), Duration::from_micros(200));
            t.on_idle();
            assert_eq!(t.window(), Duration::from_micros(200));
        }
    }

    #[test]
    fn adaptive_tick_stretches_and_collapses() {
        let max = Duration::from_millis(1);
        let mut t = AdaptiveTick::new(Duration::from_micros(50), max);
        assert_eq!(t.window(), Duration::ZERO, "starts collapsed");
        // widening drains (arrivals outpacing the window) stretch it
        for drained in 2..22usize {
            t.on_drain(drained, 64);
            assert!(t.window() <= max);
        }
        assert_eq!(t.window(), max, "sustained widening reaches the ceiling");
        t.on_idle();
        assert_eq!(t.window(), Duration::ZERO, "idle collapses to zero");
    }

    #[test]
    fn plateaued_batches_hold_the_window() {
        // closed-loop traffic: batches stop widening once every caller
        // is captured — the window must hold, not creep to the ceiling
        let mut t = AdaptiveTick::new(Duration::from_micros(50), Duration::from_millis(2));
        for drained in [2usize, 4, 8] {
            t.on_drain(drained, 64);
        }
        let settled = t.window();
        assert!(settled > Duration::ZERO);
        for _ in 0..50 {
            t.on_drain(8, 64);
        }
        assert_eq!(t.window(), settled, "plateau holds the window");
    }

    #[test]
    fn unproductive_drains_shrink_the_window() {
        let mut t = AdaptiveTick::new(Duration::from_micros(50), Duration::from_millis(1));
        t.on_drain(4, 64);
        let wide = t.window();
        assert!(wide > Duration::ZERO);
        for _ in 0..40 {
            t.on_drain(1, 64);
        }
        assert_eq!(t.window(), Duration::ZERO, "lone arrivals decay the window");
    }

    #[test]
    fn saturated_batches_hold_the_window() {
        let mut t = AdaptiveTick::new(Duration::from_micros(50), Duration::from_millis(1));
        t.on_drain(8, 64);
        let w = t.window();
        t.on_drain(64, 64);
        assert_eq!(t.window(), w, "a full batch neither grows nor shrinks");
    }
}

//! Concurrent serving front door: a sharded, request-coalescing solve
//! service over the repeated-solve engine.
//!
//! HYLU's headline number is the repeated-solve loop, and the workloads
//! that loop serves (circuit transient simulation, many-RHS node-level
//! solves) issue requests *concurrently* from many callers. A
//! [`SolverService`] turns the crate's one-caller-at-a-time `Solver`
//! API into a traffic-serving front door:
//!
//! - **Shards.** The service owns `S` independent solver engines, each
//!   carrying its systems as owning
//!   [`LinearSystem<Factored>`](crate::api::LinearSystem) handles.
//!   Systems — matrices registered at construction — are routed to
//!   shards round-robin, so a multi-matrix parameter sweep spreads
//!   across engines while each matrix keeps its warm factor/scratch
//!   state on one shard.
//! - **Coalescing queue.** Callers [`SolverService::submit`] single
//!   right-hand sides and get a [`Ticket`] (a per-request channel). A
//!   per-shard dispatcher thread drains its queue once per tick and
//!   issues **one batched block dispatch per system**
//!   ([`crate::api::LinearSystem::solve_many_into`]) for everything
//!   that piled up — k concurrent callers cost one substitution sweep
//!   over a dense n×k block instead of k scalar sweeps. Batched columns
//!   are bit-identical to independent scalar solves, so coalescing is
//!   invisible to callers.
//! - **Refactor routing.** [`SolverService::refactor`] ships new
//!   same-pattern values through the same queue; queued solves submitted
//!   before the refactor are flushed first, so a caller never observes
//!   values newer than its submission point.
//!
//! [`ServiceStats`] exposes the coalescing behavior (requests,
//! dispatches, mean/max batch width) for benches and tests.

mod shard;

pub use shard::ServiceStats;

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::Solver;
use crate::coordinator::SolverConfig;
use crate::sparse::csr::Csr;
use crate::{Error, Result};

use shard::{Job, ShardQueue, ShardWorker};

/// Configuration for [`SolverService`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Number of shards (independent solvers + dispatcher threads).
    /// Clamped to `1..=systems` at construction.
    pub shards: usize,
    /// Solver configuration used by every shard. Note `solver.threads`
    /// is the worker-pool width *per shard*.
    pub solver: SolverConfig,
    /// Maximum right-hand sides coalesced into one block dispatch.
    pub max_batch: usize,
    /// Maximum queued jobs per shard before `submit` applies
    /// backpressure (blocks).
    pub queue_cap: usize,
    /// Coalescing window: after waking on a non-empty queue, the
    /// dispatcher waits this long before draining, letting concurrent
    /// submitters pile onto the same tick. `Duration::ZERO` (default)
    /// drains immediately — lowest latency, batching only under
    /// sustained load.
    pub tick: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            shards: 1,
            solver: SolverConfig::default(),
            max_batch: 32,
            queue_cap: 4096,
            tick: Duration::ZERO,
        }
    }
}

/// Handle to one in-flight solve request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Vec<f64>>>,
}

impl Ticket {
    /// Block until the dispatcher resolves this request.
    pub fn wait(self) -> Result<Vec<f64>> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Runtime("service dropped the request".into())),
        }
    }
}

struct ShardHandle {
    queue: Arc<ShardQueue>,
    thread: Option<JoinHandle<()>>,
}

/// The sharded, coalescing solve service. See the module docs.
pub struct SolverService {
    shards: Vec<ShardHandle>,
    /// Per public system id: `(shard, shard-local index, dimension)`.
    route: Vec<(usize, usize, usize)>,
}

impl SolverService {
    /// Build the service: analyze + factor every system on its shard's
    /// solver, then start one dispatcher thread per shard. System ids
    /// are the indices into `systems`.
    pub fn new(cfg: ServiceConfig, systems: Vec<Csr>) -> Result<SolverService> {
        if systems.is_empty() {
            return Err(Error::Invalid("service needs at least one system".into()));
        }
        let nshards = cfg.shards.max(1).min(systems.len());
        let mut route = Vec::with_capacity(systems.len());
        let mut per_shard: Vec<Vec<Csr>> = (0..nshards).map(|_| Vec::new()).collect();
        for (i, a) in systems.into_iter().enumerate() {
            let shard = i % nshards;
            route.push((shard, per_shard[shard].len(), a.n));
            per_shard[shard].push(a);
        }
        let mut shards = Vec::with_capacity(nshards);
        for (s, mats) in per_shard.into_iter().enumerate() {
            // one handle-producing solver (engine) per shard; the solver
            // value is dropped after construction — every LinearSystem
            // keeps the shared engine alive
            let solver = Solver::from_config(cfg.solver.clone())?;
            let mut sys = Vec::with_capacity(mats.len());
            for a in mats {
                sys.push(solver.analyze(a)?.factor()?);
            }
            let queue = Arc::new(ShardQueue::new(cfg.queue_cap.max(1)));
            let worker = ShardWorker::new(sys, queue.clone(), cfg.tick, cfg.max_batch.max(1));
            let thread = std::thread::Builder::new()
                .name(format!("hylu-serve-{s}"))
                .spawn(move || worker.run())
                .map_err(|e| Error::Runtime(format!("spawn shard dispatcher: {e}")))?;
            shards.push(ShardHandle {
                queue,
                thread: Some(thread),
            });
        }
        Ok(SolverService { shards, route })
    }

    fn lookup(&self, sys: usize) -> Result<(usize, usize, usize)> {
        self.route
            .get(sys)
            .copied()
            .ok_or_else(|| Error::Invalid(format!("unknown system id {sys}")))
    }

    /// Enqueue one right-hand side for `sys`; returns a [`Ticket`] to
    /// wait on. Blocks only when the shard queue is at capacity
    /// (backpressure).
    pub fn submit(&self, sys: usize, b: Vec<f64>) -> Result<Ticket> {
        let (shard, local, n) = self.lookup(sys)?;
        if b.len() != n {
            return Err(Error::Invalid("rhs length mismatch".into()));
        }
        let (tx, rx) = mpsc::channel();
        self.shards[shard].queue.push(Job::Solve { sys: local, b, tx })?;
        Ok(Ticket { rx })
    }

    /// Submit and wait: the blocking convenience wrapper.
    pub fn solve(&self, sys: usize, b: Vec<f64>) -> Result<Vec<f64>> {
        self.submit(sys, b)?.wait()
    }

    /// Replace system `sys`'s values with a same-pattern matrix and
    /// refactorize on its shard (parameter-sweep step). Blocks until the
    /// refactorization is applied; solves submitted afterwards observe
    /// the new values.
    pub fn refactor(&self, sys: usize, a: Csr) -> Result<()> {
        let (shard, local, n) = self.lookup(sys)?;
        if a.n != n {
            return Err(Error::Invalid("refactor dimension mismatch".into()));
        }
        let (tx, rx) = mpsc::channel();
        self.shards[shard]
            .queue
            .push(Job::Refactor { sys: local, a, tx })?;
        match rx.recv() {
            Ok(r) => r.map(|_| ()),
            Err(_) => Err(Error::Runtime("service dropped the refactor".into())),
        }
    }

    /// Number of shards actually running.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Number of registered systems.
    pub fn system_count(&self) -> usize {
        self.route.len()
    }

    /// Aggregate coalescing statistics across shards.
    pub fn stats(&self) -> ServiceStats {
        let mut total = ServiceStats::default();
        for sh in &self.shards {
            sh.queue.add_stats_into(&mut total);
        }
        total
    }
}

impl Drop for SolverService {
    /// Graceful shutdown: dispatchers drain everything already queued
    /// (resolving those tickets), then exit and are joined.
    fn drop(&mut self) {
        for sh in &self.shards {
            sh.queue.shutdown();
        }
        for sh in &mut self.shards {
            if let Some(h) = sh.thread.take() {
                let _ = h.join();
            }
        }
    }
}

//! Concurrent serving front door: a sharded, request-coalescing,
//! **elastic** solve service over the repeated-solve engine.
//!
//! HYLU's headline number is the repeated-solve loop, and the workloads
//! that loop serves (circuit transient simulation, many-RHS node-level
//! solves) issue requests *concurrently* from many callers — and their
//! working set of matrices changes over the life of the process. A
//! [`SolverService`] turns the crate's one-caller-at-a-time `Solver`
//! API into a traffic-serving front door:
//!
//! - **Shards.** The service runs `S` dispatcher threads. Each system is
//!   an owning [`LinearSystem<Factored>`](crate::api::LinearSystem)
//!   handle — matrix, analysis, factorization and engine travel as one
//!   value — resident on exactly one shard, where its warm factor and
//!   scratch state stays local.
//! - **Elastic topology.** Systems come and go on a *live* service:
//!   [`SolverService::register`] admits a factored handle under a fresh
//!   [`SystemId`], [`SolverService::retire`] drains its in-flight
//!   tickets and hands the value back, and [`SolverService::rebalance`]
//!   moves hot systems (by per-system EWMA load,
//!   [`SolverService::system_load`]) onto quiet shards as value moves.
//!   Routing is a lock-free read of an epoch-published table
//!   (`service/route.rs`; protocol in DESIGN.md §4); requests racing a
//!   move are forwarded or briefly parked, never lost.
//! - **Coalescing queue with priority lanes.** Callers
//!   [`SolverService::submit`] single right-hand sides and get a
//!   [`Ticket`]. A per-shard dispatcher drains its queue once per tick
//!   and issues **one batched block dispatch per system** for everything
//!   that piled up. Requests ride one of two lanes
//!   ([`Priority::Deadline`] | [`Priority::Bulk`]): deadline requests
//!   dispatch first (earliest deadline first), bounded against bulk
//!   starvation (`ServiceConfig::starvation_bound`). Batched columns are
//!   bit-identical to independent scalar solves, so coalescing is
//!   invisible to callers.
//! - **Adaptive tick.** The coalescing window is no longer a fixed
//!   constant: with [`ServiceConfig::tick_max`] set, it stretches while
//!   sustained arrivals keep widening batches and collapses to zero the
//!   moment a shard idles ([`queue::AdaptiveTick`]).
//! - **Refactor routing.** [`SolverService::refactor`] ships new
//!   same-pattern values through the same queue; solves admitted before
//!   the refactor are flushed first (a barrier that lane re-ordering
//!   cannot jump), so a caller never observes values newer than its
//!   submission point.
//!
//! - **Fault tolerance.** Each dispatch runs under `catch_unwind`
//!   supervision: a panic fails that block's tickets with a typed
//!   [`crate::Error::ShardPanicked`] and the shard keeps serving — a
//!   shard is never permanently dead. A refactor that fails numerically
//!   (zero pivot / singular), panics, or blows past
//!   [`ServiceConfig::pivot_growth_limit`] moves its system to
//!   [`Health::Quarantined`]; queued solves fail fast with
//!   [`crate::Error::Quarantined`] until an EMA-gated **escalation** — a
//!   full re-pivot factorization — restores [`Health::Healthy`]
//!   ([`SolverService::health`]). Stale deadline work can be expired
//!   ([`ServiceConfig::expire_deadlines`]) and bulk load shed at
//!   admission ([`ServiceConfig::shed_depth`]). The whole model is
//!   driven deterministically by [`crate::coordinator::FaultPlan`] in
//!   the chaos soak (`rust/tests/service_soak.rs`).
//!
//! [`ServiceStats`] exposes the coalescing, elasticity, and fault
//! behavior (requests, dispatches, mean/max batch, forwards, moves,
//! panics caught, quarantines/recoveries, expired, shed) for benches
//! and tests.

pub mod queue;
mod route;
mod shard;

pub use queue::Priority;
pub use route::{Health, QuarantineReason, SystemId, SystemLoad, SystemStats};
pub use shard::ServiceStats;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{Factored, LinearSystem, SolveOpts, Solver};
use crate::coordinator::{FaultPlan, SolverConfig};
use crate::exec::lock_ignore_poison;
use crate::sparse::csr::Csr;
use crate::{Error, Result};

use queue::AdaptiveTick;
use route::{EpochCell, RouteCell, RouteEntry};
use shard::{Control, RecoveryGate, ShardPolicy, ShardQueue, ShardSystem, ShardWorker, SolveJob};

/// Configuration for [`SolverService`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Number of shards (dispatcher threads). Clamped to `>= 1`.
    pub shards: usize,
    /// Solver configuration used for systems built by
    /// [`SolverService::new`] (one solver engine per shard; note
    /// `solver.threads` is the worker-pool width *per shard*). Systems
    /// admitted through [`SolverService::register`] bring their own
    /// engine and ignore this.
    pub solver: SolverConfig,
    /// Maximum right-hand sides coalesced into one block dispatch.
    pub max_batch: usize,
    /// Maximum queued jobs per shard before `submit` applies
    /// backpressure (blocks).
    pub queue_cap: usize,
    /// Coalescing window: after waking on a non-empty queue, the
    /// dispatcher waits this long before draining, letting concurrent
    /// submitters pile onto the same tick. With `tick_max` zero this is
    /// the *static* window (`Duration::ZERO` default: drain immediately
    /// — lowest latency, batching only under sustained load); with
    /// `tick_max` set it seeds the adaptive controller's first stretch.
    pub tick: Duration,
    /// Adaptive-tick ceiling. Zero (default) keeps the static `tick`;
    /// nonzero enables the adaptive window, which stretches toward this
    /// ceiling under sustained arrivals and collapses to zero when a
    /// shard idles. See [`queue::AdaptiveTick`].
    pub tick_max: Duration,
    /// Bulk-lane starvation bound: at most this many deadline-lane
    /// requests are dispatched between consecutive bulk-lane requests
    /// (clamped to `>= 1`). See [`queue::LaneQueue`].
    pub starvation_bound: usize,
    /// Load shedding: reject bulk-lane submissions with a "shedding
    /// bulk load" `Runtime` error while the target shard's queue depth
    /// is at or above this. 0 (default) disables shedding. Deadline-lane
    /// submissions are never shed — they ride backpressure instead.
    pub shed_depth: usize,
    /// Fail deadline-lane requests whose deadline passed before
    /// dispatch with [`Error::DeadlineExpired`] instead of solving them
    /// (default off: a deadline is a scheduling hint, not a contract,
    /// unless the operator opts in).
    pub expire_deadlines: bool,
    /// SLO headroom for the deadline lane: with `expire_deadlines` on,
    /// a shard's coalescing wait is clamped to end this long before the
    /// earliest queued deadline, so a request admitted alive is
    /// dispatched with margin to spare instead of expiring during the
    /// shard's own sleep. Default 100µs.
    pub dispatch_margin: Duration,
    /// Quarantine a system whose refactor pivot-growth estimate
    /// (`FactorStats::pivot_growth`) exceeds this. Non-finite growth
    /// always quarantines; the default `f64::INFINITY` keeps finite
    /// growth unlimited.
    pub pivot_growth_limit: f64,
    /// EMA smoothing for the per-system quarantine-recovery retry
    /// controller (see `DESIGN.md` §"Fault model & recovery").
    pub recover_alpha: f64,
    /// Failure-EMA threshold below which a recovery escalation is
    /// attempted at a dispatch opportunity. The first attempt after a
    /// quarantine is always immediate (the EMA starts at zero).
    pub recover_gate: f64,
    /// Deterministic fault-injection plan for chaos testing, shared by
    /// every system the service *builds* ([`SolverService::new`]);
    /// systems admitted via [`SolverService::register`] carry their own
    /// solver's plan. `None` (default) injects nothing (modulo the
    /// `HYLU_FAULT` env override at solver construction).
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            shards: 1,
            solver: SolverConfig::default(),
            max_batch: 32,
            queue_cap: 4096,
            tick: Duration::ZERO,
            tick_max: Duration::ZERO,
            starvation_bound: 8,
            shed_depth: 0,
            expire_deadlines: false,
            dispatch_margin: Duration::from_micros(100),
            pivot_growth_limit: f64::INFINITY,
            recover_alpha: 0.5,
            recover_gate: 0.5,
            fault: None,
        }
    }
}

/// Handle to one in-flight solve request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Vec<f64>>>,
}

impl Ticket {
    /// Block until the dispatcher resolves this request. Every accepted
    /// ticket resolves exactly once — with the solution, or with the
    /// error that befell its dispatch (including a clean
    /// "shutting down" error if the service is dropped mid-move).
    pub fn wait(self) -> Result<Vec<f64>> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Runtime("service dropped the request".into())),
        }
    }
}

/// One immutable epoch of the elastic shard set: the queue of every
/// live shard, indexed by shard id. Shard ids are dense and stable —
/// [`SolverService::grow`] appends, [`SolverService::shrink`] truncates
/// from the tail — so a surviving shard keeps its index across every
/// topology change and forwarding-by-index stays valid.
#[derive(Default)]
pub(crate) struct ShardSet {
    pub(crate) queues: Vec<Arc<ShardQueue>>,
}

impl ShardSet {
    /// Copy-on-write append (grow).
    fn extended(&self, q: Arc<ShardQueue>) -> ShardSet {
        let mut queues = self.queues.clone();
        queues.push(q);
        ShardSet { queues }
    }

    /// Copy-on-write tail truncation (shrink).
    fn truncated(&self, keep: usize) -> ShardSet {
        ShardSet {
            queues: self.queues[..keep].to_vec(),
        }
    }
}

/// State shared between the service value and every shard dispatcher:
/// the routing publication cell, the epoch-published shard set (for
/// forwarding), and the elasticity counters.
pub(crate) struct ServiceShared {
    pub(crate) routes: RouteCell,
    /// The live shard set, published exactly like the routing table so
    /// dispatchers forward against a coherent (possibly one-epoch
    /// stale) view. Invariant kept by `grow`/`shrink`: a route entry
    /// never points at a shard outside the *current* set — routes move
    /// off a draining shard before the set truncates, and a grown
    /// shard's queue is published before any route targets it.
    pub(crate) shards: EpochCell<ShardSet>,
    /// Service-wide admission counter: every solve and control job is
    /// stamped from it at submission, and forwarding preserves the
    /// stamp — so barrier ordering (refactor/retire/migrate vs solves)
    /// reflects true admission order even across a shard hop.
    seq: AtomicU64,
    registers: AtomicU64,
    retires: AtomicU64,
    moves: AtomicU64,
}

impl ServiceShared {
    fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    /// The queue of shard `s` in the current shard-set epoch, if it is
    /// still (or already) live.
    pub(crate) fn queue(&self, s: usize) -> Option<Arc<ShardQueue>> {
        self.shards.load().queues.get(s).cloned()
    }

    /// Shards in the current epoch.
    fn shard_count(&self) -> usize {
        self.shards.load().queues.len()
    }
}

/// The copyable slice of [`ServiceConfig`] needed to spin up one more
/// dispatcher after construction ([`SolverService::grow`]).
#[derive(Clone, Copy)]
struct WorkerSpec {
    tick: Duration,
    tick_max: Duration,
    max_batch: usize,
    queue_cap: usize,
    starvation_bound: usize,
    policy: ShardPolicy,
}

/// The sharded, coalescing, elastic solve service. See the module docs.
pub struct SolverService {
    shared: Arc<ServiceShared>,
    /// Serializes topology operations (register / retire / migrate /
    /// rebalance / grow / shrink) and owns the next system id. Request
    /// routing never takes this lock.
    topology: Mutex<u64>,
    /// Dispatcher join handles, indexed by shard id; `shrink` joins and
    /// truncates the tail, `grow` appends. Behind a mutex so the elastic
    /// entry points work on `&self` like every other topology operation.
    threads: Mutex<Vec<Option<JoinHandle<()>>>>,
    /// Final counters of queues retired by `shrink`, folded into
    /// [`SolverService::stats`] so a shard's history survives its
    /// teardown.
    retired_stats: Mutex<ServiceStats>,
    /// Bulk-lane shedding threshold (`ServiceConfig::shed_depth`).
    shed_depth: usize,
    /// Everything needed to spin up dispatchers for grown shards.
    worker: WorkerSpec,
}

impl SolverService {
    /// Build an **empty** elastic service: `cfg.shards` dispatcher
    /// threads and no systems. Admit systems with
    /// [`SolverService::register`].
    pub fn with_shards(cfg: ServiceConfig) -> Result<SolverService> {
        let nshards = cfg.shards.max(1);
        let queues: Vec<Arc<ShardQueue>> = (0..nshards)
            .map(|_| Arc::new(ShardQueue::new(cfg.queue_cap.max(1))))
            .collect();
        let shared = Arc::new(ServiceShared {
            routes: RouteCell::new(),
            shards: EpochCell::with_value(ShardSet {
                queues: queues.clone(),
            }),
            seq: AtomicU64::new(0),
            registers: AtomicU64::new(0),
            retires: AtomicU64::new(0),
            moves: AtomicU64::new(0),
        });
        let worker = WorkerSpec {
            tick: cfg.tick,
            tick_max: cfg.tick_max,
            max_batch: cfg.max_batch.max(1),
            queue_cap: cfg.queue_cap.max(1),
            starvation_bound: cfg.starvation_bound,
            policy: ShardPolicy {
                expire_deadlines: cfg.expire_deadlines,
                dispatch_margin: cfg.dispatch_margin,
                pivot_growth_limit: cfg.pivot_growth_limit,
                recover_alpha: cfg.recover_alpha.clamp(0.0, 1.0),
                recover_gate: cfg.recover_gate,
            },
        };
        let mut threads = Vec::with_capacity(nshards);
        for (s, q) in queues.iter().enumerate() {
            match Self::spawn_dispatcher(&shared, s, q.clone(), worker) {
                Ok(h) => threads.push(Some(h)),
                Err(e) => {
                    // unwind cleanly: stop the dispatchers spawned so far
                    for q in &queues {
                        q.shutdown();
                    }
                    for h in threads.iter_mut().filter_map(Option::take) {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(SolverService {
            shared,
            topology: Mutex::new(0),
            threads: Mutex::new(threads),
            retired_stats: Mutex::new(ServiceStats::default()),
            shed_depth: cfg.shed_depth,
            worker,
        })
    }

    fn spawn_dispatcher(
        shared: &Arc<ServiceShared>,
        s: usize,
        queue: Arc<ShardQueue>,
        spec: WorkerSpec,
    ) -> Result<JoinHandle<()>> {
        let worker = ShardWorker::new(
            s,
            queue,
            shared.clone(),
            AdaptiveTick::new(spec.tick, spec.tick_max),
            spec.max_batch,
            spec.starvation_bound,
            spec.policy,
        );
        std::thread::Builder::new()
            .name(format!("hylu-serve-{s}"))
            .spawn(move || worker.run())
            .map_err(|e| Error::Runtime(format!("spawn shard dispatcher: {e}")))
    }

    /// Build the service pre-loaded with `systems`: analyze + factor
    /// every matrix on its shard's solver (round-robin assignment, one
    /// engine per shard), then register them. System ids are assigned in
    /// order: `SystemId(i)` for `systems[i]`.
    ///
    /// For an initially-empty elastic service use
    /// [`SolverService::with_shards`].
    pub fn new(cfg: ServiceConfig, systems: Vec<Csr>) -> Result<SolverService> {
        if systems.is_empty() {
            return Err(Error::Invalid(
                "service needs at least one system (use with_shards for an empty elastic service)"
                    .into(),
            ));
        }
        let mut solver_cfg = cfg.solver.clone();
        // the service-level chaos plan reaches systems the service
        // itself builds; an explicit solver-level plan wins
        if solver_cfg.fault.is_none() {
            solver_cfg.fault = cfg.fault.clone();
        }
        let svc = SolverService::with_shards(cfg)?;
        let nshards = svc.shard_count();
        // one handle-producing solver (engine) per shard actually used;
        // the solver values are dropped after construction — every
        // LinearSystem keeps its shared engine alive
        let nsolvers = nshards.min(systems.len());
        let solvers = (0..nsolvers)
            .map(|_| Solver::from_config(solver_cfg.clone()))
            .collect::<Result<Vec<_>>>()?;
        for (i, a) in systems.into_iter().enumerate() {
            let shard = i % nshards;
            let sys = solvers[shard % nsolvers].analyze(a)?.factor()?;
            svc.register_on(sys, shard)?;
        }
        Ok(svc)
    }

    /// Admit a factored system on the live service, placing it on the
    /// least-loaded shard (by EWMA load, then resident count). Returns
    /// the id all requests for this system use. The handle's engine
    /// travels with it — systems registered from different solvers keep
    /// their own pools.
    pub fn register(&self, sys: LinearSystem<Factored>) -> Result<SystemId> {
        let shard = self.least_loaded_shard();
        self.register_on(sys, shard)
    }

    /// [`SolverService::register`] onto an explicit shard.
    pub fn register_on(&self, sys: LinearSystem<Factored>, shard: usize) -> Result<SystemId> {
        // range-check under the topology lock: grow/shrink serialize on
        // it, so the target shard cannot disappear before the install
        let mut next_id = lock_ignore_poison(&self.topology);
        let Some(queue) = self.shared.queue(shard) else {
            return Err(Error::Invalid(format!(
                "shard {shard} out of range ({} shards)",
                self.shared.shard_count()
            )));
        };
        let id = *next_id;
        let n = sys.n();
        let stats = Arc::new(SystemStats::default());
        let system = Box::new(ShardSystem {
            sys,
            stats: stats.clone(),
            gate: RecoveryGate::default(),
        });
        // install BEFORE publishing the route: any request admitted
        // after the publication lands behind the install in the same
        // FIFO queue, so it can never observe a routed-but-absent system.
        // (push_control only fails after shutdown, which requires the
        // Drop's `&mut self` — unreachable while this `&self` exists, so
        // the handle inside the Install cannot actually be lost here.)
        let seq = self.shared.next_seq();
        if queue
            .push_control(Control::Install { id, system }, seq, true)
            .is_err()
        {
            return Err(Error::Runtime("service is shutting down".into()));
        }
        *next_id += 1;
        self.shared
            .routes
            .publish(|t| t.with(id, RouteEntry { shard, n, stats }));
        self.shared.registers.fetch_add(1, Ordering::Relaxed);
        Ok(SystemId(id))
    }

    /// Remove a system from the live service and hand its owning handle
    /// back. In-flight tickets admitted before the retirement drain
    /// first (the extract is a queue barrier); requests admitted after
    /// it fail fast with an `Invalid` error.
    pub fn retire(&self, id: SystemId) -> Result<LinearSystem<Factored>> {
        let _topology = lock_ignore_poison(&self.topology);
        let shard = {
            let t = self.shared.routes.load();
            t.map.get(&id.0).map(|e| e.shard)
        };
        let Some(shard) = shard else {
            return Err(Error::Invalid(format!("unknown system id {id}")));
        };
        // unpublish first: new submits fail fast instead of queueing
        // behind a teardown
        self.shared.routes.publish(|t| t.without(id.0));
        let (tx, rx) = mpsc::channel();
        let seq = self.shared.next_seq();
        let queue = self
            .shared
            .queue(shard)
            .ok_or_else(|| Error::Runtime(format!("system {id} routed to a retired shard")))?;
        if queue
            .push_control(Control::Extract { id: id.0, tx }, seq, true)
            .is_err()
        {
            return Err(Error::Runtime("service is shutting down".into()));
        }
        match rx.recv() {
            Ok(Some(system)) => {
                self.shared.retires.fetch_add(1, Ordering::Relaxed);
                Ok(system.sys)
            }
            Ok(None) | Err(_) => Err(Error::Runtime(format!(
                "system {id} vanished during retire"
            ))),
        }
    }

    /// Move one system to an explicit shard (the targeted form of
    /// [`SolverService::rebalance`]); a no-op if it is already there.
    /// Traffic keeps flowing during the move: requests racing the
    /// transition are forwarded or parked by the dispatchers, and the
    /// factor state is untouched — results are bit-identical across the
    /// move.
    pub fn migrate(&self, id: SystemId, shard: usize) -> Result<()> {
        let _topology = lock_ignore_poison(&self.topology);
        self.migrate_locked(id, shard)
    }

    fn migrate_locked(&self, id: SystemId, to: usize) -> Result<()> {
        let Some(dest_queue) = self.shared.queue(to) else {
            return Err(Error::Invalid(format!(
                "shard {to} out of range ({} shards)",
                self.shared.shard_count()
            )));
        };
        let entry = {
            let t = self.shared.routes.load();
            t.map.get(&id.0).cloned()
        };
        let Some(entry) = entry else {
            return Err(Error::Invalid(format!("unknown system id {id}")));
        };
        if entry.shard == to {
            return Ok(());
        }
        // 1. publish the new placement: new submits queue on the
        //    destination and park there until the value arrives
        let moved = RouteEntry {
            shard: to,
            n: entry.n,
            stats: entry.stats.clone(),
        };
        self.shared.routes.publish(|t| t.with(id.0, moved));
        // 2. extract from the source — queued solves admitted before
        //    this point drain there first (barrier)
        let (tx, rx) = mpsc::channel();
        let seq = self.shared.next_seq();
        let src_queue = self
            .shared
            .queue(entry.shard)
            .ok_or_else(|| Error::Runtime(format!("system {id} routed to a retired shard")))?;
        if src_queue
            .push_control(Control::Extract { id: id.0, tx }, seq, true)
            .is_err()
        {
            return Err(Error::Runtime("service is shutting down".into()));
        }
        let system = match rx.recv() {
            Ok(Some(s)) => s,
            Ok(None) | Err(_) => {
                return Err(Error::Runtime(format!("system {id} vanished during move")))
            }
        };
        // 3. install on the destination: its parked requests flush in
        //    admission order right after. (As in register_on, this push
        //    cannot fail while `&self` exists — shutdown requires Drop's
        //    `&mut self`, and a shrink of the destination requires the
        //    topology lock this move holds — so the extracted handle
        //    cannot be lost here.)
        let seq = self.shared.next_seq();
        if dest_queue
            .push_control(Control::Install { id: id.0, system }, seq, true)
            .is_err()
        {
            return Err(Error::Runtime("service is shutting down".into()));
        }
        self.shared.moves.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Rebalance load across shards: greedily move the hottest system
    /// (by EWMA load) off the most-loaded shard onto the least-loaded
    /// one, while each move strictly reduces the load spread. Returns
    /// the number of systems moved. Safe to call under traffic.
    pub fn rebalance(&self) -> Result<usize> {
        let _topology = lock_ignore_poison(&self.topology);
        let nshards = self.shared.shard_count();
        let mut moved = 0usize;
        if nshards < 2 {
            return Ok(0);
        }
        let max_moves = self.shared.routes.load().map.len();
        for _ in 0..max_moves {
            let plan = {
                let t = self.shared.routes.load();
                let mut load = vec![0.0f64; nshards];
                let mut hottest: Vec<Option<(u64, f64)>> = vec![None; nshards];
                // deterministic scan order (ids ascending)
                let mut entries: Vec<(&u64, &RouteEntry)> = t.map.iter().collect();
                entries.sort_by_key(|(id, _)| **id);
                for (id, e) in entries {
                    let l = e.stats.ewma_load();
                    load[e.shard] += l;
                    let hotter = match hottest[e.shard] {
                        Some((_, h)) => l > h,
                        None => true,
                    };
                    if hotter {
                        hottest[e.shard] = Some((*id, l));
                    }
                }
                let (mut hi, mut lo) = (0usize, 0usize);
                for s in 1..nshards {
                    if load[s] > load[hi] {
                        hi = s;
                    }
                    if load[s] < load[lo] {
                        lo = s;
                    }
                }
                match hottest[hi] {
                    // moving l from hi to lo strictly shrinks the spread
                    // iff l < load[hi] - load[lo]
                    Some((id, l)) if hi != lo && l > 0.0 && l < load[hi] - load[lo] => {
                        Some((id, lo))
                    }
                    _ => None,
                }
            };
            let Some((id, to)) = plan else { break };
            self.migrate_locked(SystemId(id), to)?;
            moved += 1;
        }
        Ok(moved)
    }

    /// Least-loaded shard by (EWMA load sum, resident count, index).
    fn least_loaded_shard(&self) -> usize {
        let nshards = self.shared.shard_count();
        let mut load = vec![(0.0f64, 0usize); nshards];
        {
            let t = self.shared.routes.load();
            for e in t.map.values() {
                load[e.shard].0 += e.stats.ewma_load();
                load[e.shard].1 += 1;
            }
        }
        let mut best = 0usize;
        for s in 1..nshards {
            if (load[s].0, load[s].1) < (load[best].0, load[best].1) {
                best = s;
            }
        }
        best
    }

    /// Enqueue one right-hand side for `id` on the bulk lane; returns a
    /// [`Ticket`] to wait on. Blocks only when the shard queue is at
    /// capacity (backpressure).
    pub fn submit(&self, id: SystemId, b: Vec<f64>) -> Result<Ticket> {
        self.submit_with(id, b, Priority::Bulk)
    }

    /// [`SolverService::submit`] with an explicit [`Priority`] lane.
    pub fn submit_with(&self, id: SystemId, b: Vec<f64>, prio: Priority) -> Result<Ticket> {
        self.submit_with_opts(id, b, prio, SolveOpts::default())
    }

    /// [`SolverService::submit_with`] plus per-call refinement overrides
    /// ([`SolveOpts`]). The dispatcher coalesces only requests carrying
    /// *equal* opts into one block dispatch, so an override never bleeds
    /// into a neighboring caller's solve; default opts resolve to the
    /// solver's configured refinement policy, bit-identical to the
    /// plain [`SolverService::submit`] path.
    pub fn submit_with_opts(
        &self,
        id: SystemId,
        b: Vec<f64>,
        prio: Priority,
        opts: SolveOpts,
    ) -> Result<Ticket> {
        let (mut shard, n, stats) = {
            let t = self.shared.routes.load();
            let e = t
                .map
                .get(&id.0)
                .ok_or_else(|| Error::Invalid(format!("unknown system id {id}")))?;
            (e.shard, e.n, e.stats.clone())
        };
        if b.len() != n {
            return Err(Error::Invalid("rhs length mismatch".into()));
        }
        let (tx, rx) = mpsc::channel();
        let seq = self.shared.next_seq();
        let mut job = SolveJob {
            id: id.0,
            b,
            opts,
            tx,
        };
        loop {
            let Some(queue) = self.shared.queue(shard) else {
                // routed to a shard the current epoch no longer has: a
                // shrink truncated it between our route read and now.
                // Routes move off a draining shard *before* the set
                // truncates, so a fresh route read lands on the new home.
                shard = self.resolve_shard(id)?;
                continue;
            };
            // load shedding: bulk traffic is rejected fast while the
            // target shard is saturated, so deadline work keeps its queue
            // headroom; deadline submissions are never shed (they ride
            // backpressure)
            if self.shed_depth > 0
                && matches!(prio, Priority::Bulk)
                && queue.depth() >= self.shed_depth
            {
                queue.shed.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Runtime(format!(
                    "shedding bulk load: shard {shard} queue depth >= {}",
                    self.shed_depth
                )));
            }
            match queue.push_solve(job, prio, seq, false) {
                Ok(()) => {
                    stats.note_request();
                    return Ok(Ticket { rx });
                }
                Err(j) => {
                    // the queue shut down under us: either a shrink
                    // drained this shard (the placement moved — chase it)
                    // or the whole service is going down (it didn't)
                    let now = self.resolve_shard(id)?;
                    if now == shard {
                        return Err(Error::Runtime("service is shutting down".into()));
                    }
                    shard = now;
                    job = j;
                }
            }
        }
    }

    /// Current placement of `id` from a fresh routing-table read.
    fn resolve_shard(&self, id: SystemId) -> Result<usize> {
        self.shared
            .routes
            .load()
            .map
            .get(&id.0)
            .map(|e| e.shard)
            .ok_or_else(|| Error::Invalid(format!("unknown system id {id}")))
    }

    /// Submit and wait: the blocking convenience wrapper (bulk lane).
    pub fn solve(&self, id: SystemId, b: Vec<f64>) -> Result<Vec<f64>> {
        self.submit(id, b)?.wait()
    }

    /// Submit on an explicit lane and wait.
    pub fn solve_with(&self, id: SystemId, b: Vec<f64>, prio: Priority) -> Result<Vec<f64>> {
        self.submit_with(id, b, prio)?.wait()
    }

    /// Submit with per-call refinement overrides and wait.
    pub fn solve_with_opts(
        &self,
        id: SystemId,
        b: Vec<f64>,
        prio: Priority,
        opts: SolveOpts,
    ) -> Result<Vec<f64>> {
        self.submit_with_opts(id, b, prio, opts)?.wait()
    }

    /// Replace system `id`'s values with a same-pattern matrix and
    /// refactorize on its shard (parameter-sweep step). Blocks until the
    /// refactorization is applied; solves submitted afterwards observe
    /// the new values, solves admitted before it are flushed first
    /// (admission order is service-wide and survives forwarding).
    ///
    /// One caveat under live topology changes: a solve whose ticket is
    /// still unresolved when a *concurrent* migration is moving this
    /// system may be re-queued behind the refactor and observe the new
    /// values — a legal ordering of the two overlapping operations. A
    /// caller that waits for each ticket before refactoring (the usual
    /// sweep loop) always sees strict program order.
    pub fn refactor(&self, id: SystemId, a: Csr) -> Result<()> {
        let (shard, n) = {
            let t = self.shared.routes.load();
            let e = t
                .map
                .get(&id.0)
                .ok_or_else(|| Error::Invalid(format!("unknown system id {id}")))?;
            (e.shard, e.n)
        };
        if a.n != n {
            return Err(Error::Invalid("refactor dimension mismatch".into()));
        }
        let (tx, rx) = mpsc::channel();
        let seq = self.shared.next_seq();
        self.push_control_routed(id, shard, Control::Refactor { id: id.0, a, tx }, seq)?;
        match rx.recv() {
            Ok(r) => r.map(|_| ()),
            Err(_) => Err(Error::Runtime("service dropped the refactor".into())),
        }
    }

    /// Push a control job at `id`'s shard, chasing the placement across
    /// a concurrent shrink exactly like [`SolverService::submit_with_opts`]
    /// does for solves. (The dispatcher forwards controls that arrive on
    /// a stale shard; this loop only handles the push itself racing a
    /// queue teardown.)
    fn push_control_routed(
        &self,
        id: SystemId,
        mut shard: usize,
        mut ctrl: Control,
        seq: u64,
    ) -> Result<()> {
        loop {
            let Some(queue) = self.shared.queue(shard) else {
                shard = self.resolve_shard(id)?;
                continue;
            };
            match queue.push_control(ctrl, seq, false) {
                Ok(()) => return Ok(()),
                Err(c) => {
                    let now = self.resolve_shard(id)?;
                    if now == shard {
                        return Err(Error::Runtime("service is shutting down".into()));
                    }
                    shard = now;
                    ctrl = c;
                }
            }
        }
    }

    /// Replace system `id`'s matrix with a same-dimension matrix whose
    /// **pattern** may differ, re-analyzing through the warm incremental
    /// path (engine, arenas, ordering seeds, and — when the pattern is
    /// unchanged — the tuned kernel plan are all reused) and
    /// refactorizing on its shard, live, without retiring the system.
    /// The same barrier contract as [`SolverService::refactor`] applies:
    /// solves admitted before the re-analysis are flushed against the
    /// old factors, solves submitted after it returns observe the new
    /// matrix. The dimension must match the registered one — routing
    /// carries `n` per system, so a size change requires
    /// retire + register.
    pub fn reanalyze(&self, id: SystemId, a: Csr) -> Result<()> {
        let (shard, n) = {
            let t = self.shared.routes.load();
            let e = t
                .map
                .get(&id.0)
                .ok_or_else(|| Error::Invalid(format!("unknown system id {id}")))?;
            (e.shard, e.n)
        };
        if a.n != n {
            return Err(Error::Invalid("reanalyze dimension mismatch".into()));
        }
        let (tx, rx) = mpsc::channel();
        let seq = self.shared.next_seq();
        self.push_control_routed(id, shard, Control::Reanalyze { id: id.0, a, tx }, seq)?;
        match rx.recv() {
            Ok(r) => r.map(|_| ()),
            Err(_) => Err(Error::Runtime("service dropped the reanalyze".into())),
        }
    }

    /// Grow the shard set by `k` dispatcher threads on the live service.
    /// New shards start empty; follow with [`SolverService::rebalance`]
    /// (or a targeted [`SolverService::migrate`]) to move load onto
    /// them. Returns the new shard count.
    ///
    /// Ordering: each dispatcher thread is spawned *before* its queue is
    /// published into the shard set, so a route can never target a shard
    /// without a running dispatcher — a failed spawn leaves the set
    /// exactly as large as the shards actually running.
    pub fn grow(&self, k: usize) -> Result<usize> {
        let _topology = lock_ignore_poison(&self.topology);
        let mut threads = lock_ignore_poison(&self.threads);
        for _ in 0..k {
            let s = self.shared.shard_count();
            let queue = Arc::new(ShardQueue::new(self.worker.queue_cap));
            let handle = Self::spawn_dispatcher(&self.shared, s, queue.clone(), self.worker)?;
            threads.push(Some(handle));
            self.shared.shards.publish(|set| set.extended(queue.clone()));
        }
        Ok(self.shared.shard_count())
    }

    /// Shrink the shard set by `k` dispatcher threads, draining from the
    /// tail, on the live service. Systems resident on the draining
    /// shards are first migrated onto the least-loaded surviving shards
    /// (EWMA-guided, heaviest first), then the truncated set is
    /// published, the drained queues are shut down — each dispatcher
    /// finishes its whole backlog, forwarding anything the current epoch
    /// routes elsewhere — and the dispatcher threads are joined. No
    /// accepted ticket is lost or resolved twice. The drained shards'
    /// counters are folded into [`SolverService::stats`]. Returns the
    /// new shard count; fails if `k` would leave no shard.
    pub fn shrink(&self, k: usize) -> Result<usize> {
        let _topology = lock_ignore_poison(&self.topology);
        let n = self.shared.shard_count();
        if k == 0 {
            return Ok(n);
        }
        if k >= n {
            return Err(Error::Invalid(format!(
                "cannot shrink {k} of {n} shards: at least one must remain"
            )));
        }
        let keep = n - k;
        // 1. move every resident system off the draining tail while the
        //    whole set is still published (forwarding stays valid)
        self.drain_systems_off(keep)?;
        // 2. publish the truncated set: new submits can no longer target
        //    the tail. A submit that raced here against an old epoch
        //    either lands before the shutdown below (drained normally) or
        //    fails its push and re-resolves against the new epoch.
        let drained: Vec<Arc<ShardQueue>> = self.shared.shards.load().queues[keep..].to_vec();
        self.shared.shards.publish(|set| set.truncated(keep));
        // 3. drain and join: the dispatchers resolve or forward
        //    everything still queued, then exit
        for q in &drained {
            q.shutdown();
        }
        let mut threads = lock_ignore_poison(&self.threads);
        let tail: Vec<Option<JoinHandle<()>>> = threads.drain(keep..).collect();
        drop(threads);
        for h in tail.into_iter().flatten() {
            let _ = h.join();
        }
        let mut retired = lock_ignore_poison(&self.retired_stats);
        for q in &drained {
            q.add_stats_into(&mut retired);
        }
        Ok(keep)
    }

    /// Migrate every system resident on shards `keep..` onto the
    /// least-loaded surviving shards — heaviest EWMA load placed first,
    /// with a running per-shard tally so one hot draining shard doesn't
    /// dump its whole population onto a single survivor.
    fn drain_systems_off(&self, keep: usize) -> Result<()> {
        let mut evacuees: Vec<(u64, f64)> = Vec::new();
        let mut load = vec![(0.0f64, 0usize); keep];
        {
            let t = self.shared.routes.load();
            for (id, e) in t.map.iter() {
                if e.shard >= keep {
                    evacuees.push((*id, e.stats.ewma_load()));
                } else {
                    load[e.shard].0 += e.stats.ewma_load();
                    load[e.shard].1 += 1;
                }
            }
        }
        evacuees.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        for (id, l) in evacuees {
            let mut best = 0usize;
            for s in 1..keep {
                if (load[s].0, load[s].1) < (load[best].0, load[best].1) {
                    best = s;
                }
            }
            self.migrate_locked(SystemId(id), best)?;
            load[best].0 += l;
            load[best].1 += 1;
        }
        Ok(())
    }

    /// Number of shards running.
    pub fn shard_count(&self) -> usize {
        self.shared.shard_count()
    }

    /// Number of currently registered systems.
    pub fn system_count(&self) -> usize {
        self.shared.routes.load().map.len()
    }

    /// Ids of all currently registered systems, ascending.
    pub fn system_ids(&self) -> Vec<SystemId> {
        let mut ids: Vec<SystemId> = self
            .shared
            .routes
            .load()
            .map
            .keys()
            .map(|&id| SystemId(id))
            .collect();
        ids.sort();
        ids
    }

    /// Shard currently owning `id`, if registered.
    pub fn shard_of(&self, id: SystemId) -> Option<usize> {
        self.shared.routes.load().map.get(&id.0).map(|e| e.shard)
    }

    /// Dimension of system `id`, if registered.
    pub fn system_dim(&self, id: SystemId) -> Option<usize> {
        self.shared.routes.load().map.get(&id.0).map(|e| e.n)
    }

    /// Serving health of system `id`, if registered: `Healthy`, or
    /// `Quarantined(reason)` while it fails fast awaiting the escalated
    /// recovery factorization. Lock-free (one routing-table read).
    pub fn health(&self, id: SystemId) -> Option<Health> {
        self.shared
            .routes
            .load()
            .map
            .get(&id.0)
            .map(|e| e.stats.health())
    }

    /// Placement and load snapshot for one system, if registered.
    pub fn system_load(&self, id: SystemId) -> Option<SystemLoad> {
        self.shared.routes.load().map.get(&id.0).map(|e| SystemLoad {
            shard: e.shard,
            requests: e.stats.requests(),
            rhs_solved: e.stats.rhs_solved(),
            ewma: e.stats.ewma_load(),
        })
    }

    /// Routing epochs published so far (1 = the initial empty table);
    /// each topology change publishes one. Observability for the
    /// publication protocol.
    pub fn route_epoch(&self) -> usize {
        self.shared.routes.epoch()
    }

    /// Shard-set epochs published so far (1 = the initial set): `grow`
    /// publishes one per shard added, `shrink` one per call.
    /// Observability for the elasticity protocol.
    pub fn shard_epoch(&self) -> usize {
        self.shared.shards.epoch()
    }

    /// Aggregate serving statistics across shards, including the final
    /// counters of shards already drained by [`SolverService::shrink`].
    pub fn stats(&self) -> ServiceStats {
        let mut total = *lock_ignore_poison(&self.retired_stats);
        for q in &self.shared.shards.load().queues {
            q.add_stats_into(&mut total);
        }
        total.registers = self.shared.registers.load(Ordering::Relaxed);
        total.retires = self.shared.retires.load(Ordering::Relaxed);
        total.moves = self.shared.moves.load(Ordering::Relaxed);
        total
    }
}

impl Drop for SolverService {
    /// Graceful shutdown: dispatchers drain everything already queued
    /// (resolving those tickets), then exit and are joined.
    fn drop(&mut self) {
        for q in &self.shared.shards.load().queues {
            q.shutdown();
        }
        let mut threads = lock_ignore_poison(&self.threads);
        for t in threads.iter_mut() {
            if let Some(h) = t.take() {
                let _ = h.join();
            }
        }
    }
}

//! Shard internals: the bounded coalescing queue and the dispatcher
//! loop that turns queued single-RHS requests into batched
//! `solve_many_into` block dispatches.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::api::{Factored, LinearSystem};
use crate::exec::{lock_ignore_poison, wait_ignore_poison};
use crate::sparse::csr::Csr;
use crate::{Error, Result};

/// Per-request reply channel (refactor acks send an empty vector,
/// hidden behind the typed wrappers in `service::SolverService`).
pub(crate) type Reply = Sender<Result<Vec<f64>>>;

/// Pending solves for one system within a drained tick.
type SolveGroup = Vec<(Vec<f64>, Reply)>;

pub(crate) enum Job {
    Solve { sys: usize, b: Vec<f64>, tx: Reply },
    Refactor { sys: usize, a: Csr, tx: Reply },
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Bounded MPSC job queue with condvar wakeups on both ends: the
/// dispatcher parks on `nonempty`, submitters at capacity park on
/// `space`. Coalescing statistics live here so the service can
/// aggregate them without touching the dispatcher thread.
pub(crate) struct ShardQueue {
    q: Mutex<QueueState>,
    nonempty: Condvar,
    space: Condvar,
    cap: usize,
    requests: AtomicU64,
    dispatches: AtomicU64,
    rhs_solved: AtomicU64,
    refactors: AtomicU64,
    max_batch: AtomicUsize,
}

impl ShardQueue {
    pub fn new(cap: usize) -> ShardQueue {
        ShardQueue {
            q: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            nonempty: Condvar::new(),
            space: Condvar::new(),
            cap,
            requests: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            rhs_solved: AtomicU64::new(0),
            refactors: AtomicU64::new(0),
            max_batch: AtomicUsize::new(0),
        }
    }

    /// Enqueue a job, blocking while the queue is at capacity; errors
    /// once shutdown has begun.
    pub fn push(&self, job: Job) -> Result<()> {
        let mut st = lock_ignore_poison(&self.q);
        loop {
            if st.shutdown {
                return Err(Error::Runtime("service is shutting down".into()));
            }
            if st.jobs.len() < self.cap {
                break;
            }
            st = wait_ignore_poison(self.space.wait(st));
        }
        if matches!(job, Job::Solve { .. }) {
            self.requests.fetch_add(1, Ordering::Relaxed);
        }
        st.jobs.push_back(job);
        self.nonempty.notify_one();
        Ok(())
    }

    pub fn shutdown(&self) {
        let mut st = lock_ignore_poison(&self.q);
        st.shutdown = true;
        self.nonempty.notify_all();
        self.space.notify_all();
    }

    pub fn add_stats_into(&self, out: &mut ServiceStats) {
        out.requests += self.requests.load(Ordering::Relaxed);
        out.dispatches += self.dispatches.load(Ordering::Relaxed);
        out.rhs_solved += self.rhs_solved.load(Ordering::Relaxed);
        out.refactors += self.refactors.load(Ordering::Relaxed);
        out.max_batch = out.max_batch.max(self.max_batch.load(Ordering::Relaxed));
    }
}

/// Aggregate coalescing statistics for a [`super::SolverService`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Solve requests accepted.
    pub requests: u64,
    /// Batched block dispatches issued.
    pub dispatches: u64,
    /// Right-hand sides solved across all dispatches.
    pub rhs_solved: u64,
    /// Refactorizations applied.
    pub refactors: u64,
    /// Widest single batch dispatched.
    pub max_batch: usize,
}

impl ServiceStats {
    /// Mean right-hand sides per block dispatch (the coalescing factor).
    pub fn mean_batch(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.rhs_solved as f64 / self.dispatches as f64
        }
    }
}

/// The dispatcher state moved onto the shard thread. Each registered
/// system is an owning [`LinearSystem<Factored>`] handle — matrix,
/// analysis and factorization travel as one value, and all handles on a
/// shard share that shard's solver engine (`Arc` internally).
pub(crate) struct ShardWorker {
    systems: Vec<LinearSystem<Factored>>,
    queue: Arc<ShardQueue>,
    tick: Duration,
    max_batch: usize,
}

impl ShardWorker {
    pub fn new(
        systems: Vec<LinearSystem<Factored>>,
        queue: Arc<ShardQueue>,
        tick: Duration,
        max_batch: usize,
    ) -> ShardWorker {
        ShardWorker {
            systems,
            queue,
            tick,
            max_batch,
        }
    }

    /// Dispatcher loop: park until work arrives, optionally sleep one
    /// coalescing tick, drain everything queued, process it as batched
    /// block dispatches. On shutdown the queue is drained to empty
    /// before exiting, so every accepted ticket resolves.
    pub fn run(mut self) {
        let mut xs: Vec<Vec<f64>> = Vec::new();
        loop {
            let drained = {
                let mut st = lock_ignore_poison(&self.queue.q);
                while st.jobs.is_empty() && !st.shutdown {
                    st = wait_ignore_poison(self.queue.nonempty.wait(st));
                }
                if st.jobs.is_empty() {
                    return; // shutdown with nothing left to do
                }
                // coalescing window — skipped when the batch is already
                // full (sleeping could not widen it) or shutdown has
                // begun (drain as fast as possible)
                if !self.tick.is_zero() && !st.shutdown && st.jobs.len() < self.max_batch {
                    drop(st);
                    std::thread::sleep(self.tick);
                    st = lock_ignore_poison(&self.queue.q);
                }
                let drained: Vec<Job> = st.jobs.drain(..).collect();
                self.queue.space.notify_all();
                drained
            };
            self.process(drained, &mut xs);
        }
    }

    fn process(&mut self, jobs: Vec<Job>, xs: &mut Vec<Vec<f64>>) {
        let nsys = self.systems.len();
        let mut groups: Vec<SolveGroup> = (0..nsys).map(|_| Vec::new()).collect();
        for job in jobs {
            match job {
                Job::Solve { sys, b, tx } => groups[sys].push((b, tx)),
                Job::Refactor { sys, a, tx } => {
                    // flush queued solves first: a request submitted
                    // before this refactor must not observe new values
                    self.flush(&mut groups, xs);
                    let r = self.apply_refactor(sys, a);
                    self.queue.refactors.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(r.map(|_| Vec::new()));
                }
            }
        }
        self.flush(&mut groups, xs);
    }

    fn apply_refactor(&mut self, sys: usize, a: Csr) -> Result<()> {
        self.systems[sys].refactor_matrix(a)
    }

    /// Solve every queued group as block dispatches of at most
    /// `max_batch` columns, replying through the per-request channels.
    /// Disconnected receivers (abandoned tickets) are ignored.
    fn flush(&self, groups: &mut [SolveGroup], xs: &mut Vec<Vec<f64>>) {
        for (sys, group) in groups.iter_mut().enumerate() {
            while !group.is_empty() {
                let take = group.len().min(self.max_batch);
                let mut bs = Vec::with_capacity(take);
                let mut txs = Vec::with_capacity(take);
                for (b, tx) in group.drain(..take) {
                    bs.push(b);
                    txs.push(tx);
                }
                match self.systems[sys].solve_many_into(&bs, xs) {
                    Ok(_) => {
                        self.queue.dispatches.fetch_add(1, Ordering::Relaxed);
                        self.queue
                            .rhs_solved
                            .fetch_add(bs.len() as u64, Ordering::Relaxed);
                        self.queue.max_batch.fetch_max(bs.len(), Ordering::Relaxed);
                        for (q, tx) in txs.into_iter().enumerate() {
                            let _ = tx.send(Ok(std::mem::take(&mut xs[q])));
                        }
                    }
                    Err(e) => {
                        for tx in txs {
                            let _ = tx.send(Err(e.clone()));
                        }
                    }
                }
            }
        }
    }
}

//! Shard internals: the bounded two-lane coalescing queue and the
//! dispatcher loop that turns queued single-RHS requests into batched
//! `solve_many_into` block dispatches.
//!
//! The elastic pieces live here too:
//!
//! - **Barrier ordering.** Every queued item carries an admission
//!   sequence number. Control jobs (refactor, install, extract) are
//!   barriers: solves admitted *before* a control are flushed before it
//!   applies, and solves admitted after it never jump it — even though
//!   the two solve lanes themselves re-order (deadline first). A solve
//!   submitted after `refactor` returns therefore always observes the
//!   new values, exactly as in the pre-elastic service.
//! - **Forwarding.** A solve (or refactor) drained by a shard that no
//!   longer owns its system is re-routed against the *current* routing
//!   epoch: forwarded to the owning shard (keeping its priority), or
//!   failed fast when the system is retired. Routing staleness costs
//!   one queue hop, never a lost ticket.
//! - **Parking.** A request that arrives at the shard the routing table
//!   points to *before* the system value itself has landed (its
//!   `Install` is still in the queue — the register/migrate window)
//!   parks locally and is retried, in admission order, after every
//!   control application. Install jobs are pushed before the routing
//!   epoch that points at them is published, so a parked request's
//!   install is always already in the queue — parking is bounded, not
//!   speculative waiting.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::api::{Factored, LinearSystem};
use crate::exec::{lock_ignore_poison, wait_ignore_poison};
use crate::sparse::csr::Csr;
use crate::{Error, Result};

use super::queue::{AdaptiveTick, Drained, LaneQueue, Priority};
use super::route::SystemStats;
use super::ServiceShared;

/// Per-request reply channel (refactor acks send an empty vector,
/// hidden behind the typed wrappers in `service::SolverService`).
pub(crate) type Reply = Sender<Result<Vec<f64>>>;

/// One system living on a shard: the owning typestate handle plus the
/// stats block that travels with it across moves.
pub(crate) struct ShardSystem {
    pub sys: LinearSystem<Factored>,
    pub stats: Arc<SystemStats>,
}

/// One queued solve request.
pub(crate) struct SolveJob {
    pub id: u64,
    pub b: Vec<f64>,
    pub tx: Reply,
}

/// Control jobs: barriers relative to the solve lanes (see module docs).
pub(crate) enum Control {
    /// Same-pattern value update; flushes earlier solves first.
    Refactor { id: u64, a: Csr, tx: Reply },
    /// A system value arriving on this shard (register / migrate).
    Install { id: u64, system: Box<ShardSystem> },
    /// Remove and return a system value (retire / migrate); earlier
    /// solves drain first, so in-flight tickets resolve before teardown.
    Extract {
        id: u64,
        tx: Sender<Option<Box<ShardSystem>>>,
    },
}

struct QueueState {
    solves: LaneQueue<SolveJob>,
    controls: VecDeque<(u64, Control)>,
    shutdown: bool,
}

impl QueueState {
    fn len(&self) -> usize {
        self.solves.len() + self.controls.len()
    }

    fn is_empty(&self) -> bool {
        self.solves.is_empty() && self.controls.is_empty()
    }
}

/// Bounded MPSC job queue with condvar wakeups on both ends: the
/// dispatcher parks on `nonempty`, submitters at capacity park on
/// `space`. Forced pushes (forwarding, topology installs) bypass the
/// capacity check — blocking a dispatcher on another shard's
/// backpressure could deadlock the pair. Coalescing statistics live
/// here so the service can aggregate them without touching the
/// dispatcher thread.
pub(crate) struct ShardQueue {
    q: Mutex<QueueState>,
    nonempty: Condvar,
    space: Condvar,
    cap: usize,
    requests: AtomicU64,
    deadline_requests: AtomicU64,
    dispatches: AtomicU64,
    rhs_solved: AtomicU64,
    refactors: AtomicU64,
    forwarded: AtomicU64,
    refine_iters: AtomicU64,
    precision_fallbacks: AtomicU64,
    max_batch: AtomicUsize,
    max_tick_ns: AtomicU64,
}

impl ShardQueue {
    pub fn new(cap: usize) -> ShardQueue {
        ShardQueue {
            q: Mutex::new(QueueState {
                solves: LaneQueue::new(),
                controls: VecDeque::new(),
                shutdown: false,
            }),
            nonempty: Condvar::new(),
            space: Condvar::new(),
            cap,
            requests: AtomicU64::new(0),
            deadline_requests: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            rhs_solved: AtomicU64::new(0),
            refactors: AtomicU64::new(0),
            forwarded: AtomicU64::new(0),
            refine_iters: AtomicU64::new(0),
            precision_fallbacks: AtomicU64::new(0),
            max_batch: AtomicUsize::new(0),
            max_tick_ns: AtomicU64::new(0),
        }
    }

    /// Enqueue a solve under its service-wide admission seq, blocking
    /// while the queue is at capacity (unless `forced` — the forwarding
    /// path, which also *preserves* the job's original seq so a
    /// forwarded solve keeps its admission order relative to barriers).
    /// Once shutdown has begun the job is handed back so the caller can
    /// resolve its ticket.
    pub fn push_solve(
        &self,
        job: SolveJob,
        prio: Priority,
        seq: u64,
        forced: bool,
    ) -> std::result::Result<(), SolveJob> {
        let mut st = lock_ignore_poison(&self.q);
        loop {
            if st.shutdown {
                return Err(job);
            }
            if forced || st.len() < self.cap {
                break;
            }
            st = wait_ignore_poison(self.space.wait(st));
        }
        if !forced {
            self.requests.fetch_add(1, Ordering::Relaxed);
            if matches!(prio, Priority::Deadline(_)) {
                self.deadline_requests.fetch_add(1, Ordering::Relaxed);
            }
        }
        st.solves.push(seq, prio, job);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Enqueue a control job (barrier) under its service-wide admission
    /// seq. Same capacity/shutdown contract as
    /// [`ShardQueue::push_solve`].
    pub fn push_control(
        &self,
        ctrl: Control,
        seq: u64,
        forced: bool,
    ) -> std::result::Result<(), Control> {
        let mut st = lock_ignore_poison(&self.q);
        loop {
            if st.shutdown {
                return Err(ctrl);
            }
            if forced || st.len() < self.cap {
                break;
            }
            st = wait_ignore_poison(self.space.wait(st));
        }
        st.controls.push_back((seq, ctrl));
        self.nonempty.notify_one();
        Ok(())
    }

    pub fn shutdown(&self) {
        let mut st = lock_ignore_poison(&self.q);
        st.shutdown = true;
        self.nonempty.notify_all();
        self.space.notify_all();
    }

    pub fn add_stats_into(&self, out: &mut ServiceStats) {
        out.requests += self.requests.load(Ordering::Relaxed);
        out.deadline_requests += self.deadline_requests.load(Ordering::Relaxed);
        out.dispatches += self.dispatches.load(Ordering::Relaxed);
        out.rhs_solved += self.rhs_solved.load(Ordering::Relaxed);
        out.refactors += self.refactors.load(Ordering::Relaxed);
        out.forwarded += self.forwarded.load(Ordering::Relaxed);
        out.refine_iters += self.refine_iters.load(Ordering::Relaxed);
        out.precision_fallbacks += self.precision_fallbacks.load(Ordering::Relaxed);
        out.max_batch = out.max_batch.max(self.max_batch.load(Ordering::Relaxed));
        let tick = Duration::from_nanos(self.max_tick_ns.load(Ordering::Relaxed));
        out.max_tick = out.max_tick.max(tick);
    }
}

/// Aggregate serving statistics for a [`super::SolverService`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Solve requests accepted.
    pub requests: u64,
    /// Subset of `requests` submitted on the deadline lane.
    pub deadline_requests: u64,
    /// Batched block dispatches issued.
    pub dispatches: u64,
    /// Right-hand sides solved across all dispatches.
    pub rhs_solved: u64,
    /// Refactorizations applied.
    pub refactors: u64,
    /// Requests re-routed between shards (routing-epoch staleness during
    /// a move; each costs one queue hop).
    pub forwarded: u64,
    /// Iterative-refinement rounds executed across all dispatches.
    pub refine_iters: u64,
    /// Mixed-precision stall fallbacks (f64 recovery refactorizations)
    /// triggered across all dispatches.
    pub precision_fallbacks: u64,
    /// Systems registered over the service lifetime (construction-time
    /// systems included).
    pub registers: u64,
    /// Systems retired.
    pub retires: u64,
    /// Systems moved between shards (`migrate` / `rebalance`).
    pub moves: u64,
    /// Widest single batch dispatched.
    pub max_batch: usize,
    /// Widest adaptive coalescing window any shard actually slept
    /// (zero with a static zero tick).
    pub max_tick: Duration,
}

impl ServiceStats {
    /// Mean right-hand sides per block dispatch (the coalescing factor).
    pub fn mean_batch(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.rhs_solved as f64 / self.dispatches as f64
        }
    }
}

/// A request parked while its system's `Install` is still queued (see
/// the module docs); retried in admission order after every control.
enum ParkedJob {
    Solve(Drained<SolveJob>),
    Refactor { seq: u64, id: u64, a: Csr, tx: Reply },
}

impl ParkedJob {
    fn seq(&self) -> u64 {
        match self {
            ParkedJob::Solve(d) => d.seq,
            ParkedJob::Refactor { seq, .. } => *seq,
        }
    }
}

/// The dispatcher state moved onto the shard thread. Each resident
/// system is an owning [`LinearSystem<Factored>`] handle — matrix,
/// analysis, factorization *and engine* travel as one value, which is
/// what makes cross-shard moves a plain value move.
pub(crate) struct ShardWorker {
    shard: usize,
    systems: HashMap<u64, ShardSystem>,
    queue: Arc<ShardQueue>,
    shared: Arc<ServiceShared>,
    tick: AdaptiveTick,
    max_batch: usize,
    starvation_bound: usize,
    parked: Vec<ParkedJob>,
    /// Per-drain-cycle dispatch counts, folded into each system's EWMA.
    batch_counts: HashMap<u64, u64>,
}

impl ShardWorker {
    pub fn new(
        shard: usize,
        queue: Arc<ShardQueue>,
        shared: Arc<ServiceShared>,
        tick: AdaptiveTick,
        max_batch: usize,
        starvation_bound: usize,
    ) -> ShardWorker {
        ShardWorker {
            shard,
            systems: HashMap::new(),
            queue,
            shared,
            tick,
            max_batch,
            starvation_bound,
            parked: Vec::new(),
            batch_counts: HashMap::new(),
        }
    }

    /// Dispatcher loop: park until work arrives (collapsing the adaptive
    /// window), optionally sleep one coalescing window, drain everything
    /// queued, process it as batched block dispatches. On shutdown the
    /// queue is drained to empty before exiting, so every accepted
    /// ticket resolves.
    pub fn run(mut self) {
        let mut xs: Vec<Vec<f64>> = Vec::new();
        loop {
            let drained = {
                let mut st = lock_ignore_poison(&self.queue.q);
                while st.is_empty() && !st.shutdown {
                    self.tick.on_idle();
                    st = wait_ignore_poison(self.queue.nonempty.wait(st));
                }
                if st.is_empty() {
                    None // shutdown with nothing left to do
                } else {
                    // coalescing window — skipped when the batch is
                    // already full (sleeping could not widen it), when a
                    // control job is waiting (refactor/retire/migrate
                    // callers block on it; sleeping cannot widen a
                    // barrier), or when shutdown has begun
                    let window = self.tick.window();
                    if !window.is_zero()
                        && !st.shutdown
                        && st.controls.is_empty()
                        && st.solves.len() < self.max_batch
                    {
                        drop(st);
                        self.queue
                            .max_tick_ns
                            .fetch_max(window.as_nanos() as u64, Ordering::Relaxed);
                        std::thread::sleep(window);
                        st = lock_ignore_poison(&self.queue.q);
                    }
                    let solves = st.solves.drain_ordered(self.starvation_bound);
                    let controls: Vec<(u64, Control)> = st.controls.drain(..).collect();
                    self.queue.space.notify_all();
                    Some((solves, controls))
                }
            };
            let Some((solves, controls)) = drained else {
                // Shutdown: anything still parked can never be satisfied
                // (no more installs are coming) — fail it loudly rather
                // than dropping the reply channel.
                for p in self.parked.drain(..) {
                    let shutting = || Error::Runtime("service is shutting down".into());
                    match p {
                        ParkedJob::Solve(d) => {
                            let _ = d.item.tx.send(Err(shutting()));
                        }
                        ParkedJob::Refactor { tx, .. } => {
                            let _ = tx.send(Err(shutting()));
                        }
                    }
                }
                return;
            };
            let nsolves = solves.len();
            self.process(solves, controls, &mut xs);
            self.tick.on_drain(nsolves, self.max_batch);
        }
    }

    /// Process one drained tick: flush solves against control barriers
    /// in admission order, then fold per-system dispatch counts into the
    /// EWMA loads that guide `rebalance`.
    fn process(
        &mut self,
        mut solves: Vec<Drained<SolveJob>>,
        controls: Vec<(u64, Control)>,
        xs: &mut Vec<Vec<f64>>,
    ) {
        self.batch_counts.clear();
        for (cseq, ctrl) in controls {
            // flush solves admitted before this barrier (the lanes
            // re-order amongst themselves, so partition by seq — a
            // later-admitted deadline solve must not jump a refactor)
            let mut rest = Vec::with_capacity(solves.len());
            let mut ready = Vec::new();
            for j in solves {
                if j.seq < cseq {
                    ready.push(j);
                } else {
                    rest.push(j);
                }
            }
            solves = rest;
            self.flush_solves(ready, xs);
            self.apply_control(cseq, ctrl);
            // a control may have installed or removed a system: parked
            // requests re-route against the new local/state view
            let parked = std::mem::take(&mut self.parked);
            self.retry_parked(parked, xs);
        }
        self.flush_solves(solves, xs);
        // one EWMA sample per resident system per drain cycle (0 when
        // quiet), so hot systems rank above merely-warm ones
        for (id, s) in &self.systems {
            let sample = self.batch_counts.get(id).copied().unwrap_or(0) as f64;
            s.stats.update_ewma(sample);
        }
    }

    fn apply_control(&mut self, seq: u64, ctrl: Control) {
        match ctrl {
            Control::Refactor { id, a, tx } => self.apply_refactor(seq, id, a, tx),
            Control::Install { id, system } => {
                self.systems.insert(id, *system);
            }
            Control::Extract { id, tx } => {
                let system = self.systems.remove(&id).map(Box::new);
                let _ = tx.send(system);
            }
        }
    }

    /// Apply a refactor locally, or park/forward/fail it by the current
    /// routing epoch when the system is not resident here.
    fn apply_refactor(&mut self, seq: u64, id: u64, a: Csr, tx: Reply) {
        if let Some(s) = self.systems.get_mut(&id) {
            let r = s.sys.refactor_matrix(a);
            self.queue.refactors.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(r.map(|_| Vec::new()));
            return;
        }
        let target = {
            let t = self.shared.routes.load();
            t.map.get(&id).map(|e| e.shard)
        };
        match target {
            Some(s) if s == self.shard => {
                self.parked.push(ParkedJob::Refactor { seq, id, a, tx });
            }
            Some(s) => {
                // forwarded with its ORIGINAL admission seq, so it keeps
                // its barrier order at the destination
                self.queue.forwarded.fetch_add(1, Ordering::Relaxed);
                if let Err(Control::Refactor { tx, .. }) =
                    self.shared.queues[s].push_control(Control::Refactor { id, a, tx }, seq, true)
                {
                    let _ = tx.send(Err(Error::Runtime("service is shutting down".into())));
                }
            }
            None => {
                let _ = tx.send(Err(Error::Invalid(format!(
                    "system sys#{id} is not registered (retired?)"
                ))));
            }
        }
    }

    /// Retry parked requests in admission order. Requests whose system
    /// landed dispatch now; the rest re-route (park again, forward, or
    /// fail) against the current epoch.
    fn retry_parked(&mut self, mut parked: Vec<ParkedJob>, xs: &mut Vec<Vec<f64>>) {
        parked.sort_by_key(|p| p.seq());
        for p in parked {
            match p {
                ParkedJob::Solve(d) => {
                    if self.systems.contains_key(&d.item.id) {
                        let id = d.item.id;
                        self.dispatch_group(id, vec![(d.item.b, d.item.tx)], xs);
                    } else {
                        self.reroute_solve(d);
                    }
                }
                ParkedJob::Refactor { seq, id, a, tx } => self.apply_refactor(seq, id, a, tx),
            }
        }
    }

    /// Flush a batch of drained solves: group per resident system in
    /// dispatch order and issue block dispatches; non-resident solves
    /// re-route (park / forward / fail).
    fn flush_solves(&mut self, jobs: Vec<Drained<SolveJob>>, xs: &mut Vec<Vec<f64>>) {
        let mut order: Vec<u64> = Vec::new();
        let mut groups: HashMap<u64, Vec<(Vec<f64>, Reply)>> = HashMap::new();
        for d in jobs {
            if self.systems.contains_key(&d.item.id) {
                let group = groups.entry(d.item.id).or_default();
                if group.is_empty() {
                    order.push(d.item.id);
                }
                group.push((d.item.b, d.item.tx));
            } else {
                self.reroute_solve(d);
            }
        }
        for id in order {
            let group = groups.remove(&id).expect("grouped above");
            self.dispatch_group(id, group, xs);
        }
    }

    /// Re-route one solve that is not resident here (see module docs).
    fn reroute_solve(&mut self, d: Drained<SolveJob>) {
        let target = {
            let t = self.shared.routes.load();
            t.map.get(&d.item.id).map(|e| e.shard)
        };
        match target {
            Some(s) if s == self.shard => self.parked.push(ParkedJob::Solve(d)),
            Some(s) => {
                // forwarded with its ORIGINAL admission seq and lane, so
                // it keeps its barrier order at the destination
                self.queue.forwarded.fetch_add(1, Ordering::Relaxed);
                let prio = match d.deadline {
                    Some(at) => Priority::Deadline(at),
                    None => Priority::Bulk,
                };
                if let Err(job) = self.shared.queues[s].push_solve(d.item, prio, d.seq, true) {
                    let _ = job
                        .tx
                        .send(Err(Error::Runtime("service is shutting down".into())));
                }
            }
            None => {
                let _ = d.item.tx.send(Err(Error::Invalid(format!(
                    "system sys#{} is not registered (retired?)",
                    d.item.id
                ))));
            }
        }
    }

    /// Solve one system's queued group as block dispatches of at most
    /// `max_batch` columns, replying through the per-request channels.
    /// Disconnected receivers (abandoned tickets) are ignored.
    fn dispatch_group(
        &mut self,
        id: u64,
        mut group: Vec<(Vec<f64>, Reply)>,
        xs: &mut Vec<Vec<f64>>,
    ) {
        while !group.is_empty() {
            let take = group.len().min(self.max_batch);
            let mut bs = Vec::with_capacity(take);
            let mut txs = Vec::with_capacity(take);
            for (b, tx) in group.drain(..take) {
                bs.push(b);
                txs.push(tx);
            }
            let res = {
                let s = self.systems.get(&id).expect("dispatch_group on resident system");
                s.sys.solve_many_into(&bs, xs)
            };
            match res {
                Ok(st) => {
                    let k = bs.len() as u64;
                    self.queue.dispatches.fetch_add(1, Ordering::Relaxed);
                    self.queue.rhs_solved.fetch_add(k, Ordering::Relaxed);
                    self.queue
                        .refine_iters
                        .fetch_add(st.refine_iters as u64, Ordering::Relaxed);
                    self.queue
                        .precision_fallbacks
                        .fetch_add(st.fallbacks, Ordering::Relaxed);
                    self.queue.max_batch.fetch_max(bs.len(), Ordering::Relaxed);
                    *self.batch_counts.entry(id).or_insert(0) += k;
                    if let Some(s) = self.systems.get(&id) {
                        s.stats.note_solved(k);
                    }
                    for (q, tx) in txs.into_iter().enumerate() {
                        let _ = tx.send(Ok(std::mem::take(&mut xs[q])));
                    }
                }
                Err(e) => {
                    for tx in txs {
                        let _ = tx.send(Err(e.clone()));
                    }
                }
            }
        }
    }
}

//! Shard internals: the bounded two-lane coalescing queue and the
//! dispatcher loop that turns queued single-RHS requests into batched
//! `solve_many_into` block dispatches.
//!
//! The elastic pieces live here too:
//!
//! - **Barrier ordering.** Every queued item carries an admission
//!   sequence number. Control jobs (refactor, install, extract) are
//!   barriers: solves admitted *before* a control are flushed before it
//!   applies, and solves admitted after it never jump it — even though
//!   the two solve lanes themselves re-order (deadline first). A solve
//!   submitted after `refactor` returns therefore always observes the
//!   new values, exactly as in the pre-elastic service.
//! - **Forwarding.** A solve (or refactor) drained by a shard that no
//!   longer owns its system is re-routed against the *current* routing
//!   epoch: forwarded to the owning shard (keeping its priority), or
//!   failed fast when the system is retired. Routing staleness costs
//!   one queue hop, never a lost ticket.
//! - **Parking.** A request that arrives at the shard the routing table
//!   points to *before* the system value itself has landed (its
//!   `Install` is still in the queue — the register/migrate window)
//!   parks locally and is retried, in admission order, after every
//!   control application. Install jobs are pushed before the routing
//!   epoch that points at them is published, so a parked request's
//!   install is always already in the queue — parking is bounded, not
//!   speculative waiting.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::api::{Factored, LinearSystem, SolveOpts};
use crate::exec::{lock_ignore_poison, wait_ignore_poison, wait_timeout_ignore_poison};
use crate::sparse::csr::Csr;
use crate::{Error, Result};

use super::queue::{AdaptiveTick, Drained, LaneQueue, Priority};
use super::route::{Health, QuarantineReason, SystemStats};
use super::ServiceShared;

/// Per-request reply channel (refactor acks send an empty vector,
/// hidden behind the typed wrappers in `service::SolverService`).
pub(crate) type Reply = Sender<Result<Vec<f64>>>;

/// One system living on a shard: the owning typestate handle plus the
/// stats block and recovery controller that travel with it across moves.
pub(crate) struct ShardSystem {
    pub sys: LinearSystem<Factored>,
    pub stats: Arc<SystemStats>,
    pub gate: RecoveryGate,
}

/// EMA-gated auto-retry controller for quarantine recovery (one per
/// resident system; travels with Extract/Install moves). Each failed
/// escalation pushes the failure EMA up past the gate; each gated-off
/// opportunity decays it back, so retries back off geometrically under
/// repeated failure instead of re-factorizing on every queued solve,
/// while the first attempt after a quarantine is always immediate
/// (EMA starts at zero).
#[derive(Debug, Default)]
pub(crate) struct RecoveryGate {
    /// EMA of recent escalation failures in `[0, 1)`.
    ema: f64,
}

impl RecoveryGate {
    /// Whether to attempt recovery at this dispatch opportunity. A
    /// skipped opportunity decays the EMA so a later one passes.
    fn should_attempt(&mut self, alpha: f64, gate: f64) -> bool {
        if self.ema < gate {
            true
        } else {
            self.ema *= 1.0 - alpha;
            false
        }
    }

    fn on_failure(&mut self, alpha: f64) {
        self.ema = alpha + (1.0 - alpha) * self.ema;
    }

    fn on_success(&mut self) {
        self.ema = 0.0;
    }
}

/// Fault-tolerance knobs handed to each shard dispatcher (the copyable
/// slice of `ServiceConfig` the supervision paths read per dispatch).
#[derive(Clone, Copy, Debug)]
pub(crate) struct ShardPolicy {
    /// Fail deadline-lane items whose deadline passed before dispatch
    /// with [`Error::DeadlineExpired`] instead of solving them.
    pub expire_deadlines: bool,
    /// SLO headroom: with `expire_deadlines` on, the coalescing wait is
    /// clamped to end this long *before* the earliest queued deadline,
    /// so the dispatch itself has time to land inside the deadline.
    pub dispatch_margin: Duration,
    /// Quarantine a system whose refactor pivot-growth estimate exceeds
    /// this (non-finite growth always quarantines).
    pub pivot_growth_limit: f64,
    /// EMA smoothing for the per-system [`RecoveryGate`].
    pub recover_alpha: f64,
    /// Failure-EMA threshold below which a recovery attempt is allowed.
    pub recover_gate: f64,
}

/// The quarantine class of a numeric-failure error, if it has one.
fn quarantine_reason(e: &Error) -> Option<QuarantineReason> {
    match e {
        Error::ZeroPivot { .. } => Some(QuarantineReason::ZeroPivot),
        Error::StructurallySingular { .. } => Some(QuarantineReason::Singular),
        _ => None,
    }
}

/// One queued solve request. `opts` carries the per-call refinement
/// overrides; the dispatcher only batches requests with *equal* opts
/// into one block, so overrides never leak across a batch boundary and
/// default-opts requests keep their bit-identity with scalar solves.
pub(crate) struct SolveJob {
    pub id: u64,
    pub b: Vec<f64>,
    pub opts: SolveOpts,
    pub tx: Reply,
}

/// Control jobs: barriers relative to the solve lanes (see module docs).
pub(crate) enum Control {
    /// Same-pattern value update; flushes earlier solves first.
    Refactor { id: u64, a: Csr, tx: Reply },
    /// Same-dimension pattern update: warm re-analysis + refactorization
    /// on the owning shard, with the same barrier contract as
    /// [`Control::Refactor`].
    Reanalyze { id: u64, a: Csr, tx: Reply },
    /// A system value arriving on this shard (register / migrate).
    Install { id: u64, system: Box<ShardSystem> },
    /// Remove and return a system value (retire / migrate); earlier
    /// solves drain first, so in-flight tickets resolve before teardown.
    Extract {
        id: u64,
        tx: Sender<Option<Box<ShardSystem>>>,
    },
}

struct QueueState {
    solves: LaneQueue<SolveJob>,
    controls: VecDeque<(u64, Control)>,
    shutdown: bool,
}

impl QueueState {
    fn len(&self) -> usize {
        self.solves.len() + self.controls.len()
    }

    fn is_empty(&self) -> bool {
        self.solves.is_empty() && self.controls.is_empty()
    }
}

/// Bounded MPSC job queue with condvar wakeups on both ends: the
/// dispatcher parks on `nonempty`, submitters at capacity park on
/// `space`. Forced pushes (forwarding, topology installs) bypass the
/// capacity check — blocking a dispatcher on another shard's
/// backpressure could deadlock the pair. Coalescing statistics live
/// here so the service can aggregate them without touching the
/// dispatcher thread.
pub(crate) struct ShardQueue {
    q: Mutex<QueueState>,
    nonempty: Condvar,
    space: Condvar,
    cap: usize,
    requests: AtomicU64,
    deadline_requests: AtomicU64,
    dispatches: AtomicU64,
    rhs_solved: AtomicU64,
    refactors: AtomicU64,
    reanalyzes: AtomicU64,
    forwarded: AtomicU64,
    refine_iters: AtomicU64,
    precision_fallbacks: AtomicU64,
    max_batch: AtomicUsize,
    max_tick_ns: AtomicU64,
    panics_caught: AtomicU64,
    quarantines: AtomicU64,
    recovery_attempts: AtomicU64,
    recoveries: AtomicU64,
    expired: AtomicU64,
    pub(crate) shed: AtomicU64,
}

impl ShardQueue {
    pub fn new(cap: usize) -> ShardQueue {
        ShardQueue {
            q: Mutex::new(QueueState {
                solves: LaneQueue::new(),
                controls: VecDeque::new(),
                shutdown: false,
            }),
            nonempty: Condvar::new(),
            space: Condvar::new(),
            cap,
            requests: AtomicU64::new(0),
            deadline_requests: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            rhs_solved: AtomicU64::new(0),
            refactors: AtomicU64::new(0),
            reanalyzes: AtomicU64::new(0),
            forwarded: AtomicU64::new(0),
            refine_iters: AtomicU64::new(0),
            precision_fallbacks: AtomicU64::new(0),
            max_batch: AtomicUsize::new(0),
            max_tick_ns: AtomicU64::new(0),
            panics_caught: AtomicU64::new(0),
            quarantines: AtomicU64::new(0),
            recovery_attempts: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    /// Enqueue a solve under its service-wide admission seq, blocking
    /// while the queue is at capacity (unless `forced` — the forwarding
    /// path, which also *preserves* the job's original seq so a
    /// forwarded solve keeps its admission order relative to barriers).
    /// Once shutdown has begun the job is handed back so the caller can
    /// resolve its ticket.
    pub fn push_solve(
        &self,
        job: SolveJob,
        prio: Priority,
        seq: u64,
        forced: bool,
    ) -> std::result::Result<(), SolveJob> {
        let mut st = lock_ignore_poison(&self.q);
        loop {
            if st.shutdown {
                return Err(job);
            }
            if forced || st.len() < self.cap {
                break;
            }
            st = wait_ignore_poison(self.space.wait(st));
        }
        if !forced {
            self.requests.fetch_add(1, Ordering::Relaxed);
            if matches!(prio, Priority::Deadline(_)) {
                self.deadline_requests.fetch_add(1, Ordering::Relaxed);
            }
        }
        st.solves.push(seq, prio, job);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Enqueue a control job (barrier) under its service-wide admission
    /// seq. Same capacity/shutdown contract as
    /// [`ShardQueue::push_solve`].
    pub fn push_control(
        &self,
        ctrl: Control,
        seq: u64,
        forced: bool,
    ) -> std::result::Result<(), Control> {
        let mut st = lock_ignore_poison(&self.q);
        loop {
            if st.shutdown {
                return Err(ctrl);
            }
            if forced || st.len() < self.cap {
                break;
            }
            st = wait_ignore_poison(self.space.wait(st));
        }
        st.controls.push_back((seq, ctrl));
        self.nonempty.notify_one();
        Ok(())
    }

    /// Currently queued jobs (solves + controls). Approximate by the
    /// time the caller acts on it; good enough for load shedding.
    pub fn depth(&self) -> usize {
        lock_ignore_poison(&self.q).len()
    }

    pub fn shutdown(&self) {
        let mut st = lock_ignore_poison(&self.q);
        st.shutdown = true;
        self.nonempty.notify_all();
        self.space.notify_all();
    }

    pub fn add_stats_into(&self, out: &mut ServiceStats) {
        out.requests += self.requests.load(Ordering::Relaxed);
        out.deadline_requests += self.deadline_requests.load(Ordering::Relaxed);
        out.dispatches += self.dispatches.load(Ordering::Relaxed);
        out.rhs_solved += self.rhs_solved.load(Ordering::Relaxed);
        out.refactors += self.refactors.load(Ordering::Relaxed);
        out.reanalyzes += self.reanalyzes.load(Ordering::Relaxed);
        out.forwarded += self.forwarded.load(Ordering::Relaxed);
        out.refine_iters += self.refine_iters.load(Ordering::Relaxed);
        out.precision_fallbacks += self.precision_fallbacks.load(Ordering::Relaxed);
        out.panics_caught += self.panics_caught.load(Ordering::Relaxed);
        out.quarantines += self.quarantines.load(Ordering::Relaxed);
        out.recovery_attempts += self.recovery_attempts.load(Ordering::Relaxed);
        out.recoveries += self.recoveries.load(Ordering::Relaxed);
        out.expired += self.expired.load(Ordering::Relaxed);
        out.shed += self.shed.load(Ordering::Relaxed);
        out.max_batch = out.max_batch.max(self.max_batch.load(Ordering::Relaxed));
        let tick = Duration::from_nanos(self.max_tick_ns.load(Ordering::Relaxed));
        out.max_tick = out.max_tick.max(tick);
    }
}

/// Aggregate serving statistics for a [`super::SolverService`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Solve requests accepted.
    pub requests: u64,
    /// Subset of `requests` submitted on the deadline lane.
    pub deadline_requests: u64,
    /// Batched block dispatches issued.
    pub dispatches: u64,
    /// Right-hand sides solved across all dispatches.
    pub rhs_solved: u64,
    /// Refactorizations applied.
    pub refactors: u64,
    /// Live re-analyses applied (same-dimension pattern updates shipped
    /// through [`super::SolverService::reanalyze`]).
    pub reanalyzes: u64,
    /// Requests re-routed between shards (routing-epoch staleness during
    /// a move; each costs one queue hop).
    pub forwarded: u64,
    /// Iterative-refinement rounds executed across all dispatches.
    pub refine_iters: u64,
    /// Mixed-precision stall fallbacks (f64 recovery refactorizations)
    /// triggered across all dispatches.
    pub precision_fallbacks: u64,
    /// Systems registered over the service lifetime (construction-time
    /// systems included).
    pub registers: u64,
    /// Systems retired.
    pub retires: u64,
    /// Systems moved between shards (`migrate` / `rebalance`).
    pub moves: u64,
    /// Widest single batch dispatched.
    pub max_batch: usize,
    /// Widest coalescing wait any shard *actually* slept — the measured
    /// elapsed wait, not the requested window, so preemption (a control
    /// arrival, a filling batch, a deadline clamp) shows up as a shorter
    /// tick instead of over-reporting. Zero with a static zero tick.
    pub max_tick: Duration,
    /// Panics caught by shard supervision (the shard scrubbed, failed
    /// the in-flight tickets with [`Error::ShardPanicked`], and kept
    /// serving).
    pub panics_caught: u64,
    /// Healthy → quarantined transitions across all systems.
    pub quarantines: u64,
    /// Escalated (full re-pivot) recovery factorizations attempted.
    pub recovery_attempts: u64,
    /// Recovery attempts that restored a system to healthy.
    pub recoveries: u64,
    /// Deadline-lane requests failed with [`Error::DeadlineExpired`]
    /// because their deadline passed before dispatch
    /// (`ServiceConfig::expire_deadlines`).
    pub expired: u64,
    /// Bulk requests rejected at admission by load shedding
    /// (`ServiceConfig::shed_depth`).
    pub shed: u64,
}

impl ServiceStats {
    /// Mean right-hand sides per block dispatch (the coalescing factor).
    pub fn mean_batch(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.rhs_solved as f64 / self.dispatches as f64
        }
    }
}

/// A request parked while its system's `Install` is still queued (see
/// the module docs); retried in admission order after every control.
enum ParkedJob {
    Solve(Drained<SolveJob>),
    Refactor { seq: u64, id: u64, a: Csr, tx: Reply },
    Reanalyze { seq: u64, id: u64, a: Csr, tx: Reply },
}

impl ParkedJob {
    fn seq(&self) -> u64 {
        match self {
            ParkedJob::Solve(d) => d.seq,
            ParkedJob::Refactor { seq, .. } => *seq,
            ParkedJob::Reanalyze { seq, .. } => *seq,
        }
    }
}

/// The dispatcher state moved onto the shard thread. Each resident
/// system is an owning [`LinearSystem<Factored>`] handle — matrix,
/// analysis, factorization *and engine* travel as one value, which is
/// what makes cross-shard moves a plain value move.
pub(crate) struct ShardWorker {
    shard: usize,
    systems: HashMap<u64, ShardSystem>,
    queue: Arc<ShardQueue>,
    shared: Arc<ServiceShared>,
    tick: AdaptiveTick,
    max_batch: usize,
    starvation_bound: usize,
    policy: ShardPolicy,
    parked: Vec<ParkedJob>,
    /// Per-drain-cycle dispatch counts, folded into each system's EWMA.
    batch_counts: HashMap<u64, u64>,
}

impl ShardWorker {
    pub fn new(
        shard: usize,
        queue: Arc<ShardQueue>,
        shared: Arc<ServiceShared>,
        tick: AdaptiveTick,
        max_batch: usize,
        starvation_bound: usize,
        policy: ShardPolicy,
    ) -> ShardWorker {
        ShardWorker {
            shard,
            systems: HashMap::new(),
            queue,
            shared,
            tick,
            max_batch,
            starvation_bound,
            policy,
            parked: Vec::new(),
            batch_counts: HashMap::new(),
        }
    }

    /// Dispatcher loop: park until work arrives (collapsing the adaptive
    /// window), optionally sleep one coalescing window, drain everything
    /// queued, process it as batched block dispatches. On shutdown the
    /// queue is drained to empty before exiting, so every accepted
    /// ticket resolves.
    pub fn run(mut self) {
        let mut xs: Vec<Vec<f64>> = Vec::new();
        loop {
            let drained = {
                let mut st = lock_ignore_poison(&self.queue.q);
                while st.is_empty() && !st.shutdown {
                    self.tick.on_idle();
                    st = wait_ignore_poison(self.queue.nonempty.wait(st));
                }
                if st.is_empty() {
                    None // shutdown with nothing left to do
                } else {
                    // coalescing window — skipped when the batch is
                    // already full (sleeping could not widen it), when a
                    // control job is waiting (refactor/retire/migrate
                    // callers block on it; sleeping cannot widen a
                    // barrier), or when shutdown has begun.
                    //
                    // The wait is an *SLO-aware* condvar park, never a
                    // bare sleep: every push notifies `nonempty`, so a
                    // control-job arrival, a batch reaching `max_batch`,
                    // shutdown, or a deadline-lane admission re-evaluates
                    // the wait immediately instead of sleeping it out.
                    // With deadline expiry on, the wake time is further
                    // clamped to (earliest queued deadline − dispatch
                    // margin): a request admitted alive is dispatched
                    // with margin to spare rather than expired by the
                    // shard's own coalescing.
                    let window = self.tick.window();
                    if !window.is_zero()
                        && !st.shutdown
                        && st.controls.is_empty()
                        && st.solves.len() < self.max_batch
                    {
                        let start = Instant::now();
                        let until = start + window;
                        loop {
                            if st.shutdown
                                || !st.controls.is_empty()
                                || st.solves.len() >= self.max_batch
                            {
                                break;
                            }
                            let mut wake = until;
                            if self.policy.expire_deadlines {
                                if let Some(at) = st.solves.earliest_deadline() {
                                    // an Instant cannot underflow: a
                                    // margin reaching past the epoch
                                    // clamps to "wake now"
                                    let slo = at
                                        .checked_sub(self.policy.dispatch_margin)
                                        .unwrap_or(start);
                                    wake = wake.min(slo);
                                }
                            }
                            let left = wake.saturating_duration_since(Instant::now());
                            if left.is_zero() {
                                break;
                            }
                            st = wait_timeout_ignore_poison(
                                self.queue.nonempty.wait_timeout(st, left),
                            );
                        }
                        // telemetry records the wait actually slept, not
                        // the window requested — preemption makes the
                        // two diverge
                        self.queue
                            .max_tick_ns
                            .fetch_max(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                    let (solves, expired) = if self.policy.expire_deadlines {
                        st.solves
                            .drain_ordered_expiring(Instant::now(), self.starvation_bound)
                    } else {
                        (st.solves.drain_ordered(self.starvation_bound), Vec::new())
                    };
                    let controls: Vec<(u64, Control)> = st.controls.drain(..).collect();
                    self.queue.space.notify_all();
                    Some((solves, expired, controls))
                }
            };
            let Some((solves, expired, controls)) = drained else {
                // Shutdown: anything still parked can never be satisfied
                // (no more installs are coming) — fail it loudly rather
                // than dropping the reply channel.
                for p in self.parked.drain(..) {
                    let shutting = || Error::Runtime("service is shutting down".into());
                    match p {
                        ParkedJob::Solve(d) => {
                            let _ = d.item.tx.send(Err(shutting()));
                        }
                        ParkedJob::Refactor { tx, .. } | ParkedJob::Reanalyze { tx, .. } => {
                            let _ = tx.send(Err(shutting()));
                        }
                    }
                }
                return;
            };
            if !expired.is_empty() {
                // stale deadline work: nobody benefits from solving it —
                // fail the tickets without spending factor bandwidth
                self.queue
                    .expired
                    .fetch_add(expired.len() as u64, Ordering::Relaxed);
                for d in expired {
                    let _ = d.item.tx.send(Err(Error::DeadlineExpired));
                }
            }
            let nsolves = solves.len();
            self.process(solves, controls, &mut xs);
            self.tick.on_drain(nsolves, self.max_batch);
        }
    }

    /// Process one drained tick: flush solves against control barriers
    /// in admission order, then fold per-system dispatch counts into the
    /// EWMA loads that guide `rebalance`.
    fn process(
        &mut self,
        mut solves: Vec<Drained<SolveJob>>,
        controls: Vec<(u64, Control)>,
        xs: &mut Vec<Vec<f64>>,
    ) {
        self.batch_counts.clear();
        for (cseq, ctrl) in controls {
            // flush solves admitted before this barrier (the lanes
            // re-order amongst themselves, so partition by seq — a
            // later-admitted deadline solve must not jump a refactor)
            let mut rest = Vec::with_capacity(solves.len());
            let mut ready = Vec::new();
            for j in solves {
                if j.seq < cseq {
                    ready.push(j);
                } else {
                    rest.push(j);
                }
            }
            solves = rest;
            self.flush_solves(ready, xs);
            self.apply_control(cseq, ctrl);
            // a control may have installed or removed a system: parked
            // requests re-route against the new local/state view
            let parked = std::mem::take(&mut self.parked);
            self.retry_parked(parked, xs);
        }
        self.flush_solves(solves, xs);
        // one EWMA sample per resident system per drain cycle (0 when
        // quiet), so hot systems rank above merely-warm ones
        for (id, s) in &self.systems {
            let sample = self.batch_counts.get(id).copied().unwrap_or(0) as f64;
            s.stats.update_ewma(sample);
        }
    }

    fn apply_control(&mut self, seq: u64, ctrl: Control) {
        match ctrl {
            Control::Refactor { id, a, tx } => self.apply_update(seq, id, a, tx, false),
            Control::Reanalyze { id, a, tx } => self.apply_update(seq, id, a, tx, true),
            Control::Install { id, system } => {
                self.systems.insert(id, *system);
            }
            Control::Extract { id, tx } => {
                let system = self.systems.remove(&id).map(Box::new);
                let _ = tx.send(system);
            }
        }
    }

    /// Apply a refactor (or, with `reanalyze`, a same-dimension pattern
    /// update through the warm re-analysis path) locally under shard
    /// supervision, or park/forward/fail it by the current routing epoch
    /// when the system is not resident here.
    ///
    /// Failure handling (the quarantine half of the fault model):
    /// a numeric failure (`ZeroPivot` / `StructurallySingular`) leaves
    /// the system on its previous values (the handle only commits the
    /// new matrix on success) and quarantines it; a caught panic
    /// quarantines it as `Panic` — the factors may be half-written; an
    /// update that *succeeds* but whose pivot-growth estimate crosses
    /// the policy limit commits the new values, acks the caller, and
    /// quarantines as `PivotGrowth` (the stored pivot order has gone
    /// rotten — queued solves must not trust it). Recovery is the gated
    /// full re-pivot escalation in [`ShardWorker::check_health`].
    fn apply_update(&mut self, seq: u64, id: u64, mut a: Csr, mut tx: Reply, reanalyze: bool) {
        if self.systems.contains_key(&id) {
            // a quarantined system recovers (or fails fast) before new
            // values are replayed on its stored pivot order
            if let Some(reason) = self.check_health(id) {
                let _ = tx.send(Err(Error::Quarantined(reason.to_string())));
                return;
            }
            let Some(s) = self.systems.get_mut(&id) else {
                let _ = tx.send(Err(Error::Invalid(format!(
                    "system sys#{id} is not registered (retired?)"
                ))));
                return;
            };
            if reanalyze {
                self.queue.reanalyzes.fetch_add(1, Ordering::Relaxed);
            } else {
                self.queue.refactors.fetch_add(1, Ordering::Relaxed);
            }
            let apply = |s: &mut ShardSystem, a: Csr| {
                if reanalyze {
                    s.sys.reanalyze_matrix(a)
                } else {
                    s.sys.refactor_matrix(a)
                }
            };
            match catch_unwind(AssertUnwindSafe(|| apply(s, a))) {
                Ok(Ok(())) => {
                    let g = s.sys.factor_stats().pivot_growth;
                    if !g.is_finite() || g > self.policy.pivot_growth_limit {
                        if s.stats
                            .set_health(Health::Quarantined(QuarantineReason::PivotGrowth))
                        {
                            self.queue.quarantines.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let _ = tx.send(Ok(Vec::new()));
                }
                Ok(Err(e)) => {
                    if let Some(reason) = quarantine_reason(&e) {
                        if s.stats.set_health(Health::Quarantined(reason)) {
                            self.queue.quarantines.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let _ = tx.send(Err(e));
                }
                Err(_) => {
                    self.queue.panics_caught.fetch_add(1, Ordering::Relaxed);
                    if s.stats.set_health(Health::Quarantined(QuarantineReason::Panic)) {
                        self.queue.quarantines.fetch_add(1, Ordering::Relaxed);
                    }
                    let _ = tx.send(Err(Error::ShardPanicked { shard: self.shard }));
                }
            }
            return;
        }
        // Forwarding re-resolves route + shard set in a loop, exactly as
        // `reroute_solve` does: a shrink can retire the target shard
        // between the route read and the push, and the publication order
        // (routes first, set truncation second) makes one re-read land
        // on a live placement.
        loop {
            let target = {
                let t = self.shared.routes.load();
                t.map.get(&id).map(|e| e.shard)
            };
            match target {
                Some(s) if s == self.shard => {
                    let parked = if reanalyze {
                        ParkedJob::Reanalyze { seq, id, a, tx }
                    } else {
                        ParkedJob::Refactor { seq, id, a, tx }
                    };
                    self.parked.push(parked);
                    return;
                }
                Some(s) => {
                    let Some(q) = self.shared.queue(s) else {
                        continue; // stale route raced a shrink; re-read
                    };
                    // forwarded with its ORIGINAL admission seq, so it
                    // keeps its barrier order at the destination
                    let ctrl = if reanalyze {
                        Control::Reanalyze { id, a, tx }
                    } else {
                        Control::Refactor { id, a, tx }
                    };
                    match q.push_control(ctrl, seq, true) {
                        Ok(()) => {
                            self.queue.forwarded.fetch_add(1, Ordering::Relaxed);
                            return;
                        }
                        Err(
                            Control::Refactor { a: ra, tx: rtx, .. }
                            | Control::Reanalyze { a: ra, tx: rtx, .. },
                        ) => {
                            let again = {
                                let t = self.shared.routes.load();
                                t.map.get(&id).map(|e| e.shard)
                            };
                            if again == Some(s) {
                                let _ = rtx
                                    .send(Err(Error::Runtime("service is shutting down".into())));
                                return;
                            }
                            a = ra;
                            tx = rtx;
                        }
                        Err(_) => unreachable!("push_control returns the pushed control"),
                    }
                }
                None => {
                    let _ = tx.send(Err(Error::Invalid(format!(
                        "system sys#{id} is not registered (retired?)"
                    ))));
                    return;
                }
            }
        }
    }

    /// Retry parked requests in admission order. Requests whose system
    /// landed dispatch now; the rest re-route (park again, forward, or
    /// fail) against the current epoch.
    fn retry_parked(&mut self, mut parked: Vec<ParkedJob>, xs: &mut Vec<Vec<f64>>) {
        parked.sort_by_key(|p| p.seq());
        for p in parked {
            match p {
                ParkedJob::Solve(d) => {
                    if self.systems.contains_key(&d.item.id) {
                        let id = d.item.id;
                        self.dispatch_group(id, vec![(d.item.b, d.item.opts, d.item.tx)], xs);
                    } else {
                        self.reroute_solve(d);
                    }
                }
                ParkedJob::Refactor { seq, id, a, tx } => {
                    self.apply_update(seq, id, a, tx, false)
                }
                ParkedJob::Reanalyze { seq, id, a, tx } => {
                    self.apply_update(seq, id, a, tx, true)
                }
            }
        }
    }

    /// Flush a batch of drained solves: group per resident system in
    /// dispatch order and issue block dispatches; non-resident solves
    /// re-route (park / forward / fail).
    fn flush_solves(&mut self, jobs: Vec<Drained<SolveJob>>, xs: &mut Vec<Vec<f64>>) {
        let mut order: Vec<u64> = Vec::new();
        let mut groups: HashMap<u64, Vec<(Vec<f64>, SolveOpts, Reply)>> = HashMap::new();
        for d in jobs {
            if self.systems.contains_key(&d.item.id) {
                let group = groups.entry(d.item.id).or_default();
                if group.is_empty() {
                    order.push(d.item.id);
                }
                group.push((d.item.b, d.item.opts, d.item.tx));
            } else {
                self.reroute_solve(d);
            }
        }
        for id in order {
            // a racing Extract between grouping and dispatch must not
            // panic the dispatcher — an absent group simply has nothing
            // left to do
            let Some(group) = groups.remove(&id) else {
                continue;
            };
            self.dispatch_group(id, group, xs);
        }
    }

    /// Re-route one solve that is not resident here (see module docs).
    ///
    /// Forwarding re-resolves against the *current* routing epoch and
    /// the *current* shard set in a loop: a shrink can retire the target
    /// shard between the route read and the queue push, but the protocol
    /// (routes move off a draining shard before the set truncates, both
    /// SeqCst publications) guarantees a re-read after observing either
    /// staleness lands on a live placement. The loop only continues
    /// while the placement actually changed, so it cannot spin.
    fn reroute_solve(&mut self, mut d: Drained<SolveJob>) {
        loop {
            let target = {
                let t = self.shared.routes.load();
                t.map.get(&d.item.id).map(|e| e.shard)
            };
            match target {
                Some(s) if s == self.shard => {
                    self.parked.push(ParkedJob::Solve(d));
                    return;
                }
                Some(s) => {
                    let Some(q) = self.shared.queue(s) else {
                        // route read raced a shrink: the shard is gone
                        // from the current set, so the next route read is
                        // guaranteed to see the migrated placement
                        continue;
                    };
                    // forwarded with its ORIGINAL admission seq and
                    // lane, so it keeps its barrier order at the
                    // destination
                    let prio = match d.deadline {
                        Some(at) => Priority::Deadline(at),
                        None => Priority::Bulk,
                    };
                    let (seq, deadline) = (d.seq, d.deadline);
                    match q.push_solve(d.item, prio, seq, true) {
                        Ok(()) => {
                            self.queue.forwarded.fetch_add(1, Ordering::Relaxed);
                            return;
                        }
                        Err(job) => {
                            // the target shut down mid-forward: if the
                            // route moved on (a shrink drained it),
                            // chase the new placement; if it still
                            // points there, the whole service is going
                            // down and the ticket fails cleanly
                            let again = {
                                let t = self.shared.routes.load();
                                t.map.get(&job.id).map(|e| e.shard)
                            };
                            if again == Some(s) {
                                let _ = job
                                    .tx
                                    .send(Err(Error::Runtime("service is shutting down".into())));
                                return;
                            }
                            d = Drained {
                                seq,
                                deadline,
                                item: job,
                            };
                        }
                    }
                }
                None => {
                    let _ = d.item.tx.send(Err(Error::Invalid(format!(
                        "system sys#{} is not registered (retired?)",
                        d.item.id
                    ))));
                    return;
                }
            }
        }
    }

    /// The dispatch-time health gate: `None` when the system may serve
    /// (healthy, or just recovered), `Some(reason)` when it must fail
    /// fast. A quarantined system attempts the **escalated recovery** —
    /// a full re-pivot [`LinearSystem::factorize`] of its current
    /// values, itself supervised — when the EMA gate allows; success
    /// restores `Healthy` with factors bit-identical to a clean
    /// full-pivot factorization of those values. Recovery runs here, at
    /// dispatch time, rather than at admission: rejecting at admission
    /// would starve the system of the very opportunities recovery needs.
    fn check_health(&mut self, id: u64) -> Option<QuarantineReason> {
        let ShardPolicy {
            pivot_growth_limit,
            recover_alpha,
            recover_gate,
            ..
        } = self.policy;
        let s = self.systems.get_mut(&id)?;
        let Health::Quarantined(mut reason) = s.stats.health() else {
            return None;
        };
        if !s.gate.should_attempt(recover_alpha, recover_gate) {
            return Some(reason);
        }
        self.queue.recovery_attempts.fetch_add(1, Ordering::Relaxed);
        let ok = match catch_unwind(AssertUnwindSafe(|| s.sys.factorize())) {
            Ok(Ok(())) => {
                let g = s.sys.factor_stats().pivot_growth;
                if !g.is_finite() || g > pivot_growth_limit {
                    reason = QuarantineReason::PivotGrowth;
                    false
                } else {
                    true
                }
            }
            Ok(Err(e)) => {
                if let Some(r) = quarantine_reason(&e) {
                    reason = r;
                }
                false
            }
            Err(_) => {
                self.queue.panics_caught.fetch_add(1, Ordering::Relaxed);
                reason = QuarantineReason::Panic;
                false
            }
        };
        s.stats.note_recovery_attempt(ok);
        if ok {
            self.queue.recoveries.fetch_add(1, Ordering::Relaxed);
            s.stats.set_health(Health::Healthy);
            s.gate.on_success();
            None
        } else {
            s.stats.set_health(Health::Quarantined(reason));
            s.gate.on_failure(recover_alpha);
            Some(reason)
        }
    }

    /// Solve one system's queued group as block dispatches of at most
    /// `max_batch` columns, replying through the per-request channels.
    /// Disconnected receivers (abandoned tickets) are ignored.
    ///
    /// Every block runs under `catch_unwind` supervision: a panic fails
    /// that block's tickets with [`Error::ShardPanicked`] (the engine
    /// scrubbed its worker scratch on the unwind path) and the
    /// dispatcher keeps serving — the system stays healthy, since solves
    /// never mutate the factors.
    fn dispatch_group(
        &mut self,
        id: u64,
        mut group: Vec<(Vec<f64>, SolveOpts, Reply)>,
        xs: &mut Vec<Vec<f64>>,
    ) {
        if let Some(reason) = self.check_health(id) {
            let msg = reason.to_string();
            for (_, _, tx) in group {
                let _ = tx.send(Err(Error::Quarantined(msg.clone())));
            }
            return;
        }
        while !group.is_empty() {
            // a block shares one set of refinement overrides: batch the
            // longest prefix with equal opts (in practice one run — the
            // default — so coalescing width is unaffected)
            let opts = group[0].1;
            let take = group
                .iter()
                .take(self.max_batch)
                .take_while(|(_, o, _)| *o == opts)
                .count();
            let mut bs = Vec::with_capacity(take);
            let mut txs = Vec::with_capacity(take);
            for (b, _, tx) in group.drain(..take) {
                bs.push(b);
                txs.push(tx);
            }
            let Some(s) = self.systems.get(&id) else {
                // a retire raced the drain: fail the tickets the way a
                // route miss would, instead of panicking the dispatcher
                let e = Error::Invalid(format!("system sys#{id} is not registered (retired?)"));
                for tx in txs.into_iter().chain(group.drain(..).map(|(_, _, tx)| tx)) {
                    let _ = tx.send(Err(e.clone()));
                }
                return;
            };
            match catch_unwind(AssertUnwindSafe(|| s.sys.solve_many_into_with_opts(&bs, xs, &opts))) {
                Ok(Ok(st)) => {
                    let k = bs.len() as u64;
                    self.queue.dispatches.fetch_add(1, Ordering::Relaxed);
                    self.queue.rhs_solved.fetch_add(k, Ordering::Relaxed);
                    self.queue
                        .refine_iters
                        .fetch_add(st.refine_iters as u64, Ordering::Relaxed);
                    self.queue
                        .precision_fallbacks
                        .fetch_add(st.fallbacks, Ordering::Relaxed);
                    self.queue.max_batch.fetch_max(bs.len(), Ordering::Relaxed);
                    *self.batch_counts.entry(id).or_insert(0) += k;
                    if let Some(s) = self.systems.get(&id) {
                        s.stats.note_solved(k);
                    }
                    for (q, tx) in txs.into_iter().enumerate() {
                        let _ = tx.send(Ok(std::mem::take(&mut xs[q])));
                    }
                }
                Ok(Err(e)) => {
                    for tx in txs {
                        let _ = tx.send(Err(e.clone()));
                    }
                }
                Err(_) => {
                    self.queue.panics_caught.fetch_add(1, Ordering::Relaxed);
                    for tx in txs {
                        let _ = tx.send(Err(Error::ShardPanicked { shard: self.shard }));
                    }
                }
            }
        }
    }
}

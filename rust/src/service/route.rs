//! System identity, per-system load statistics, and the lock-free-read
//! routing table behind the elastic service.
//!
//! # Routing-table publication protocol
//!
//! Request routing is the hottest read in the service — every `submit`
//! does one lookup — while topology changes (register / retire /
//! rebalance) are rare events. [`RouteCell`] therefore publishes
//! immutable [`RouteTable`] snapshots arc-swap style:
//!
//! - **Readers** pin the cell (one `SeqCst` increment — never a lock,
//!   never blocking or spinning), do one `SeqCst` `AtomicPtr` load, and
//!   use the table; the guard unpins on drop. The pin is load-bearing
//!   for reclamation — see the soundness argument on
//!   [`RouteCell::load`] — so it must not be "optimized away".
//! - **Writers** serialize on a mutex, build a *new* table derived from
//!   the current one, and publish it with a Release store. Superseded
//!   epochs are **parked** in the writer's epoch list; a reader that
//!   loaded the pointer a microsecond before a swap therefore still
//!   dereferences a live table. Parked epochs are reclaimed through a
//!   **quiescence check**: every reader pins the cell (one atomic
//!   increment) for the duration of its borrow, and a writer whose
//!   parked list has grown past a threshold frees everything but the
//!   current epoch at a moment it observes zero pins — if readers are
//!   never simultaneously quiescent it simply skips and retries on the
//!   next publication, so reads stay lock-free (pin/unpin never blocks
//!   or spins) and memory stays bounded by the threshold plus transient
//!   overlap. Teardown (`&mut`) frees the rest.
//!
//! The protocol gives in-flight requests a coherent (possibly one-epoch
//! stale) view: a request routed on epoch `e` to a shard that no longer
//! owns the system is *forwarded* by that shard's dispatcher against
//! the current epoch (see [`super::shard`]), so staleness costs one
//! queue hop, never correctness.

use std::collections::HashMap;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::lock_ignore_poison;

/// Opaque identity of one registered system on a
/// [`super::SolverService`]. Ids are assigned in registration order
/// (construction-time systems get `0..k`) and are never reused, so a
/// retired id stays invalid forever instead of aliasing a newcomer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SystemId(pub u64);

impl std::fmt::Display for SystemId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sys#{}", self.0)
    }
}

/// Why a system was quarantined (see [`Health`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuarantineReason {
    /// A refactorization hit an exactly-zero pivot that perturbation
    /// could not rescue.
    ZeroPivot,
    /// A refactorization found the matrix numerically singular.
    Singular,
    /// The pivot-growth estimate crossed
    /// `ServiceConfig::pivot_growth_limit` (or went non-finite): the
    /// stored pivot order has gone numerically rotten for the current
    /// values.
    PivotGrowth,
    /// A panic was caught while the system's factors were being written;
    /// they may be half-updated and must not serve solves.
    Panic,
}

impl std::fmt::Display for QuarantineReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            QuarantineReason::ZeroPivot => "zero pivot",
            QuarantineReason::Singular => "singular",
            QuarantineReason::PivotGrowth => "pivot growth",
            QuarantineReason::Panic => "panic during factorization",
        })
    }
}

/// Serving health of one registered system. A quarantined system fails
/// queued solves fast (with [`crate::Error::Quarantined`]) until the
/// owning shard's escalation — a full re-pivot factorization — restores
/// it to `Healthy`; see `DESIGN.md` §"Fault model & recovery".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Serving normally.
    Healthy,
    /// Failing fast; recovery attempts are gated by the shard's EMA
    /// controller.
    Quarantined(QuarantineReason),
}

impl Health {
    /// Stable numeric encoding for the atomic health word and the C ABI
    /// (`hylu_service_health`): 0 healthy, 1..=4 a quarantine reason.
    pub(crate) fn encode(self) -> u64 {
        match self {
            Health::Healthy => 0,
            Health::Quarantined(QuarantineReason::ZeroPivot) => 1,
            Health::Quarantined(QuarantineReason::Singular) => 2,
            Health::Quarantined(QuarantineReason::PivotGrowth) => 3,
            Health::Quarantined(QuarantineReason::Panic) => 4,
        }
    }

    pub(crate) fn decode(w: u64) -> Health {
        match w {
            1 => Health::Quarantined(QuarantineReason::ZeroPivot),
            2 => Health::Quarantined(QuarantineReason::Singular),
            3 => Health::Quarantined(QuarantineReason::PivotGrowth),
            4 => Health::Quarantined(QuarantineReason::Panic),
            _ => Health::Healthy,
        }
    }
}

/// EWMA smoothing factor for per-system load: ~4-drain memory, enough
/// to rank hot vs cold systems without chasing single bursts.
const EWMA_ALPHA: f64 = 0.25;

/// Per-system serving statistics, updated lock-free by submitters and
/// the owning shard dispatcher; travels with the system across moves.
#[derive(Debug, Default)]
pub struct SystemStats {
    requests: AtomicU64,
    rhs_solved: AtomicU64,
    /// EWMA of right-hand sides dispatched per drain cycle, as f64 bits.
    ewma_bits: AtomicU64,
    /// Current [`Health`], encoded (0 healthy, 1..=4 quarantine reason).
    /// Written by the owning shard dispatcher, read lock-free through
    /// the routing table by `SolverService::health`.
    health_word: AtomicU64,
    /// Times this system entered quarantine.
    quarantines: AtomicU64,
    /// Escalated (full re-pivot) recovery factorizations attempted.
    recovery_attempts: AtomicU64,
    /// Recovery attempts that restored `Healthy`.
    recoveries: AtomicU64,
}

impl SystemStats {
    pub(crate) fn note_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_solved(&self, k: u64) {
        self.rhs_solved.fetch_add(k, Ordering::Relaxed);
    }

    /// Fold one drain-cycle sample (right-hand sides dispatched for this
    /// system in the cycle; 0 when it was quiet) into the EWMA.
    pub(crate) fn update_ewma(&self, sample: f64) {
        let prev = f64::from_bits(self.ewma_bits.load(Ordering::Relaxed));
        let next = EWMA_ALPHA * sample + (1.0 - EWMA_ALPHA) * prev;
        self.ewma_bits.store(next.to_bits(), Ordering::Relaxed);
    }

    /// Solve requests accepted for this system.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Right-hand sides dispatched for this system.
    pub fn rhs_solved(&self) -> u64 {
        self.rhs_solved.load(Ordering::Relaxed)
    }

    /// EWMA load (right-hand sides per drain cycle) — what
    /// [`super::SolverService::rebalance`] ranks systems by.
    pub fn ewma_load(&self) -> f64 {
        f64::from_bits(self.ewma_bits.load(Ordering::Relaxed))
    }

    /// Transition health, bumping the quarantine counter on each
    /// Healthy → Quarantined edge (reason changes inside quarantine do
    /// not double-count). Returns whether this call was such an edge, so
    /// the shard can mirror the count into its aggregate stats.
    pub(crate) fn set_health(&self, h: Health) -> bool {
        let prev = Health::decode(self.health_word.swap(h.encode(), Ordering::Relaxed));
        let edge = prev == Health::Healthy && h != Health::Healthy;
        if edge {
            self.quarantines.fetch_add(1, Ordering::Relaxed);
        }
        edge
    }

    pub(crate) fn note_recovery_attempt(&self, succeeded: bool) {
        self.recovery_attempts.fetch_add(1, Ordering::Relaxed);
        if succeeded {
            self.recoveries.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Current serving health.
    pub fn health(&self) -> Health {
        Health::decode(self.health_word.load(Ordering::Relaxed))
    }

    /// Times this system entered quarantine.
    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    /// Escalated recovery factorizations attempted.
    pub fn recovery_attempts(&self) -> u64 {
        self.recovery_attempts.load(Ordering::Relaxed)
    }

    /// Recovery attempts that restored [`Health::Healthy`].
    pub fn recoveries(&self) -> u64 {
        self.recoveries.load(Ordering::Relaxed)
    }
}

/// Copyable snapshot of one system's placement and load, for
/// observability ([`super::SolverService::system_load`]).
#[derive(Clone, Copy, Debug)]
pub struct SystemLoad {
    /// Shard currently owning the system.
    pub shard: usize,
    /// Solve requests accepted.
    pub requests: u64,
    /// Right-hand sides dispatched.
    pub rhs_solved: u64,
    /// EWMA load (RHS per drain cycle).
    pub ewma: f64,
}

/// One routing entry: where the system lives and what a valid request
/// looks like, plus the shared stats block that travels with it.
#[derive(Clone)]
pub(crate) struct RouteEntry {
    pub shard: usize,
    pub n: usize,
    pub stats: Arc<SystemStats>,
}

/// One immutable routing epoch: system id → entry.
#[derive(Default)]
pub(crate) struct RouteTable {
    pub map: HashMap<u64, RouteEntry>,
}

impl RouteTable {
    /// Copy-on-write insert/replace.
    pub fn with(&self, id: u64, entry: RouteEntry) -> RouteTable {
        let mut map = self.map.clone();
        map.insert(id, entry);
        RouteTable { map }
    }

    /// Copy-on-write removal.
    pub fn without(&self, id: u64) -> RouteTable {
        let mut map = self.map.clone();
        map.remove(&id);
        RouteTable { map }
    }
}

/// Parked-epoch threshold past which a publication attempts the
/// quiescence-based reclamation described in the [module docs](self).
const EPOCH_PRUNE_THRESHOLD: usize = 16;

/// The arc-swap-style publication cell described in the [module
/// docs](self), generic over the published snapshot: lock-free pinned
/// reads of the current epoch, mutex-serialized copy-on-write
/// publication, superseded epochs parked until a quiescent reclamation
/// (or drop). The service publishes two snapshot kinds through it: the
/// routing table ([`RouteCell`]) and — since the shard set became
/// elastic — the shard-queue set itself (`service::ShardSet`), which
/// rides the identical protocol so grow/shrink gets the same
/// staleness-costs-one-hop guarantee as system moves.
pub(crate) struct EpochCell<T> {
    /// The current epoch. Always points into a `Box` owned by `epochs`.
    current: AtomicPtr<T>,
    /// Readers currently holding an [`EpochRef`]. Writers free parked
    /// epochs only at an observed-zero moment (see `publish`).
    pins: AtomicU64,
    /// Published epochs, oldest first; the last entry is always the
    /// current one. Pruned down to the current epoch when the threshold
    /// is exceeded and no reader is pinned; fully dropped in `Drop`.
    epochs: Mutex<Vec<Box<T>>>,
    /// Monotone count of publications (1 = the initial value);
    /// independent of pruning.
    published: AtomicU64,
}

/// The routing-table publication cell (see [`EpochCell`]).
pub(crate) type RouteCell = EpochCell<RouteTable>;

impl<T: Default> Default for EpochCell<T> {
    fn default() -> Self {
        EpochCell::new()
    }
}

/// A pinned borrow of the current epoch; unpins on drop. Keep it
/// short-lived — a held guard defers (never blocks) epoch pruning.
pub(crate) struct EpochRef<'a, T> {
    cell: &'a EpochCell<T>,
    table: *const T,
}

impl<T> std::ops::Deref for EpochRef<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // Safety: the pin taken before the pointer load keeps writers
        // from freeing this epoch while the guard lives (see `load`).
        unsafe { &*self.table }
    }
}

impl<T> Drop for EpochRef<'_, T> {
    fn drop(&mut self) {
        self.cell.pins.fetch_sub(1, Ordering::SeqCst);
    }
}

impl<T: Default> EpochCell<T> {
    pub fn new() -> EpochCell<T> {
        EpochCell::with_value(T::default())
    }
}

impl<T> EpochCell<T> {
    /// A cell whose first epoch is `value`.
    pub fn with_value(value: T) -> EpochCell<T> {
        let first = Box::new(value);
        let ptr = &*first as *const T as *mut T;
        EpochCell {
            current: AtomicPtr::new(ptr),
            pins: AtomicU64::new(0),
            epochs: Mutex::new(vec![first]),
            published: AtomicU64::new(1),
        }
    }

    /// Lock-free pinned read of the current epoch.
    ///
    /// Soundness of the pin/prune handshake (all SeqCst): the reader
    /// pins *before* loading the pointer; the writer publishes the new
    /// current *before* checking for zero pins. In the SeqCst total
    /// order, a reader that observed an old epoch's pointer did so
    /// before the writer's swap, hence its pin also precedes the
    /// writer's zero-pins check — the writer either sees the pin (and
    /// skips freeing) or the reader has already unpinned (and is done
    /// with the epoch).
    pub fn load(&self) -> EpochRef<'_, T> {
        self.pins.fetch_add(1, Ordering::SeqCst);
        let table = self.current.load(Ordering::SeqCst);
        EpochRef { cell: self, table }
    }

    /// Publish a new epoch derived from the current one. Writers
    /// serialize on the epoch list's mutex; readers are never blocked.
    /// When the parked list outgrows its threshold, epochs older than
    /// the new current are freed at an observed-zero-pins moment
    /// (skipped — not waited for — if readers are active).
    pub fn publish(&self, f: impl FnOnce(&T) -> T) {
        let mut epochs = lock_ignore_poison(&self.epochs);
        // Safe to re-read under the writer lock: publications are
        // serialized here, so `current` cannot move beneath us.
        let cur = unsafe { &*self.current.load(Ordering::SeqCst) };
        let next = Box::new(f(cur));
        let ptr = &*next as *const T as *mut T;
        epochs.push(next);
        self.current.store(ptr, Ordering::SeqCst);
        self.published.fetch_add(1, Ordering::Relaxed);
        if epochs.len() > EPOCH_PRUNE_THRESHOLD && self.pins.load(Ordering::SeqCst) == 0 {
            // zero pins observed after the swap: nobody can still be
            // dereferencing a superseded epoch (see `load`)
            let current = epochs.pop().expect("current epoch present");
            epochs.clear();
            epochs.push(current);
        }
    }

    /// Number of epochs published so far (1 = the initial value);
    /// monotone, unaffected by reclamation.
    pub fn epoch(&self) -> usize {
        self.published.load(Ordering::Relaxed) as usize
    }

    /// Parked epochs currently held (current included) — observability
    /// for the reclamation tests.
    #[cfg(test)]
    fn parked(&self) -> usize {
        lock_ignore_poison(&self.epochs).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(shard: usize, n: usize) -> RouteEntry {
        RouteEntry {
            shard,
            n,
            stats: Arc::new(SystemStats::default()),
        }
    }

    #[test]
    fn publish_is_visible_and_epochs_count() {
        let cell = RouteCell::new();
        assert_eq!(cell.epoch(), 1);
        assert!(cell.load().map.is_empty());
        cell.publish(|t| t.with(7, entry(2, 100)));
        assert_eq!(cell.epoch(), 2);
        let e = cell.load().map.get(&7).expect("published entry");
        assert_eq!((e.shard, e.n), (2, 100));
        cell.publish(|t| t.without(7));
        assert_eq!(cell.epoch(), 3);
        assert!(cell.load().map.is_empty());
    }

    #[test]
    fn stale_borrows_survive_later_publications() {
        // The pinning guarantee: a guard loaded before a swap keeps
        // reading its (stale) epoch safely — pruning is deferred, never
        // forced, while it lives.
        let cell = RouteCell::new();
        cell.publish(|t| t.with(1, entry(0, 10)));
        let stale = cell.load();
        for i in 2..50u64 {
            cell.publish(|t| t.with(i, entry(i as usize % 3, 10)));
        }
        assert_eq!(stale.map.len(), 1, "stale epoch is immutable");
        assert_eq!(cell.load().map.len(), 49);
        assert!(
            cell.parked() > EPOCH_PRUNE_THRESHOLD,
            "pinned reader defers pruning ({} parked)",
            cell.parked()
        );
        drop(stale);
        // with no pins the next publication reclaims the backlog
        cell.publish(|t| t.with(99, entry(0, 10)));
        assert_eq!(cell.parked(), 1, "quiescent publication prunes to current");
        assert_eq!(cell.epoch(), 51, "the publication count is monotone");
        assert_eq!(cell.load().map.len(), 50);
    }

    #[test]
    fn concurrent_readers_race_writers_safely() {
        let cell = Arc::new(RouteCell::new());
        std::thread::scope(|sc| {
            for _ in 0..4 {
                let cell = &cell;
                sc.spawn(move || {
                    for _ in 0..2000 {
                        let t = cell.load();
                        // every observed entry must be internally coherent
                        for (id, e) in &t.map {
                            assert_eq!(e.n, (*id as usize % 7) + 1);
                        }
                    }
                });
            }
            let cell = &cell;
            sc.spawn(move || {
                for i in 0..500u64 {
                    cell.publish(|t| t.with(i, entry(0, (i as usize % 7) + 1)));
                    if i % 3 == 0 {
                        cell.publish(|t| t.without(i / 2));
                    }
                }
            });
        });
    }

    #[test]
    fn epoch_cell_is_generic_over_the_snapshot() {
        // the same cell publishes the shard set in `service::mod` — pin
        // the genericity here with a plain value type
        let cell: EpochCell<Vec<usize>> = EpochCell::with_value(vec![0]);
        assert_eq!(cell.epoch(), 1);
        cell.publish(|v| {
            let mut next = v.clone();
            next.push(next.len());
            next
        });
        assert_eq!(cell.load().as_slice(), &[0, 1]);
        assert_eq!(cell.epoch(), 2);
    }

    #[test]
    fn health_encoding_round_trips_and_counts_edges() {
        for h in [
            Health::Healthy,
            Health::Quarantined(QuarantineReason::ZeroPivot),
            Health::Quarantined(QuarantineReason::Singular),
            Health::Quarantined(QuarantineReason::PivotGrowth),
            Health::Quarantined(QuarantineReason::Panic),
        ] {
            assert_eq!(Health::decode(h.encode()), h);
        }
        let s = SystemStats::default();
        assert_eq!(s.health(), Health::Healthy);
        s.set_health(Health::Quarantined(QuarantineReason::Panic));
        // a reason change inside quarantine is not a second quarantine
        s.set_health(Health::Quarantined(QuarantineReason::ZeroPivot));
        assert_eq!(s.quarantines(), 1);
        s.set_health(Health::Healthy);
        s.set_health(Health::Quarantined(QuarantineReason::PivotGrowth));
        assert_eq!(s.quarantines(), 2);
        s.note_recovery_attempt(false);
        s.note_recovery_attempt(true);
        assert_eq!((s.recovery_attempts(), s.recoveries()), (2, 1));
    }

    #[test]
    fn ewma_tracks_sustained_load() {
        let s = SystemStats::default();
        assert_eq!(s.ewma_load(), 0.0);
        for _ in 0..50 {
            s.update_ewma(8.0);
        }
        assert!((s.ewma_load() - 8.0).abs() < 1e-3, "converges to the rate");
        for _ in 0..50 {
            s.update_ewma(0.0);
        }
        assert!(s.ewma_load() < 1e-3, "decays when quiet");
    }
}

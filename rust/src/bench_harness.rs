//! Benchmark harness: timing, geometric means, and the table printer that
//! regenerates the paper's figures as text series. (criterion is not in the
//! offline registry; a purpose-built harness prints exactly the rows the
//! paper plots anyway.)

use std::time::Instant;

/// Best-of-`reps` wall time of `f` in seconds.
pub fn time_best<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// Geometric mean of positive values.
pub fn geomean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let s: f64 = v.iter().map(|&x| x.max(1e-300).ln()).sum();
    (s / v.len() as f64).exp()
}

/// Table I: testbed environment (the paper's hardware/software table).
pub fn environment() -> String {
    let cores = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    let os = std::fs::read_to_string("/proc/sys/kernel/osrelease")
        .unwrap_or_else(|_| "unknown".into());
    let model = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .map(|l| l.split(':').nth(1).unwrap_or("?").trim().to_string())
        })
        .unwrap_or_else(|| "unknown".into());
    format!(
        "Table I (this testbed): CPU = {model}; cores = {cores}; \
         kernel = {}; HYLU repro = {}; comparators = in-repo PARDISO-like / KLU-like \
         (MKL PARDISO unavailable offline, DESIGN.md §2)",
        os.trim(),
        env!("CARGO_PKG_VERSION"),
    )
}

/// A figure-style results table: per-matrix rows plus geomean footer.
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
    speedups: Vec<f64>,
}

impl Table {
    /// New table with column headers (first column is the matrix name).
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            speedups: Vec::new(),
        }
    }

    /// Add a row; `speedup` feeds the geomean footer.
    pub fn row(&mut self, cells: Vec<String>, speedup: f64) {
        self.rows.push(cells);
        if speedup.is_finite() && speedup > 0.0 {
            self.speedups.push(speedup);
        }
    }

    /// Geomean of the speedup column so far.
    pub fn geomean_speedup(&self) -> f64 {
        geomean(&self.speedups)
    }

    /// Render the full table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let hdr: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        out.push_str(&hdr.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(hdr.join("  ").len()));
        out.push('\n');
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
        }
        out.push_str(&format!(
            "geomean speedup: {:.2}x over {} matrices\n",
            self.geomean_speedup(),
            self.speedups.len()
        ));
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds with sensible units.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_constants() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn time_best_monotone() {
        let t = time_best(3, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(t >= 0.0 && t < 1.0);
    }

    #[test]
    fn table_renders_rows_and_footer() {
        let mut t = Table::new("Fig X", &["matrix", "a", "speedup"]);
        t.row(vec!["m1".into(), "1.0".into(), "2.0".into()], 2.0);
        t.row(vec!["m2".into(), "1.0".into(), "8.0".into()], 8.0);
        let s = t.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("m1"));
        assert!(s.contains("geomean speedup: 4.00x"));
    }

    #[test]
    fn environment_mentions_cores() {
        assert!(environment().contains("cores"));
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with('s'));
        assert!(fmt_time(0.002).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
    }
}

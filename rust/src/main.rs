//! `hylu` CLI — leader entrypoint. See [`hylu::cli`] for usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(hylu::cli::run(&argv));
}

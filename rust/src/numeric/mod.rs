//! Numeric factorization: the paper's hybrid kernels (row-row, sup-row,
//! sup-sup), supernode diagonal pivoting with perturbation, the sequential
//! and dual-mode parallel drivers, and the refactorization fast path. The
//! dense inner loops live in [`kernels`] — tiled microkernels behind a
//! runtime dispatch layer (scalar / portable / AVX2+FMA native).
//!
//! The whole numeric path is generic over the element type via
//! [`Scalar`], defaulting to `f64` everywhere; the `f32` instantiation is
//! the mixed-precision factor core (`Precision::Mixed` in
//! [`crate::coordinator`]).

pub mod factor;
pub mod kernels;
pub mod parallel;
pub mod scalar;
pub mod select;

pub use scalar::Scalar;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::symbolic::Symbolic;

/// Pivoting / perturbation configuration.
#[derive(Clone, Copy, Debug)]
pub struct PivotConfig {
    /// Row swaps inside supernode diagonal blocks (pattern-preserving).
    pub supernode_pivoting: bool,
    /// Replace tiny pivots by `±perturb_eps · max|A|` (SuperLU_DIST-style,
    /// paper ref [13]); triggers iterative refinement in the solve phase.
    pub perturb: bool,
    /// Relative perturbation threshold (default `1e-8 ≈ sqrt(eps)`).
    pub perturb_eps: f64,
}

impl Default for PivotConfig {
    fn default() -> Self {
        PivotConfig {
            supernode_pivoting: true,
            perturb: true,
            perturb_eps: 1e-8,
        }
    }
}

/// Numeric LU factors, laid out against a [`Symbolic`]'s patterns.
///
/// Standalone rows store sparse `lvals`/`uvals` aligned with
/// `sym.lcols`/`sym.ucols` plus `diag`; supernodes store a dense row-major
/// panel `[L-part | diagonal block | U-tail]` per node (L unit diagonal
/// implicit, multipliers in the strictly-lower block triangle).
///
/// Generic over the stored element type (`f64` by default; `f32` for the
/// mixed-precision factor core).
#[derive(Clone, Debug)]
pub struct LuFactors<T = f64> {
    /// Dimension.
    pub n: usize,
    /// Row-node L values (aligned with `sym.lcols`; unused for supernodes).
    pub lvals: Vec<T>,
    /// Row-node U values (aligned with `sym.ucols`; unused for supernodes).
    pub uvals: Vec<T>,
    /// Row-node pivots, indexed by row.
    pub diag: Vec<T>,
    /// Concatenated supernode panels.
    pub panels: Vec<T>,
    /// Panel offset per node (row nodes get a zero-length slot).
    pub panel_ptr: Vec<usize>,
    /// Factor-row -> analyzed-row mapping from supernode diagonal pivoting
    /// (identity outside supernodes). `pivot_perm[i] = r` means factor row
    /// `i` holds row `r` of the permuted input.
    pub pivot_perm: Vec<u32>,
    /// Number of perturbed pivots in the last factorization.
    pub perturbed: usize,
    /// Pivot-growth estimate from the last factorization:
    /// `max|U_ij| / max|A_ij|` (the `‖U‖∞/‖A‖∞`-style stability monitor,
    /// tracked during the factor sweep). `0.0` before the first
    /// factorization; non-finite when the factors went numerically bad.
    pub growth: f64,
}

impl<T: Scalar> LuFactors<T> {
    /// Allocate zeroed factors shaped for `sym`.
    pub fn alloc(sym: &Symbolic) -> Self {
        let mut panel_ptr = Vec::with_capacity(sym.nodes.len() + 1);
        let mut off = 0usize;
        for nd in &sym.nodes {
            panel_ptr.push(off);
            if nd.is_super {
                off += nd.width as usize * nd.panel_width();
            }
        }
        panel_ptr.push(off);
        LuFactors {
            n: sym.n,
            lvals: vec![T::ZERO; sym.lcols.len()],
            uvals: vec![T::ZERO; sym.ucols.len()],
            diag: vec![T::ZERO; sym.n],
            panels: vec![T::ZERO; off],
            panel_ptr,
            pivot_perm: (0..sym.n as u32).collect(),
            perturbed: 0,
            growth: 0.0,
        }
    }

    /// Zero-storage placeholder of dimension `n` with an identity pivot
    /// permutation — the shape the `f64` slot of a mixed-precision
    /// factorization holds while the `f32` factors are the active ones.
    pub fn placeholder(n: usize) -> Self {
        LuFactors {
            n,
            lvals: Vec::new(),
            uvals: Vec::new(),
            diag: Vec::new(),
            panels: Vec::new(),
            panel_ptr: vec![0],
            pivot_perm: (0..n as u32).collect(),
            perturbed: 0,
            growth: 0.0,
        }
    }

    /// Panel slice of node `id`.
    pub fn panel(&self, id: usize) -> &[T] {
        &self.panels[self.panel_ptr[id]..self.panel_ptr[id + 1]]
    }

    /// nnz actually stored (panel cells + sparse rows).
    pub fn stored_entries(&self) -> usize {
        self.lvals.len() + self.uvals.len() + self.diag.len() + self.panels.len()
    }
}

/// Per-thread scratch for numeric factorization, type-tagged by the
/// factor element type (each persistent worker carries one arena per
/// precision; see [`crate::exec::WorkerCtx`]).
pub struct Workspace<T = f64> {
    /// Dense accumulator (row kernels), maintained all-zero between rows.
    pub x: Vec<T>,
    /// Global column -> panel column map (panel kernel), -1 default.
    pub colmap: Vec<i32>,
    /// GEMM output scratch.
    pub cbuf: Vec<T>,
    /// TRSM triangle scratch (column-major gather).
    pub tbuf: Vec<T>,
    /// Scatter map scratch (per-group U-tail -> panel column).
    pub map_idx: Vec<i32>,
    /// GEMM B-operand packing scratch (source-panel U-tail sliver,
    /// gathered contiguous once per target panel).
    pub pbuf: Vec<T>,
    /// GEMM A-operand packing scratch (target-panel L-part columns,
    /// gathered contiguous when the tuned `KernelPlan` enables A packing).
    pub abuf: Vec<T>,
}

impl<T: Scalar> Workspace<T> {
    /// Fresh workspace for dimension `n`.
    pub fn new(n: usize) -> Self {
        Workspace {
            x: vec![T::ZERO; n],
            colmap: vec![-1; n],
            cbuf: Vec::new(),
            tbuf: Vec::new(),
            map_idx: Vec::new(),
            pbuf: Vec::new(),
            abuf: Vec::new(),
        }
    }

    /// Empty workspace (grown on demand by [`Workspace::ensure`]) — the
    /// shape used by the persistent worker arenas in [`crate::exec`].
    pub fn empty() -> Self {
        Workspace::new(0)
    }

    /// Grow the dense accumulator and column map to dimension `n`,
    /// preserving the all-zero / all-`-1` between-use invariants. Returns
    /// `true` when storage actually grew (an allocation happened) so
    /// callers can account scratch allocations.
    pub fn ensure(&mut self, n: usize) -> bool {
        if self.x.len() >= n {
            return false;
        }
        self.x.resize(n, T::ZERO);
        self.colmap.resize(n, -1);
        true
    }

    /// Pre-reserve the kernel scratch vectors (`cbuf`/`tbuf`/`map_idx`/
    /// `pbuf`/`abuf`) to the given capacities so the numeric kernels never
    /// reallocate mid-factorization. Returns `true` when any buffer grew.
    pub fn reserve_kernel(
        &mut self,
        cbuf: usize,
        tbuf: usize,
        map_idx: usize,
        pbuf: usize,
        abuf: usize,
    ) -> bool {
        let mut grew = false;
        if self.cbuf.capacity() < cbuf {
            self.cbuf.reserve(cbuf - self.cbuf.len());
            grew = true;
        }
        if self.tbuf.capacity() < tbuf {
            self.tbuf.reserve(tbuf - self.tbuf.len());
            grew = true;
        }
        if self.map_idx.capacity() < map_idx {
            self.map_idx.reserve(map_idx - self.map_idx.len());
            grew = true;
        }
        if self.pbuf.capacity() < pbuf {
            self.pbuf.reserve(pbuf - self.pbuf.len());
            grew = true;
        }
        if self.abuf.capacity() < abuf {
            self.abuf.reserve(abuf - self.abuf.len());
            grew = true;
        }
        grew
    }

    /// Restore the between-use invariants unconditionally (used after a
    /// caught panic may have left a kernel half-way through a node).
    pub fn scrub(&mut self) {
        self.x.fill(T::ZERO);
        self.colmap.fill(-1);
    }
}

/// Shared mutable view over [`LuFactors`] used by the parallel driver.
///
/// Safety contract: each node's storage (its panel range / lvals / uvals /
/// diag / pivot_perm rows) is written by exactly one thread, and reads of a
/// *source* node's storage happen only after its done-flag is observed with
/// Acquire ordering (or, in the sequential driver, after program order).
pub(crate) struct SharedFactors<T = f64> {
    pub lvals: *mut T,
    pub uvals: *mut T,
    pub diag: *mut T,
    pub panels: *mut T,
    pub pivot_perm: *mut u32,
    pub perturbed: AtomicUsize,
    /// Running `max|U_ij|` over finalized factor rows, stored as `f64`
    /// bits (monotone CAS max; non-negative, so the float compare below
    /// is total except for NaN, which is handled explicitly).
    pub umax: AtomicU64,
    pub panel_ptr: *const usize,
}

unsafe impl<T: Scalar> Send for SharedFactors<T> {}
unsafe impl<T: Scalar> Sync for SharedFactors<T> {}

impl<T: Scalar> SharedFactors<T> {
    pub fn new(fac: &mut LuFactors<T>) -> Self {
        SharedFactors {
            lvals: fac.lvals.as_mut_ptr(),
            uvals: fac.uvals.as_mut_ptr(),
            diag: fac.diag.as_mut_ptr(),
            panels: fac.panels.as_mut_ptr(),
            pivot_perm: fac.pivot_perm.as_mut_ptr(),
            perturbed: AtomicUsize::new(0),
            umax: AtomicU64::new(0),
            panel_ptr: fac.panel_ptr.as_ptr(),
        }
    }

    /// Mutable panel slice for node `id` (must be the owning thread).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn panel_mut(&self, id: usize) -> &mut [T] {
        let s = *self.panel_ptr.add(id);
        let e = *self.panel_ptr.add(id + 1);
        std::slice::from_raw_parts_mut(self.panels.add(s), e - s)
    }

    /// Read-only panel slice for a completed source node.
    pub unsafe fn panel_ref(&self, id: usize) -> &[T] {
        let s = *self.panel_ptr.add(id);
        let e = *self.panel_ptr.add(id + 1);
        std::slice::from_raw_parts(self.panels.add(s), e - s)
    }

    pub fn add_perturbed(&self, k: usize) {
        if k > 0 {
            self.perturbed.fetch_add(k, Ordering::Relaxed);
        }
    }

    /// Fold a node-local `max|U_ij|` into the shared running maximum.
    /// A NaN sample wins over any finite value (and then sticks), so a
    /// factorization that went numerically bad surfaces as non-finite
    /// growth instead of being masked by a later finite node.
    pub fn update_umax(&self, v: f64) {
        let mut cur = self.umax.load(Ordering::Relaxed);
        loop {
            let c = f64::from_bits(cur);
            if c.is_nan() || v <= c {
                return;
            }
            match self.umax.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// The accumulated `max|U_ij|` of this factorization.
    pub fn umax_value(&self) -> f64 {
        f64::from_bits(self.umax.load(Ordering::Relaxed))
    }
}

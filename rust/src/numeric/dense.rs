//! Dense microkernels for the supernode panels — the CPU-native analogue of
//! the MKL BLAS calls in the paper (the Pallas/XLA path in
//! [`crate::runtime`] is the TPU-shaped alternative; see DESIGN.md
//! §Hardware-Adaptation).
//!
//! All matrices are row-major with explicit leading dimensions (panels are
//! strided). Kernels are written so the hot loops vectorize: fixed 4-wide
//! row blocking on GEMM with contiguous inner axpy loops.

/// `C[m×n] -= A[m×k] · B[k×n]`, row-major with leading dimensions
/// `lda/ldb/ldc`. The sup-sup update's level-3 core.
#[allow(clippy::too_many_arguments)]
pub fn gemm_sub(
    c: &mut [f64],
    ldc: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    debug_assert!(c.len() >= (m - 1) * ldc + n);
    debug_assert!(a.len() >= (m - 1) * lda + k);
    debug_assert!(b.len() >= (k - 1) * ldb + n);
    // Safety: bounds established by the debug_asserts above (callers pass
    // panel-backed slices with exact leading dimensions).
    unsafe { gemm_sub_raw(c.as_mut_ptr(), ldc, a.as_ptr(), lda, b.as_ptr(), ldb, m, k, n) }
}

/// Raw-pointer core of [`gemm_sub`]: register-tiled 4x16 microkernel. A
/// 4-row x 16-col C tile lives in registers (8 zmm accumulators on AVX-512)
/// across the whole k loop; the j chunk is OUTER so each (k x 16) B sliver
/// stays in L1 across row blocks. Also used by the sup-sup kernel's
/// contiguous fast path, where A and C are disjoint column ranges of the
/// same panel (element-disjoint, so raw pointers, not slices).
///
/// Safety: `cp/ap/bp` must be valid for the strided `m x n`, `m x k`,
/// `k x n` accesses, and the C range must not overlap A or B element-wise.
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_sub_raw(
    cp: *mut f64,
    ldc: usize,
    ap: *const f64,
    lda: usize,
    bp: *const f64,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    {
        // j-chunk OUTER so each (k x 16) B sliver stays in L1 across all
        // row blocks; C tiles are touched exactly once.
        let mut j = 0;
        while j + 16 <= n {
            let mut i = 0;
            while i + 4 <= m {
                let a0 = ap.add(i * lda);
                let a1 = ap.add((i + 1) * lda);
                let a2 = ap.add((i + 2) * lda);
                let a3 = ap.add((i + 3) * lda);
                let c0 = cp.add(i * ldc + j);
                let c1 = cp.add((i + 1) * ldc + j);
                let c2 = cp.add((i + 2) * ldc + j);
                let c3 = cp.add((i + 3) * ldc + j);
                let mut t0 = [0.0f64; 16];
                let mut t1 = [0.0f64; 16];
                let mut t2 = [0.0f64; 16];
                let mut t3 = [0.0f64; 16];
                for q in 0..16 {
                    t0[q] = *c0.add(q);
                    t1[q] = *c1.add(q);
                    t2[q] = *c2.add(q);
                    t3[q] = *c3.add(q);
                }
                for p in 0..k {
                    let f0 = *a0.add(p);
                    let f1 = *a1.add(p);
                    let f2 = *a2.add(p);
                    let f3 = *a3.add(p);
                    let brow = bp.add(p * ldb + j);
                    for q in 0..16 {
                        let bv = *brow.add(q);
                        t0[q] -= f0 * bv;
                        t1[q] -= f1 * bv;
                        t2[q] -= f2 * bv;
                        t3[q] -= f3 * bv;
                    }
                }
                for q in 0..16 {
                    *c0.add(q) = t0[q];
                    *c1.add(q) = t1[q];
                    *c2.add(q) = t2[q];
                    *c3.add(q) = t3[q];
                }
                i += 4;
            }
            // row remainder (m % 4) for this j chunk
            while i < m {
                let arow = ap.add(i * lda);
                let crow = cp.add(i * ldc + j);
                let mut t = [0.0f64; 16];
                for q in 0..16 {
                    t[q] = *crow.add(q);
                }
                for p in 0..k {
                    let f = *arow.add(p);
                    let brow = bp.add(p * ldb + j);
                    for q in 0..16 {
                        t[q] -= f * *brow.add(q);
                    }
                }
                for q in 0..16 {
                    *crow.add(q) = t[q];
                }
                i += 1;
            }
            j += 16;
        }
        if j < n {
            // column remainder: simple row loop with zero-skip
            for i in 0..m {
                let arow = ap.add(i * lda);
                let crow = cp.add(i * ldc);
                for p in 0..k {
                    let f = *arow.add(p);
                    if f == 0.0 {
                        continue; // padded L columns are exactly zero
                    }
                    let brow = bp.add(p * ldb);
                    for jj in j..n {
                        *crow.add(jj) -= f * *brow.add(jj);
                    }
                }
            }
        }
    }
}

/// In-place right triangular solve `X · U = B` where `U` is the `len×len`
/// upper-triangular (non-unit) diagonal sub-block of a source supernode
/// panel, and `B`/`X` occupy `len` *columns* of the target panel starting at
/// `x_off`. Column-forward substitution; this is the TRSM half of the
/// sup-sup kernel.
///
/// `u` points at the source panel; row `r` of the sub-block lives at
/// `u[(u_row0 + r) * ldu + u_col0 + r .. ]` (upper triangle only read).
#[allow(clippy::too_many_arguments)]
pub fn trsm_right_upper(
    x: &mut [f64],
    ldx: usize,
    x_off: usize,
    m: usize,
    u: &[f64],
    ldu: usize,
    u_row0: usize,
    u_col0: usize,
    len: usize,
    scratch: &mut Vec<f64>,
) {
    if len >= 48 && m >= 8 {
        // Large triangles: gather columns into a contiguous column-major
        // scratch so the reduction streams linearly instead of striding by
        // ldu per element. (Small triangles stay in L1 either way and the
        // gather costs more than it saves — measured, EXPERIMENTS.md §Perf.)
        scratch.clear();
        scratch.resize(len * len, 0.0);
        let ucols: &mut [f64] = scratch;
        for cc in 0..len {
            for pp in 0..=cc {
                ucols[cc * len + pp] = u[(u_row0 + pp) * ldu + u_col0 + cc];
            }
        }
        for cc in 0..len {
            let col = &ucols[cc * len..cc * len + cc];
            let inv = 1.0 / ucols[cc * len + cc];
            for r in 0..m {
                let row = &mut x[r * ldx + x_off..r * ldx + x_off + len];
                let s = row[cc] - dot(&row[..cc], col);
                row[cc] = s * inv;
            }
        }
        return;
    }
    for cc in 0..len {
        let ucc = u[(u_row0 + cc) * ldu + u_col0 + cc];
        let inv = 1.0 / ucc;
        // X[:, cc] = (B[:, cc] - X[:, 0..cc] * U[0..cc, cc]) / U[cc, cc]
        for r in 0..m {
            let row = &mut x[r * ldx + x_off..r * ldx + x_off + len];
            let mut s = row[cc];
            for pp in 0..cc {
                s -= row[pp] * u[(u_row0 + pp) * ldu + u_col0 + cc];
            }
            row[cc] = s * inv;
        }
    }
}

/// `y[0..n] -= f * x[0..n]` (axpy with negative sign).
#[inline]
pub fn axpy_sub(y: &mut [f64], x: &[f64], f: f64) {
    debug_assert!(y.len() >= x.len());
    for (yy, xx) in y.iter_mut().zip(x) {
        *yy -= f * xx;
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let mut i = 0;
    let n = a.len().min(b.len());
    while i + 4 <= n {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    while i < n {
        s0 += a[i] * b[i];
        i += 1;
    }
    s0 + s1 + s2 + s3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Prng;

    fn naive_gemm_sub(c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize) {
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] -= s;
            }
        }
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Prng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 2, 5), (4, 4, 4), (7, 5, 9), (12, 8, 16)] {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let mut c1: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut c2 = c1.clone();
            gemm_sub(&mut c1, n, &a, k, &b, n, m, k, n);
            naive_gemm_sub(&mut c2, &a, &b, m, k, n);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gemm_respects_leading_dimensions() {
        let mut rng = Prng::new(4);
        let (m, k, n) = (3usize, 2usize, 4usize);
        let (lda, ldb, ldc) = (5usize, 7usize, 6usize);
        let a: Vec<f64> = (0..m * lda).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * ldb).map(|_| rng.normal()).collect();
        let mut c: Vec<f64> = (0..m * ldc).map(|_| rng.normal()).collect();
        let c0 = c.clone();
        gemm_sub(&mut c, ldc, &a, lda, &b, ldb, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[i * lda + p] * b[p * ldb + j];
                }
                assert!((c[i * ldc + j] - (c0[i * ldc + j] - s)).abs() < 1e-12);
            }
            // untouched beyond n
            for j in n..ldc {
                assert_eq!(c[i * ldc + j], c0[i * ldc + j]);
            }
        }
    }

    #[test]
    fn trsm_solves_upper_system() {
        let mut rng = Prng::new(5);
        let len = 5usize;
        let m = 3usize;
        let ldu = 8usize;
        // source "panel": upper triangle at (row0=1, col0=2)
        let mut u = vec![0.0; (len + 1) * ldu];
        for r in 0..len {
            for c in r..len {
                u[(1 + r) * ldu + 2 + c] = if r == c {
                    2.0 + rng.uniform()
                } else {
                    rng.normal() * 0.3
                };
            }
        }
        // target panel: X region at offset 1, width len, ldx = len + 3
        let ldx = len + 3;
        let mut x = vec![0.0; m * ldx];
        let xs: Vec<f64> = (0..m * len).map(|_| rng.normal()).collect(); // true solution
        // B = Xs * U
        for r in 0..m {
            for c in 0..len {
                let mut s = 0.0;
                for p in 0..=c {
                    s += xs[r * len + p] * u[(1 + p) * ldu + 2 + c];
                }
                x[r * ldx + 1 + c] = s;
            }
        }
        trsm_right_upper(&mut x, ldx, 1, m, &u, ldu, 1, 2, len, &mut Vec::new());
        for r in 0..m {
            for c in 0..len {
                assert!(
                    (x[r * ldx + 1 + c] - xs[r * len + c]).abs() < 1e-10,
                    "({r},{c})"
                );
            }
        }
    }

    #[test]
    fn dot_and_axpy() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 2.0, 2.0, 2.0];
        assert_eq!(dot(&a, &b), 30.0);
        let mut y = [10.0, 10.0, 10.0];
        axpy_sub(&mut y, &[1.0, 2.0, 3.0], 2.0);
        assert_eq!(y, [8.0, 6.0, 4.0]);
    }
}

//! Dual-mode parallel numeric factorization (paper §2.2.1, Fig. 2).
//!
//! Front (wide) levels run in **bulk mode**: each level's nodes are split
//! among threads balanced by flop estimates, with a barrier between levels.
//! The tail of the DAG — typically a long dependent chain — runs in
//! **pipeline mode**: workers claim nodes from a shared topological cursor
//! and spin on the done-flags of each claimed node's dependencies, so
//! dependent nodes overlap at sub-node granularity instead of serializing
//! on level barriers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;

use crate::numeric::factor::{factor_node, GemmBackend};
use crate::numeric::select::KernelMode;
use crate::numeric::{LuFactors, PivotConfig, SharedFactors, Workspace};
use crate::par::{balanced_chunks, DoneFlags};
use crate::sparse::csr::Csr;
use crate::symbolic::Symbolic;

/// Parallel factor/refactor. Falls back to the sequential driver for
/// `nthreads <= 1`. Returns the number of perturbed pivots.
#[allow(clippy::too_many_arguments)]
pub fn factor_parallel(
    a: &Csr,
    sym: &Symbolic,
    mode: KernelMode,
    cfg: &PivotConfig,
    fac: &mut LuFactors,
    refactor: bool,
    gemm: &(dyn GemmBackend + Sync),
    nthreads: usize,
) -> usize {
    if nthreads <= 1 || sym.nodes.len() < 2 {
        return crate::numeric::factor::factor(a, sym, mode, cfg, fac, refactor, gemm);
    }
    if !refactor {
        for (i, p) in fac.pivot_perm.iter_mut().enumerate() {
            *p = i as u32;
        }
    }
    let eps_abs = if cfg.perturb {
        cfg.perturb_eps * a.max_abs().max(1e-300)
    } else {
        0.0
    };
    let sf = SharedFactors::new(fac);
    let sched = &sym.schedule;
    let done = DoneFlags::new(sym.nodes.len());
    let barrier = Barrier::new(nthreads);

    // pre-compute per-level thread chunks balanced by flops
    let mut chunks: Vec<Vec<(usize, usize)>> = Vec::with_capacity(sched.bulk_levels);
    for lv in 0..sched.bulk_levels {
        let ids = sched.nodes_at(lv);
        let weights: Vec<f64> = ids.iter().map(|&id| sym.nodes[id as usize].flops).collect();
        chunks.push(balanced_chunks(&weights, nthreads));
    }
    // pipeline segment: nodes at levels >= bulk_levels, topological order
    let pipe_start = sched.level_ptr[sched.bulk_levels];
    let pipe_nodes = &sched.level_nodes[pipe_start..];
    let cursor = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for t in 0..nthreads {
            let sfr = &sf;
            let doner = &done;
            let barrierr = &barrier;
            let chunksr = &chunks;
            let cursorr = &cursor;
            scope.spawn(move || {
                let mut ws = Workspace::new(sym.n);
                // bulk mode
                for (lv, lv_chunks) in chunksr.iter().enumerate() {
                    let ids = sched.nodes_at(lv);
                    let (s, e) = lv_chunks[t];
                    for &id in &ids[s..e] {
                        // Safety: deps are in earlier levels (complete
                        // before the previous barrier); this node's storage
                        // is written only by this thread.
                        unsafe {
                            factor_node(
                                id as usize,
                                a,
                                sym,
                                sfr,
                                &mut ws,
                                mode,
                                cfg,
                                eps_abs,
                                refactor,
                                gemm,
                            )
                        };
                        doner.set(id as usize);
                    }
                    barrierr.wait();
                }
                // pipeline mode
                loop {
                    let k = cursorr.fetch_add(1, Ordering::Relaxed);
                    if k >= pipe_nodes.len() {
                        break;
                    }
                    let id = pipe_nodes[k] as usize;
                    let nd = &sym.nodes[id];
                    for g in &sym.groups[nd.g_start..nd.g_end] {
                        doner.wait(g.src as usize);
                    }
                    // Safety: all deps observed complete (Acquire above).
                    unsafe {
                        factor_node(id, a, sym, sfr, &mut ws, mode, cfg, eps_abs, refactor, gemm)
                    };
                    doner.set(id);
                }
            });
        }
    });

    let perturbed = sf.perturbed.load(Ordering::Relaxed);
    fac.perturbed = perturbed;
    perturbed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::factor::{factor, NativeGemm};
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};

    /// Parallel factorization must produce bit-identical factors to the
    /// sequential driver (same operations, same order per node).
    fn check_parallel_matches_sequential(a: &crate::sparse::csr::Csr, mode: KernelMode) {
        let policy = match mode {
            KernelMode::RowRow => MergePolicy::None,
            _ => MergePolicy::Exact { max_width: 16 },
        };
        let sym = analyze_pattern(a, policy, 4);
        let cfg = PivotConfig::default();
        let mut f1 = LuFactors::alloc(&sym);
        factor(a, &sym, mode, &cfg, &mut f1, false, &NativeGemm);
        for threads in [2usize, 4] {
            let mut f2 = LuFactors::alloc(&sym);
            factor_parallel(a, &sym, mode, &cfg, &mut f2, false, &NativeGemm, threads);
            assert_eq!(f1.pivot_perm, f2.pivot_perm, "pivot mismatch t={threads}");
            assert_eq!(f1.panels, f2.panels, "panel mismatch t={threads}");
            assert_eq!(f1.lvals, f2.lvals, "lvals mismatch t={threads}");
            assert_eq!(f1.uvals, f2.uvals, "uvals mismatch t={threads}");
            assert_eq!(f1.diag, f2.diag, "diag mismatch t={threads}");
        }
    }

    #[test]
    fn parallel_grid_supsup() {
        check_parallel_matches_sequential(&gen::grid2d(12, 12), KernelMode::SupSup);
    }

    #[test]
    fn parallel_circuit_rowrow() {
        check_parallel_matches_sequential(&gen::circuit(400, 2), KernelMode::RowRow);
    }

    #[test]
    fn parallel_power_suprow() {
        check_parallel_matches_sequential(&gen::power_network(300, 5), KernelMode::SupRow);
    }

    #[test]
    fn parallel_banded_pipeline_heavy() {
        // long chain: exercises pipeline mode spin-waits
        check_parallel_matches_sequential(&gen::banded(200, 4, 7), KernelMode::SupSup);
    }

    #[test]
    fn parallel_refactor_matches() {
        let a = gen::grid2d(10, 10);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let cfg = PivotConfig::default();
        let mut f1 = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut f1, false, &NativeGemm);
        let mut f2 = f1.clone();
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut f1, true, &NativeGemm);
        factor_parallel(
            &a,
            &sym,
            KernelMode::SupSup,
            &cfg,
            &mut f2,
            true,
            &NativeGemm,
            3,
        );
        assert_eq!(f1.panels, f2.panels);
        assert_eq!(f1.diag, f2.diag);
    }
}

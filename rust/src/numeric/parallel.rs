//! Dual-mode parallel numeric factorization (paper §2.2.1, Fig. 2) on the
//! persistent worker pool.
//!
//! Front (wide) levels run in **bulk mode**: each level's nodes are split
//! among workers balanced by flop estimates, with a barrier between levels.
//! The tail of the DAG — typically a long dependent chain — runs in
//! **pipeline mode**: workers claim nodes from a shared topological cursor
//! and spin on the done-flags of each claimed node's dependencies, so
//! dependent nodes overlap at sub-node granularity instead of serializing
//! on level barriers.
//!
//! The drivers run as jobs on a [`WorkerPool`]: no OS threads are spawned
//! per call, each worker reuses its persistent
//! [`crate::numeric::Workspace`] arena, the level chunks come precomputed
//! from an [`ExecPlan`], and the pipeline done-flags are a caller-owned
//! reusable arena. The [`factor_parallel`] wrapper keeps the old
//! spawn-per-call signature for standalone use (tests, one-shot tools) by
//! building a temporary pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;

use crate::exec::{ExecPlan, WorkerPool};
use crate::numeric::factor::{factor_node, GemmBackend};
use crate::numeric::select::KernelMode;
use crate::numeric::{LuFactors, PivotConfig, Scalar, SharedFactors};
use crate::par::DoneFlags;
use crate::sparse::csr::Csr;
use crate::symbolic::Symbolic;

/// Parallel factor/refactor as a job on a persistent pool. Runs
/// sequentially (on worker 0's arena) for single-worker pools or trivial
/// DAGs. Returns the number of perturbed pivots.
///
/// The plan is normally built for `sym` with `plan.nthreads ==
/// pool.nthreads()` (the coordinator builds both from the same config); a
/// mismatched plan — an `Analysis` used with a different solver — falls
/// back to rebuilding a throwaway plan for this pool's width.
///
/// `done` is the caller's reusable pipeline-mode done-flag arena (at least
/// `sym.nodes.len()` flags); it is reset under the pool's dispatch lock.
/// It lives with the caller — not in the shared plan — so one `Analysis`
/// used by two solvers concurrently cannot race on it.
#[allow(clippy::too_many_arguments)]
pub fn factor_parallel_pooled<T: Scalar>(
    a: &Csr,
    sym: &Symbolic,
    mode: KernelMode,
    cfg: &PivotConfig,
    fac: &mut LuFactors<T>,
    refactor: bool,
    gemm: &(dyn GemmBackend + Sync),
    pool: &WorkerPool,
    plan: &ExecPlan,
    done: &DoneFlags,
) -> usize {
    assert!(
        done.len() >= sym.nodes.len(),
        "done-flag arena smaller than the node count"
    );
    let mut plan_storage = None;
    let plan = plan.for_width(sym, pool.nthreads(), &mut plan_storage);
    if !refactor {
        for (i, p) in fac.pivot_perm.iter_mut().enumerate() {
            *p = i as u32;
        }
    }
    let amax = a.max_abs();
    let eps_abs = if cfg.perturb {
        cfg.perturb_eps * amax.max(1e-300)
    } else {
        0.0
    };
    let sf = SharedFactors::new(fac);
    let sched = &sym.schedule;
    let nthreads = pool.nthreads();
    let sequential = nthreads <= 1 || sym.nodes.len() < 2;
    let barrier = Barrier::new(nthreads);
    // pipeline segment: nodes at levels >= bulk_levels, topological order
    let pipe_start = sched.level_ptr[sched.bulk_levels];
    let pipe_nodes = &sched.level_nodes[pipe_start..];
    let cursor = AtomicUsize::new(0);

    pool.run(
        || done.reset(),
        |t, ctx| {
            // T::workspace routes to the worker's per-precision arena
            // (`ws` for f64, `ws32` for f32) so one pool serves both.
            let ws = T::workspace(
                ctx,
                sym.n,
                plan.max_cbuf,
                plan.max_tbuf,
                plan.max_map,
                plan.max_pbuf,
                plan.max_abuf,
            );
            let kp = &plan.kernel;
            if sequential {
                if t == 0 {
                    for id in 0..sym.nodes.len() {
                        // Safety: sequential — every source node is
                        // complete in program order.
                        unsafe {
                            factor_node(
                                id, a, sym, &sf, ws, mode, cfg, eps_abs, refactor, gemm, kp,
                            )
                        };
                    }
                }
                return;
            }
            // bulk mode
            for (lv, lv_chunks) in plan.factor_chunks.iter().enumerate() {
                let ids = sched.nodes_at(lv);
                let (s, e) = lv_chunks[t];
                for &id in &ids[s..e] {
                    // Safety: deps are in earlier levels (complete before
                    // the previous barrier); this node's storage is
                    // written only by this worker.
                    unsafe {
                        factor_node(
                            id as usize,
                            a,
                            sym,
                            &sf,
                            ws,
                            mode,
                            cfg,
                            eps_abs,
                            refactor,
                            gemm,
                            kp,
                        )
                    };
                    done.set(id as usize);
                }
                barrier.wait();
            }
            // pipeline mode
            loop {
                let k = cursor.fetch_add(1, Ordering::Relaxed);
                if k >= pipe_nodes.len() {
                    break;
                }
                let id = pipe_nodes[k] as usize;
                let nd = &sym.nodes[id];
                for g in &sym.groups[nd.g_start..nd.g_end] {
                    done.wait(g.src as usize);
                }
                // Safety: all deps observed complete (Acquire above).
                unsafe {
                    factor_node(id, a, sym, &sf, ws, mode, cfg, eps_abs, refactor, gemm, kp)
                };
                done.set(id);
            }
        },
    );

    let perturbed = sf.perturbed.load(Ordering::Relaxed);
    fac.perturbed = perturbed;
    // the atomic max is schedule-independent, so parallel growth is
    // bit-identical to the sequential driver's
    fac.growth = crate::numeric::factor::pivot_growth(sf.umax_value(), amax);
    perturbed
}

/// Standalone parallel factor/refactor: spawns a temporary pool (and
/// builds a throwaway plan) per call. Falls back to the sequential driver
/// for `nthreads <= 1`. Returns the number of perturbed pivots.
///
/// Repeated-solve callers should go through
/// [`crate::coordinator::Solver`], which owns a persistent pool and a
/// cached plan instead.
#[allow(clippy::too_many_arguments)]
pub fn factor_parallel<T: Scalar>(
    a: &Csr,
    sym: &Symbolic,
    mode: KernelMode,
    cfg: &PivotConfig,
    fac: &mut LuFactors<T>,
    refactor: bool,
    gemm: &(dyn GemmBackend + Sync),
    nthreads: usize,
) -> usize {
    if nthreads <= 1 || sym.nodes.len() < 2 {
        return crate::numeric::factor::factor(a, sym, mode, cfg, fac, refactor, gemm);
    }
    let pool = WorkerPool::new(nthreads);
    let plan = ExecPlan::build(sym, nthreads);
    let done = DoneFlags::new(sym.nodes.len());
    factor_parallel_pooled(a, sym, mode, cfg, fac, refactor, gemm, &pool, &plan, &done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::factor::{factor, NativeGemm};
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};

    /// Parallel factorization must produce bit-identical factors to the
    /// sequential driver (same operations, same order per node).
    fn check_parallel_matches_sequential(a: &crate::sparse::csr::Csr, mode: KernelMode) {
        let policy = match mode {
            KernelMode::RowRow => MergePolicy::None,
            _ => MergePolicy::Exact { max_width: 16 },
        };
        let sym = analyze_pattern(a, policy, 4);
        let cfg = PivotConfig::default();
        let mut f1: LuFactors = LuFactors::alloc(&sym);
        factor(a, &sym, mode, &cfg, &mut f1, false, &NativeGemm);
        for threads in [2usize, 4] {
            let mut f2: LuFactors = LuFactors::alloc(&sym);
            factor_parallel(a, &sym, mode, &cfg, &mut f2, false, &NativeGemm, threads);
            assert_eq!(f1.pivot_perm, f2.pivot_perm, "pivot mismatch t={threads}");
            assert_eq!(f1.panels, f2.panels, "panel mismatch t={threads}");
            assert_eq!(f1.lvals, f2.lvals, "lvals mismatch t={threads}");
            assert_eq!(f1.uvals, f2.uvals, "uvals mismatch t={threads}");
            assert_eq!(f1.diag, f2.diag, "diag mismatch t={threads}");
        }
        // a persistent pool re-running the same factorization must also be
        // bit-identical, including refactor replays on warm arenas
        let pool = WorkerPool::new(3);
        let plan = ExecPlan::build(&sym, 3);
        let done = DoneFlags::new(sym.nodes.len());
        let mut f3: LuFactors = LuFactors::alloc(&sym);
        for round in 0..3 {
            let refactor = round > 0;
            factor_parallel_pooled(
                a, &sym, mode, &cfg, &mut f3, refactor, &NativeGemm, &pool, &plan, &done,
            );
            assert_eq!(f1.pivot_perm, f3.pivot_perm, "pooled pivot, round {round}");
            assert_eq!(f1.panels, f3.panels, "pooled panels, round {round}");
            assert_eq!(f1.lvals, f3.lvals, "pooled lvals, round {round}");
            assert_eq!(f1.uvals, f3.uvals, "pooled uvals, round {round}");
            assert_eq!(f1.diag, f3.diag, "pooled diag, round {round}");
        }
    }

    #[test]
    fn parallel_grid_supsup() {
        check_parallel_matches_sequential(&gen::grid2d(12, 12), KernelMode::SupSup);
    }

    #[test]
    fn parallel_circuit_rowrow() {
        check_parallel_matches_sequential(&gen::circuit(400, 2), KernelMode::RowRow);
    }

    #[test]
    fn parallel_power_suprow() {
        check_parallel_matches_sequential(&gen::power_network(300, 5), KernelMode::SupRow);
    }

    #[test]
    fn parallel_banded_pipeline_heavy() {
        // long chain: exercises pipeline mode spin-waits
        check_parallel_matches_sequential(&gen::banded(200, 4, 7), KernelMode::SupSup);
    }

    #[test]
    fn parallel_refactor_matches() {
        let a = gen::grid2d(10, 10);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let cfg = PivotConfig::default();
        let mut f1: LuFactors = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut f1, false, &NativeGemm);
        let mut f2 = f1.clone();
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut f1, true, &NativeGemm);
        factor_parallel(
            &a,
            &sym,
            KernelMode::SupSup,
            &cfg,
            &mut f2,
            true,
            &NativeGemm,
            3,
        );
        assert_eq!(f1.panels, f2.panels);
        assert_eq!(f1.diag, f2.diag);
    }

    #[test]
    fn parallel_f32_matches_sequential_f32_bitwise() {
        // the parallel-vs-sequential bit-identity contract holds for the
        // f32 numeric core too (same per-node operations, same order)
        let a = gen::grid2d(10, 11);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let cfg = PivotConfig::default();
        let mut f1: LuFactors<f32> = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut f1, false, &NativeGemm);
        for threads in [2usize, 3] {
            let mut f2: LuFactors<f32> = LuFactors::alloc(&sym);
            factor_parallel(
                &a,
                &sym,
                KernelMode::SupSup,
                &cfg,
                &mut f2,
                false,
                &NativeGemm,
                threads,
            );
            assert_eq!(f1.pivot_perm, f2.pivot_perm, "f32 pivot, t={threads}");
            assert_eq!(f1.panels, f2.panels, "f32 panels, t={threads}");
            assert_eq!(f1.diag, f2.diag, "f32 diag, t={threads}");
        }
    }

    #[test]
    fn single_worker_pool_matches_sequential_driver() {
        let a = gen::grid2d(9, 9);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let cfg = PivotConfig::default();
        let mut f1: LuFactors = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut f1, false, &NativeGemm);
        let pool = WorkerPool::new(1);
        let plan = ExecPlan::build(&sym, 1);
        let done = DoneFlags::new(sym.nodes.len());
        let mut f2: LuFactors = LuFactors::alloc(&sym);
        factor_parallel_pooled(
            &a,
            &sym,
            KernelMode::SupSup,
            &cfg,
            &mut f2,
            false,
            &NativeGemm,
            &pool,
            &plan,
            &done,
        );
        assert_eq!(f1.panels, f2.panels);
        assert_eq!(f1.diag, f2.diag);
        assert_eq!(f1.pivot_perm, f2.pivot_perm);
    }
}

//! Scalar abstraction over the numeric core's element type.
//!
//! Every layer of the numeric path — the factor drivers, the kernel
//! tiers, the substitution kernels, and the workspace arenas — is generic
//! over [`Scalar`], instantiated at `f64` (the default everywhere, via
//! default type parameters) and `f32` (the mixed-precision factor core;
//! see the `Precision` policy in [`crate::coordinator`]). The trait is
//! deliberately small: plain IEEE arithmetic plus explicit `f64`
//! conversions, and three capability hooks that let the generic code
//! reach precision-specific machinery without `cfg` soup at every call
//! site:
//!
//! - [`Scalar::workspace`] selects the per-worker arena of this
//!   precision out of a [`crate::exec::WorkerCtx`] (each worker carries
//!   one type-tagged [`Workspace`] per precision, both bounded by the
//!   same element-count `ExecPlan` high-water marks).
//! - [`Scalar::backend_gemm`] routes through the pluggable
//!   [`GemmBackend`] (the XLA/PJRT ablation path), which is `f64`-only —
//!   `f32` returns `false` and the caller takes the in-process kernels.
//! - The `native_*` hooks expose the AVX2+FMA `std::arch` microkernels,
//!   which exist only for `f64`; `f32` reports "not handled" and the
//!   dispatch layer falls through to the portable tier (whose blocked
//!   shapes the autovectorizer lowers at twice the lane width for `f32`
//!   anyway).
//!
//! Determinism: `to_f64`/`from_f64` are the identity for `f64`, so
//! instantiating the generic code at `f64` reproduces the pre-generic
//! operation sequence bit-for-bit — all existing bit-identity oracles
//! (refactor replay, parallel-vs-sequential, batched-vs-single solves)
//! hold unchanged.

use std::fmt::{Debug, Display};
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

use crate::exec::WorkerCtx;
use crate::numeric::factor::GemmBackend;
use crate::numeric::Workspace;

/// Element type of the numeric factorization core (`f64` or `f32`).
pub trait Scalar:
    Copy
    + Send
    + Sync
    + 'static
    + Default
    + Debug
    + Display
    + PartialEq
    + PartialOrd
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Type name for diagnostics (`"f64"` / `"f32"`).
    const NAME: &'static str;

    /// Round an `f64` into this precision (identity for `f64`).
    fn from_f64(v: f64) -> Self;
    /// Widen to `f64` (identity for `f64`).
    fn to_f64(self) -> f64;
    /// Absolute value.
    fn abs(self) -> Self;

    /// The per-worker factor arena of this precision. Each
    /// [`WorkerCtx`] holds one lazily-grown [`Workspace`] per supported
    /// precision; this hook is what lets the generic parallel driver pick
    /// the right one without knowing the concrete type.
    #[allow(clippy::too_many_arguments)]
    fn workspace(
        ctx: &mut WorkerCtx,
        n: usize,
        cbuf: usize,
        tbuf: usize,
        map_idx: usize,
        pbuf: usize,
        abuf: usize,
    ) -> &mut Workspace<Self>;

    /// Route a GEMM through the pluggable backend. Returns `false` when
    /// the backend does not handle this precision (always, for `f32`:
    /// the XLA/PJRT artifacts are compiled for `f64`) or declines the
    /// shape — the caller then uses the in-process kernels.
    #[allow(clippy::too_many_arguments)]
    fn backend_gemm(
        gemm: &dyn GemmBackend,
        c: &mut [Self],
        a: &[Self],
        lda: usize,
        b: &[Self],
        ldb: usize,
        m: usize,
        k: usize,
        n: usize,
    ) -> bool;

    /// Native-tier (AVX2+FMA intrinsics) GEMM. Returns `false` when this
    /// precision has no native microkernel; the dispatch layer then runs
    /// the portable tier.
    ///
    /// # Safety
    /// Caller guarantees pointer validity for the strided `m×n`, `m×k`,
    /// `k×n` accesses, no C/A/B element overlap, and (when it returns
    /// `true` on x86_64) runtime AVX2+FMA support.
    #[allow(clippy::too_many_arguments)]
    unsafe fn native_gemm_sub(
        cp: *mut Self,
        ldc: usize,
        ap: *const Self,
        lda: usize,
        bp: *const Self,
        ldb: usize,
        m: usize,
        k: usize,
        n: usize,
    ) -> bool;

    /// Native-tier dot product; `None` when this precision has no native
    /// kernel. Caller guarantees runtime AVX2+FMA support before calling.
    fn native_dot(a: &[Self], b: &[Self]) -> Option<Self>;

    /// Native-tier axpy (`y -= f * x`); returns `false` when this
    /// precision has no native kernel. Caller guarantees runtime AVX2+FMA
    /// support before calling.
    fn native_axpy_sub(y: &mut [Self], x: &[Self], f: Self) -> bool;
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const NAME: &'static str = "f64";

    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline(always)]
    fn abs(self) -> Self {
        f64::abs(self)
    }

    #[inline]
    fn workspace(
        ctx: &mut WorkerCtx,
        n: usize,
        cbuf: usize,
        tbuf: usize,
        map_idx: usize,
        pbuf: usize,
        abuf: usize,
    ) -> &mut Workspace<f64> {
        ctx.workspace(n, cbuf, tbuf, map_idx, pbuf, abuf)
    }

    #[inline]
    fn backend_gemm(
        gemm: &dyn GemmBackend,
        c: &mut [f64],
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        m: usize,
        k: usize,
        n: usize,
    ) -> bool {
        gemm.gemm_sub(c, a, lda, b, ldb, m, k, n)
    }

    #[inline]
    unsafe fn native_gemm_sub(
        cp: *mut f64,
        ldc: usize,
        ap: *const f64,
        lda: usize,
        bp: *const f64,
        ldb: usize,
        m: usize,
        k: usize,
        n: usize,
    ) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            crate::numeric::kernels::x86::gemm_sub_raw(cp, ldc, ap, lda, bp, ldb, m, k, n);
            true
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (cp, ldc, ap, lda, bp, ldb, m, k, n);
            false
        }
    }

    #[inline]
    fn native_dot(a: &[f64], b: &[f64]) -> Option<f64> {
        #[cfg(target_arch = "x86_64")]
        {
            let n = a.len().min(b.len());
            // Safety: bounds by `n`; caller checked runtime support.
            Some(unsafe { crate::numeric::kernels::x86::dot(a.as_ptr(), b.as_ptr(), n) })
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (a, b);
            None
        }
    }

    #[inline]
    fn native_axpy_sub(y: &mut [f64], x: &[f64], f: f64) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            let n = y.len().min(x.len());
            // Safety: bounds by `n`; caller checked runtime support.
            unsafe { crate::numeric::kernels::x86::axpy_sub(y.as_mut_ptr(), x.as_ptr(), n, f) }
            true
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (y, x, f);
            false
        }
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const NAME: &'static str = "f32";

    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v as f32
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn abs(self) -> Self {
        f32::abs(self)
    }

    #[inline]
    fn workspace(
        ctx: &mut WorkerCtx,
        n: usize,
        cbuf: usize,
        tbuf: usize,
        map_idx: usize,
        pbuf: usize,
        abuf: usize,
    ) -> &mut Workspace<f32> {
        ctx.workspace_f32(n, cbuf, tbuf, map_idx, pbuf, abuf)
    }

    #[inline]
    fn backend_gemm(
        _gemm: &dyn GemmBackend,
        _c: &mut [f32],
        _a: &[f32],
        _lda: usize,
        _b: &[f32],
        _ldb: usize,
        _m: usize,
        _k: usize,
        _n: usize,
    ) -> bool {
        // the XLA/PJRT AOT artifacts are f64-only; in-process kernels run
        false
    }

    #[inline]
    unsafe fn native_gemm_sub(
        _cp: *mut f32,
        _ldc: usize,
        _ap: *const f32,
        _lda: usize,
        _bp: *const f32,
        _ldb: usize,
        _m: usize,
        _k: usize,
        _n: usize,
    ) -> bool {
        false
    }

    #[inline]
    fn native_dot(_a: &[f32], _b: &[f32]) -> Option<f32> {
        None
    }

    #[inline]
    fn native_axpy_sub(_y: &mut [f32], _x: &[f32], _f: f32) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Scalar>(v: f64) -> f64 {
        T::from_f64(v).to_f64()
    }

    #[test]
    fn conversions_are_identity_for_f64() {
        for v in [0.0, -0.0, 1.5, -3.25e-200, f64::INFINITY] {
            assert_eq!(roundtrip::<f64>(v).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn f32_roundtrip_rounds() {
        assert_eq!(roundtrip::<f32>(1.5), 1.5);
        // 1 + 2^-30 is not representable in f32
        let v = 1.0 + 2f64.powi(-30);
        assert_eq!(roundtrip::<f32>(v), 1.0);
    }

    #[test]
    fn constants_and_abs() {
        assert_eq!(<f32 as Scalar>::ZERO, 0.0f32);
        assert_eq!(<f64 as Scalar>::ONE, 1.0);
        assert_eq!(Scalar::abs(-2.5f32), 2.5f32);
        assert_eq!(Scalar::abs(-2.5f64), 2.5f64);
        assert_eq!(<f32 as Scalar>::NAME, "f32");
        assert_eq!(<f64 as Scalar>::NAME, "f64");
    }
}

//! AVX-512 tier: 8-wide (zmm) blocked kernels in safe, dependency-free
//! Rust.
//!
//! Unlike the [`super::x86`] tier these functions contain no `std::arch`
//! intrinsics: AVX-512 intrinsics would pin the crate to a newer compiler
//! than the baseline toolchain guarantees, so the tier is written as
//! fixed-width blocked shapes (8-row x 16-column GEMM tiles — two zmm
//! vectors per row — and 8-lane reductions) that the autovectorizer lowers
//! to zmm code when the crate is compiled with
//! `-C target-feature=+avx512f,+avx512vl` (the dedicated CI leg).
//!
//! [`super::KernelTier::Avx512`] is therefore only *selected* by
//! `best_available` when the crate was compiled with those target features
//! **and** the CPU reports them at runtime — a baseline build never routes
//! here by default. Every function is nevertheless plain safe-shape Rust
//! that executes correctly on any machine, which is what lets the test
//! suite exercise this tier's numerics everywhere (no illegal-instruction
//! hazard; the dispatch guard is a performance gate, not a safety gate).
//!
//! Determinism: the GEMM tile keeps one accumulator per C element, walks
//! `k` ascending, and uses a separate multiply and subtract — bit-identical
//! to the scalar reference (no FMA contraction in Rust by default). The
//! lane kernels perform exactly one multiply+subtract (or divide) per lane,
//! bit-identical to every other tier, preserving the batched-solve
//! contract.
//!
//! The GEMM/dot/axpy kernels are generic over the factor element type
//! ([`Scalar`]); the lane kernels stay `f64` because substitution right-
//! hand sides are always held in `f64` regardless of factor precision.

#![allow(clippy::needless_range_loop)]

use crate::numeric::Scalar;

/// Raw 8x16-blocked core of `gemm_sub`: `C[m×n] -= A[m×k] · B[k×n]`,
/// row-major with leading dimensions. Row remainders run as 1x16 strips;
/// the column remainder falls back to the portable core (also
/// scalar-order-preserving).
///
/// # Safety
/// `cp/ap/bp` must be valid for the strided `m×n`, `m×k`, `k×n` accesses,
/// and the C range must not overlap A or B element-wise.
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_sub_raw<T: Scalar>(
    cp: *mut T,
    ldc: usize,
    ap: *const T,
    lda: usize,
    bp: *const T,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    let mut j = 0;
    while j + 16 <= n {
        let mut i = 0;
        while i + 8 <= m {
            let mut t = [[T::ZERO; 16]; 8];
            for r in 0..8 {
                let crow = cp.add((i + r) * ldc + j);
                for q in 0..16 {
                    t[r][q] = *crow.add(q);
                }
            }
            for p in 0..k {
                let brow = bp.add(p * ldb + j);
                let mut bv = [T::ZERO; 16];
                for q in 0..16 {
                    bv[q] = *brow.add(q);
                }
                for r in 0..8 {
                    let f = *ap.add((i + r) * lda + p);
                    for q in 0..16 {
                        t[r][q] -= f * bv[q];
                    }
                }
            }
            for r in 0..8 {
                let crow = cp.add((i + r) * ldc + j);
                for q in 0..16 {
                    *crow.add(q) = t[r][q];
                }
            }
            i += 8;
        }
        // row remainder (m % 8): 1x16 strips
        while i < m {
            let mut t = [T::ZERO; 16];
            let crow = cp.add(i * ldc + j);
            for q in 0..16 {
                t[q] = *crow.add(q);
            }
            let arow = ap.add(i * lda);
            for p in 0..k {
                let f = *arow.add(p);
                let brow = bp.add(p * ldb + j);
                for q in 0..16 {
                    t[q] -= f * *brow.add(q);
                }
            }
            for q in 0..16 {
                *crow.add(q) = t[q];
            }
            i += 1;
        }
        j += 16;
    }
    if j < n {
        // column remainder strip (n % 16): portable core
        super::portable::gemm_sub_raw(cp.add(j), ldc, ap, lda, bp.add(j), ldb, m, k, n - j);
    }
}

/// 8-lane blocked dot product (one accumulator per lane, pairwise
/// horizontal sum at the end).
#[inline]
pub fn dot<T: Scalar>(a: &[T], b: &[T]) -> T {
    let n = a.len().min(b.len());
    let mut lanes = [T::ZERO; 8];
    let mut i = 0;
    while i + 8 <= n {
        for q in 0..8 {
            lanes[q] += a[i + q] * b[i + q];
        }
        i += 8;
    }
    let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// `y[0..n] -= f * x[0..n]` in 8-wide chunks.
#[inline]
pub fn axpy_sub<T: Scalar>(y: &mut [T], x: &[T], f: T) {
    let n = y.len().min(x.len());
    let split = n - n % 8;
    let (yc, yr) = y[..n].split_at_mut(split);
    let (xc, xr) = x[..n].split_at(split);
    for (y8, x8) in yc.chunks_exact_mut(8).zip(xc.chunks_exact(8)) {
        for q in 0..8 {
            y8[q] -= f * x8[q];
        }
    }
    for (yy, xx) in yr.iter_mut().zip(xr) {
        *yy -= f * *xx;
    }
}

/// Lane update `dst[0..n] -= m * src[0..n]` in 8-wide chunks with a
/// separate multiply and subtract per lane — bit-identical per lane to the
/// scalar tier (no FMA; see the module docs).
#[inline]
pub fn lanes_axpy_sub(dst: &mut [f64], src: &[f64], m: f64) {
    let n = dst.len().min(src.len());
    let split = n - n % 8;
    let (dc, dr) = dst[..n].split_at_mut(split);
    let (sc, sr) = src[..n].split_at(split);
    for (d8, s8) in dc.chunks_exact_mut(8).zip(sc.chunks_exact(8)) {
        for q in 0..8 {
            d8[q] -= m * s8[q];
        }
    }
    for (d, s) in dr.iter_mut().zip(sr) {
        *d -= m * *s;
    }
}

/// Lane divide `dst[0..n] /= piv` in 8-wide chunks (IEEE division,
/// bit-identical to the scalar tier per lane).
#[inline]
pub fn lanes_div(dst: &mut [f64], piv: f64) {
    let n = dst.len();
    let split = n - n % 8;
    let (dc, dr) = dst.split_at_mut(split);
    for d8 in dc.chunks_exact_mut(8) {
        for q in 0..8 {
            d8[q] /= piv;
        }
    }
    for d in dr.iter_mut() {
        *d /= piv;
    }
}

//! Portable tier: register-blocked, autovectorization-friendly kernels
//! with fixed 4-wide inner shapes. No `std::arch` — this is the fallback
//! on targets without the AVX2+FMA native tier, and what `HYLU_KERNEL=
//! portable` selects for A/B runs. LLVM vectorizes the fixed-trip inner
//! loops with whatever the target baseline offers (SSE2 on stock x86_64,
//! NEON on aarch64). Generic over the factor element type ([`Scalar`]):
//! the same 4x16 shapes lower to twice the lane count for `f32`.

use crate::numeric::Scalar;

/// Raw core of the portable `gemm_sub`: register-tiled 4x16 microkernel.
/// A 4-row x 16-col C tile lives in registers across the whole k loop;
/// the j chunk is OUTER so each (k x 16) B sliver stays in L1 across row
/// blocks.
///
/// # Safety
/// `cp/ap/bp` must be valid for the strided `m x n`, `m x k`, `k x n`
/// accesses, and the C range must not overlap A or B element-wise.
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_sub_raw<T: Scalar>(
    cp: *mut T,
    ldc: usize,
    ap: *const T,
    lda: usize,
    bp: *const T,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    // j-chunk OUTER so each (k x 16) B sliver stays in L1 across all
    // row blocks; C tiles are touched exactly once.
    let mut j = 0;
    while j + 16 <= n {
        let mut i = 0;
        while i + 4 <= m {
            let a0 = ap.add(i * lda);
            let a1 = ap.add((i + 1) * lda);
            let a2 = ap.add((i + 2) * lda);
            let a3 = ap.add((i + 3) * lda);
            let c0 = cp.add(i * ldc + j);
            let c1 = cp.add((i + 1) * ldc + j);
            let c2 = cp.add((i + 2) * ldc + j);
            let c3 = cp.add((i + 3) * ldc + j);
            let mut t0 = [T::ZERO; 16];
            let mut t1 = [T::ZERO; 16];
            let mut t2 = [T::ZERO; 16];
            let mut t3 = [T::ZERO; 16];
            for q in 0..16 {
                t0[q] = *c0.add(q);
                t1[q] = *c1.add(q);
                t2[q] = *c2.add(q);
                t3[q] = *c3.add(q);
            }
            for p in 0..k {
                let f0 = *a0.add(p);
                let f1 = *a1.add(p);
                let f2 = *a2.add(p);
                let f3 = *a3.add(p);
                let brow = bp.add(p * ldb + j);
                for q in 0..16 {
                    let bv = *brow.add(q);
                    t0[q] -= f0 * bv;
                    t1[q] -= f1 * bv;
                    t2[q] -= f2 * bv;
                    t3[q] -= f3 * bv;
                }
            }
            for q in 0..16 {
                *c0.add(q) = t0[q];
                *c1.add(q) = t1[q];
                *c2.add(q) = t2[q];
                *c3.add(q) = t3[q];
            }
            i += 4;
        }
        // row remainder (m % 4) for this j chunk
        while i < m {
            let arow = ap.add(i * lda);
            let crow = cp.add(i * ldc + j);
            let mut t = [T::ZERO; 16];
            for q in 0..16 {
                t[q] = *crow.add(q);
            }
            for p in 0..k {
                let f = *arow.add(p);
                let brow = bp.add(p * ldb + j);
                for q in 0..16 {
                    t[q] -= f * *brow.add(q);
                }
            }
            for q in 0..16 {
                *crow.add(q) = t[q];
            }
            i += 1;
        }
        j += 16;
    }
    if j < n {
        // column remainder: simple row loop. No zero-skip here — the main
        // strip and the scalar tier don't skip either, so every tier stays
        // structurally uniform (matters only for non-finite data, but a
        // column must not behave differently for landing in the remainder)
        for i in 0..m {
            let arow = ap.add(i * lda);
            let crow = cp.add(i * ldc);
            for p in 0..k {
                let f = *arow.add(p);
                let brow = bp.add(p * ldb);
                for jj in j..n {
                    *crow.add(jj) -= f * *brow.add(jj);
                }
            }
        }
    }
}

/// Dot product with 4 parallel accumulators (vectorization-friendly
/// reduction shape).
#[inline]
pub fn dot<T: Scalar>(a: &[T], b: &[T]) -> T {
    let mut s0 = T::ZERO;
    let mut s1 = T::ZERO;
    let mut s2 = T::ZERO;
    let mut s3 = T::ZERO;
    let mut i = 0;
    let n = a.len().min(b.len());
    while i + 4 <= n {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    while i < n {
        s0 += a[i] * b[i];
        i += 1;
    }
    s0 + s1 + s2 + s3
}

/// `y[0..n] -= f * x[0..n]` (contiguous axpy; the compiler vectorizes the
/// simple zip loop at the target baseline width).
#[inline]
pub fn axpy_sub<T: Scalar>(y: &mut [T], x: &[T], f: T) {
    for (yy, xx) in y.iter_mut().zip(x) {
        *yy -= f * *xx;
    }
}

//! Scalar reference tier: straight-line loops with no register blocking
//! and no explicit vector widths. This is the baseline the dispatch layer
//! A/Bs against (`HYLU_KERNEL=scalar`) and the semantics reference the
//! property tests compare the other tiers to. Generic over the factor
//! element type ([`Scalar`]); the loop structure is identical for `f64`
//! and `f32`.

use crate::numeric::Scalar;

/// Raw scalar core of `gemm_sub`: `C[m×n] -= A[m×k] · B[k×n]`, row-major
/// with leading dimensions.
///
/// # Safety
/// `cp/ap/bp` must be valid for the strided `m×n`, `m×k`, `k×n` accesses,
/// and the C range must not overlap A or B element-wise.
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_sub_raw<T: Scalar>(
    cp: *mut T,
    ldc: usize,
    ap: *const T,
    lda: usize,
    bp: *const T,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    for i in 0..m {
        let arow = ap.add(i * lda);
        let crow = cp.add(i * ldc);
        for p in 0..k {
            let f = *arow.add(p);
            let brow = bp.add(p * ldb);
            for jj in 0..n {
                *crow.add(jj) -= f * *brow.add(jj);
            }
        }
    }
}

/// Scalar dot product (strict left-to-right accumulation).
#[inline]
pub fn dot<T: Scalar>(a: &[T], b: &[T]) -> T {
    let mut s = T::ZERO;
    for (x, y) in a.iter().zip(b) {
        s += *x * *y;
    }
    s
}

/// `y[0..n] -= f * x[0..n]`.
#[inline]
pub fn axpy_sub<T: Scalar>(y: &mut [T], x: &[T], f: T) {
    for (yy, xx) in y.iter_mut().zip(x) {
        *yy -= f * *xx;
    }
}

//! Native x86_64 tier: AVX2+FMA microkernels via `std::arch` intrinsics.
//!
//! Every function here carries `#[target_feature(enable = "avx2",
//! enable = "fma")]` and must only be *called* after runtime detection —
//! the dispatch layer in [`super`] guards every entry with
//! `is_x86_feature_detected!`. The GEMM/axpy/dot kernels use fused
//! multiply-add freely (per-tier determinism only); the lane kernels used
//! by block substitution deliberately stick to separate multiply+subtract
//! so every tier — and therefore every batched solve column — stays
//! bit-identical to the scalar single-RHS path.

use std::arch::x86_64::*;

/// Raw AVX2+FMA core of `gemm_sub`: 4-row x 8-col register tile (8 ymm
/// accumulators held across the whole k loop), j chunk outer for B-sliver
/// L1 reuse; remainders fall back to the portable core.
///
/// # Safety
/// AVX2+FMA must be available (runtime-detected by the caller), `cp/ap/bp`
/// must be valid for the strided `m x n`, `m x k`, `k x n` accesses, and
/// the C range must not overlap A or B element-wise.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn gemm_sub_raw(
    cp: *mut f64,
    ldc: usize,
    ap: *const f64,
    lda: usize,
    bp: *const f64,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    let mut j = 0;
    while j + 8 <= n {
        let mut i = 0;
        while i + 4 <= m {
            let a0 = ap.add(i * lda);
            let a1 = ap.add((i + 1) * lda);
            let a2 = ap.add((i + 2) * lda);
            let a3 = ap.add((i + 3) * lda);
            let c0 = cp.add(i * ldc + j);
            let c1 = cp.add((i + 1) * ldc + j);
            let c2 = cp.add((i + 2) * ldc + j);
            let c3 = cp.add((i + 3) * ldc + j);
            let mut t00 = _mm256_loadu_pd(c0);
            let mut t01 = _mm256_loadu_pd(c0.add(4));
            let mut t10 = _mm256_loadu_pd(c1);
            let mut t11 = _mm256_loadu_pd(c1.add(4));
            let mut t20 = _mm256_loadu_pd(c2);
            let mut t21 = _mm256_loadu_pd(c2.add(4));
            let mut t30 = _mm256_loadu_pd(c3);
            let mut t31 = _mm256_loadu_pd(c3.add(4));
            for p in 0..k {
                let brow = bp.add(p * ldb + j);
                let b0 = _mm256_loadu_pd(brow);
                let b1 = _mm256_loadu_pd(brow.add(4));
                let f0 = _mm256_set1_pd(*a0.add(p));
                t00 = _mm256_fnmadd_pd(f0, b0, t00);
                t01 = _mm256_fnmadd_pd(f0, b1, t01);
                let f1 = _mm256_set1_pd(*a1.add(p));
                t10 = _mm256_fnmadd_pd(f1, b0, t10);
                t11 = _mm256_fnmadd_pd(f1, b1, t11);
                let f2 = _mm256_set1_pd(*a2.add(p));
                t20 = _mm256_fnmadd_pd(f2, b0, t20);
                t21 = _mm256_fnmadd_pd(f2, b1, t21);
                let f3 = _mm256_set1_pd(*a3.add(p));
                t30 = _mm256_fnmadd_pd(f3, b0, t30);
                t31 = _mm256_fnmadd_pd(f3, b1, t31);
            }
            _mm256_storeu_pd(c0, t00);
            _mm256_storeu_pd(c0.add(4), t01);
            _mm256_storeu_pd(c1, t10);
            _mm256_storeu_pd(c1.add(4), t11);
            _mm256_storeu_pd(c2, t20);
            _mm256_storeu_pd(c2.add(4), t21);
            _mm256_storeu_pd(c3, t30);
            _mm256_storeu_pd(c3.add(4), t31);
            i += 4;
        }
        // row remainder (m % 4): 1x8 tiles
        while i < m {
            let arow = ap.add(i * lda);
            let crow = cp.add(i * ldc + j);
            let mut t0 = _mm256_loadu_pd(crow);
            let mut t1 = _mm256_loadu_pd(crow.add(4));
            for p in 0..k {
                let brow = bp.add(p * ldb + j);
                let f = _mm256_set1_pd(*arow.add(p));
                t0 = _mm256_fnmadd_pd(f, _mm256_loadu_pd(brow), t0);
                t1 = _mm256_fnmadd_pd(f, _mm256_loadu_pd(brow.add(4)), t1);
            }
            _mm256_storeu_pd(crow, t0);
            _mm256_storeu_pd(crow.add(4), t1);
            i += 1;
        }
        j += 8;
    }
    if j < n {
        // column remainder strip (n % 8): portable core
        super::portable::gemm_sub_raw(cp.add(j), ldc, ap, lda, bp.add(j), ldb, m, k, n - j);
    }
}

/// FMA dot product (two 4-wide accumulators, horizontal sum at the end).
///
/// # Safety
/// AVX2+FMA must be available; `a`/`b` must be valid for `n` reads.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot(a: *const f64, b: *const f64, n: usize) -> f64 {
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut i = 0;
    while i + 8 <= n {
        acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(a.add(i)), _mm256_loadu_pd(b.add(i)), acc0);
        acc1 = _mm256_fmadd_pd(
            _mm256_loadu_pd(a.add(i + 4)),
            _mm256_loadu_pd(b.add(i + 4)),
            acc1,
        );
        i += 8;
    }
    if i + 4 <= n {
        acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(a.add(i)), _mm256_loadu_pd(b.add(i)), acc0);
        i += 4;
    }
    let acc = _mm256_add_pd(acc0, acc1);
    let mut tmp = [0.0f64; 4];
    _mm256_storeu_pd(tmp.as_mut_ptr(), acc);
    let mut s = (tmp[0] + tmp[1]) + (tmp[2] + tmp[3]);
    while i < n {
        s += *a.add(i) * *b.add(i);
        i += 1;
    }
    s
}

/// FMA `y[0..n] -= f * x[0..n]`.
///
/// # Safety
/// AVX2+FMA must be available; `y`/`x` must be valid for `n` accesses and
/// must not overlap.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn axpy_sub(y: *mut f64, x: *const f64, n: usize, f: f64) {
    let vf = _mm256_set1_pd(f);
    let mut i = 0;
    while i + 4 <= n {
        let yy = _mm256_loadu_pd(y.add(i));
        let xx = _mm256_loadu_pd(x.add(i));
        _mm256_storeu_pd(y.add(i), _mm256_fnmadd_pd(vf, xx, yy));
        i += 4;
    }
    while i < n {
        *y.add(i) -= f * *x.add(i);
        i += 1;
    }
}

/// Lane update `dst[0..n] -= m * src[0..n]` with separate multiply and
/// subtract — bit-identical per lane to the scalar tier (NO fma here; see
/// the module docs).
///
/// # Safety
/// AVX2 must be available; `dst`/`src` must be valid for `n` accesses and
/// must not overlap.
#[target_feature(enable = "avx2")]
pub unsafe fn lanes_axpy_sub(dst: *mut f64, src: *const f64, n: usize, m: f64) {
    let vm = _mm256_set1_pd(m);
    let mut q = 0;
    while q + 4 <= n {
        let y = _mm256_loadu_pd(dst.add(q));
        let x = _mm256_loadu_pd(src.add(q));
        _mm256_storeu_pd(dst.add(q), _mm256_sub_pd(y, _mm256_mul_pd(vm, x)));
        q += 4;
    }
    while q < n {
        *dst.add(q) -= m * *src.add(q);
        q += 1;
    }
}

/// Lane divide `dst[0..n] /= piv` (IEEE division, bit-identical to the
/// scalar tier per lane).
///
/// # Safety
/// AVX2 must be available; `dst` must be valid for `n` accesses.
#[target_feature(enable = "avx2")]
pub unsafe fn lanes_div(dst: *mut f64, n: usize, piv: f64) {
    let vp = _mm256_set1_pd(piv);
    let mut q = 0;
    while q + 4 <= n {
        let y = _mm256_loadu_pd(dst.add(q));
        _mm256_storeu_pd(dst.add(q), _mm256_div_pd(y, vp));
        q += 4;
    }
    while q < n {
        *dst.add(q) /= piv;
        q += 1;
    }
}

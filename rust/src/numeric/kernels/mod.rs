//! Tiled, runtime-dispatched dense microkernels — the CPU-native analogue
//! of the MKL BLAS calls in the paper (the Pallas/XLA path in
//! [`crate::runtime`] is the TPU-shaped alternative; see DESIGN.md §5).
//!
//! Four dispatch tiers implement one kernel family:
//!
//! - [`KernelTier::Scalar`] — straight-line reference loops
//!   ([`scalar`]); the semantics baseline and the `HYLU_KERNEL=scalar`
//!   A/B leg.
//! - [`KernelTier::Portable`] — register-blocked 4x16 shapes the
//!   autovectorizer lowers at the target baseline width ([`portable`]);
//!   the default off x86_64.
//! - [`KernelTier::Native`] — AVX2+FMA `std::arch` microkernels
//!   ([`x86`]), selected at runtime via `is_x86_feature_detected!`.
//! - [`KernelTier::Avx512`] — 8-wide zmm-shaped blocked kernels
//!   ([`avx512`]); selected only when the crate was *compiled* with
//!   `+avx512f,+avx512vl` **and** the CPU reports both at runtime (the
//!   code itself is safe on any machine).
//!
//! The tier resolves lazily: `HYLU_KERNEL=scalar|portable|native|avx512`
//! overrides, otherwise the best available tier wins; an unavailable
//! request falls back to portable. [`set_tier`] (the `hylu bench
//! --kernel` flag) re-pins the tier at any time, and the calibration
//! [`probe`] re-measures itself on the next read after a tier change —
//! a later `set_tier` can no longer leave `select_kernel` scaled by a
//! stale tier's probe. All matrices are row-major with explicit leading
//! dimensions (panels are strided).
//!
//! On top of the per-process tier, the [`tuner`] module searches
//! per-analyzed-pattern GEMM tile variants, A-operand packing, and TRSM
//! crossovers, recording the winner as a [`KernelPlan`] inside the
//! analysis' exec plan (see DESIGN.md §5).
//!
//! Determinism contract: within one tier every kernel is deterministic
//! (refactor replay and parallel-vs-sequential bit-equality hold per
//! tier). *Across* tiers the factor-side kernels (`gemm_sub`, `trsm`,
//! `axpy_sub`, `dot`) may differ by rounding (the native tier fuses
//! multiply-adds); the substitution lane kernels ([`lanes_axpy_sub`],
//! [`lanes_div`], the panel block routines) are bit-identical across
//! every tier by construction — they vectorize only across RHS lanes and
//! keep each lane's multiply/subtract/divide sequence exactly the scalar
//! one, which is what keeps batched `solve_many` columns bit-identical
//! to independent single-RHS solves.

mod scalar;

pub mod avx512;
pub mod portable;
pub mod tuner;

#[cfg(target_arch = "x86_64")]
pub mod x86;

pub use tuner::{GemmVariant, KernelPlan, Tuning};

use crate::numeric::Scalar;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

/// One dispatch tier of the dense-kernel family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Straight-line reference loops.
    Scalar,
    /// Register-blocked, autovectorization-friendly shapes.
    Portable,
    /// AVX2+FMA `std::arch` microkernels (x86_64 with runtime support).
    Native,
    /// 8-wide zmm-shaped blocked kernels (x86_64 compiled with
    /// `+avx512f,+avx512vl` and runtime support).
    Avx512,
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelTier::Scalar => write!(f, "scalar"),
            KernelTier::Portable => write!(f, "portable"),
            KernelTier::Native => write!(f, "native"),
            KernelTier::Avx512 => write!(f, "avx512"),
        }
    }
}

/// Runtime check for the native tier's ISA (cached by std).
#[inline]
fn native_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Detection chain for the AVX-512 tier: the kernels are plain safe Rust
/// (no intrinsics), so they only *pay off* when the compiler was allowed
/// to lower their 8-wide shapes to zmm code — hence the `cfg!` half of
/// the check — and the runtime half keeps a `+avx512` build honest on a
/// machine without the feature.
#[inline]
fn avx512_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        cfg!(target_feature = "avx512f")
            && std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512vl")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

impl KernelTier {
    /// Parse a tier name (`scalar` / `portable` / `native` / `avx512`).
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s {
            "scalar" => Some(KernelTier::Scalar),
            "portable" => Some(KernelTier::Portable),
            "native" => Some(KernelTier::Native),
            "avx512" => Some(KernelTier::Avx512),
            _ => None,
        }
    }

    /// Whether this tier can run at full speed on this machine (for
    /// [`KernelTier::Avx512`] that includes having been *compiled* with
    /// the AVX-512 target features; see [`avx512`]).
    pub fn available(self) -> bool {
        match self {
            KernelTier::Native => native_supported(),
            KernelTier::Avx512 => avx512_supported(),
            _ => true,
        }
    }

    /// Best tier this machine supports.
    pub fn best_available() -> KernelTier {
        if avx512_supported() {
            KernelTier::Avx512
        } else if native_supported() {
            KernelTier::Native
        } else {
            KernelTier::Portable
        }
    }

    /// This tier, or portable when it is unavailable here.
    fn or_fallback(self) -> KernelTier {
        if self.available() {
            self
        } else {
            KernelTier::Portable
        }
    }
}

/// Process-wide active tier: 0 = unresolved, else `encode_tier + 1`-style
/// codes (see [`decode_tier`]). An atomic rather than a `OnceLock` so
/// [`set_tier`] can re-pin mid-process — the calibration probe keys its
/// cache by tier and re-measures after a change.
static TIER: AtomicU8 = AtomicU8::new(0);

fn encode_tier(t: KernelTier) -> u8 {
    match t {
        KernelTier::Scalar => 1,
        KernelTier::Portable => 2,
        KernelTier::Native => 3,
        KernelTier::Avx512 => 4,
    }
}

fn decode_tier(v: u8) -> Option<KernelTier> {
    match v {
        1 => Some(KernelTier::Scalar),
        2 => Some(KernelTier::Portable),
        3 => Some(KernelTier::Native),
        4 => Some(KernelTier::Avx512),
        _ => None,
    }
}

fn resolve_env_tier() -> KernelTier {
    match std::env::var("HYLU_KERNEL") {
        // empty = unset (CI matrix legs define the var with no value)
        Ok(s) if s.is_empty() => KernelTier::best_available(),
        Ok(s) => match KernelTier::parse(&s) {
            Some(t) => t.or_fallback(),
            None => {
                // an A/B run with a mistyped tier must not silently
                // measure the wrong kernels
                eprintln!(
                    "hylu: ignoring unknown HYLU_KERNEL={s:?} \
                     (expected scalar|portable|native|avx512)"
                );
                KernelTier::best_available()
            }
        },
        Err(_) => KernelTier::best_available(),
    }
}

/// The active dispatch tier. Resolved lazily on first use: the
/// `HYLU_KERNEL` env var (`scalar|portable|native|avx512`) wins, else the
/// best available tier; unavailable requests fall back to portable. An
/// explicit [`set_tier`] call overrides at any time.
pub fn active_tier() -> KernelTier {
    if let Some(t) = decode_tier(TIER.load(Ordering::Relaxed)) {
        return t;
    }
    let t = resolve_env_tier();
    // first resolver wins a race; a concurrent set_tier still lands after
    let _ = TIER.compare_exchange(0, encode_tier(t), Ordering::Relaxed, Ordering::Relaxed);
    decode_tier(TIER.load(Ordering::Relaxed)).unwrap_or(t)
}

/// Pin the dispatch tier for this process (A/B runs: `hylu bench
/// --kernel`). Takes effect immediately — even after kernels already
/// dispatched — and invalidates the cached calibration [`probe`], which
/// re-measures on its next read. Unavailable tiers fall back to portable.
/// Always returns `true` (kept for call-site compatibility with the old
/// resolve-once semantics, where a late call could lose).
pub fn set_tier(tier: KernelTier) -> bool {
    TIER.store(encode_tier(tier.or_fallback()), Ordering::Relaxed);
    true
}

/// Supernodes at least this wide route their block substitution through
/// the panel TRSM+GEMM kernels ([`forward_panel_block`] /
/// [`backward_panel_block`]) instead of the row-wise lane loop.
pub const BLOCK_PANEL_MIN_W: usize = 8;

// ---------------------------------------------------------------------
// GEMM
// ---------------------------------------------------------------------

/// `C[m×n] -= A[m×k] · B[k×n]`, row-major with leading dimensions
/// `lda/ldb/ldc`, on the given tier. The sup-sup update's level-3 core.
/// Generic over the factor element type; the native (`std::arch`) tier
/// exists only for `f64` and other precisions fall through to portable.
#[allow(clippy::too_many_arguments)]
pub fn gemm_sub<T: Scalar>(
    tier: KernelTier,
    c: &mut [T],
    ldc: usize,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    debug_assert!(c.len() >= (m - 1) * ldc + n);
    debug_assert!(a.len() >= (m - 1) * lda + k);
    debug_assert!(b.len() >= (k - 1) * ldb + n);
    // Safety: bounds established by the debug_asserts above (callers pass
    // panel-backed slices with exact leading dimensions).
    unsafe { gemm_sub_raw(tier, c.as_mut_ptr(), ldc, a.as_ptr(), lda, b.as_ptr(), ldb, m, k, n) }
}

/// Raw-pointer core of [`gemm_sub`], used by the sup-sup kernel's
/// contiguous fast path where A and C are disjoint column ranges of the
/// same panel (element-disjoint, so raw pointers, not slices).
///
/// # Safety
/// `cp/ap/bp` must be valid for the strided `m x n`, `m x k`, `k x n`
/// accesses, and the C range must not overlap A or B element-wise.
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_sub_raw<T: Scalar>(
    tier: KernelTier,
    cp: *mut T,
    ldc: usize,
    ap: *const T,
    lda: usize,
    bp: *const T,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    match tier {
        KernelTier::Scalar => scalar::gemm_sub_raw(cp, ldc, ap, lda, bp, ldb, m, k, n),
        KernelTier::Native if native_supported() => {
            // precisions without a native microkernel fall through to the
            // portable tier (the Scalar hook reports "not handled")
            if !T::native_gemm_sub(cp, ldc, ap, lda, bp, ldb, m, k, n) {
                portable::gemm_sub_raw(cp, ldc, ap, lda, bp, ldb, m, k, n)
            }
        }
        // safe blocked shapes — correct on any machine, zmm-fast only on
        // the builds/CPUs `best_available` actually selects it for
        KernelTier::Avx512 => avx512::gemm_sub_raw(cp, ldc, ap, lda, bp, ldb, m, k, n),
        _ => portable::gemm_sub_raw(cp, ldc, ap, lda, bp, ldb, m, k, n),
    }
}

/// [`gemm_sub`] with an analysis' tuned [`KernelPlan`] applied: a tuned
/// tile variant replaces the tier microkernel when the plan carries one.
#[allow(clippy::too_many_arguments)]
pub fn gemm_sub_planned<T: Scalar>(
    tier: KernelTier,
    plan: &KernelPlan,
    c: &mut [T],
    ldc: usize,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    debug_assert!(c.len() >= (m - 1) * ldc + n);
    debug_assert!(a.len() >= (m - 1) * lda + k);
    debug_assert!(b.len() >= (k - 1) * ldb + n);
    // Safety: bounds established by the debug_asserts above.
    unsafe {
        gemm_sub_raw_planned(
            tier,
            plan,
            c.as_mut_ptr(),
            ldc,
            a.as_ptr(),
            lda,
            b.as_ptr(),
            ldb,
            m,
            k,
            n,
        )
    }
}

/// Raw-pointer core of [`gemm_sub_planned`] for the sup-sup contiguous
/// fast path (A and C are element-disjoint ranges of one panel).
///
/// # Safety
/// Same contract as [`gemm_sub_raw`].
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_sub_raw_planned<T: Scalar>(
    tier: KernelTier,
    plan: &KernelPlan,
    cp: *mut T,
    ldc: usize,
    ap: *const T,
    lda: usize,
    bp: *const T,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    match plan.gemm {
        GemmVariant::Tier => gemm_sub_raw(tier, cp, ldc, ap, lda, bp, ldb, m, k, n),
        GemmVariant::Tiled { mr, nr, ku } => {
            tuner::gemm_sub_tiled(mr, nr, ku, cp, ldc, ap, lda, bp, ldb, m, k, n)
        }
    }
}

/// Pack `rows × cols` out of a strided row-major source (leading
/// dimension `ld`) into a contiguous buffer: `dst[r*cols + c] =
/// src[r*ld + c]`. The sup-sup kernel packs each source panel's U-tail
/// sliver once per *target* panel so the GEMM microkernel streams B
/// linearly instead of striding by the source panel width per element;
/// `dst` is a reusable arena sized by `ExecPlan::max_pbuf` so the warm
/// path never allocates.
pub fn pack_rows<T: Scalar>(dst: &mut Vec<T>, src: &[T], ld: usize, rows: usize, cols: usize) {
    dst.clear();
    // extend (not resize-then-copy): each element is written exactly once
    for r in 0..rows {
        dst.extend_from_slice(&src[r * ld..r * ld + cols]);
    }
}

// ---------------------------------------------------------------------
// TRSM
// ---------------------------------------------------------------------

/// In-place right triangular solve `X · U = B` where `U` is the `len×len`
/// upper-triangular (non-unit) diagonal sub-block of a source supernode
/// panel, and `B`/`X` occupy `len` *columns* of the target panel starting
/// at `x_off`. Column-forward substitution; this is the TRSM half of the
/// sup-sup kernel.
///
/// `u` points at the source panel; row `r` of the sub-block lives at
/// `u[(u_row0 + r) * ldu + u_col0 + r .. ]` (upper triangle only read).
/// Large triangles on the vectorized tiers gather the triangle columns
/// into `scratch` (column-major) so the reduction streams linearly
/// instead of striding by `ldu` per element; `scratch` is a reusable
/// arena sized by `ExecPlan::max_tbuf`. The gather crossover is the
/// historical `len >= 48 && m >= 8`; the autotuner varies it per pattern
/// through [`trsm_right_upper_with`].
#[allow(clippy::too_many_arguments)]
pub fn trsm_right_upper<T: Scalar>(
    tier: KernelTier,
    x: &mut [T],
    ldx: usize,
    x_off: usize,
    m: usize,
    u: &[T],
    ldu: usize,
    u_row0: usize,
    u_col0: usize,
    len: usize,
    scratch: &mut Vec<T>,
) {
    trsm_right_upper_with(tier, x, ldx, x_off, m, u, ldu, u_row0, u_col0, len, scratch, 48, 8)
}

/// [`trsm_right_upper`] with an explicit gather crossover `(min_len,
/// min_m)` — the [`KernelPlan`]'s tuned thresholds; `(usize::MAX,
/// usize::MAX)` disables the gather path entirely.
#[allow(clippy::too_many_arguments)]
pub fn trsm_right_upper_with<T: Scalar>(
    tier: KernelTier,
    x: &mut [T],
    ldx: usize,
    x_off: usize,
    m: usize,
    u: &[T],
    ldu: usize,
    u_row0: usize,
    u_col0: usize,
    len: usize,
    scratch: &mut Vec<T>,
    min_len: usize,
    min_m: usize,
) {
    if tier != KernelTier::Scalar && len >= min_len && m >= min_m {
        // Large triangles: gather columns into a contiguous column-major
        // scratch so the dot reductions stream linearly. (Small triangles
        // stay in L1 either way and the gather costs more than it saves.)
        scratch.clear();
        scratch.resize(len * len, T::ZERO);
        for cc in 0..len {
            for pp in 0..=cc {
                scratch[cc * len + pp] = u[(u_row0 + pp) * ldu + u_col0 + cc];
            }
        }
        for cc in 0..len {
            let col = &scratch[cc * len..cc * len + cc];
            let inv = T::ONE / scratch[cc * len + cc];
            for r in 0..m {
                let row = &mut x[r * ldx + x_off..r * ldx + x_off + len];
                let s = row[cc] - dot(tier, &row[..cc], col);
                row[cc] = s * inv;
            }
        }
        return;
    }
    for cc in 0..len {
        let ucc = u[(u_row0 + cc) * ldu + u_col0 + cc];
        let inv = T::ONE / ucc;
        // X[:, cc] = (B[:, cc] - X[:, 0..cc] * U[0..cc, cc]) / U[cc, cc]
        for r in 0..m {
            let row = &mut x[r * ldx + x_off..r * ldx + x_off + len];
            let mut s = row[cc];
            for pp in 0..cc {
                s -= row[pp] * u[(u_row0 + pp) * ldu + u_col0 + cc];
            }
            row[cc] = s * inv;
        }
    }
}

// ---------------------------------------------------------------------
// Level-1 helpers
// ---------------------------------------------------------------------

/// `y[0..n] -= f * x[0..n]` (axpy with negative sign) on the given tier.
#[inline]
pub fn axpy_sub<T: Scalar>(tier: KernelTier, y: &mut [T], x: &[T], f: T) {
    debug_assert!(y.len() >= x.len());
    match tier {
        KernelTier::Scalar => scalar::axpy_sub(y, x, f),
        KernelTier::Native if native_supported() => {
            if !T::native_axpy_sub(y, x, f) {
                portable::axpy_sub(y, x, f)
            }
        }
        KernelTier::Avx512 => avx512::axpy_sub(y, x, f),
        _ => portable::axpy_sub(y, x, f),
    }
}

/// Dot product on the given tier (reduction order differs per tier).
#[inline]
pub fn dot<T: Scalar>(tier: KernelTier, a: &[T], b: &[T]) -> T {
    match tier {
        KernelTier::Scalar => scalar::dot(a, b),
        KernelTier::Native if native_supported() => {
            T::native_dot(a, b).unwrap_or_else(|| portable::dot(a, b))
        }
        KernelTier::Avx512 => avx512::dot(a, b),
        _ => portable::dot(a, b),
    }
}

// ---------------------------------------------------------------------
// Lane-major block-substitution kernels
// ---------------------------------------------------------------------

/// Lane update `dst[q] -= m * src[q]` for `q` in `0..min(len)`. Every
/// tier performs a separate multiply and subtract per lane, so the result
/// is bit-identical across tiers and to the scalar single-RHS sequence.
#[inline]
pub fn lanes_axpy_sub(tier: KernelTier, dst: &mut [f64], src: &[f64], m: f64) {
    let n = dst.len().min(src.len());
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Native if native_supported() => {
            // Safety: bounds by `n`; `dst`/`src` are distinct row slices.
            unsafe { x86::lanes_axpy_sub(dst.as_mut_ptr(), src.as_ptr(), n, m) }
        }
        KernelTier::Avx512 => avx512::lanes_axpy_sub(dst, src, m),
        KernelTier::Scalar | KernelTier::Portable | KernelTier::Native => {
            for (d, s) in dst[..n].iter_mut().zip(&src[..n]) {
                *d -= m * *s;
            }
        }
    }
}

/// Lane divide `dst[q] /= piv` (bit-identical across tiers: IEEE
/// division either way).
#[inline]
pub fn lanes_div(tier: KernelTier, dst: &mut [f64], piv: f64) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Native if native_supported() => {
            // Safety: bounds by `dst.len()`.
            unsafe { x86::lanes_div(dst.as_mut_ptr(), dst.len(), piv) }
        }
        KernelTier::Avx512 => avx512::lanes_div(dst, piv),
        KernelTier::Scalar | KernelTier::Portable | KernelTier::Native => {
            for d in dst.iter_mut() {
                *d /= piv;
            }
        }
    }
}

/// Forward block substitution for one wide supernode over a row-major
/// `n×k` RHS block: a source-column-outer "GEMM" applies the panel's L
/// part (each gathered source row is loaded once and applied to all `w`
/// target rows), then a unit-lower TRSM finishes the diagonal block
/// across the `k` lanes. Per lane, every target element receives exactly
/// the scalar kernel's updates in exactly its order (L columns ascending,
/// then in-block columns ascending), so the result is bit-identical to
/// the row-wise path — on every tier.
///
/// `y` is the full block; the node's rows are `first..first+w` and every
/// `lcols` entry is `< first`.
///
/// Generic over the factor element type: the RHS lanes are always `f64`;
/// each panel multiplier is widened once (`to_f64`, the identity for
/// `f64`) before the bit-specified lane update — the f64-refinement half
/// of the mixed-precision contract.
#[allow(clippy::too_many_arguments)]
pub fn forward_panel_block<T: Scalar>(
    tier: KernelTier,
    y: &mut [f64],
    k: usize,
    first: usize,
    w: usize,
    stride: usize,
    panel: &[T],
    lcols: &[u32],
) {
    if k == 0 || w == 0 {
        return;
    }
    let nl = lcols.len();
    let (src, rest) = y.split_at_mut(first * k);
    let dst = &mut rest[..w * k];
    // "GEMM": column-outer over the L part.
    for (c, &j) in lcols.iter().enumerate() {
        let s0 = j as usize * k;
        let s = &src[s0..s0 + k];
        for (r, row) in dst.chunks_exact_mut(k).enumerate() {
            lanes_axpy_sub(tier, row, s, panel[r * stride + c].to_f64());
        }
    }
    // "TRSM": unit-lower solve of the diagonal block across the lanes.
    for r in 1..w {
        let (done, tail) = dst.split_at_mut(r * k);
        let row = &mut tail[..k];
        for kk in 0..r {
            lanes_axpy_sub(
                tier,
                row,
                &done[kk * k..(kk + 1) * k],
                panel[r * stride + nl + kk].to_f64(),
            );
        }
    }
}

/// Backward block substitution for one wide supernode over a row-major
/// `n×k` RHS block: a column-outer "GEMM" applies the shared U tail, then
/// an upper TRSM (rows descending, with the pivot divisions) finishes the
/// diagonal block across the `k` lanes. Bit-identical to the row-wise
/// path per lane, on every tier (see [`forward_panel_block`]).
///
/// Every `ucols` entry is `>= first + w`.
///
/// Generic over the factor element type on the same terms as
/// [`forward_panel_block`].
#[allow(clippy::too_many_arguments)]
pub fn backward_panel_block<T: Scalar>(
    tier: KernelTier,
    y: &mut [f64],
    k: usize,
    first: usize,
    w: usize,
    nl: usize,
    stride: usize,
    panel: &[T],
    ucols: &[u32],
) {
    if k == 0 || w == 0 {
        return;
    }
    let (head, usrc) = y.split_at_mut((first + w) * k);
    let dst = &mut head[first * k..];
    // "GEMM": column-outer over the shared U tail (all beyond the block).
    for (c, &j) in ucols.iter().enumerate() {
        let s0 = (j as usize - first - w) * k;
        let s = &usrc[s0..s0 + k];
        for (r, row) in dst.chunks_exact_mut(k).enumerate() {
            lanes_axpy_sub(tier, row, s, panel[r * stride + nl + w + c].to_f64());
        }
    }
    // "TRSM": upper solve of the diagonal block, rows descending.
    for r in (0..w).rev() {
        let (head2, tail) = dst.split_at_mut((r + 1) * k);
        let row = &mut head2[r * k..];
        for kk in r + 1..w {
            lanes_axpy_sub(
                tier,
                row,
                &tail[(kk - r - 1) * k..(kk - r) * k],
                panel[r * stride + nl + kk].to_f64(),
            );
        }
        lanes_div(tier, row, panel[r * stride + nl + r].to_f64());
    }
}

// ---------------------------------------------------------------------
// Throughput probe & selection calibration
// ---------------------------------------------------------------------

/// One-shot microkernel throughput measurement: the active tier's GEMM
/// against the scalar reference on a small panel. Feeds
/// [`calibration`] and the `hylu bench` report.
#[derive(Clone, Copy, Debug)]
pub struct KernelProbe {
    /// Tier that was measured (the active dispatch tier).
    pub tier: KernelTier,
    /// Active-tier GEMM throughput on the probe panel.
    pub gemm_gflops: f64,
    /// Scalar-reference GEMM throughput on the same panel.
    pub scalar_gflops: f64,
}

impl KernelProbe {
    /// Measured dense-kernel advantage over the scalar reference.
    pub fn advantage(&self) -> f64 {
        self.gemm_gflops / self.scalar_gflops.max(1e-9)
    }
}

/// Cached probe measurement, keyed by the tier it measured: a
/// [`set_tier`] change self-invalidates the cache on the next read, so a
/// re-pinned process never keeps the previous tier's probe-scaled
/// selection crossovers (the old `OnceLock` did exactly that).
static PROBE: Mutex<Option<KernelProbe>> = Mutex::new(None);

/// Dense-advantage assumed by the selection thresholds' reference tuning
/// (the pre-probe hard-coded flop ratios were measured at ~2x).
const REFERENCE_ADVANTAGE: f64 = 2.0;

fn run_probe(tier: KernelTier) -> KernelProbe {
    const D: usize = 48;
    let a: Vec<f64> = (0..D * D).map(|i| ((i % 13) as f64 - 6.0) * 0.125).collect();
    let b: Vec<f64> = (0..D * D).map(|i| ((i % 7) as f64 - 3.0) * 0.25).collect();
    let mut c = vec![0.0f64; D * D];
    let flops = 2.0 * (D * D * D) as f64;
    let mut time_tier = |t: KernelTier| {
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            gemm_sub(t, &mut c, D, &a, D, &b, D, D, D, D);
            std::hint::black_box(c[0]);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let t_active = time_tier(tier);
    let t_scalar = time_tier(KernelTier::Scalar);
    KernelProbe {
        tier,
        gemm_gflops: flops / t_active.max(1e-9) / 1e9,
        scalar_gflops: flops / t_scalar.max(1e-9) / 1e9,
    }
}

/// Run (once per active tier) and cache the microkernel throughput probe.
/// Costs well under a millisecond; later calls return the cached
/// measurement until [`set_tier`] changes the tier, which re-measures on
/// the next read — `calibration`-scaled kernel selection always reflects
/// the tier actually dispatching.
pub fn probe() -> KernelProbe {
    let tier = active_tier();
    let mut cached = PROBE.lock().unwrap();
    if let Some(p) = *cached {
        if p.tier == tier {
            return p;
        }
    }
    let p = run_probe(tier);
    *cached = Some(p);
    p
}

/// Multiplier applied to the kernel-selection flop thresholds, calibrated
/// from the [`probe`]: a faster-than-reference dense tier lowers the
/// crossover (dense kernels pay off sooner), a slower one raises it. The
/// band is clamped tight so selection stays stable across noisy testbeds;
/// `HYLU_PROBE=off` pins it to 1.0 (the pre-probe hard-coded ratios).
pub fn calibration() -> f64 {
    if matches!(std::env::var("HYLU_PROBE").as_deref(), Ok("off") | Ok("0")) {
        return 1.0;
    }
    (REFERENCE_ADVANTAGE / probe().advantage().max(1e-3)).clamp(0.9, 1.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Prng;

    fn available_tiers() -> Vec<KernelTier> {
        [KernelTier::Scalar, KernelTier::Portable, KernelTier::Native, KernelTier::Avx512]
            .into_iter()
            .filter(|t| t.available())
            .collect()
    }

    fn naive_gemm_sub(c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize) {
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] -= s;
            }
        }
    }

    #[test]
    fn gemm_matches_naive_on_every_tier() {
        let mut rng = Prng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 2, 5), (4, 4, 4), (7, 5, 9), (12, 8, 16), (20, 17, 33)] {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want = c0.clone();
            naive_gemm_sub(&mut want, &a, &b, m, k, n);
            for tier in available_tiers() {
                let mut c = c0.clone();
                gemm_sub(tier, &mut c, n, &a, k, &b, n, m, k, n);
                for (x, y) in c.iter().zip(&want) {
                    assert!((x - y).abs() < 1e-11 * k as f64, "{tier} ({m},{k},{n})");
                }
            }
        }
    }

    #[test]
    fn gemm_respects_leading_dimensions_on_every_tier() {
        let mut rng = Prng::new(4);
        let (m, k, n) = (5usize, 3usize, 11usize);
        let (lda, ldb, ldc) = (7usize, 13usize, 14usize);
        let a: Vec<f64> = (0..m * lda).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * ldb).map(|_| rng.normal()).collect();
        let c0: Vec<f64> = (0..m * ldc).map(|_| rng.normal()).collect();
        for tier in available_tiers() {
            let mut c = c0.clone();
            gemm_sub(tier, &mut c, ldc, &a, lda, &b, ldb, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0;
                    for p in 0..k {
                        s += a[i * lda + p] * b[p * ldb + j];
                    }
                    assert!(
                        (c[i * ldc + j] - (c0[i * ldc + j] - s)).abs() < 1e-11,
                        "{tier} ({i},{j})"
                    );
                }
                // untouched beyond n
                for j in n..ldc {
                    assert_eq!(c[i * ldc + j], c0[i * ldc + j], "{tier} touched padding");
                }
            }
        }
    }

    #[test]
    fn trsm_solves_upper_system_on_every_tier() {
        let mut rng = Prng::new(5);
        for len in [5usize, 60] {
            let m = 9usize;
            let ldu = len + 4;
            // source "panel": upper triangle at (row0=1, col0=2)
            let mut u = vec![0.0; (len + 1) * ldu];
            for r in 0..len {
                for c in r..len {
                    u[(1 + r) * ldu + 2 + c] = if r == c {
                        2.0 + rng.uniform()
                    } else {
                        rng.normal() * 0.3
                    };
                }
            }
            // target panel: X region at offset 1, width len, ldx = len + 3
            let ldx = len + 3;
            let xs: Vec<f64> = (0..m * len).map(|_| rng.normal()).collect(); // true solution
            let mut b0 = vec![0.0; m * ldx];
            for r in 0..m {
                for c in 0..len {
                    let mut s = 0.0;
                    for p in 0..=c {
                        s += xs[r * len + p] * u[(1 + p) * ldu + 2 + c];
                    }
                    b0[r * ldx + 1 + c] = s;
                }
            }
            for tier in available_tiers() {
                let mut x = b0.clone();
                trsm_right_upper(tier, &mut x, ldx, 1, m, &u, ldu, 1, 2, len, &mut Vec::new());
                for r in 0..m {
                    for c in 0..len {
                        assert!(
                            (x[r * ldx + 1 + c] - xs[r * len + c]).abs() < 1e-9,
                            "{tier} len={len} ({r},{c})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lane_kernels_are_bit_identical_across_tiers() {
        let mut rng = Prng::new(6);
        for k in [1usize, 3, 4, 7, 16, 33] {
            let src: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let y0: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let m = rng.normal();
            let piv = 2.0 + rng.uniform();
            // scalar reference sequence
            let mut want = y0.clone();
            for (d, s) in want.iter_mut().zip(&src) {
                *d -= m * *s;
            }
            for d in want.iter_mut() {
                *d /= piv;
            }
            for tier in available_tiers() {
                let mut y = y0.clone();
                lanes_axpy_sub(tier, &mut y, &src, m);
                lanes_div(tier, &mut y, piv);
                assert_eq!(y, want, "{tier} k={k} must be bit-identical");
            }
        }
    }

    #[test]
    fn panel_block_kernels_match_rowwise_reference_bitwise() {
        let mut rng = Prng::new(7);
        let (first, w, nl, nu, k) = (6usize, 9usize, 4usize, 5usize, 3usize);
        let stride = nl + w + nu;
        let n = first + w + nu + 2;
        let lcols: Vec<u32> = (0..nl as u32).collect();
        let ucols: Vec<u32> = (0..nu as u32).map(|c| (first + w) as u32 + c).collect();
        let mut panel: Vec<f64> = (0..w * stride).map(|_| rng.normal()).collect();
        for r in 0..w {
            panel[r * stride + nl + r] = 3.0 + rng.uniform(); // solid pivots
        }
        let y0: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();

        // row-wise reference: the scalar per-row loops
        let mut want = y0.clone();
        for r in 0..w {
            let base = r * stride;
            let row = (first + r) * k;
            for (c, &j) in lcols.iter().enumerate() {
                let mlt = panel[base + c];
                let src = j as usize * k;
                for q in 0..k {
                    let t = mlt * want[src + q];
                    want[row + q] -= t;
                }
            }
            for kk in 0..r {
                let mlt = panel[base + nl + kk];
                let src = (first + kk) * k;
                for q in 0..k {
                    let t = mlt * want[src + q];
                    want[row + q] -= t;
                }
            }
        }
        for r in (0..w).rev() {
            let base = r * stride;
            let row = (first + r) * k;
            for (c, &j) in ucols.iter().enumerate() {
                let mlt = panel[base + nl + w + c];
                let src = j as usize * k;
                for q in 0..k {
                    let t = mlt * want[src + q];
                    want[row + q] -= t;
                }
            }
            for kk in r + 1..w {
                let mlt = panel[base + nl + kk];
                let src = (first + kk) * k;
                for q in 0..k {
                    let t = mlt * want[src + q];
                    want[row + q] -= t;
                }
            }
            let piv = panel[base + nl + r];
            for q in 0..k {
                want[row + q] /= piv;
            }
        }

        for tier in available_tiers() {
            let mut y = y0.clone();
            forward_panel_block(tier, &mut y, k, first, w, stride, &panel, &lcols);
            backward_panel_block(tier, &mut y, k, first, w, nl, stride, &panel, &ucols);
            assert_eq!(y, want, "{tier} panel block must be bit-identical");
        }
    }

    #[test]
    fn pack_rows_gathers_strided_rows() {
        let src: Vec<f64> = (0..24).map(|i| i as f64).collect();
        let mut dst = Vec::new();
        pack_rows(&mut dst, &src, 8, 3, 5);
        assert_eq!(dst.len(), 15);
        for r in 0..3 {
            for c in 0..5 {
                assert_eq!(dst[r * 5 + c], (r * 8 + c) as f64);
            }
        }
    }

    #[test]
    fn dot_and_axpy_on_every_tier() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 2.0, 2.0, 2.0];
        for tier in available_tiers() {
            assert_eq!(dot(tier, &a[..], &b[..]), 30.0, "{tier}");
            let mut y = [10.0, 10.0, 10.0];
            axpy_sub(tier, &mut y[..], &[1.0, 2.0, 3.0][..], 2.0);
            assert_eq!(y, [8.0, 6.0, 4.0], "{tier}");
        }
    }

    #[test]
    fn f32_gemm_is_bit_identical_to_f32_scalar_on_every_tier() {
        // the generic tiers must keep the scalar op order at every
        // precision; the native tier has no f32 microkernel and must fall
        // through to portable (handled inside the dispatch)
        let mut rng = Prng::new(21);
        for (m, k, n) in [(1, 1, 1), (7, 5, 9), (8, 8, 16), (9, 17, 33), (20, 9, 18)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let mut want = c0.clone();
            gemm_sub(KernelTier::Scalar, &mut want, n, &a, k, &b, n, m, k, n);
            for tier in [KernelTier::Portable, KernelTier::Avx512] {
                let mut c = c0.clone();
                gemm_sub(tier, &mut c, n, &a, k, &b, n, m, k, n);
                assert_eq!(c, want, "{tier} f32 gemm must keep the scalar op order");
            }
        }
    }

    #[test]
    fn f32_trsm_and_level1_run_on_every_tier() {
        let mut rng = Prng::new(22);
        let (len, m) = (12usize, 5usize);
        let ldu = len + 2;
        let mut u = vec![0.0f32; (len + 1) * ldu];
        for r in 0..len {
            for c in r..len {
                u[(1 + r) * ldu + c] =
                    if r == c { 2.0 + rng.uniform() as f32 } else { rng.normal() as f32 * 0.2 };
            }
        }
        let b0: Vec<f32> = (0..m * len).map(|_| rng.normal() as f32).collect();
        for tier in available_tiers() {
            let mut x = b0.clone();
            trsm_right_upper(tier, &mut x, len, 0, m, &u, ldu, 1, 0, len, &mut Vec::new());
            // verify against the triangular system: X · U = B
            for r in 0..m {
                for c in 0..len {
                    let mut s = 0.0f32;
                    for p in 0..=c {
                        s += x[r * len + p] * u[(1 + p) * ldu + c];
                    }
                    assert!((s - b0[r * len + c]).abs() < 1e-3, "{tier} ({r},{c})");
                }
            }
            assert_eq!(dot(tier, &b0[..4], &b0[..4]), dot(KernelTier::Scalar, &b0[..4], &b0[..4]));
            let mut y = b0.clone();
            axpy_sub(tier, &mut y, &b0.clone(), 0.5f32);
            assert_eq!(y[0], b0[0] - 0.5 * b0[0], "{tier}");
        }
    }

    #[test]
    fn tier_parse_and_availability() {
        assert_eq!(KernelTier::parse("scalar"), Some(KernelTier::Scalar));
        assert_eq!(KernelTier::parse("portable"), Some(KernelTier::Portable));
        assert_eq!(KernelTier::parse("native"), Some(KernelTier::Native));
        assert_eq!(KernelTier::parse("avx512"), Some(KernelTier::Avx512));
        assert_eq!(KernelTier::parse("bogus"), None);
        assert!(KernelTier::Scalar.available());
        assert!(KernelTier::Portable.available());
        // detection chain: the AVX-512 tier is only available when the
        // crate was compiled with the feature AND the CPU reports it
        if !cfg!(target_feature = "avx512f") {
            assert!(!KernelTier::Avx512.available());
        }
        let best = KernelTier::best_available();
        assert!(best.available());
        assert_ne!(best, KernelTier::Scalar);
    }

    #[test]
    fn avx512_gemm_matches_scalar_bitwise() {
        // the avx512 tier is safe blocked Rust: its numerics are testable
        // on every machine regardless of hardware support, and it keeps
        // the scalar per-element operation order exactly
        let mut rng = Prng::new(11);
        for (m, k, n) in [(1, 1, 1), (7, 5, 9), (8, 8, 16), (9, 17, 33), (20, 9, 18)] {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want = c0.clone();
            gemm_sub(KernelTier::Scalar, &mut want, n, &a, k, &b, n, m, k, n);
            let mut c = c0.clone();
            gemm_sub(KernelTier::Avx512, &mut c, n, &a, k, &b, n, m, k, n);
            assert_eq!(c, want, "avx512 gemm must keep the scalar op order ({m},{k},{n})");
        }
    }

    #[test]
    fn probe_and_calibration_are_sane() {
        let p = probe();
        assert!(p.gemm_gflops > 0.0);
        assert!(p.scalar_gflops > 0.0);
        assert!(p.advantage() > 0.0);
        let cal = calibration();
        assert!((0.9..=1.5).contains(&cal), "calibration {cal} outside clamp");
        // cached: second call returns the identical measurement
        assert_eq!(p.gemm_gflops, probe().gemm_gflops);
    }
}

//! Per-pattern kernel autotuner.
//!
//! The dispatch layer in [`super`] picks one kernel *tier* per process
//! from a one-shot probe; real HYLU deployments factor the *same sparsity
//! pattern* millions of times, which pays for much deeper tuning. This
//! module searches a bounded variant space — GEMM register-tile shapes
//! ([`TILE_VARIANTS`]: MR×NR ∈ {4×8, 8×8, 4×16, 8×16, 2×24}, k-loop
//! unroll ∈ {1, 4}), A-operand packing on/off, and the TRSM
//! gather-crossover thresholds — and times every candidate **on the
//! pattern's own supernode shape histogram** (the same nodes×groups sweep
//! that sizes the `ExecPlan` scratch bounds), weighted by each shape's
//! flop share. The winner is recorded as a [`KernelPlan`] and cached
//! inside the analysis' `ExecPlan`, so warm refactor+solve paths stay
//! zero-alloc and zero-probe: tuning cost is paid once at analyze/tune
//! time.
//!
//! Determinism: every tiled GEMM variant keeps one accumulator per C
//! element, walks `k` ascending, and separates multiply from subtract, so
//! it is **bit-identical to the scalar reference** (no FMA contraction) —
//! swapping variants never changes factor bits. The TRSM thresholds pick
//! between the two existing per-tier paths (gather vs direct), which may
//! differ by rounding within a tier; a plan is fixed per analysis, so
//! refactor replay and parallel-vs-sequential bit-equality still hold.
//! Tuned plans are memoized in-process per `(tier, pattern hash)` so two
//! solvers analyzing the same pattern always agree on one plan.
//!
//! Persistence: with `HYLU_TUNE_CACHE=dir` set, winning plans are written
//! to a small versioned text file keyed by `(format version, tier,
//! pattern hash)` and reloaded on the next analyze of the same pattern —
//! a service restart starts warm. Corrupt, truncated, or version-bumped
//! entries are ignored (the search simply re-runs); cache writes are
//! best-effort.

use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use super::{gemm_sub, pack_rows, trsm_right_upper_with, KernelTier};
use crate::numeric::Scalar;
use crate::symbolic::Symbolic;

/// How much search effort `analyze` spends tuning kernels per pattern.
/// Selected by `SolverBuilder::tuning` / `hylu bench --tuning`; the
/// `HYLU_TUNING=off|quick|full` env var overrides the configured value
/// (see [`effective`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tuning {
    /// No search: every analysis uses the default [`KernelPlan`]
    /// (exactly the pre-tuner behavior). The default.
    Off,
    /// Bounded search: top 3 histogram shapes, unroll-4 tile variants
    /// only, few timing reps. Adds on the order of milliseconds to
    /// analyze.
    Quick,
    /// Full search: top 8 histogram shapes, every tile variant, more
    /// timing reps.
    Full,
}

impl Tuning {
    /// Parse a tuning level name (`off` / `quick` / `full`).
    pub fn parse(s: &str) -> Option<Tuning> {
        match s {
            "off" => Some(Tuning::Off),
            "quick" => Some(Tuning::Quick),
            "full" => Some(Tuning::Full),
            _ => None,
        }
    }
}

impl std::fmt::Display for Tuning {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Tuning::Off => write!(f, "off"),
            Tuning::Quick => write!(f, "quick"),
            Tuning::Full => write!(f, "full"),
        }
    }
}

/// The configured tuning level with the `HYLU_TUNING` env override
/// applied (set and parseable wins; anything else keeps `cfg`). This is
/// what lets a CI leg or an operator flip tuning on without touching
/// call sites.
pub fn effective(cfg: Tuning) -> Tuning {
    match std::env::var("HYLU_TUNING") {
        Ok(s) if !s.is_empty() => Tuning::parse(&s).unwrap_or(cfg),
        _ => cfg,
    }
}

/// GEMM inner-kernel choice inside a [`KernelPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmVariant {
    /// The active tier's own microkernel (the untuned default).
    Tier,
    /// Register-tiled variant: `mr`×`nr` C tile held in accumulators
    /// across the whole k loop, k loop unrolled by `ku`. Bit-identical
    /// to the scalar reference on every shape.
    Tiled {
        /// Tile rows.
        mr: u8,
        /// Tile columns.
        nr: u8,
        /// k-loop unroll factor.
        ku: u8,
    },
}

impl std::fmt::Display for GemmVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GemmVariant::Tier => write!(f, "tier"),
            GemmVariant::Tiled { mr, nr, ku } => write!(f, "tiled {mr}x{nr}/u{ku}"),
        }
    }
}

/// Winning kernel configuration for one analyzed pattern. Cached in
/// `ExecPlan::kernel`; [`KernelPlan::default`] reproduces the untuned
/// behavior exactly (tier microkernel, strided A, the historical
/// `len >= 48 && m >= 8` TRSM gather crossover).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelPlan {
    /// Which GEMM inner kernel the sup-sup update uses.
    pub gemm: GemmVariant,
    /// Pack the GEMM A operand (the target panel's L-part columns) into
    /// the `Workspace::abuf` arena so both operands stream contiguously.
    pub pack_a: bool,
    /// Minimum triangle size for the TRSM gather path.
    pub trsm_min_len: usize,
    /// Minimum target row count for the TRSM gather path.
    pub trsm_min_m: usize,
}

impl Default for KernelPlan {
    fn default() -> Self {
        KernelPlan { gemm: GemmVariant::Tier, pack_a: false, trsm_min_len: 48, trsm_min_m: 8 }
    }
}

impl std::fmt::Display for KernelPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "gemm={} pack_a={} trsm>=({},{})",
            self.gemm,
            if self.pack_a { "on" } else { "off" },
            self.trsm_min_len,
            self.trsm_min_m
        )
    }
}

/// The enumerated GEMM tile variant space: `(MR, NR, KU)` triples. Every
/// triple here has a monomorphized kernel instance in
/// [`gemm_sub_tiled`]; the disk cache rejects triples outside this list
/// (a stale entry from an older variant space must not dispatch to the
/// scalar fallback silently).
pub const TILE_VARIANTS: [(u8, u8, u8); 10] = [
    (4, 8, 1),
    (4, 8, 4),
    (8, 8, 1),
    (8, 8, 4),
    (4, 16, 1),
    (4, 16, 4),
    (8, 16, 1),
    (8, 16, 4),
    (2, 24, 1),
    (2, 24, 4),
];

// ---------------------------------------------------------------------
// Tiled GEMM variants
// ---------------------------------------------------------------------

/// One monomorphized tile variant of `gemm_sub`: MR×NR C tile held in
/// per-element accumulators across the whole k loop (unrolled by KU),
/// 1×NR row-remainder strips, scalar-order column remainder. Each C
/// element sees exactly the scalar reference's operation sequence
/// (products subtracted one at a time, k ascending), so the result is
/// bit-identical to [`super::KernelTier::Scalar`] for every shape.
///
/// # Safety
/// `cp/ap/bp` must be valid for the strided `m×n`, `m×k`, `k×n` accesses,
/// and the C range must not overlap A or B element-wise.
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
unsafe fn gemm_sub_tile<T: Scalar, const MR: usize, const NR: usize, const KU: usize>(
    cp: *mut T,
    ldc: usize,
    ap: *const T,
    lda: usize,
    bp: *const T,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    let mut j = 0;
    while j + NR <= n {
        let mut i = 0;
        while i + MR <= m {
            let mut t = [[T::ZERO; NR]; MR];
            for r in 0..MR {
                let crow = cp.add((i + r) * ldc + j);
                for q in 0..NR {
                    t[r][q] = *crow.add(q);
                }
            }
            let mut p = 0;
            while p + KU <= k {
                for u in 0..KU {
                    let pp = p + u;
                    let brow = bp.add(pp * ldb + j);
                    for r in 0..MR {
                        let f = *ap.add((i + r) * lda + pp);
                        for q in 0..NR {
                            t[r][q] -= f * *brow.add(q);
                        }
                    }
                }
                p += KU;
            }
            while p < k {
                let brow = bp.add(p * ldb + j);
                for r in 0..MR {
                    let f = *ap.add((i + r) * lda + p);
                    for q in 0..NR {
                        t[r][q] -= f * *brow.add(q);
                    }
                }
                p += 1;
            }
            for r in 0..MR {
                let crow = cp.add((i + r) * ldc + j);
                for q in 0..NR {
                    *crow.add(q) = t[r][q];
                }
            }
            i += MR;
        }
        // row remainder (m % MR): 1×NR strips
        while i < m {
            let mut t = [T::ZERO; NR];
            let crow = cp.add(i * ldc + j);
            for q in 0..NR {
                t[q] = *crow.add(q);
            }
            let arow = ap.add(i * lda);
            for p in 0..k {
                let f = *arow.add(p);
                let brow = bp.add(p * ldb + j);
                for q in 0..NR {
                    t[q] -= f * *brow.add(q);
                }
            }
            for q in 0..NR {
                *crow.add(q) = t[q];
            }
            i += 1;
        }
        j += NR;
    }
    if j < n {
        // column remainder strip (n % NR): scalar-order loop, same
        // per-element update sequence
        super::scalar::gemm_sub_raw(cp.add(j), ldc, ap, lda, bp.add(j), ldb, m, k, n - j);
    }
}

/// Runtime dispatch over the monomorphized [`TILE_VARIANTS`] instances.
/// Unknown triples (possible only via a hand-edited plan) run the scalar
/// reference, which every variant is bit-identical to anyway.
///
/// # Safety
/// Same contract as [`gemm_sub_tile`].
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_sub_tiled<T: Scalar>(
    mr: u8,
    nr: u8,
    ku: u8,
    cp: *mut T,
    ldc: usize,
    ap: *const T,
    lda: usize,
    bp: *const T,
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    match (mr, nr, ku) {
        (4, 8, 1) => gemm_sub_tile::<T, 4, 8, 1>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (4, 8, 4) => gemm_sub_tile::<T, 4, 8, 4>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (8, 8, 1) => gemm_sub_tile::<T, 8, 8, 1>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (8, 8, 4) => gemm_sub_tile::<T, 8, 8, 4>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (4, 16, 1) => gemm_sub_tile::<T, 4, 16, 1>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (4, 16, 4) => gemm_sub_tile::<T, 4, 16, 4>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (8, 16, 1) => gemm_sub_tile::<T, 8, 16, 1>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (8, 16, 4) => gemm_sub_tile::<T, 8, 16, 4>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (2, 24, 1) => gemm_sub_tile::<T, 2, 24, 1>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        (2, 24, 4) => gemm_sub_tile::<T, 2, 24, 4>(cp, ldc, ap, lda, bp, ldb, m, k, n),
        _ => super::scalar::gemm_sub_raw(cp, ldc, ap, lda, bp, ldb, m, k, n),
    }
}

// ---------------------------------------------------------------------
// Shape histogram + candidate timing
// ---------------------------------------------------------------------

/// One aggregated sup-sup GEMM shape from the pattern: `m×k×n` =
/// (target width × group length × source U-tail), weighted by its total
/// flop share across the whole factorization.
#[derive(Clone, Copy, Debug)]
pub struct Shape {
    /// GEMM rows (target panel width).
    pub m: usize,
    /// GEMM depth (update group length = triangle size of the TRSM).
    pub k: usize,
    /// GEMM columns (source U-tail width).
    pub n: usize,
    /// Total `2·m·k·n` flop weight of every group with this shape.
    pub weight: f64,
}

/// Aggregate the pattern's sup-sup update shapes (the same nodes×groups
/// sweep `ExecPlan::build` uses for its scratch bounds), heaviest first,
/// truncated to `cap` entries. Empty when the pattern has no sup-sup
/// updates — nothing to tune.
pub fn shape_histogram(sym: &Symbolic, cap: usize) -> Vec<Shape> {
    use std::collections::HashMap;
    let mut acc: HashMap<(usize, usize, usize), f64> = HashMap::new();
    for node in &sym.nodes {
        if !node.is_super {
            continue;
        }
        let w = node.width as usize;
        for g in &sym.groups[node.g_start..node.g_end] {
            let src = &sym.nodes[g.src as usize];
            if !src.is_super {
                continue;
            }
            let len = g.len as usize;
            let s_nu = src.nu();
            if len == 0 || s_nu == 0 {
                continue;
            }
            *acc.entry((w, len, s_nu)).or_insert(0.0) += 2.0 * (w * len * s_nu) as f64;
        }
    }
    let mut shapes: Vec<Shape> =
        acc.into_iter().map(|((m, k, n), weight)| Shape { m, k, n, weight }).collect();
    // heaviest first; deterministic tie-break on the shape key
    shapes.sort_by(|a, b| {
        b.weight
            .partial_cmp(&a.weight)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then((a.m, a.k, a.n).cmp(&(b.m, b.k, b.n)))
    });
    shapes.truncate(cap);
    shapes
}

/// Deterministic pseudo-values for timing buffers (same idiom as the
/// dispatch probe).
fn fill(buf: &mut [f64], phase: usize) {
    for (i, v) in buf.iter_mut().enumerate() {
        *v = (((i + phase) % 13) as f64 - 6.0) * 0.125;
    }
}

/// Best-of-`reps` wall time of one GEMM candidate on one shape. A is laid
/// out strided (`lda = k + 8`, mimicking the panel read); `pack_a`
/// candidates pay the pack inside the timed region, exactly as the factor
/// kernel would.
fn bench_gemm(
    tier: KernelTier,
    variant: GemmVariant,
    pack_a: bool,
    m: usize,
    k: usize,
    n: usize,
    reps: usize,
) -> f64 {
    let lda_strided = k + 8;
    let mut a = vec![0.0f64; m * lda_strided];
    let mut b = vec![0.0f64; k * n];
    let mut c = vec![0.0f64; m * n];
    fill(&mut a, 1);
    fill(&mut b, 2);
    fill(&mut c, 3);
    let mut abuf: Vec<f64> = Vec::with_capacity(m * k);
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        let (ap, lda): (&[f64], usize) = if pack_a {
            pack_rows(&mut abuf, &a, lda_strided, m, k);
            (&abuf, k)
        } else {
            (&a, lda_strided)
        };
        match variant {
            GemmVariant::Tier => gemm_sub(tier, &mut c, n, ap, lda, &b, n, m, k, n),
            GemmVariant::Tiled { mr, nr, ku } => unsafe {
                gemm_sub_tiled(mr, nr, ku, c.as_mut_ptr(), n, ap.as_ptr(), lda, b.as_ptr(), n, m, k, n)
            },
        }
        std::hint::black_box(&c);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Best-of-`reps` wall times of the TRSM (gather path, direct path) on a
/// `len`-triangle against `m` target rows.
fn bench_trsm(tier: KernelTier, len: usize, m: usize, reps: usize) -> (f64, f64) {
    let ldu = len;
    let mut u = vec![0.0f64; len * ldu];
    for r in 0..len {
        for c in r..len {
            u[r * ldu + c] = if r == c { 2.0 + ((c % 5) as f64) * 0.1 } else { 0.01 };
        }
    }
    let mut x0 = vec![0.0f64; m * len];
    fill(&mut x0, 5);
    let mut x = x0.clone();
    let mut scratch = Vec::new();
    let mut time_path = |min_len: usize, min_m: usize| {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            x.copy_from_slice(&x0);
            let t0 = Instant::now();
            trsm_right_upper_with(
                tier,
                &mut x,
                len,
                0,
                m,
                &u,
                ldu,
                0,
                0,
                len,
                &mut scratch,
                min_len,
                min_m,
            );
            std::hint::black_box(&x);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let gather = time_path(0, 0);
    let direct = time_path(usize::MAX, usize::MAX);
    (gather, direct)
}

/// The TRSM crossover candidates: three graded thresholds plus "gather
/// off". `(48, 8)` is the historical default.
const TRSM_CANDIDATES: [(usize, usize); 4] =
    [(32, 4), (48, 8), (64, 16), (usize::MAX, usize::MAX)];

fn pick_trsm(tier: KernelTier, shapes: &[Shape], reps: usize) -> (usize, usize) {
    if tier == KernelTier::Scalar {
        // the gather path never triggers on the scalar tier
        return (48, 8);
    }
    // time both paths once per shape, then score every candidate from the
    // same measurements (deterministic given the timings)
    let timed: Vec<(usize, usize, f64, f64, f64)> = shapes
        .iter()
        .map(|s| {
            let len = s.k.clamp(1, 192);
            let m = s.m.clamp(1, 48);
            let (gather, direct) = bench_trsm(tier, len, m, reps);
            (len, m, s.weight, gather, direct)
        })
        .collect();
    let mut best = (48usize, 8usize);
    let mut best_cost = f64::INFINITY;
    for (min_len, min_m) in TRSM_CANDIDATES {
        let cost: f64 = timed
            .iter()
            .map(|&(len, m, w, gather, direct)| {
                w * if len >= min_len && m >= min_m { gather } else { direct }
            })
            .sum();
        if cost < best_cost {
            best_cost = cost;
            best = (min_len, min_m);
        }
    }
    best
}

/// The GEMM candidate list for one tuning level: the tier's own kernel
/// plus the tile variants (Quick keeps only the unroll-4 tiles).
fn candidate_variants(tuning: Tuning) -> Vec<GemmVariant> {
    let mut v = vec![GemmVariant::Tier];
    for &(mr, nr, ku) in TILE_VARIANTS.iter() {
        if tuning == Tuning::Quick && ku != 4 {
            continue;
        }
        v.push(GemmVariant::Tiled { mr, nr, ku });
    }
    v
}

/// Run the search: time every candidate on the pattern's shape histogram
/// and return the flop-weighted winner. Does not consult any cache — use
/// [`tune_cached`] from the analyze path.
pub fn search(sym: &Symbolic, tier: KernelTier, tuning: Tuning) -> KernelPlan {
    let (cap, reps) = match tuning {
        Tuning::Off => return KernelPlan::default(),
        Tuning::Quick => (3, 3),
        Tuning::Full => (8, 5),
    };
    let shapes = shape_histogram(sym, cap);
    if shapes.is_empty() {
        // no sup-sup updates: the dense GEMM never runs on this pattern
        return KernelPlan::default();
    }
    let mut best = (GemmVariant::Tier, false);
    let mut best_cost = f64::INFINITY;
    for variant in candidate_variants(tuning) {
        for pack_a in [false, true] {
            let cost: f64 = shapes
                .iter()
                .map(|s| {
                    s.weight
                        * bench_gemm(
                            tier,
                            variant,
                            pack_a,
                            s.m.clamp(1, 96),
                            s.k.clamp(1, 384),
                            s.n.clamp(1, 384),
                            reps,
                        )
                })
                .sum();
            if cost < best_cost {
                best_cost = cost;
                best = (variant, pack_a);
            }
        }
    }
    let (trsm_min_len, trsm_min_m) = pick_trsm(tier, &shapes, reps);
    KernelPlan { gemm: best.0, pack_a: best.1, trsm_min_len, trsm_min_m }
}

// ---------------------------------------------------------------------
// In-process memo + on-disk cache
// ---------------------------------------------------------------------

/// In-process memo of tuned plans keyed by `(tier, pattern hash)`: two
/// solvers analyzing the same pattern in one process must agree on one
/// plan (timing noise would otherwise let their TRSM thresholds — the one
/// non-bit-identical knob — diverge).
static MEMO: Mutex<Vec<(KernelTier, u64, KernelPlan)>> = Mutex::new(Vec::new());
const MEMO_CAP: usize = 32;

fn memo_get(tier: KernelTier, hash: u64) -> Option<KernelPlan> {
    let memo = MEMO.lock().unwrap();
    memo.iter().find(|e| e.0 == tier && e.1 == hash).map(|e| e.2)
}

fn memo_put(tier: KernelTier, hash: u64, plan: KernelPlan) {
    let mut memo = MEMO.lock().unwrap();
    if memo.iter().any(|e| e.0 == tier && e.1 == hash) {
        return;
    }
    if memo.len() >= MEMO_CAP {
        memo.remove(0);
    }
    memo.push((tier, hash, plan));
}

/// On-disk cache format version; bumped whenever [`KernelPlan`] or the
/// variant space changes meaning. Entries from other versions are
/// ignored (both the filename and the header carry it).
pub const TUNE_CACHE_VERSION: u32 = 1;

fn cache_dir() -> Option<PathBuf> {
    match std::env::var("HYLU_TUNE_CACHE") {
        Ok(s) if !s.is_empty() => Some(PathBuf::from(s)),
        _ => None,
    }
}

fn cache_path(dir: &Path, tier: KernelTier, hash: u64) -> PathBuf {
    dir.join(format!("hylu-tune-v{TUNE_CACHE_VERSION}-{tier}-{hash:016x}.txt"))
}

/// Best-effort write of a tuned plan to the on-disk cache directory
/// (created if missing; I/O errors are ignored — the cache is an
/// optimization, never a correctness dependency).
pub fn store_cached(dir: &Path, tier: KernelTier, hash: u64, plan: &KernelPlan) {
    let _ = std::fs::create_dir_all(dir);
    let gemm = match plan.gemm {
        GemmVariant::Tier => "tier".to_string(),
        GemmVariant::Tiled { mr, nr, ku } => format!("tiled {mr} {nr} {ku}"),
    };
    let body = format!(
        "hylu-tune-cache v{TUNE_CACHE_VERSION}\ngemm {gemm}\npack_a {}\ntrsm {} {}\n",
        plan.pack_a as u8,
        plan.trsm_min_len,
        plan.trsm_min_m
    );
    let _ = std::fs::write(cache_path(dir, tier, hash), body);
}

/// Load a tuned plan from the on-disk cache. Returns `None` — never an
/// error — for missing, truncated, garbage, version-bumped, or
/// out-of-variant-space entries.
pub fn load_cached(dir: &Path, tier: KernelTier, hash: u64) -> Option<KernelPlan> {
    let text = std::fs::read_to_string(cache_path(dir, tier, hash)).ok()?;
    parse_plan(&text)
}

fn parse_plan(text: &str) -> Option<KernelPlan> {
    let mut lines = text.lines();
    if lines.next()? != format!("hylu-tune-cache v{TUNE_CACHE_VERSION}").as_str() {
        return None;
    }
    let mut gemm = None;
    let mut pack_a = None;
    let mut trsm = None;
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("gemm") => match it.next()? {
                "tier" => gemm = Some(GemmVariant::Tier),
                "tiled" => {
                    let mr: u8 = it.next()?.parse().ok()?;
                    let nr: u8 = it.next()?.parse().ok()?;
                    let ku: u8 = it.next()?.parse().ok()?;
                    if !TILE_VARIANTS.contains(&(mr, nr, ku)) {
                        return None;
                    }
                    gemm = Some(GemmVariant::Tiled { mr, nr, ku });
                }
                _ => return None,
            },
            Some("pack_a") => {
                pack_a = Some(match it.next()? {
                    "0" => false,
                    "1" => true,
                    _ => return None,
                })
            }
            Some("trsm") => {
                let l: usize = it.next()?.parse().ok()?;
                let m: usize = it.next()?.parse().ok()?;
                trsm = Some((l, m));
            }
            Some(_) => return None,
            None => {} // blank line
        }
    }
    let (trsm_min_len, trsm_min_m) = trsm?;
    Some(KernelPlan { gemm: gemm?, pack_a: pack_a?, trsm_min_len, trsm_min_m })
}

/// The analyze-path entry point: resolve a plan for `(tier, pattern)`
/// through the in-process memo, then the optional on-disk cache
/// (`HYLU_TUNE_CACHE=dir`), then a fresh [`search`]; winners propagate
/// back into both caches. `Tuning::Off` short-circuits to the default
/// plan with zero probing.
pub fn tune_cached(sym: &Symbolic, tier: KernelTier, tuning: Tuning, pattern_hash: u64) -> KernelPlan {
    if tuning == Tuning::Off {
        return KernelPlan::default();
    }
    if let Some(p) = memo_get(tier, pattern_hash) {
        return p;
    }
    if let Some(dir) = cache_dir() {
        if let Some(p) = load_cached(&dir, tier, pattern_hash) {
            memo_put(tier, pattern_hash, p);
            return p;
        }
    }
    let plan = search(sym, tier, tuning);
    memo_put(tier, pattern_hash, plan);
    if let Some(dir) = cache_dir() {
        store_cached(&dir, tier, pattern_hash, &plan);
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_roundtrips_through_the_text_format() {
        for gemm in [GemmVariant::Tier, GemmVariant::Tiled { mr: 8, nr: 16, ku: 4 }] {
            for pack_a in [false, true] {
                let plan =
                    KernelPlan { gemm, pack_a, trsm_min_len: 32, trsm_min_m: 4 };
                let gemm_txt = match plan.gemm {
                    GemmVariant::Tier => "tier".to_string(),
                    GemmVariant::Tiled { mr, nr, ku } => format!("tiled {mr} {nr} {ku}"),
                };
                let body = format!(
                    "hylu-tune-cache v{TUNE_CACHE_VERSION}\ngemm {gemm_txt}\npack_a {}\ntrsm {} {}\n",
                    plan.pack_a as u8, plan.trsm_min_len, plan.trsm_min_m
                );
                assert_eq!(parse_plan(&body), Some(plan));
            }
        }
    }

    #[test]
    fn parser_rejects_bad_entries() {
        assert_eq!(parse_plan(""), None);
        assert_eq!(parse_plan("hylu-tune-cache v999\ngemm tier\npack_a 0\ntrsm 48 8\n"), None);
        assert_eq!(
            parse_plan("hylu-tune-cache v1\ngemm tiled 5 5 5\npack_a 0\ntrsm 48 8\n"),
            None,
            "out-of-variant-space tile must be rejected"
        );
        assert_eq!(parse_plan("hylu-tune-cache v1\ngemm tier\n"), None, "truncated");
        assert_eq!(parse_plan("garbage\nbytes"), None);
    }

    #[test]
    fn effective_defaults_to_configured_level() {
        // HYLU_TUNING is not set in the test environment
        if std::env::var("HYLU_TUNING").is_err() {
            assert_eq!(effective(Tuning::Quick), Tuning::Quick);
            assert_eq!(effective(Tuning::Off), Tuning::Off);
        }
    }
}

//! The hybrid numeric kernels and the sequential factorization driver.
//!
//! One engine, three kernels (paper Fig. 1):
//! - **row-row**: scalar up-looking Gilbert–Peierls; sources and target are
//!   sparse rows. No BLAS-like calls at all.
//! - **sup-row**: target is a row (possibly of a supernode panel being
//!   filled row-wise); supernode sources are applied with dense panel rows
//!   (TRSV + GEMV shape, level-2).
//! - **sup-sup**: target is a whole supernode panel; supernode sources are
//!   applied with TRSM + GEMM (level-3), and the panel finishes with a
//!   partially-pivoted dense internal factorization (supernode diagonal
//!   pivoting + perturbation).
//!
//! Refactorization (`refactor = true`) replays the stored pivot order with
//! no search — the paper's repeated-solve fast path.

use crate::numeric::kernels;
use crate::numeric::kernels::KernelPlan;
use crate::numeric::select::KernelMode;
use crate::numeric::{LuFactors, PivotConfig, Scalar, SharedFactors, Workspace};
use crate::sparse::csr::Csr;
use crate::symbolic::Symbolic;

/// Pluggable dense-GEMM backend: the sup-sup kernel calls this for its
/// level-3 update; [`NativeGemm`] uses the in-crate tiered microkernels
/// ([`crate::numeric::kernels`]), and the XLA/PJRT runtime provides an
/// AOT-Pallas-artifact implementation ([`crate::runtime`]).
pub trait GemmBackend: Sync {
    /// `c[m×n] (ldc=n, zeroed) -= a[m×k] (lda) · b[k×n] (ldb)`. The B
    /// operand arrives pre-packed contiguous (`ldb == n`). Return `false`
    /// to fall back to the in-crate microkernel.
    #[allow(clippy::too_many_arguments)]
    fn gemm_sub(
        &self,
        c: &mut [f64],
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        m: usize,
        k: usize,
        n: usize,
    ) -> bool;
}

/// Default backend: the in-crate runtime-dispatched microkernels.
pub struct NativeGemm;

impl GemmBackend for NativeGemm {
    fn gemm_sub(
        &self,
        _c: &mut [f64],
        _a: &[f64],
        _lda: usize,
        _b: &[f64],
        _ldb: usize,
        _m: usize,
        _k: usize,
        _n: usize,
    ) -> bool {
        false // always use the native path inline (no copy indirection)
    }
}

/// Factor (or refactor) `a` (already permuted + scaled) into `fac`.
/// Returns the number of perturbed pivots. Generic over the factor
/// element type: `T = f64` is the bit-exact legacy path, `T = f32` is
/// the mixed-precision numeric core (A values are rounded on scatter;
/// pivot search, perturbation, and all updates then run entirely in
/// `f32`).
pub fn factor<T: Scalar>(
    a: &Csr,
    sym: &Symbolic,
    mode: KernelMode,
    cfg: &PivotConfig,
    fac: &mut LuFactors<T>,
    refactor: bool,
    gemm: &dyn GemmBackend,
) -> usize {
    assert_eq!(a.n, sym.n);
    if !refactor {
        for (i, p) in fac.pivot_perm.iter_mut().enumerate() {
            *p = i as u32;
        }
    }
    let amax = a.max_abs();
    let eps_abs = if cfg.perturb {
        cfg.perturb_eps * amax.max(1e-300)
    } else {
        0.0
    };
    let sf = SharedFactors::new(fac);
    let mut ws = Workspace::new(sym.n);
    // The standalone driver has no ExecPlan to carry a tuned kernel plan;
    // the default plan keeps it bit-compatible with pre-tuner behavior.
    let plan = KernelPlan::default();
    for id in 0..sym.nodes.len() {
        // Safety: sequential — every source node is complete in program
        // order; each node writes only its own storage.
        unsafe {
            factor_node(id, a, sym, &sf, &mut ws, mode, cfg, eps_abs, refactor, gemm, &plan)
        };
    }
    let perturbed = sf.perturbed.load(std::sync::atomic::Ordering::Relaxed);
    fac.perturbed = perturbed;
    fac.growth = pivot_growth(sf.umax_value(), amax);
    perturbed
}

/// Element-growth ratio `max|U_ij| / max|A_ij|` from the tracked maxima.
/// A non-finite `max|U|` (overflow / NaN factors) is passed through
/// untouched so the quarantine monitor sees it; an all-zero matrix
/// reports zero growth.
pub(crate) fn pivot_growth(umax: f64, amax: f64) -> f64 {
    if !umax.is_finite() {
        umax
    } else if amax > 0.0 {
        umax / amax
    } else {
        0.0
    }
}

/// Fold one `|U_ij|` sample into a thread-local growth maximum. NaN wins
/// and then sticks (mirroring [`SharedFactors::update_umax`]) so bad
/// arithmetic is never masked by a later finite entry.
#[inline]
fn fold_max(cur: f64, v: f64) -> f64 {
    if cur.is_nan() || v <= cur {
        cur
    } else {
        v
    }
}

/// Factor one node. Safety: caller guarantees all source nodes (this node's
/// groups) are complete and no other thread touches this node's storage.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn factor_node<T: Scalar>(
    id: usize,
    a: &Csr,
    sym: &Symbolic,
    sf: &SharedFactors<T>,
    ws: &mut Workspace<T>,
    mode: KernelMode,
    cfg: &PivotConfig,
    eps_abs: f64,
    refactor: bool,
    gemm: &dyn GemmBackend,
    plan: &KernelPlan,
) {
    let nd = &sym.nodes[id];
    if nd.is_super && mode == KernelMode::SupSup {
        factor_panel(id, a, sym, sf, ws, cfg, eps_abs, refactor, gemm, plan);
    } else {
        factor_rows(id, a, sym, sf, ws, eps_abs);
    }
}

/// Perturb a tiny pivot; returns (pivot, perturbed?). The threshold
/// compare and replacement magnitude are computed in `f64` (bit-identical
/// to the historical scalar code when `T = f64`; a single rounding on the
/// replacement value when `T = f32`).
#[inline]
fn perturb_pivot<T: Scalar>(p: T, eps_abs: f64) -> (T, bool) {
    if eps_abs > 0.0 && p.to_f64().abs() < eps_abs {
        let s = if p < T::ZERO { -1.0 } else { 1.0 };
        (T::from_f64(s * eps_abs), true)
    } else {
        (p, false)
    }
}

/// The sup-sup kernel: whole-panel target.
#[allow(clippy::too_many_arguments)]
unsafe fn factor_panel<T: Scalar>(
    id: usize,
    a: &Csr,
    sym: &Symbolic,
    sf: &SharedFactors<T>,
    ws: &mut Workspace<T>,
    cfg: &PivotConfig,
    eps_abs: f64,
    refactor: bool,
    gemm: &dyn GemmBackend,
    plan: &KernelPlan,
) {
    let tier = kernels::active_tier();
    let nd = &sym.nodes[id];
    let first = nd.first as usize;
    let w = nd.width as usize;
    let nl = nd.nl();
    let nu = nd.nu();
    let stride = nl + w + nu;
    let lcols = &sym.lcols[nd.l_start..nd.l_end];
    let ucols = &sym.ucols[nd.u_start..nd.u_end];
    let panel = sf.panel_mut(id);
    panel.fill(T::ZERO);

    // column map
    for (c, &j) in lcols.iter().enumerate() {
        ws.colmap[j as usize] = c as i32;
    }
    for kk in 0..w {
        ws.colmap[first + kk] = (nl + kk) as i32;
    }
    for (c, &j) in ucols.iter().enumerate() {
        ws.colmap[j as usize] = (nl + w + c) as i32;
    }

    // scatter A rows (refactor replays the recorded pivot order)
    for r in 0..w {
        let src_row = if refactor {
            *sf.pivot_perm.add(first + r) as usize
        } else {
            first + r
        };
        let base = r * stride;
        for (k, &j) in a.row_indices(src_row).iter().enumerate() {
            let pc = ws.colmap[j];
            debug_assert!(pc >= 0, "A entry ({src_row},{j}) outside pattern");
            panel[base + pc as usize] = T::from_f64(a.row_vals(src_row)[k]);
        }
    }

    // updates from previous nodes, ascending column order
    for g in &sym.groups[nd.g_start..nd.g_end] {
        let src = &sym.nodes[g.src as usize];
        let len = g.len as usize;
        let goff = g.offset as usize;
        if src.is_super {
            let s_nl = src.nl();
            let s_w = src.width as usize;
            let s_nu = src.nu();
            let sstride = s_nl + s_w + s_nu;
            let k0 = lcols[goff] as usize - src.first as usize;
            debug_assert_eq!(k0 + len, s_w, "group must be a tail segment");
            let spanel = sf.panel_ref(g.src as usize);
            // TRSM: finalize L block (panel cols goff..goff+len); the
            // gather crossover comes from the tuned plan.
            kernels::trsm_right_upper_with(
                tier,
                panel,
                stride,
                goff,
                w,
                spanel,
                sstride,
                k0,
                s_nl + k0,
                len,
                &mut ws.tbuf,
                plan.trsm_min_len,
                plan.trsm_min_m,
            );
            // GEMM: C = X · U_tail, then scatter-subtract
            if s_nu > 0 {
                let sucols = &sym.ucols[src.u_start..src.u_end];
                // Pack the source panel's U-tail sliver (len × s_nu,
                // strided by sstride) contiguous ONCE per target panel,
                // so the microkernel streams B linearly instead of
                // re-striding the source panel for every row block.
                kernels::pack_rows(
                    &mut ws.pbuf,
                    &spanel[k0 * sstride + s_nl + s_w..],
                    sstride,
                    len,
                    s_nu,
                );
                // Fast path: both column lists are sorted, so the map is
                // monotone; if it is also *contiguous* the GEMM can run
                // directly into the target panel — no cbuf, no scatter.
                // A-operand packing (tuned): gather the w × len multiplier
                // block (panel cols [goff, goff+len), strided) contiguous
                // into the `abuf` arena so the microkernel streams *both*
                // operands linearly. Same values, same FP order — only the
                // leading dimension changes, so this is bit-neutral.
                let (a_lda, pack) = if plan.pack_a {
                    kernels::pack_rows(&mut ws.abuf, &panel[goff..], stride, w, len);
                    (len, true)
                } else {
                    (stride, false)
                };
                let pc0 = ws.colmap[sucols[0] as usize];
                let pc_last = ws.colmap[sucols[s_nu - 1] as usize];
                if pc0 >= 0 && (pc_last - pc0) as usize == s_nu - 1 {
                    // Safety: C columns [pc0, pc0+s_nu) and A columns
                    // [goff, goff+len) are disjoint ranges of the same
                    // panel rows (goff+len <= nl <= pc0) — or A is the
                    // packed copy in `abuf` — so the raw-core accesses
                    // never alias element-wise.
                    let ap = if pack {
                        ws.abuf.as_ptr()
                    } else {
                        panel.as_ptr().add(goff)
                    };
                    kernels::gemm_sub_raw_planned(
                        tier,
                        plan,
                        panel.as_mut_ptr().add(pc0 as usize),
                        stride,
                        ap,
                        a_lda,
                        ws.pbuf.as_ptr(),
                        s_nu,
                        w,
                        len,
                        s_nu,
                    );
                    continue;
                }
                ws.cbuf.clear();
                ws.cbuf.resize(w * s_nu, T::ZERO);
                // X lives in panel cols [goff, goff+len) (strided), or
                // contiguous in abuf when the plan packs A. The pluggable
                // backend is f64-only; `T::backend_gemm` routes f64 through
                // it and reports "not handled" for f32 (in-crate tiers).
                let did = if pack {
                    T::backend_gemm(gemm, &mut ws.cbuf, &ws.abuf, a_lda, &ws.pbuf, s_nu, w, len, s_nu)
                } else {
                    T::backend_gemm(
                        gemm,
                        &mut ws.cbuf,
                        &panel[goff..],
                        a_lda,
                        &ws.pbuf,
                        s_nu,
                        w,
                        len,
                        s_nu,
                    )
                };
                if !did {
                    if pack {
                        kernels::gemm_sub_planned(
                            tier, plan, &mut ws.cbuf, s_nu, &ws.abuf, a_lda, &ws.pbuf, s_nu, w,
                            len, s_nu,
                        );
                    } else {
                        kernels::gemm_sub_planned(
                            tier,
                            plan,
                            &mut ws.cbuf,
                            s_nu,
                            &panel[goff..],
                            a_lda,
                            &ws.pbuf,
                            s_nu,
                            w,
                            len,
                            s_nu,
                        );
                    }
                }
                // cbuf now holds -X·U; add into panel through the map
                let sucols = &sym.ucols[src.u_start..src.u_end];
                ws.map_idx.clear();
                ws.map_idx
                    .extend(sucols.iter().map(|&j| ws.colmap[j as usize]));
                for r in 0..w {
                    let base = r * stride;
                    let crow = &ws.cbuf[r * s_nu..(r + 1) * s_nu];
                    for (idx, &pc) in ws.map_idx.iter().enumerate() {
                        if pc >= 0 {
                            panel[base + pc as usize] += crow[idx];
                        } else {
                            debug_assert!(
                                crow[idx].to_f64().abs() < 1e-30,
                                "nonzero update outside pattern"
                            );
                        }
                    }
                }
            }
        } else {
            // standalone-row source: scale column then rank-1 update
            let k = lcols[goff] as usize;
            debug_assert_eq!(len, 1);
            let d = *sf.diag.add(k);
            let sucols = &sym.ucols[src.u_start..src.u_end];
            let suvals =
                std::slice::from_raw_parts(sf.uvals.add(src.u_start), src.u_end - src.u_start);
            for r in 0..w {
                let base = r * stride;
                let m = panel[base + goff] / d;
                panel[base + goff] = m;
                if m != T::ZERO {
                    for (idx, &j) in sucols.iter().enumerate() {
                        let pc = ws.colmap[j as usize];
                        debug_assert!(pc >= 0);
                        panel[base + pc as usize] -= m * suvals[idx];
                    }
                }
            }
        }
    }

    // internal factorization of the diagonal block + trailing U tail
    let mut perturbed = 0usize;
    let mut umax = 0.0f64;
    for c in 0..w {
        let pcol = nl + c;
        if !refactor && cfg.supernode_pivoting {
            // supernode diagonal pivoting: max |.| in column c, rows c..w
            let mut best = c;
            let mut bestv = panel[c * stride + pcol].abs();
            for r in c + 1..w {
                let v = panel[r * stride + pcol].abs();
                if v > bestv {
                    bestv = v;
                    best = r;
                }
            }
            if best != c {
                // swap full panel rows + record in pivot_perm
                for jj in 0..stride {
                    panel.swap(c * stride + jj, best * stride + jj);
                }
                let pa = sf.pivot_perm.add(first + c);
                let pb = sf.pivot_perm.add(first + best);
                std::ptr::swap(pa, pb);
            }
        }
        let (piv, pert) = perturb_pivot(panel[c * stride + pcol], eps_abs);
        panel[c * stride + pcol] = piv;
        perturbed += pert as usize;
        let inv = T::ONE / piv;
        let (head, tail) = panel.split_at_mut((c + 1) * stride);
        let crow = &head[c * stride + pcol + 1..c * stride + stride];
        // row c of U (pivot + everything right of it) is final here —
        // fold it into the pivot-growth monitor while it is cache-hot
        umax = fold_max(umax, piv.to_f64().abs());
        for &v in crow {
            umax = fold_max(umax, v.to_f64().abs());
        }
        for r in c + 1..w {
            let base = (r - c - 1) * stride;
            let f = tail[base + pcol] * inv;
            tail[base + pcol] = f;
            if f != T::ZERO {
                kernels::axpy_sub(tier, &mut tail[base + pcol + 1..base + stride], crow, f);
            }
        }
        // keep diag[] mirror for row-kernel sources reading supernode rows
        *sf.diag.add(first + c) = piv;
    }
    sf.add_perturbed(perturbed);
    sf.update_umax(umax);

    // reset colmap
    for &j in lcols {
        ws.colmap[j as usize] = -1;
    }
    for kk in 0..w {
        ws.colmap[first + kk] = -1;
    }
    for &j in ucols {
        ws.colmap[j as usize] = -1;
    }
}

/// The row-row / sup-row kernels: row-at-a-time target with a dense
/// accumulator. Handles standalone rows (sparse storage) and supernode
/// panels filled row-wise (sup-row mode).
unsafe fn factor_rows<T: Scalar>(
    id: usize,
    a: &Csr,
    sym: &Symbolic,
    sf: &SharedFactors<T>,
    ws: &mut Workspace<T>,
    eps_abs: f64,
) {
    let nd = &sym.nodes[id];
    let first = nd.first as usize;
    let w = nd.width as usize;
    let nl = nd.nl();
    let nu = nd.nu();
    let stride = nl + w + nu;
    let lcols = &sym.lcols[nd.l_start..nd.l_end];
    let ucols = &sym.ucols[nd.u_start..nd.u_end];
    if nd.is_super {
        sf.panel_mut(id).fill(T::ZERO);
    }
    let x = &mut ws.x;
    let mut perturbed = 0usize;
    let mut umax = 0.0f64;

    for r in 0..w {
        let i = first + r;
        // scatter
        for (k, &j) in a.row_indices(i).iter().enumerate() {
            x[j] = T::from_f64(a.row_vals(i)[k]);
        }
        // updates from earlier nodes (ascending column order)
        for g in &sym.groups[nd.g_start..nd.g_end] {
            let src = &sym.nodes[g.src as usize];
            let goff = g.offset as usize;
            let len = g.len as usize;
            if src.is_super {
                let s_first = src.first as usize;
                let s_nl = src.nl();
                let s_w = src.width as usize;
                let sstride = s_nl + s_w + src.nu();
                let spanel = sf.panel_ref(g.src as usize);
                let sucols = &sym.ucols[src.u_start..src.u_end];
                for cc in 0..len {
                    let k = lcols[goff + cc] as usize;
                    let klocal = k - s_first;
                    let srow = &spanel[klocal * sstride..(klocal + 1) * sstride];
                    let m = x[k] / srow[s_nl + klocal];
                    x[k] = m;
                    if m != T::ZERO {
                        // sup-row: dense panel row drives the update
                        for jj in klocal + 1..s_w {
                            x[s_first + jj] -= m * srow[s_nl + jj];
                        }
                        let utail = &srow[s_nl + s_w..];
                        for (idx, &j) in sucols.iter().enumerate() {
                            x[j as usize] -= m * utail[idx];
                        }
                    }
                }
            } else {
                debug_assert_eq!(len, 1);
                let k = lcols[goff] as usize;
                let m = x[k] / *sf.diag.add(k);
                x[k] = m;
                if m != T::ZERO {
                    let sucols = &sym.ucols[src.u_start..src.u_end];
                    let suvals = std::slice::from_raw_parts(
                        sf.uvals.add(src.u_start),
                        src.u_end - src.u_start,
                    );
                    for (idx, &j) in sucols.iter().enumerate() {
                        x[j as usize] -= m * suvals[idx];
                    }
                }
            }
        }
        // within-block updates from this panel's previous rows (sup-row
        // filling a supernode row-wise)
        if nd.is_super {
            let p = sf.panel_ref(id);
            for kk in 0..r {
                let k = first + kk;
                let krow = &p[kk * stride..(kk + 1) * stride];
                let m = x[k] / krow[nl + kk];
                x[k] = m;
                if m != T::ZERO {
                    for jj in kk + 1..w {
                        x[first + jj] -= m * krow[nl + jj];
                    }
                    let utail = &krow[nl + w..];
                    for (idx, &j) in ucols.iter().enumerate() {
                        x[j as usize] -= m * utail[idx];
                    }
                }
            }
        }

        // pivot + gather + reset (the gather doubles as the U sweep for
        // the pivot-growth monitor: every finalized U entry passes here)
        let (piv, pert) = perturb_pivot(x[i], eps_abs);
        perturbed += pert as usize;
        umax = fold_max(umax, piv.to_f64().abs());
        if nd.is_super {
            // write the whole row into the panel
            let p = sf.panel_mut(id); // re-borrow (same thread)
            let base = r * stride;
            for (c, &j) in lcols.iter().enumerate() {
                p[base + c] = x[j as usize];
                x[j as usize] = T::ZERO;
            }
            for kk in 0..w {
                let v = x[first + kk];
                p[base + nl + kk] = v;
                x[first + kk] = T::ZERO;
                if kk > r {
                    umax = fold_max(umax, v.to_f64().abs());
                }
            }
            p[base + nl + r] = piv;
            for (c, &j) in ucols.iter().enumerate() {
                let v = x[j as usize];
                p[base + nl + w + c] = v;
                x[j as usize] = T::ZERO;
                umax = fold_max(umax, v.to_f64().abs());
            }
            *sf.diag.add(i) = piv;
        } else {
            let lv = std::slice::from_raw_parts_mut(sf.lvals.add(nd.l_start), nl);
            for (c, &j) in lcols.iter().enumerate() {
                lv[c] = x[j as usize];
                x[j as usize] = T::ZERO;
            }
            *sf.diag.add(i) = piv;
            x[i] = T::ZERO;
            let uv = std::slice::from_raw_parts_mut(sf.uvals.add(nd.u_start), nu);
            for (c, &j) in ucols.iter().enumerate() {
                let v = x[j as usize];
                uv[c] = v;
                x[j as usize] = T::ZERO;
                umax = fold_max(umax, v.to_f64().abs());
            }
        }
    }
    sf.add_perturbed(perturbed);
    sf.update_umax(umax);
}

/// Secondary within-block reordering for the adaptive refactor path
/// (CKTSO-style): refresh `pivot_perm` inside each supernode diagonal
/// block by greedily assigning to each block column the unused block row
/// with the largest current magnitude in `a` (the permuted matrix about
/// to be refactored). Pattern-preserving by construction — the swap set
/// is exactly the one in-kernel supernode pivoting may explore, so a
/// replay refactorization after this pass stays valid. Standalone rows
/// are untouched (`factor_rows` never consults `pivot_perm`).
///
/// Returns the number of blocks whose permutation changed. Deterministic:
/// ties pick the lowest remaining row.
pub fn secondary_block_reorder(a: &Csr, sym: &Symbolic, pivot_perm: &mut [u32]) -> usize {
    assert_eq!(a.n, sym.n);
    assert_eq!(pivot_perm.len(), sym.n);
    let mut changed_blocks = 0usize;
    let mut block: Vec<f64> = Vec::new();
    let mut taken: Vec<bool> = Vec::new();
    let mut pick: Vec<u32> = Vec::new();
    for nd in &sym.nodes {
        if !nd.is_super {
            continue;
        }
        let first = nd.first as usize;
        let w = nd.width as usize;
        // dense |A| block: block[r*w + c] = |a[perm_row(r), first + c]|,
        // gathered through the *current* pivot_perm so repeated reorders
        // rank the same physical rows they will scatter.
        block.clear();
        block.resize(w * w, 0.0);
        for r in 0..w {
            let src = pivot_perm[first + r] as usize;
            let (cols, vals) = (a.row_indices(src), a.row_vals(src));
            let lo = cols.partition_point(|&j| j < first);
            for k in lo..cols.len() {
                let j = cols[k];
                if j >= first + w {
                    break;
                }
                block[r * w + (j - first)] = vals[k].abs();
            }
        }
        taken.clear();
        taken.resize(w, false);
        pick.clear();
        for c in 0..w {
            let mut best = usize::MAX;
            let mut best_v = f64::NEG_INFINITY;
            for (r, &t) in taken.iter().enumerate() {
                if !t && block[r * w + c] > best_v {
                    best_v = block[r * w + c];
                    best = r;
                }
            }
            taken[best] = true;
            pick.push(pivot_perm[first + best]);
        }
        let dst = &mut pivot_perm[first..first + w];
        if dst != pick.as_slice() {
            changed_blocks += 1;
            dst.copy_from_slice(&pick);
        }
    }
    changed_blocks
}

/// Reconstruct the dense `L·U` product for tests (small n).
pub fn reconstruct_dense(sym: &Symbolic, fac: &LuFactors) -> crate::testutil::Dense {
    let n = sym.n;
    assert!(n <= 2048);
    // expand L and U rows densely
    let mut l = crate::testutil::Dense::zeros(n);
    let mut u = crate::testutil::Dense::zeros(n);
    for (id, nd) in sym.nodes.iter().enumerate() {
        let first = nd.first as usize;
        let w = nd.width as usize;
        let nl = nd.nl();
        let nu = nd.nu();
        let stride = nl + w + nu;
        let lcols = &sym.lcols[nd.l_start..nd.l_end];
        let ucols = &sym.ucols[nd.u_start..nd.u_end];
        for r in 0..w {
            let i = first + r;
            l.set(i, i, 1.0);
            if nd.is_super {
                let p = fac.panel(id);
                let base = r * stride;
                for (c, &j) in lcols.iter().enumerate() {
                    l.set(i, j as usize, p[base + c]);
                }
                for kk in 0..w {
                    let v = p[base + nl + kk];
                    if kk < r {
                        l.set(i, first + kk, v);
                    } else {
                        u.set(i, first + kk, v);
                    }
                }
                for (c, &j) in ucols.iter().enumerate() {
                    u.set(i, j as usize, p[base + nl + w + c]);
                }
            } else {
                for (c, &j) in lcols.iter().enumerate() {
                    l.set(i, j as usize, fac.lvals[nd.l_start + c]);
                }
                u.set(i, i, fac.diag[i]);
                for (c, &j) in ucols.iter().enumerate() {
                    u.set(i, j as usize, fac.uvals[nd.u_start + c]);
                }
            }
        }
    }
    // product
    let mut prod = crate::testutil::Dense::zeros(n);
    for i in 0..n {
        for k in 0..=i {
            let lik = l.get(i, k);
            if lik != 0.0 {
                for j in 0..n {
                    let u_kj = u.get(k, j);
                    if u_kj != 0.0 {
                        prod.set(i, j, prod.get(i, j) + lik * u_kj);
                    }
                }
            }
        }
    }
    prod
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::select::KernelMode;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};
    use crate::testutil::{for_each_seed, Prng};

    fn diag_dominant(a: &Csr, boost: f64) -> Csr {
        let mut c = Coo::new(a.n);
        for i in 0..a.n {
            for (k, &j) in a.row_indices(i).iter().enumerate() {
                c.push(i, j, a.row_vals(i)[k]);
            }
            c.push(i, i, boost);
        }
        c.to_csr()
    }

    /// Check P_pivot·A == L·U to tolerance, where P_pivot is fac.pivot_perm.
    fn check_reconstruction(a: &Csr, sym: &Symbolic, fac: &LuFactors, tol: f64) {
        let n = a.n;
        let prod = reconstruct_dense(sym, fac);
        let ad = a.to_dense();
        let mut maxerr = 0.0f64;
        for i in 0..n {
            let src = fac.pivot_perm[i] as usize;
            for j in 0..n {
                let want = ad.get(src, j);
                let got = prod.get(i, j);
                maxerr = maxerr.max((want - got).abs());
            }
        }
        assert!(maxerr < tol, "reconstruction error {maxerr}");
    }

    fn run_all_modes(a: &Csr, tol: f64) {
        let cfg = PivotConfig::default();
        for (mode, policy) in [
            (KernelMode::RowRow, MergePolicy::None),
            (KernelMode::SupRow, MergePolicy::Exact { max_width: 16 }),
            (KernelMode::SupSup, MergePolicy::Exact { max_width: 16 }),
            (
                KernelMode::SupSup,
                MergePolicy::Relaxed {
                    max_width: 16,
                    budget_frac: 0.25,
                    budget_abs: 8,
                },
            ),
            (
                KernelMode::SupSup,
                MergePolicy::Forced {
                    min_width: 4,
                    max_width: 16,
                },
            ),
        ] {
            let sym = analyze_pattern(a, policy, 4);
            let mut fac: LuFactors = LuFactors::alloc(&sym);
            factor(a, &sym, mode, &cfg, &mut fac, false, &NativeGemm);
            check_reconstruction(a, &sym, &fac, tol);
        }
    }

    #[test]
    fn identity_factors_trivially() {
        let a = Csr::identity(10);
        run_all_modes(&a, 1e-14);
    }

    #[test]
    fn dense_block_supsup() {
        let mut rng = Prng::new(1);
        let n = 12;
        let mut c = Coo::new(n);
        for i in 0..n {
            for j in 0..n {
                c.push(i, j, rng.normal() + if i == j { 10.0 } else { 0.0 });
            }
        }
        run_all_modes(&c.to_csr(), 1e-9);
    }

    #[test]
    fn grid_factors_correctly_all_modes() {
        let a = gen::grid2d(7, 8);
        run_all_modes(&a, 1e-9);
    }

    #[test]
    fn circuit_factors_correctly_all_modes() {
        let a = diag_dominant(&gen::circuit(80, 3), 8.0);
        run_all_modes(&a, 1e-8);
    }

    #[test]
    fn banded_factors_correctly() {
        let a = gen::banded(40, 3, 5);
        run_all_modes(&a, 1e-8);
    }

    #[test]
    fn pivoting_handles_small_leading_diagonal() {
        // diagonal block where pivoting matters: first diagonal tiny inside
        // a dense 4x4 supernode
        let n = 4;
        let mut c = Coo::new(n);
        let vals = [
            [1e-13, 2.0, 3.0, 1.0],
            [2.0, 1.0, 1.0, 4.0],
            [3.0, 1.0, 5.0, 1.0],
            [1.0, 4.0, 1.0, 2.0],
        ];
        for i in 0..n {
            for j in 0..n {
                c.push(i, j, vals[i][j]);
            }
        }
        let a = c.to_csr();
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 8 }, 4);
        assert!(sym.nodes[0].is_super);
        let cfg = PivotConfig::default();
        let mut fac: LuFactors = LuFactors::alloc(&sym);
        let perturbed = factor(&a, &sym, KernelMode::SupSup, &cfg, &mut fac, false, &NativeGemm);
        assert_eq!(perturbed, 0, "pivoting should avoid perturbation");
        // pivot moved a big row first
        assert_ne!(fac.pivot_perm[0], 0);
        check_reconstruction(&a, &sym, &fac, 1e-9);
    }

    #[test]
    fn perturbation_kicks_in_without_pivoting() {
        let n = 3;
        let mut c = Coo::new(n);
        c.push(0, 0, 0.0);
        c.push(0, 1, 1.0);
        c.push(1, 0, 1.0);
        c.push(1, 1, 1.0);
        c.push(2, 2, 1.0);
        let a = c.to_csr();
        let sym = analyze_pattern(&a, MergePolicy::None, 4);
        let cfg = PivotConfig {
            supernode_pivoting: false,
            perturb: true,
            perturb_eps: 1e-8,
        };
        let mut fac: LuFactors = LuFactors::alloc(&sym);
        let perturbed = factor(&a, &sym, KernelMode::RowRow, &cfg, &mut fac, false, &NativeGemm);
        assert!(perturbed >= 1);
        assert!(fac.diag[0].abs() > 0.0);
    }

    #[test]
    fn refactor_reproduces_factor_exactly() {
        let a = gen::grid2d(6, 6);
        let cfg = PivotConfig::default();
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let mut fac: LuFactors = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut fac, false, &NativeGemm);
        let panels1 = fac.panels.clone();
        let lv1 = fac.lvals.clone();
        let pp1 = fac.pivot_perm.clone();
        // refactor with the same values must reproduce identical factors
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut fac, true, &NativeGemm);
        assert_eq!(fac.pivot_perm, pp1);
        assert_eq!(fac.panels, panels1);
        assert_eq!(fac.lvals, lv1);
    }

    #[test]
    fn refactor_with_new_values_is_correct() {
        let mut rng = Prng::new(9);
        let a = gen::power_network(60, 4);
        let cfg = PivotConfig::default();
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let mut fac: LuFactors = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut fac, false, &NativeGemm);
        // new values, same pattern
        let mut b = a.clone();
        for v in &mut b.vals {
            *v *= rng.range_f64(0.5, 1.5);
        }
        factor(&b, &sym, KernelMode::SupSup, &cfg, &mut fac, true, &NativeGemm);
        check_reconstruction(&b, &sym, &fac, 1e-8);
    }

    #[test]
    fn f32_factor_tracks_f64_factor() {
        let a = gen::grid2d(6, 7);
        let cfg = PivotConfig::default();
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let mut hi: LuFactors = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut hi, false, &NativeGemm);
        let mut lo: LuFactors<f32> = LuFactors::alloc(&sym);
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut lo, false, &NativeGemm);
        // same pivot order (grid2d has no near-ties), values within f32
        // rounding of the f64 factors
        assert_eq!(lo.pivot_perm, hi.pivot_perm);
        assert_eq!(lo.perturbed, hi.perturbed);
        for (l, h) in lo.diag.iter().zip(&hi.diag) {
            assert!((l.to_f64() - h).abs() <= 1e-4 * h.abs().max(1.0));
        }
        for (l, h) in lo.panels.iter().zip(&hi.panels) {
            assert!((l.to_f64() - h).abs() <= 1e-3 * h.abs().max(1.0));
        }
        // f32 refactor replays the recorded pivots bit-identically
        let p1 = lo.panels.clone();
        let d1 = lo.diag.clone();
        factor(&a, &sym, KernelMode::SupSup, &cfg, &mut lo, true, &NativeGemm);
        assert!(lo.panels.iter().zip(&p1).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(lo.diag.iter().zip(&d1).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn pivot_growth_is_tracked_across_modes_and_refactor() {
        let a = diag_dominant(&gen::circuit(80, 3), 8.0);
        let cfg = PivotConfig::default();
        for (mode, policy) in [
            (KernelMode::RowRow, MergePolicy::None),
            (KernelMode::SupRow, MergePolicy::Exact { max_width: 16 }),
            (KernelMode::SupSup, MergePolicy::Exact { max_width: 16 }),
        ] {
            let sym = analyze_pattern(&a, policy, 4);
            let mut fac: LuFactors = LuFactors::alloc(&sym);
            factor(&a, &sym, mode, &cfg, &mut fac, false, &NativeGemm);
            // |U| always contains the largest pivot, and every pivot of a
            // diagonally-dominant matrix is bounded by ~max|A| growth
            assert!(fac.growth.is_finite() && fac.growth > 0.0, "{mode:?}: {}", fac.growth);
            assert!(fac.growth < 1e3, "{mode:?}: implausible growth {}", fac.growth);
            let g1 = fac.growth;
            // a same-values refactor replays the same arithmetic: the
            // monitor must reproduce the identical estimate
            factor(&a, &sym, mode, &cfg, &mut fac, true, &NativeGemm);
            assert_eq!(fac.growth.to_bits(), g1.to_bits(), "{mode:?}");
        }
    }

    #[test]
    fn modes_agree_with_each_other() {
        // same matrix, all three kernels: reconstructions must agree with A
        let a = diag_dominant(&gen::random_sparse(50, 4, 8), 6.0);
        run_all_modes(&a, 1e-8);
    }

    #[test]
    fn property_factor_reconstructs_random_matrices() {
        for_each_seed(10, |rng| {
            let n = rng.range(5, 40);
            let mut c = Coo::new(n);
            for i in 0..n {
                c.push(i, i, 4.0 + rng.uniform());
                for _ in 0..rng.range(0, 4) {
                    c.push(i, rng.below(n), rng.nonzero());
                }
            }
            let a = c.to_csr();
            run_all_modes(&a, 1e-7);
        });
    }
}

//! Kernel selection — the paper's "smart kernel selection strategy based on
//! the matrix sparsity" (§2.1, last sentence): symbolic factorization
//! produces flop counts and supernode statistics, and HYLU picks the numeric
//! kernel from them. The flop crossovers are no longer fixed constants:
//! they are calibrated once per process from the microkernel throughput
//! probe ([`crate::numeric::kernels::probe`]), so a machine whose dense
//! tier beats the scalar reference by more than the reference tuning
//! assumed routes borderline matrices to the dense kernels sooner (and a
//! scalar-dispatch run routes them later).

use crate::numeric::kernels;
use crate::symbolic::Symbolic;

/// Which numeric kernel family drives the factorization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Ordinary up-looking scalar kernel (KLU-like). Best for extremely
    /// sparse matrices (circuit class) where gathering into dense blocks
    /// costs more than it saves.
    RowRow,
    /// Row-at-a-time targets, supernode sources applied with dense panel
    /// rows (level-2 shape). The middle ground.
    SupRow,
    /// Panel-at-a-time targets with TRSM + GEMM (level-3 shape). Best when
    /// supernodes are wide and flops dominate (mesh / KKT classes).
    SupSup,
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelMode::RowRow => write!(f, "row-row"),
            KernelMode::SupRow => write!(f, "sup-row"),
            KernelMode::SupSup => write!(f, "sup-sup"),
        }
    }
}

/// Decision inputs, reported to the user alongside the choice.
#[derive(Clone, Copy, Debug)]
pub struct SelectionStats {
    /// Fraction of rows inside supernodes (width >= 2).
    pub coverage: f64,
    /// Mean node width across ALL nodes — supernode panels *and* the
    /// singleton trailing columns between/after them. (The panels-only
    /// mean lives in [`SelectionStats::avg_panel_width`]; reporting it as
    /// "the" average width overstated typical width on circuit-class
    /// matrices, where a handful of wide panels sit in a sea of
    /// singletons.)
    pub avg_super_width: f64,
    /// Mean width over supernode panels only — the wide-panel signal
    /// that drives the level-3 escape in [`select_kernel`].
    pub avg_panel_width: f64,
    /// Factorization flops per row.
    pub flops_per_row: f64,
    /// Factorization flops per stored LU entry (compute density).
    pub flops_per_entry: f64,
}

/// Gather the selection statistics from a symbolic analysis.
pub fn selection_stats(sym: &Symbolic) -> SelectionStats {
    let n = sym.n.max(1) as f64;
    let supers = sym.nodes.iter().filter(|nd| nd.is_super).count();
    let rows_in_supers: usize = sym
        .nodes
        .iter()
        .filter(|nd| nd.is_super)
        .map(|nd| nd.width as usize)
        .sum();
    SelectionStats {
        coverage: sym.supernode_coverage,
        // every row belongs to exactly one node, so the node widths sum
        // to n and the all-node mean is n / |nodes|
        avg_super_width: n / sym.nodes.len().max(1) as f64,
        avg_panel_width: if supers == 0 {
            1.0
        } else {
            rows_in_supers as f64 / supers as f64
        },
        flops_per_row: sym.flops / n,
        flops_per_entry: sym.flops / sym.lu_entries.max(1) as f64,
    }
}

/// Flop-per-row crossover below which (with narrow panels) the scalar
/// row-row kernel wins, at the reference dense advantage.
const ROW_ROW_FLOPS: f64 = 2500.0;
/// Flop-per-row crossover below which (with narrow panels) the level-2
/// sup-row kernel wins over sup-sup, at the reference dense advantage.
const SUP_ROW_FLOPS: f64 = 20_000.0;

/// Pick the kernel for a symbolic analysis.
///
/// The base thresholds were tuned against measured factor times on the
/// synthetic suite (EXPERIMENTS.md, ablation 1): extremely sparse
/// low-flop matrices (circuit class: ~1.9k flops/row) want the scalar
/// kernel; narrow supernodes with moderate compute want sup-row; wide
/// supernodes or heavy compute (bands, KKT, 3-D meshes, power networks)
/// want the level-3 sup-sup kernel. The flop crossovers are scaled by
/// [`kernels::calibration`] — a one-shot microkernel throughput probe —
/// instead of being trusted verbatim on every machine: the faster the
/// dense tier actually is here, the earlier the dense kernels pay off.
pub fn select_kernel(sym: &Symbolic) -> KernelMode {
    let s = selection_stats(sym);
    let cal = kernels::calibration();
    if s.flops_per_row < ROW_ROW_FLOPS * cal && s.avg_panel_width < 8.0 {
        KernelMode::RowRow
    } else if s.avg_panel_width < 3.0 && s.flops_per_row < SUP_ROW_FLOPS * cal {
        KernelMode::SupRow
    } else {
        KernelMode::SupSup
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};

    fn mode_for(a: &crate::sparse::csr::Csr) -> KernelMode {
        let sym = analyze_pattern(a, MergePolicy::Exact { max_width: 64 }, 4);
        select_kernel(&sym)
    }

    /// Selection through the real pipeline (MC64 + ordering), which is what
    /// the thresholds were tuned against.
    fn pipeline_mode(a: &crate::sparse::csr::Csr) -> KernelMode {
        let s = crate::api::SolverBuilder::new().threads(1).build().unwrap();
        s.analyze(a).unwrap().analysis().mode
    }

    #[test]
    fn circuit_class_selects_row_row() {
        // selection is tuned for post-pipeline (MC64 + ordering) patterns;
        // natural-order analysis has artificial fill and is not asserted
        assert_eq!(pipeline_mode(&gen::circuit(3000, 1)), KernelMode::RowRow);
    }

    #[test]
    fn heavy_classes_select_supernodal() {
        // 3-D mesh and KKT: heavy flops per row => level-3 kernel
        for a in [gen::grid3d(12, 12, 12), gen::kkt(1500, 500, 3)] {
            let m = pipeline_mode(&a);
            assert!(m == KernelMode::SupSup || m == KernelMode::SupRow, "{m}");
        }
    }

    #[test]
    fn dense_band_selects_sup_sup() {
        assert_eq!(mode_for(&gen::banded(600, 24, 2)), KernelMode::SupSup);
    }

    #[test]
    fn stats_are_sane() {
        let a = gen::grid2d(20, 20);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 64 }, 4);
        let s = selection_stats(&sym);
        assert!(s.coverage >= 0.0 && s.coverage <= 1.0);
        assert!(s.avg_super_width >= 1.0);
        assert!(s.avg_panel_width >= 1.0);
        assert!(s.flops_per_row > 0.0);
    }

    #[test]
    fn mean_width_counts_singleton_trailing_columns() {
        // Regression: banded under Exact merge yields one wide panel at
        // the dense trailing corner plus a long run of singleton columns.
        // The all-node mean must be dragged down by those singletons (the
        // old accounting averaged panels only and reported ~25 here),
        // while the panels-only mean keeps carrying the wide-panel signal
        // that routes this matrix to the level-3 kernel.
        let a = gen::banded(600, 24, 2);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 64 }, 4);
        let s = selection_stats(&sym);
        assert!(
            s.avg_panel_width > 8.0,
            "panel mean lost the wide-panel signal: {}",
            s.avg_panel_width
        );
        assert!(
            s.avg_super_width < 2.0,
            "all-node mean must count singleton columns: {}",
            s.avg_super_width
        );
        // the two agree exactly when every row lives in a panel
        let d = gen::banded(16, 15, 1); // fully dense block => one panel
        let dsym = analyze_pattern(&d, MergePolicy::Forced { min_width: 16, max_width: 16 }, 4);
        let ds = selection_stats(&dsym);
        if dsym.nodes.len() == 1 {
            assert!((ds.avg_super_width - ds.avg_panel_width).abs() < 1e-12);
        }
    }

    #[test]
    fn calibration_stays_in_band_and_selection_is_stable() {
        // the probe-scaled thresholds must never swing selection outside
        // the clamp band, whatever this testbed measures
        let cal = kernels::calibration();
        assert!((0.9..=1.5).contains(&cal), "calibration {cal}");
        // repeated calls see the same cached probe => same selection
        let a = gen::banded(600, 24, 2);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 64 }, 4);
        assert_eq!(select_kernel(&sym), select_kernel(&sym));
    }
}

//! Kernel selection — the paper's "smart kernel selection strategy based on
//! the matrix sparsity" (§2.1, last sentence): symbolic factorization
//! produces flop counts and supernode statistics, and HYLU picks the numeric
//! kernel from them.

use crate::symbolic::Symbolic;

/// Which numeric kernel family drives the factorization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Ordinary up-looking scalar kernel (KLU-like). Best for extremely
    /// sparse matrices (circuit class) where gathering into dense blocks
    /// costs more than it saves.
    RowRow,
    /// Row-at-a-time targets, supernode sources applied with dense panel
    /// rows (level-2 shape). The middle ground.
    SupRow,
    /// Panel-at-a-time targets with TRSM + GEMM (level-3 shape). Best when
    /// supernodes are wide and flops dominate (mesh / KKT classes).
    SupSup,
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelMode::RowRow => write!(f, "row-row"),
            KernelMode::SupRow => write!(f, "sup-row"),
            KernelMode::SupSup => write!(f, "sup-sup"),
        }
    }
}

/// Decision inputs, reported to the user alongside the choice.
#[derive(Clone, Copy, Debug)]
pub struct SelectionStats {
    /// Fraction of rows inside supernodes (width >= 2).
    pub coverage: f64,
    /// Mean width of supernodes.
    pub avg_super_width: f64,
    /// Factorization flops per row.
    pub flops_per_row: f64,
    /// Factorization flops per stored LU entry (compute density).
    pub flops_per_entry: f64,
}

/// Gather the selection statistics from a symbolic analysis.
pub fn selection_stats(sym: &Symbolic) -> SelectionStats {
    let n = sym.n.max(1) as f64;
    let supers = sym.nodes.iter().filter(|nd| nd.is_super).count();
    let rows_in_supers: usize = sym
        .nodes
        .iter()
        .filter(|nd| nd.is_super)
        .map(|nd| nd.width as usize)
        .sum();
    SelectionStats {
        coverage: sym.supernode_coverage,
        avg_super_width: if supers == 0 {
            1.0
        } else {
            rows_in_supers as f64 / supers as f64
        },
        flops_per_row: sym.flops / n,
        flops_per_entry: sym.flops / sym.lu_entries.max(1) as f64,
    }
}

/// Pick the kernel for a symbolic analysis.
///
/// Thresholds are tuned against measured factor times on the synthetic
/// suite (EXPERIMENTS.md, ablation 1): extremely sparse low-flop matrices
/// (circuit class: ~1.9k flops/row) want the scalar kernel; narrow
/// supernodes with moderate compute want sup-row; wide supernodes or
/// heavy compute (bands, KKT, 3-D meshes, power networks) want the
/// level-3 sup-sup kernel.
pub fn select_kernel(sym: &Symbolic) -> KernelMode {
    let s = selection_stats(sym);
    if s.flops_per_row < 2500.0 && s.avg_super_width < 8.0 {
        KernelMode::RowRow
    } else if s.avg_super_width < 3.0 && s.flops_per_row < 20_000.0 {
        KernelMode::SupRow
    } else {
        KernelMode::SupSup
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};

    fn mode_for(a: &crate::sparse::csr::Csr) -> KernelMode {
        let sym = analyze_pattern(a, MergePolicy::Exact { max_width: 64 }, 4);
        select_kernel(&sym)
    }

    /// Selection through the real pipeline (MC64 + ordering), which is what
    /// the thresholds were tuned against.
    fn pipeline_mode(a: &crate::sparse::csr::Csr) -> KernelMode {
        use crate::coordinator::{Solver, SolverConfig};
        let s = Solver::new(SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        });
        s.analyze(a).unwrap().mode
    }

    #[test]
    fn circuit_class_selects_row_row() {
        // selection is tuned for post-pipeline (MC64 + ordering) patterns;
        // natural-order analysis has artificial fill and is not asserted
        assert_eq!(pipeline_mode(&gen::circuit(3000, 1)), KernelMode::RowRow);
    }

    #[test]
    fn heavy_classes_select_supernodal() {
        // 3-D mesh and KKT: heavy flops per row => level-3 kernel
        for a in [gen::grid3d(12, 12, 12), gen::kkt(1500, 500, 3)] {
            let m = pipeline_mode(&a);
            assert!(m == KernelMode::SupSup || m == KernelMode::SupRow, "{m}");
        }
    }

    #[test]
    fn dense_band_selects_sup_sup() {
        assert_eq!(mode_for(&gen::banded(600, 24, 2)), KernelMode::SupSup);
    }

    #[test]
    fn stats_are_sane() {
        let a = gen::grid2d(20, 20);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 64 }, 4);
        let s = selection_stats(&sym);
        assert!(s.coverage >= 0.0 && s.coverage <= 1.0);
        assert!(s.avg_super_width >= 1.0);
        assert!(s.flops_per_row > 0.0);
    }
}

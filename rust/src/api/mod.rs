//! The public solver API: owning, typestate `LinearSystem` handles.
//!
//! HYLU's value proposition is the `analyze → factor → refactor → solve`
//! lifecycle. The legacy coordinator API forced every caller to thread a
//! `(matrix, &Analysis, &Factorization)` triple through each call — the
//! exact stale-pairing footgun the engine's uid-keyed caches exist to
//! defend against. This module makes the pairing a *type*:
//!
//! - [`SolverBuilder`] (chained configuration, `one_shot()` /
//!   `repeated()` presets) builds a [`Solver`].
//! - [`Solver::analyze`] ingests any [`MatrixInput`] (CSR, COO, CSC
//!   triplets, a MatrixMarket path) and returns a
//!   [`LinearSystem<Analyzed>`](LinearSystem) that **owns** the matrix
//!   and its analysis.
//! - [`LinearSystem::factor`] consumes it into a
//!   [`LinearSystem<Factored>`](LinearSystem) with `refactor`, `solve`,
//!   `solve_into`, `solve_many`, and per-call [`SolveOpts`].
//!
//! A factorization paired with the wrong analysis, or a solve before a
//! factor, is now unrepresentable at compile time. The same handles back
//! the C ABI in [`crate::ffi`] (opaque pointers over
//! `LinearSystem<Factored>`), so the compile-time story degrades to a
//! checked state machine across the FFI boundary.
//!
//! ```
//! use hylu::prelude::*;
//!
//! let a = hylu::sparse::gen::grid2d(8, 8);
//! let b = hylu::sparse::gen::rhs_for_ones(&a);
//!
//! let solver = SolverBuilder::new().one_shot().threads(1).build().unwrap();
//! let system = solver.analyze(&a).unwrap(); // LinearSystem<Analyzed>
//! let system = system.factor().unwrap(); //    LinearSystem<Factored>
//! let x = system.solve(&b).unwrap();
//! assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-8));
//! ```

mod builder;

pub use builder::{SolveOpts, SolverBuilder};

use std::marker::PhantomData;
use std::sync::Arc;

use crate::coordinator::{
    Analysis, EscalationController, Factorization, FactorStats, Precision, ReanalyzeKind,
    RefactorTier, RefineParams, Solver as Core, SolveStats, SolverConfig, SymbolicStats,
};
use crate::exec::Engine;
use crate::sparse::csr::Csr;
use crate::sparse::input::MatrixInput;
use crate::{Error, Result};

/// Typestate marker: analyzed, not yet numerically factorized.
pub enum Analyzed {}

/// Typestate marker: numerically factorized, ready to solve.
pub enum Factored {}

mod sealed {
    pub trait Sealed {}
    impl Sealed for super::Analyzed {}
    impl Sealed for super::Factored {}
}

/// The set of [`LinearSystem`] states ([`Analyzed`] | [`Factored`]).
pub trait State: sealed::Sealed {}
impl State for Analyzed {}
impl State for Factored {}

/// The handle-producing solver: configuration plus the persistent
/// execution engine (worker pool, scratch arenas), shared by every
/// [`LinearSystem`] it analyzes.
///
/// Cheap to clone (`Arc` internally); clones share the engine. Built by
/// [`SolverBuilder`]; see the [module docs](self) for the lifecycle.
#[derive(Clone)]
pub struct Solver {
    core: Arc<Core>,
}

impl Solver {
    /// Start a chained configuration ([`SolverBuilder::new`]).
    pub fn builder() -> SolverBuilder {
        SolverBuilder::new()
    }

    /// Build directly from a raw [`SolverConfig`] (the escape hatch for
    /// code that already carries one, e.g. [`crate::service::ServiceConfig`]).
    pub fn from_config(cfg: SolverConfig) -> Result<Solver> {
        Ok(Solver {
            core: Arc::new(Core::try_new(cfg)?),
        })
    }

    /// Active configuration.
    pub fn config(&self) -> &SolverConfig {
        &self.core.cfg
    }

    /// The persistent execution engine (pool + scratch arenas). Exposed
    /// for observability: its counters back the zero-spawn / zero-alloc
    /// guarantees of the warm path.
    pub fn engine(&self) -> &Engine {
        self.core.engine()
    }

    /// Ingest and analyze a matrix: validation, static pivoting (MC64),
    /// fill-reducing ordering, symbolic factorization with supernode
    /// detection, kernel selection, and pool schedule construction.
    ///
    /// Accepts any [`MatrixInput`]: `Csr`/`&Csr`, [`crate::sparse::Coo`],
    /// CSC triplets ([`crate::sparse::CscInput`]), or a MatrixMarket path.
    /// The returned handle owns the (validated) matrix and its analysis.
    ///
    /// ```
    /// use hylu::prelude::*;
    /// let solver = SolverBuilder::new().threads(1).build().unwrap();
    /// let mut coo = Coo::new(2);
    /// coo.push(0, 0, 2.0);
    /// coo.push(1, 1, 4.0);
    /// coo.push(1, 0, 1.0);
    /// let system = solver.analyze(coo).unwrap().factor().unwrap();
    /// let x = system.solve(&[2.0, 5.0]).unwrap();
    /// assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 1.0).abs() < 1e-12);
    /// ```
    pub fn analyze<M: MatrixInput>(&self, m: M) -> Result<LinearSystem<Analyzed>> {
        let a = m.into_csr()?;
        let an = self.core.analyze_core(&a)?;
        Ok(LinearSystem {
            core: self.core.clone(),
            a,
            an,
            f: None,
            esc: None,
            _state: PhantomData,
        })
    }
}

/// An owning handle to one linear system `A x = b` on one [`Solver`].
///
/// The handle owns the matrix, its [`Analysis`], and (in the
/// [`Factored`] state) its [`Factorization`], so a stale
/// matrix/analysis/factorization pairing cannot be expressed. It is
/// `Send + Sync`: a `&LinearSystem<Factored>` can be shared across
/// threads and `solve*` called concurrently (each call checks a private
/// scratch arena out of the engine's pool); `refactor` requires `&mut`.
///
/// Because the handle also keeps its engine alive (`Arc` internally),
/// **moving** it between threads is a plain value move with no
/// rebinding: factor state, plan, and warm arenas travel with it, and
/// `refactor`/`solve` results are bit-identical wherever the value
/// lands. This is the property the elastic
/// [`SolverService`](crate::service::SolverService) leans on when it
/// migrates systems between shards under traffic (asserted in
/// `rust/tests/handle_moves.rs`).
pub struct LinearSystem<S: State> {
    core: Arc<Core>,
    a: Csr,
    an: Analysis,
    f: Option<Factorization>,
    /// Pivot-stability escalation state for the adaptive refactor path
    /// (`None` unless [`SolverConfig::adaptive_refactor`] is on).
    esc: Option<EscalationController>,
    _state: PhantomData<S>,
}

impl<S: State> LinearSystem<S> {
    /// Dimension of the system.
    pub fn n(&self) -> usize {
        self.a.n
    }

    /// A [`Solver`] handle sharing this system's engine (cheap `Arc`
    /// clone). Lets code that only holds a handle — e.g. after
    /// [`crate::service::SolverService::retire`] returned it — analyze
    /// further systems on the same pool without having kept the original
    /// `Solver` value around.
    pub fn solver(&self) -> Solver {
        Solver {
            core: self.core.clone(),
        }
    }

    /// Stored nonzeros of the owned matrix.
    pub fn nnz(&self) -> usize {
        self.a.nnz()
    }

    /// The owned (validated) matrix.
    pub fn matrix(&self) -> &Csr {
        &self.a
    }

    /// The owned analysis (permutations, scalings, symbolic
    /// factorization, execution plan).
    pub fn analysis(&self) -> &Analysis {
        &self.an
    }

    /// Preprocessing statistics of the owned analysis.
    pub fn symbolic_stats(&self) -> &SymbolicStats {
        &self.an.stats
    }

    /// How the owned analysis was produced: `None` for a cold
    /// [`Solver::analyze`], `Some(kind)` after a `reanalyze` (warm reuse,
    /// delta patch, or full fallback).
    pub fn reanalysis_kind(&self) -> Option<ReanalyzeKind> {
        self.an.stats.reanalysis
    }
}

impl LinearSystem<Analyzed> {
    /// Numeric factorization (supernode diagonal pivoting), consuming
    /// the analyzed handle into a solvable one.
    pub fn factor(self) -> Result<LinearSystem<Factored>> {
        let f = self.core.factor_core(&self.a, &self.an)?;
        let cfg = &self.core.cfg;
        let esc = if cfg.adaptive_effective() {
            Some(EscalationController::new(
                cfg.escalate_reorder_growth,
                cfg.escalate_repivot_growth,
            ))
        } else {
            None
        };
        Ok(LinearSystem {
            core: self.core,
            a: self.a,
            an: self.an,
            f: Some(f),
            esc,
            _state: PhantomData,
        })
    }
}

impl LinearSystem<Factored> {
    fn fac(&self) -> &Factorization {
        self.f.as_ref().expect("Factored state always holds factors")
    }

    /// The owned numeric factorization.
    pub fn factorization(&self) -> &Factorization {
        self.fac()
    }

    /// Statistics of the last (re)factorization.
    pub fn factor_stats(&self) -> &FactorStats {
        &self.fac().stats
    }

    /// Precision of the factors a solve would use right now: `Mixed`
    /// while the `f32` core is active, `F64` otherwise (including after
    /// the stall fallback latched).
    pub fn precision(&self) -> Precision {
        self.fac().precision()
    }

    /// Stall-driven `f64` fallback events recorded against the current
    /// factorization.
    pub fn fallback_events(&self) -> u64 {
        self.fac().fallback_events()
    }

    /// Replace the matrix values (same pattern) and refactorize on the
    /// stored pivot order without a pivot search — the repeated-solve
    /// fast path. `new_vals` must align with the owned matrix's
    /// [`Csr::vals`] (CSR order, length [`LinearSystem::nnz`]). On a
    /// warm engine this spawns no threads and performs no O(n) scratch
    /// allocation.
    ///
    /// ```
    /// use hylu::prelude::*;
    /// let a = hylu::sparse::gen::grid2d(6, 6);
    /// let solver = SolverBuilder::new().repeated().threads(1).build().unwrap();
    /// let mut system = solver.analyze(&a).unwrap().factor().unwrap();
    /// // Newton-style value update: same pattern, scaled values
    /// let vals: Vec<f64> = a.vals.iter().map(|v| v * 2.0).collect();
    /// system.refactor(&vals).unwrap();
    /// let b = hylu::sparse::gen::rhs_for_ones(&a);
    /// let x = system.solve(&b).unwrap();
    /// assert!(x.iter().all(|v| (v - 0.5).abs() < 1e-8)); // A doubled ⇒ x halved
    /// ```
    pub fn refactor(&mut self, new_vals: &[f64]) -> Result<()> {
        if new_vals.len() != self.a.nnz() {
            return Err(Error::Invalid(format!(
                "refactor values length {} does not match matrix nnz {}",
                new_vals.len(),
                self.a.nnz()
            )));
        }
        self.a.vals.copy_from_slice(new_vals);
        let tier = self.next_tier();
        self.refactor_at_tier(tier)
    }

    /// [`LinearSystem::refactor`] from a whole same-pattern matrix (any
    /// [`MatrixInput`]). Rejected — with the owned matrix and factors
    /// untouched — when the ingested pattern differs from the analyzed
    /// one.
    pub fn refactor_matrix<M: MatrixInput>(&mut self, m: M) -> Result<()> {
        let a = m.into_csr()?;
        let tier = self.next_tier();
        match tier {
            RefactorTier::Repivot => {
                let f = self.core.factor_core(&a, &self.an)?;
                self.f = Some(f);
                if let Some(esc) = self.esc.as_mut() {
                    esc.reset();
                }
            }
            _ => {
                self.core.refactor_core_tiered(
                    &a,
                    &self.an,
                    self.f.as_mut().expect("factored"),
                    tier == RefactorTier::Reorder,
                )?;
            }
        }
        self.a = a;
        Ok(())
    }

    /// Pick the tier for the refactorization about to run: always
    /// [`RefactorTier::Replay`] without the escalation controller;
    /// otherwise the controller decides from the last factorization's
    /// pivot growth.
    fn next_tier(&mut self) -> RefactorTier {
        let growth = self.fac().stats.pivot_growth;
        match self.esc.as_mut() {
            Some(esc) => esc.decide(growth),
            None => RefactorTier::Replay,
        }
    }

    fn refactor_at_tier(&mut self, tier: RefactorTier) -> Result<()> {
        match tier {
            RefactorTier::Replay => {
                self.core
                    .refactor_core(&self.a, &self.an, self.f.as_mut().expect("factored"))
            }
            RefactorTier::Reorder => self.core.refactor_core_tiered(
                &self.a,
                &self.an,
                self.f.as_mut().expect("factored"),
                true,
            ),
            RefactorTier::Repivot => {
                self.f = Some(self.core.factor_core(&self.a, &self.an)?);
                if let Some(esc) = self.esc.as_mut() {
                    esc.reset();
                }
                Ok(())
            }
        }
    }

    /// The escalation controller driving the adaptive refactor path
    /// (`None` unless [`SolverConfig::adaptive_refactor`] is enabled on
    /// this handle's solver). Exposes the EMA state and the
    /// replay/reorder/repivot decision counters.
    pub fn escalation(&self) -> Option<&EscalationController> {
        self.esc.as_ref()
    }

    /// Incremental re-analysis: consume this factored handle and return
    /// an analyzed one for (possibly pattern-changed) `m`, reusing the
    /// engine, worker pool, scratch arenas, and — depending on how far
    /// the pattern moved — the cached permutations, symbolic
    /// factorization, execution plan, and tuned kernel plan. See
    /// [`ReanalyzeKind`] for the tiers; the produced analysis is
    /// bit-identical to what a cold analysis pipeline run under the same
    /// cached permutations would produce.
    ///
    /// The factors are dropped (the pattern may have changed under
    /// them). On error the handle is lost too — callers that need the
    /// old system to survive a failed update should use
    /// [`LinearSystem::reanalyze_matrix`] instead.
    ///
    /// ```
    /// use hylu::prelude::*;
    /// let a = hylu::sparse::gen::grid2d(6, 6);
    /// let solver = SolverBuilder::new().repeated().threads(1).build().unwrap();
    /// let system = solver.analyze(&a).unwrap().factor().unwrap();
    /// // same pattern → warm re-analysis, everything symbolic reused
    /// let system = system.reanalyze(&a).unwrap().factor().unwrap();
    /// let b = hylu::sparse::gen::rhs_for_ones(&a);
    /// let x = system.solve(&b).unwrap();
    /// assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-8));
    /// ```
    pub fn reanalyze<M: MatrixInput>(self, m: M) -> Result<LinearSystem<Analyzed>> {
        let a = m.into_csr()?;
        let an = self.core.reanalyze_core(&a, &self.an)?;
        Ok(LinearSystem {
            core: self.core,
            a,
            an,
            f: None,
            esc: None,
            _state: PhantomData,
        })
    }

    /// In-place incremental re-analysis + factorization: ingest `m`,
    /// re-analyze against the cached analysis (warm / delta-patched /
    /// full, as [`LinearSystem::reanalyze`]), factor the result, and
    /// commit — all behind `&mut self`, so the handle stays `Factored`
    /// throughout. **Commit-on-success**: any failure leaves the old
    /// matrix, analysis, and factors fully usable. This is the primitive
    /// the service's live-reanalyze control rides on.
    pub fn reanalyze_matrix<M: MatrixInput>(&mut self, m: M) -> Result<()> {
        let a = m.into_csr()?;
        let an = self.core.reanalyze_core(&a, &self.an)?;
        let f = self.core.factor_core(&a, &an)?;
        self.a = a;
        self.an = an;
        self.f = Some(f);
        if let Some(esc) = self.esc.as_mut() {
            esc.reset();
        }
        Ok(())
    }

    /// Full numeric re-factorization of the current values *with* a
    /// fresh pivot search (what [`LinearSystem::factor`] does),
    /// replacing the stored factors. Use after `refactor` drift
    /// accumulates perturbed pivots, or to time factorization
    /// repeatedly.
    pub fn factorize(&mut self) -> Result<()> {
        self.f = Some(self.core.factor_core(&self.a, &self.an)?);
        Ok(())
    }

    /// Solve `A x = b`; iterative refinement runs automatically when
    /// pivots were perturbed or the residual exceeds the configured
    /// tolerance.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        Ok(self.solve_with_stats(b)?.0)
    }

    /// [`LinearSystem::solve`] with phase statistics.
    pub fn solve_with_stats(&self, b: &[f64]) -> Result<(Vec<f64>, SolveStats)> {
        let mut x = Vec::new();
        let st = self.solve_into(b, &mut x)?;
        Ok((x, st))
    }

    /// Solve into a caller-provided buffer (`x` is resized to `n`). With
    /// a reused buffer on a warm engine the whole call performs no O(n)
    /// allocation — the repeated-solve inner loop.
    pub fn solve_into(&self, b: &[f64], x: &mut Vec<f64>) -> Result<SolveStats> {
        self.core.solve_into_core(
            &self.a,
            &self.an,
            self.fac(),
            b,
            x,
            &RefineParams::from_config(&self.core.cfg),
        )
    }

    /// Solve with per-call [`SolveOpts`] overriding the configured
    /// refinement policy (iteration cap, start tolerance, residual
    /// target).
    ///
    /// ```
    /// use hylu::prelude::*;
    /// let a = hylu::sparse::gen::grid2d(6, 6);
    /// let b = hylu::sparse::gen::rhs_for_ones(&a);
    /// let solver = SolverBuilder::new().threads(1).build().unwrap();
    /// let system = solver.analyze(&a).unwrap().factor().unwrap();
    /// let opts = SolveOpts::new().refine_max_iter(0); // raw substitution
    /// let (x, st) = system.solve_with_opts(&b, &opts).unwrap();
    /// assert_eq!(st.refine_iters, 0);
    /// assert_eq!(x.len(), a.n);
    /// ```
    pub fn solve_with_opts(&self, b: &[f64], opts: &SolveOpts) -> Result<(Vec<f64>, SolveStats)> {
        let mut x = Vec::new();
        let st = self.solve_into_with_opts(b, &mut x, opts)?;
        Ok((x, st))
    }

    /// [`LinearSystem::solve_into`] with per-call [`SolveOpts`].
    pub fn solve_into_with_opts(
        &self,
        b: &[f64],
        x: &mut Vec<f64>,
        opts: &SolveOpts,
    ) -> Result<SolveStats> {
        self.core
            .solve_into_core(&self.a, &self.an, self.fac(), b, x, &opts.resolve(&self.core.cfg))
    }

    /// Batched repeated solve: all right-hand sides sweep through
    /// substitution as one dense block with a single pool dispatch.
    /// Column `q` is bit-identical to `solve(&bs[q])`.
    pub fn solve_many(&self, bs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        Ok(self.solve_many_with_stats(bs)?.0)
    }

    /// [`LinearSystem::solve_many`] with aggregate statistics
    /// (`residual` is the worst per-RHS residual, `refine_iters` the
    /// total across RHS).
    pub fn solve_many_with_stats(&self, bs: &[Vec<f64>]) -> Result<(Vec<Vec<f64>>, SolveStats)> {
        let mut xs = Vec::new();
        let st = self.solve_many_into(bs, &mut xs)?;
        Ok((xs, st))
    }

    /// Batched solve into caller-provided buffers (`xs` is resized to
    /// `bs.len()` vectors of length `n`); allocation-free with reused
    /// buffers on a warm engine.
    pub fn solve_many_into(&self, bs: &[Vec<f64>], xs: &mut Vec<Vec<f64>>) -> Result<SolveStats> {
        self.core.solve_many_into_core(
            &self.a,
            &self.an,
            self.fac(),
            bs,
            xs,
            &RefineParams::from_config(&self.core.cfg),
        )
    }

    /// [`LinearSystem::solve_many_into`] with per-call [`SolveOpts`].
    pub fn solve_many_into_with_opts(
        &self,
        bs: &[Vec<f64>],
        xs: &mut Vec<Vec<f64>>,
        opts: &SolveOpts,
    ) -> Result<SolveStats> {
        self.core
            .solve_many_into_core(&self.a, &self.an, self.fac(), bs, xs, &opts.resolve(&self.core.cfg))
    }
}

//! Chained solver configuration ([`SolverBuilder`]) and per-solve
//! refinement overrides ([`SolveOpts`]).

use std::sync::Arc;

use crate::coordinator::{FaultPlan, Precision, RefineParams, SolverConfig};
use crate::numeric::kernels::Tuning;
use crate::numeric::select::KernelMode;
use crate::ordering::OrderingChoice;
use crate::Result;

use super::Solver;

/// Chained configuration for a [`Solver`], replacing raw
/// [`SolverConfig`] field-poking with presets and named knobs.
///
/// The two presets mirror the paper's two scenarios:
/// [`SolverBuilder::one_shot`] (the default; fastest single
/// analyze+factor+solve) and [`SolverBuilder::repeated`] (pays for
/// relaxed supernodes once in analysis, refactors faster forever —
/// circuit transient simulation, parameter sweeps).
///
/// ```
/// use hylu::prelude::*;
/// let solver = SolverBuilder::new()
///     .repeated()
///     .threads(2)
///     .kernel(KernelMode::SupSup)
///     .refine_target(1e-12)
///     .build()
///     .unwrap();
/// assert!(solver.config().repeated);
/// assert_eq!(solver.config().threads, 2);
/// ```
#[derive(Clone, Debug, Default)]
pub struct SolverBuilder {
    cfg: SolverConfig,
}

impl SolverBuilder {
    /// Start from the defaults (the paper's one-time-solve setup).
    pub fn new() -> SolverBuilder {
        SolverBuilder {
            cfg: SolverConfig::default(),
        }
    }

    /// Start from an existing raw configuration.
    pub fn from_config(cfg: SolverConfig) -> SolverBuilder {
        SolverBuilder { cfg }
    }

    /// Preset: optimize for a single `analyze → factor → solve` pass
    /// (exact supernode merging, fastest preprocessing). The default.
    pub fn one_shot(mut self) -> SolverBuilder {
        self.cfg.repeated = false;
        self
    }

    /// Preset: optimize preprocessing for repeated solving with a fixed
    /// pattern (relaxed supernode merging: slower analysis, faster
    /// `refactor`; paper §3.2).
    pub fn repeated(mut self) -> SolverBuilder {
        self.cfg.repeated = true;
        self
    }

    /// Worker-pool width (0 = all available cores). Fixed at `build`.
    pub fn threads(mut self, n: usize) -> SolverBuilder {
        self.cfg.threads = n;
        self
    }

    /// Fill-reducing ordering (default: auto-select AMD vs ND).
    pub fn ordering(mut self, o: OrderingChoice) -> SolverBuilder {
        self.cfg.ordering = o;
        self
    }

    /// Force a numeric kernel family instead of selecting from symbolic
    /// statistics.
    pub fn kernel(mut self, k: KernelMode) -> SolverBuilder {
        self.cfg.kernel = Some(k);
        self
    }

    /// Enable/disable MC64 static pivoting + scaling (disable only for
    /// pre-scaled diagonally-dominant inputs).
    pub fn static_pivoting(mut self, on: bool) -> SolverBuilder {
        self.cfg.static_pivoting = on;
        self
    }

    /// Per-pattern kernel autotuning level (default [`Tuning::Off`]).
    /// `Quick`/`Full` search GEMM tile / A-packing / TRSM-crossover
    /// variants against the analyzed pattern's supernode shape histogram
    /// at analyze time; warm refactor+solve replays the winner for free.
    /// Overridable process-wide via the `HYLU_TUNING` env var
    /// (`off`/`quick`/`full`).
    pub fn tuning(mut self, t: Tuning) -> SolverBuilder {
        self.cfg.tuning = t;
        self
    }

    /// Concurrent `solve*` scratch checkout slots (0 = auto).
    pub fn scratch_slots(mut self, slots: usize) -> SolverBuilder {
        self.cfg.scratch_slots = slots;
        self
    }

    /// Iterative-refinement iteration cap (the configured default;
    /// override per call with [`SolveOpts`]).
    pub fn refine_max_iter(mut self, n: usize) -> SolverBuilder {
        self.cfg.refine_max_iter = n;
        self
    }

    /// Residual above which refinement starts even without pivot
    /// perturbation.
    pub fn refine_tol(mut self, tol: f64) -> SolverBuilder {
        self.cfg.refine_tol = tol;
        self
    }

    /// Residual below which refinement stops.
    pub fn refine_target(mut self, target: f64) -> SolverBuilder {
        self.cfg.refine_target = target;
        self
    }

    /// Numeric precision policy (default [`Precision::F64`]).
    /// [`Precision::Mixed`] factors in `f32` and recovers double
    /// accuracy in `f64` iterative refinement, falling back to a full
    /// `f64` refactorization when refinement stalls. Overridable
    /// process-wide via the `HYLU_PRECISION` env var (`f64`/`mixed`).
    pub fn precision(mut self, p: Precision) -> SolverBuilder {
        self.cfg.precision = p;
        self
    }

    /// Deterministic fault-injection plan for chaos testing (see
    /// [`FaultPlan`]): panics, forced zero pivots, and kernel stalls
    /// fire on a seeded step grid at the factor/solve entry points.
    /// Share one `Arc` across solvers to draw from a single schedule.
    /// Without an explicit plan the `HYLU_FAULT` env var can supply one
    /// at `build` (unless [`SolverBuilder::pin_fault`]).
    pub fn fault(mut self, plan: Arc<FaultPlan>) -> SolverBuilder {
        self.cfg.fault = Some(plan);
        self
    }

    /// Ignore the `HYLU_FAULT` env override: this solver injects no
    /// faults unless [`SolverBuilder::fault`] set a plan explicitly.
    /// Test oracles use this to stay fault-free under a chaos
    /// environment.
    pub fn pin_fault(mut self) -> SolverBuilder {
        self.cfg.pin_fault = true;
        self
    }

    /// Delta-patch budget for `reanalyze`: patch the symbolic DAG
    /// incrementally when at most this fraction of permuted rows changed
    /// structure; re-analyze in full beyond it (bit-identical either
    /// way). 0 disables patching.
    pub fn reanalyze_delta_frac(mut self, frac: f64) -> SolverBuilder {
        self.cfg.reanalyze_delta_frac = frac.clamp(0.0, 1.0);
        self
    }

    /// Cold-restart threshold for `reanalyze`: when more than this
    /// fraction of rows changed structure, re-analysis discards the
    /// cached matching/scaling/ordering seeds and restarts cold (fresh
    /// MC64 + fill ordering), keeping only the warm engine. Defaults to
    /// 0.5; set to 1.0 to always reuse the cached seeds.
    pub fn reanalyze_cold_frac(mut self, frac: f64) -> SolverBuilder {
        self.cfg.reanalyze_cold_frac = frac.clamp(0.0, 1.0);
        self
    }

    /// Enable the pivot-stability escalation controller on the
    /// repeated-refactor path: cheap replay while pivot growth is
    /// stable, a secondary within-supernode-block reordering pass when
    /// the growth EMA trends up, and a full re-pivoting factorization
    /// past the hard threshold. Overridable process-wide via the
    /// `HYLU_ADAPTIVE` env var (`0`/`1`).
    pub fn adaptive_refactor(mut self, on: bool) -> SolverBuilder {
        self.cfg.adaptive_refactor = on;
        self
    }

    /// Escalation thresholds for the adaptive refactor path: fast-EMA
    /// pivot growth at which a replay promotes to the secondary reorder
    /// pass, and the hard growth level that forces a full re-pivoting
    /// factorization.
    pub fn escalation_thresholds(mut self, reorder: f64, repivot: f64) -> SolverBuilder {
        self.cfg.escalate_reorder_growth = reorder;
        self.cfg.escalate_repivot_growth = repivot;
        self
    }

    /// Route large sup-sup GEMMs through the XLA/PJRT AOT artifacts in
    /// `artifacts_dir` (ablation path; the native microkernel is
    /// default).
    pub fn use_xla(mut self, artifacts_dir: impl Into<String>) -> SolverBuilder {
        self.cfg.use_xla = true;
        self.cfg.artifacts_dir = artifacts_dir.into();
        self
    }

    /// Escape hatch: mutate the underlying [`SolverConfig`] for knobs
    /// without a named builder method (pivoting thresholds, supernode
    /// caps, …).
    pub fn configure(mut self, f: impl FnOnce(&mut SolverConfig)) -> SolverBuilder {
        f(&mut self.cfg);
        self
    }

    /// The configuration built so far.
    pub fn config(&self) -> &SolverConfig {
        &self.cfg
    }

    /// Finish into the raw configuration (for
    /// [`crate::service::ServiceConfig`] and other config carriers).
    pub fn into_config(self) -> SolverConfig {
        self.cfg
    }

    /// Build the solver (engine + GEMM backend). Worker threads spawn
    /// lazily on the first numeric dispatch.
    pub fn build(self) -> Result<Solver> {
        Solver::from_config(self.cfg)
    }
}

/// Per-solve overrides for the iterative-refinement policy. Unset knobs
/// fall back to the solver's configured defaults.
///
/// ```
/// use hylu::prelude::*;
/// let opts = SolveOpts::new().refine_max_iter(5).refine_target(1e-13);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SolveOpts {
    refine_max_iter: Option<usize>,
    refine_tol: Option<f64>,
    refine_target: Option<f64>,
    precision: Option<Precision>,
}

impl SolveOpts {
    /// No overrides: the solver's configured refinement policy.
    pub fn new() -> SolveOpts {
        SolveOpts::default()
    }

    /// Cap refinement iterations for this solve (0 disables refinement).
    pub fn refine_max_iter(mut self, n: usize) -> SolveOpts {
        self.refine_max_iter = Some(n);
        self
    }

    /// Residual above which refinement starts even without pivot
    /// perturbation, for this solve.
    pub fn refine_tol(mut self, tol: f64) -> SolveOpts {
        self.refine_tol = Some(tol);
        self
    }

    /// Residual target at which refinement stops, for this solve.
    pub fn refine_target(mut self, target: f64) -> SolveOpts {
        self.refine_target = Some(target);
        self
    }

    /// Precision override for this solve. `Precision::F64` forces the
    /// solve onto `f64` factors even when the factorization is mixed
    /// (building the recovery factors on first use); `Precision::Mixed`
    /// is a no-op on a pure-`f64` factorization.
    pub fn precision(mut self, p: Precision) -> SolveOpts {
        self.precision = Some(p);
        self
    }

    pub(crate) fn resolve(&self, cfg: &SolverConfig) -> RefineParams {
        let d = RefineParams::from_config(cfg);
        RefineParams {
            max_iter: self.refine_max_iter.unwrap_or(d.max_iter),
            tol: self.refine_tol.unwrap_or(d.tol),
            target: self.refine_target.unwrap_or(d.target),
            precision: self.precision,
        }
    }
}

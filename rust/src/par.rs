//! Minimal threading substrate: done-flags with acquire/release publication
//! and flop-balanced chunk partitioning. (tokio/rayon are unavailable in the
//! offline registry; the paper's scheduler is custom anyway — std::thread +
//! atomics express it directly.)

use std::sync::atomic::{AtomicU32, Ordering};

/// Resolve a requested thread count (0 = use all available cores).
pub fn effective_threads(requested: usize) -> usize {
    let avail = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    if requested == 0 {
        avail
    } else {
        requested
    }
}

/// One done-flag per node; set with Release after a node's storage is
/// final, awaited with Acquire before reading it.
pub struct DoneFlags {
    flags: Vec<AtomicU32>,
}

impl Default for DoneFlags {
    fn default() -> Self {
        DoneFlags::new(0)
    }
}

impl DoneFlags {
    /// All-clear flags for `n` nodes.
    pub fn new(n: usize) -> Self {
        DoneFlags {
            flags: (0..n).map(|_| AtomicU32::new(0)).collect(),
        }
    }

    /// Publish node `i` as complete.
    #[inline]
    pub fn set(&self, i: usize) {
        self.flags[i].store(1, Ordering::Release);
    }

    /// Number of flags.
    pub fn len(&self) -> usize {
        self.flags.len()
    }

    /// True when tracking zero nodes.
    pub fn is_empty(&self) -> bool {
        self.flags.is_empty()
    }

    /// Clear every flag for reuse (relaxed stores: the caller publishes
    /// the reset to workers through its own synchronization — e.g. the
    /// worker pool's dispatch lock).
    pub fn reset(&self) {
        for f in &self.flags {
            f.store(0, Ordering::Relaxed);
        }
    }

    /// True if node `i` is complete (Acquire).
    #[inline]
    pub fn is_set(&self, i: usize) -> bool {
        self.flags[i].load(Ordering::Acquire) == 1
    }

    /// Spin (with backoff) until node `i` completes — the pipeline-mode
    /// wait. Safe against missed wakeups because producers always store 1.
    #[inline]
    pub fn wait(&self, i: usize) {
        let mut spins = 0u32;
        while !self.is_set(i) {
            spins += 1;
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }
}

/// Split `items` (with weights) into `parts` contiguous chunks with roughly
/// equal weight; returns (start, end) index pairs. Used to balance bulk
/// levels across threads by flops.
///
/// Greedy bound: a chunk takes the next item only while doing so leaves it
/// closer to its per-part target than stopping would (i.e. while
/// `acc + w/2 <= target`), so one dominant weight never drags a whole
/// prefix of light items into its chunk. Each non-empty chunk therefore
/// overshoots its target by at most half of its last item, and a chunk's
/// weight never exceeds `target + max_item/2` — in particular a dominant
/// item ends up isolated instead of stacked on top of everything before it.
pub fn balanced_chunks(weights: &[f64], parts: usize) -> Vec<(usize, usize)> {
    let n = weights.len();
    let parts = parts.max(1);
    let total: f64 = weights.iter().sum();
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut consumed = 0.0;
    for p in 0..parts {
        let remaining_parts = (parts - p) as f64;
        let target = (total - consumed) / remaining_parts;
        let mut end = start;
        let mut acc = 0.0;
        while end < n && (end == start || acc + 0.5 * weights[end] <= target) {
            acc += weights[end];
            end += 1;
        }
        if p == parts - 1 {
            end = n;
        }
        out.push((start, end.min(n)));
        consumed += acc;
        start = end.min(n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let w: Vec<f64> = (0..37).map(|i| (i % 5 + 1) as f64).collect();
        for parts in [1usize, 2, 3, 7, 40] {
            let ch = balanced_chunks(&w, parts);
            assert_eq!(ch.len(), parts);
            let mut pos = 0;
            for &(s, e) in &ch {
                assert_eq!(s, pos);
                assert!(e >= s);
                pos = e;
            }
            assert_eq!(pos, w.len());
        }
    }

    #[test]
    fn chunks_are_roughly_balanced() {
        let w = vec![1.0; 100];
        let ch = balanced_chunks(&w, 4);
        for &(s, e) in &ch {
            let sum = (e - s) as f64;
            assert!((sum - 25.0).abs() <= 2.0, "{sum}");
        }
    }

    /// Regression: a single dominant weight near the end must not make the
    /// first chunk swallow every light item before it (leaving the other
    /// parts idle), which the old `acc < target` greedy did — its first
    /// chunk kept accepting items until it crossed a target inflated by
    /// the giant, i.e. all of them.
    #[test]
    fn dominant_tail_weight_does_not_starve_other_chunks() {
        let mut w = vec![1.0; 99];
        w.push(1000.0);
        let ch = balanced_chunks(&w, 4);
        let weight = |&(s, e): &(usize, usize)| w[s..e].iter().sum::<f64>();
        // the giant sits alone in its chunk...
        let giant = ch.iter().find(|&&(s, e)| s <= 99 && 99 < e).unwrap();
        assert_eq!(*giant, (99, 100), "giant must be isolated: {ch:?}");
        // ...and the light prefix still occupies a non-empty earlier chunk
        assert!(ch[0].1 > ch[0].0, "first chunk starved: {ch:?}");
        let heaviest = ch.iter().map(weight).fold(0.0, f64::max);
        assert!(heaviest <= 1000.0 + 1e-9, "heaviest chunk {heaviest}");
    }

    #[test]
    fn dominant_leading_weight_is_isolated_too() {
        let mut w = vec![1.0; 51];
        w[0] = 500.0;
        let ch = balanced_chunks(&w, 3);
        assert_eq!(ch[0], (0, 1), "giant head must not absorb the tail: {ch:?}");
        // remaining parts split the light tail
        assert!(ch[1].1 > ch[1].0 && ch[2].1 > ch[2].0, "{ch:?}");
    }

    #[test]
    fn done_flags_reset_clears_all() {
        let f = DoneFlags::new(4);
        f.set(1);
        f.set(3);
        f.reset();
        for i in 0..4 {
            assert!(!f.is_set(i));
        }
        assert_eq!(f.len(), 4);
    }

    #[test]
    fn done_flags_roundtrip() {
        let f = DoneFlags::new(3);
        assert!(!f.is_set(1));
        f.set(1);
        assert!(f.is_set(1));
        f.wait(1); // returns immediately
    }

    #[test]
    fn done_flags_cross_thread() {
        let f = std::sync::Arc::new(DoneFlags::new(1));
        let f2 = f.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            f2.set(0);
        });
        f.wait(0);
        assert!(f.is_set(0));
        h.join().unwrap();
    }

    #[test]
    fn effective_threads_resolves_zero() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }
}

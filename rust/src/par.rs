//! Minimal threading substrate: done-flags with acquire/release publication
//! and flop-balanced chunk partitioning. (tokio/rayon are unavailable in the
//! offline registry; the paper's scheduler is custom anyway — std::thread +
//! atomics express it directly.)

use std::sync::atomic::{AtomicU32, Ordering};

/// Resolve a requested thread count (0 = use all available cores).
pub fn effective_threads(requested: usize) -> usize {
    let avail = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    if requested == 0 {
        avail
    } else {
        requested
    }
}

/// One done-flag per node; set with Release after a node's storage is
/// final, awaited with Acquire before reading it.
pub struct DoneFlags {
    flags: Vec<AtomicU32>,
}

impl DoneFlags {
    /// All-clear flags for `n` nodes.
    pub fn new(n: usize) -> Self {
        DoneFlags {
            flags: (0..n).map(|_| AtomicU32::new(0)).collect(),
        }
    }

    /// Publish node `i` as complete.
    #[inline]
    pub fn set(&self, i: usize) {
        self.flags[i].store(1, Ordering::Release);
    }

    /// True if node `i` is complete (Acquire).
    #[inline]
    pub fn is_set(&self, i: usize) -> bool {
        self.flags[i].load(Ordering::Acquire) == 1
    }

    /// Spin (with backoff) until node `i` completes — the pipeline-mode
    /// wait. Safe against missed wakeups because producers always store 1.
    #[inline]
    pub fn wait(&self, i: usize) {
        let mut spins = 0u32;
        while !self.is_set(i) {
            spins += 1;
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }
}

/// Split `items` (with weights) into `parts` contiguous chunks with roughly
/// equal weight; returns (start, end) index pairs. Used to balance bulk
/// levels across threads by flops.
pub fn balanced_chunks(weights: &[f64], parts: usize) -> Vec<(usize, usize)> {
    let n = weights.len();
    let parts = parts.max(1);
    let total: f64 = weights.iter().sum();
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut consumed = 0.0;
    for p in 0..parts {
        let remaining_parts = (parts - p) as f64;
        let target = (total - consumed) / remaining_parts;
        let mut end = start;
        let mut acc = 0.0;
        while end < n && (acc < target || end == start) {
            // leave enough items for remaining parts? contiguous greedy is
            // fine for our level sizes
            acc += weights[end];
            end += 1;
        }
        if p == parts - 1 {
            end = n;
        }
        out.push((start, end.min(n)));
        consumed += acc;
        start = end.min(n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let w: Vec<f64> = (0..37).map(|i| (i % 5 + 1) as f64).collect();
        for parts in [1usize, 2, 3, 7, 40] {
            let ch = balanced_chunks(&w, parts);
            assert_eq!(ch.len(), parts);
            let mut pos = 0;
            for &(s, e) in &ch {
                assert_eq!(s, pos);
                assert!(e >= s);
                pos = e;
            }
            assert_eq!(pos, w.len());
        }
    }

    #[test]
    fn chunks_are_roughly_balanced() {
        let w = vec![1.0; 100];
        let ch = balanced_chunks(&w, 4);
        for &(s, e) in &ch {
            let sum = (e - s) as f64;
            assert!((sum - 25.0).abs() <= 2.0, "{sum}");
        }
    }

    #[test]
    fn done_flags_roundtrip() {
        let f = DoneFlags::new(3);
        assert!(!f.is_set(1));
        f.set(1);
        assert!(f.is_set(1));
        f.wait(1); // returns immediately
    }

    #[test]
    fn done_flags_cross_thread() {
        let f = std::sync::Arc::new(DoneFlags::new(1));
        let f2 = f.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            f2.set(0);
        });
        f.wait(0);
        assert!(f.is_set(0));
        h.join().unwrap();
    }

    #[test]
    fn effective_threads_resolves_zero() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }
}

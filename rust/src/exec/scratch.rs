//! Per-call scratch checkout: a lock-free pool of [`SolveScratch`]
//! instances.
//!
//! The engine's original design kept one `SolveScratch` behind a mutex,
//! which serialized every `solve*` call on a solver — exactly one
//! in-flight solve per handle, no matter how many callers. The checkout
//! pool removes that bottleneck: a caller pops a scratch instance off a
//! lock-free free-list (a 64-bit bitmask, one bit per slot), works
//! against it, and pushes it back on drop. Concurrent callers therefore
//! overlap on substitution and refinement; only the genuinely shared
//! state — the worker-pool dispatch and the factor-side arenas — still
//! serializes.
//!
//! Checkout is LIFO on the lowest free slot, so a sequential caller
//! always gets the *same* instance back and the warm-path "zero O(n)
//! allocations" guarantee is untouched: arena growth happens once per
//! slot actually exercised by concurrency, counted through the usual
//! [`PoolCounters`] events. When every slot is checked out, callers park
//! on a condvar until one returns — the pool caps memory at
//! `cap ×` (high-water scratch footprint).

use std::cell::UnsafeCell;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use super::{lock_ignore_poison, wait_ignore_poison, SolveScratch};

/// Hard cap on checkout-pool width: the free-list is one 64-bit mask.
pub const MAX_SCRATCH_SLOTS: usize = 64;

/// One pool slot. Interior mutability is sound because a slot is only
/// ever reachable through a [`ScratchGuard`] holding exclusive ownership
/// of the slot's free-list bit.
struct Slot(UnsafeCell<SolveScratch>);

// Safety: access is gated by free-list bit ownership (see `Slot`).
unsafe impl Sync for Slot {}
unsafe impl Send for Slot {}

/// A fixed-capacity checkout pool of [`SolveScratch`] arenas with a
/// lock-free bitmask free-list and a condvar fallback for the all-busy
/// case.
pub struct ScratchPool {
    slots: Box<[Slot]>,
    /// Bit `i` set ⇔ slot `i` is free. Checkout clears the lowest set
    /// bit (LIFO on slot index → stable warm slot for sequential use).
    free: AtomicU64,
    /// Callers currently parked waiting for a slot. Incremented under
    /// `park` *before* the final free-list retry, so a concurrent
    /// check-in either satisfies the retry or sees the waiter and
    /// notifies (SeqCst pairs the bit publication with this read).
    waiters: AtomicUsize,
    park: Mutex<()>,
    cv: Condvar,
}

impl ScratchPool {
    /// Pool with `cap` slots (clamped to `1..=`[`MAX_SCRATCH_SLOTS`]).
    /// Slots start as empty arenas; each grows to its own high-water
    /// mark on first use, with growth counted by the engine counters.
    pub fn new(cap: usize) -> ScratchPool {
        let cap = cap.clamp(1, MAX_SCRATCH_SLOTS);
        let slots: Box<[Slot]> = (0..cap)
            .map(|_| Slot(UnsafeCell::new(SolveScratch::default())))
            .collect();
        let free = if cap == MAX_SCRATCH_SLOTS {
            u64::MAX
        } else {
            (1u64 << cap) - 1
        };
        ScratchPool {
            slots,
            free: AtomicU64::new(free),
            waiters: AtomicUsize::new(0),
            park: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Total slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Slots currently checked out.
    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.load(Ordering::SeqCst).count_ones() as usize
    }

    /// Non-blocking checkout: `None` when every slot is in use.
    pub fn try_checkout(&self) -> Option<ScratchGuard<'_>> {
        let mut mask = self.free.load(Ordering::SeqCst);
        loop {
            if mask == 0 {
                return None;
            }
            let idx = mask.trailing_zeros() as usize;
            let bit = 1u64 << idx;
            match self.free.compare_exchange_weak(
                mask,
                mask & !bit,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return Some(ScratchGuard { pool: self, idx }),
                Err(cur) => mask = cur,
            }
        }
    }

    /// Checkout, parking on the condvar while every slot is in use.
    pub fn checkout(&self) -> ScratchGuard<'_> {
        if let Some(g) = self.try_checkout() {
            return g;
        }
        let mut guard = lock_ignore_poison(&self.park);
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let g = loop {
            if let Some(g) = self.try_checkout() {
                break g;
            }
            guard = wait_ignore_poison(self.cv.wait(guard));
        };
        self.waiters.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
        g
    }

    fn checkin(&self, idx: usize) {
        self.free.fetch_or(1u64 << idx, Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) > 0 {
            // Taking the park lock orders this notify against a waiter
            // that has registered but not yet parked.
            let _g = lock_ignore_poison(&self.park);
            self.cv.notify_one();
        }
    }
}

/// Exclusive handle to one checked-out [`SolveScratch`]; returns the
/// slot to the pool on drop.
pub struct ScratchGuard<'a> {
    pool: &'a ScratchPool,
    idx: usize,
}

impl Deref for ScratchGuard<'_> {
    type Target = SolveScratch;
    fn deref(&self) -> &SolveScratch {
        // Safety: exclusive ownership of the slot's free-list bit.
        unsafe { &*self.pool.slots[self.idx].0.get() }
    }
}

impl DerefMut for ScratchGuard<'_> {
    fn deref_mut(&mut self) -> &mut SolveScratch {
        // Safety: as above.
        unsafe { &mut *self.pool.slots[self.idx].0.get() }
    }
}

impl Drop for ScratchGuard<'_> {
    fn drop(&mut self) {
        self.pool.checkin(self.idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn sequential_checkout_reuses_the_same_slot() {
        let pool = ScratchPool::new(4);
        {
            let mut g = pool.checkout();
            g.y.resize(100, 1.0);
            assert_eq!(pool.in_use(), 1);
        }
        let g = pool.checkout();
        assert_eq!(g.y.len(), 100, "LIFO must return the warm slot");
        assert_eq!(pool.in_use(), 1);
    }

    #[test]
    fn concurrent_checkouts_get_distinct_slots() {
        let pool = ScratchPool::new(3);
        let g1 = pool.checkout();
        let g2 = pool.checkout();
        let g3 = pool.checkout();
        assert_eq!(pool.in_use(), 3);
        assert!(pool.try_checkout().is_none(), "pool exhausted at cap");
        drop(g2);
        assert!(pool.try_checkout().is_some()); // guard dropped immediately
        drop(g1);
        drop(g3);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn exhausted_pool_blocks_then_resumes() {
        let pool = Arc::new(ScratchPool::new(1));
        let got = Arc::new(AtomicUsize::new(0));
        let g = pool.checkout();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = pool.clone();
            let c = got.clone();
            handles.push(std::thread::spawn(move || {
                let _g = p.checkout(); // blocks until a slot frees
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(got.load(Ordering::SeqCst), 0, "cap=1 must block all");
        drop(g);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(got.load(Ordering::SeqCst), 4);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn cap_is_clamped() {
        assert_eq!(ScratchPool::new(0).capacity(), 1);
        assert_eq!(ScratchPool::new(1000).capacity(), MAX_SCRATCH_SLOTS);
    }

    #[test]
    fn hammered_pool_never_double_hands_a_slot() {
        let pool = Arc::new(ScratchPool::new(2));
        let mut handles = Vec::new();
        for t in 0..6 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..200 {
                    let mut g = p.checkout();
                    // exclusive access: write a token, yield, read it back
                    g.y.clear();
                    g.y.push((t * 1000 + i) as f64);
                    std::thread::yield_now();
                    assert_eq!(g.y[0], (t * 1000 + i) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.in_use(), 0);
    }
}

//! Cached per-analysis schedule state.
//!
//! The scoped-thread drivers recomputed the flop-balanced bulk-level
//! chunks and the substitution chunks on every numeric call. All of that
//! is a pure function of the [`Symbolic`] and the pool width, so it is
//! computed once here (in `Solver::analyze`) and replayed by every
//! `factor`/`refactor`/`solve` afterwards. (The pipeline-mode done-flags
//! are *mutable* per-call state and therefore live in the engine's
//! scratch, not here — a plan shared between two solvers must stay
//! race-free.)

use crate::numeric::kernels::KernelPlan;
use crate::par::balanced_chunks;
use crate::symbolic::Symbolic;

/// Immutable execution plan for one symbolic analysis on one pool width.
/// Shared freely by reference across factor/refactor/solve calls (and
/// across solvers). `Clone` so a warm re-analysis of an unchanged
/// pattern can reuse the plan (tuned kernel included) wholesale.
#[derive(Clone)]
pub struct ExecPlan {
    /// Pool width the chunks were balanced for.
    pub nthreads: usize,
    /// Per bulk level: `(start, end)` node ranges per worker, balanced by
    /// node flop estimates (factorization).
    pub factor_chunks: Vec<Vec<(usize, usize)>>,
    /// Per forward-substitution bulk level: ranges balanced by L nonzeros.
    pub fwd_chunks: Vec<Vec<(usize, usize)>>,
    /// Per backward-substitution bulk level (reverse levelization): ranges
    /// balanced by U nonzeros.
    pub bwd_chunks: Vec<Vec<(usize, usize)>>,
    /// High-water bound for the sup-sup GEMM scatter buffer (`cbuf`).
    pub max_cbuf: usize,
    /// High-water bound for the TRSM gather scratch (`tbuf`).
    pub max_tbuf: usize,
    /// High-water bound for the U-tail scatter map (`map_idx`).
    pub max_map: usize,
    /// High-water bound for the GEMM B-operand packing scratch (`pbuf`).
    pub max_pbuf: usize,
    /// High-water bound for the GEMM A-operand packing scratch (`abuf`);
    /// only consumed when [`ExecPlan::kernel`] enables A packing, but
    /// always reserved so toggling the plan never reallocates warm paths.
    pub max_abuf: usize,
    /// Tuned kernel plan for this pattern (GEMM variant, A-packing, TRSM
    /// crossovers). Defaults to [`KernelPlan::default`]; `Solver::analyze`
    /// overwrites it with the autotuner's winner when tuning is enabled.
    pub kernel: KernelPlan,
}

impl ExecPlan {
    /// Borrow `self` when it matches `nthreads`, otherwise build a fresh
    /// throwaway plan for that width into `storage`. Keeps an `Analysis`
    /// usable with a solver of a different pool width (cold path: the
    /// rebuild allocates; the owning solver's width always matches).
    pub fn for_width<'a>(
        &'a self,
        sym: &Symbolic,
        nthreads: usize,
        storage: &'a mut Option<ExecPlan>,
    ) -> &'a ExecPlan {
        if self.nthreads == nthreads {
            self
        } else {
            let mut p = ExecPlan::build(sym, nthreads);
            p.kernel = self.kernel; // keep the tuned plan across rebuilds
            storage.insert(p)
        }
    }

    /// Build the plan for `sym` on a pool of `nthreads` workers.
    pub fn build(sym: &Symbolic, nthreads: usize) -> ExecPlan {
        let nthreads = nthreads.max(1);
        let sched = &sym.schedule;
        let mut weights: Vec<f64> = Vec::new();

        let mut factor_chunks = Vec::with_capacity(sched.bulk_levels);
        let mut fwd_chunks = Vec::with_capacity(sched.bulk_levels);
        for lv in 0..sched.bulk_levels {
            let ids = sched.nodes_at(lv);
            weights.clear();
            weights.extend(ids.iter().map(|&id| sym.nodes[id as usize].flops));
            factor_chunks.push(balanced_chunks(&weights, nthreads));
            weights.clear();
            weights.extend(ids.iter().map(|&id| (sym.nodes[id as usize].nl() + 1) as f64));
            fwd_chunks.push(balanced_chunks(&weights, nthreads));
        }

        let mut bwd_chunks = Vec::with_capacity(sched.rbulk_levels);
        for lv in 0..sched.rbulk_levels {
            let ids = &sched.rlevel_nodes[sched.rlevel_ptr[lv]..sched.rlevel_ptr[lv + 1]];
            weights.clear();
            weights.extend(ids.iter().map(|&id| (sym.nodes[id as usize].nu() + 1) as f64));
            bwd_chunks.push(balanced_chunks(&weights, nthreads));
        }

        // Kernel scratch high-water marks: sized so no worker workspace
        // ever reallocates mid-factorization regardless of which worker
        // claims which node (pipeline-mode assignment is nondeterministic).
        // The bounds are ELEMENT counts, not bytes: each per-precision
        // worker arena (`Workspace<f64>` / `Workspace<f32>`) reserves the
        // same element capacity, so one plan serves both precisions.
        let mut max_cbuf = 0usize;
        let mut max_tbuf = 0usize;
        let mut max_map = 0usize;
        let mut max_pbuf = 0usize;
        let mut max_abuf = 0usize;
        for nd in &sym.nodes {
            let w = nd.width as usize;
            for g in &sym.groups[nd.g_start..nd.g_end] {
                let src = &sym.nodes[g.src as usize];
                if src.is_super {
                    let s_nu = src.nu();
                    let len = g.len as usize;
                    max_cbuf = max_cbuf.max(w * s_nu);
                    max_tbuf = max_tbuf.max(len * len);
                    max_map = max_map.max(s_nu);
                    max_pbuf = max_pbuf.max(len * s_nu);
                    max_abuf = max_abuf.max(w * len);
                }
            }
        }

        ExecPlan {
            nthreads,
            factor_chunks,
            fwd_chunks,
            bwd_chunks,
            max_cbuf,
            max_tbuf,
            max_map,
            max_pbuf,
            max_abuf,
            kernel: KernelPlan::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic::{analyze_pattern, MergePolicy};

    #[test]
    fn plan_chunks_match_fresh_computation() {
        let a = gen::grid2d(14, 14);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let plan = ExecPlan::build(&sym, 3);
        assert_eq!(plan.nthreads, 3);
        assert_eq!(plan.factor_chunks.len(), sym.schedule.bulk_levels);
        for (lv, chunks) in plan.factor_chunks.iter().enumerate() {
            let ids = sym.schedule.nodes_at(lv);
            let weights: Vec<f64> = ids.iter().map(|&id| sym.nodes[id as usize].flops).collect();
            assert_eq!(chunks, &balanced_chunks(&weights, 3));
        }
        assert_eq!(plan.bwd_chunks.len(), sym.schedule.rbulk_levels);
    }

    #[test]
    fn plan_scratch_bounds_cover_every_group() {
        let a = gen::banded(120, 6, 3);
        let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 16 }, 4);
        let plan = ExecPlan::build(&sym, 2);
        for nd in &sym.nodes {
            for g in &sym.groups[nd.g_start..nd.g_end] {
                let src = &sym.nodes[g.src as usize];
                if src.is_super {
                    assert!(nd.width as usize * src.nu() <= plan.max_cbuf);
                    assert!(src.nu() <= plan.max_map);
                    assert!(g.len as usize * src.nu() <= plan.max_pbuf);
                    assert!(nd.width as usize * g.len as usize <= plan.max_abuf);
                }
            }
        }
    }

    #[test]
    fn plan_handles_single_thread_and_trivial_matrices() {
        let a = crate::sparse::csr::Csr::identity(8);
        let sym = analyze_pattern(&a, MergePolicy::None, 4);
        let plan = ExecPlan::build(&sym, 1);
        assert_eq!(plan.nthreads, 1);
        for chunks in &plan.factor_chunks {
            assert_eq!(chunks.len(), 1);
        }
    }
}
